"""L2 — the tensorized EMS maximal matcher (JAX), calling the L1 Pallas
segment-min kernel.

This is the EMS/IDMM baseline family (paper §II-C/D) reformulated for
dense-tensor hardware: each round does a kernel-backed segment-min
"reserve", a mutual-selection "commit", and a vertex-state "prune", iterated
with ``lax.while_loop`` until no live edge remains. Deterministic (edge-id
priorities), like IDMM.

The function is shape-polymorphic in nothing: each (V, E) variant is lowered
separately by ``aot.py`` so the rust runtime can compile one executable per
variant and never touch python at request time.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.segment_min import BIG, segment_min


def ems_round(edge_u, edge_v, active, matched, match_flag, num_vertices: int):
    """One EMS round. Returns updated (active, matched, match_flag)."""
    e = edge_u.shape[0]
    ids = jnp.arange(e, dtype=jnp.int32)
    prio = jnp.where(active, ids, BIG)
    # L1 kernel: per-vertex min incident priority ("reserve")
    prop = segment_min(edge_u, edge_v, prio, num_vertices)
    # "commit": mutually-selected edges win
    win = active & (prop[edge_u] == prio) & (prop[edge_v] == prio)
    match_flag = match_flag | win
    matched = matched.at[edge_u].max(win)
    matched = matched.at[edge_v].max(win)
    # "prune": deactivate covered edges
    active = active & ~matched[edge_u] & ~matched[edge_v]
    return active, matched, match_flag


def ems_match(edge_u, edge_v, valid, *, num_vertices: int):
    """Full tensorized EMS maximal matching.

    Args:
      edge_u, edge_v: int32[E] endpoints (padding arbitrary where invalid).
      valid: int32[E] 1/0 mask of real edges.

    Returns:
      (match_flag int32[E], matched int32[V], rounds int32)
    """
    active0 = (valid != 0) & (edge_u != edge_v)
    matched0 = jnp.zeros((num_vertices,), dtype=jnp.bool_)
    flag0 = jnp.zeros_like(active0)

    def cond(state):
        active, _, _, _ = state
        return jnp.any(active)

    def body(state):
        active, matched, flag, rounds = state
        active, matched, flag = ems_round(
            edge_u, edge_v, active, matched, flag, num_vertices
        )
        return active, matched, flag, rounds + 1

    _, matched, flag, rounds = lax.while_loop(
        cond, body, (active0, matched0, flag0, jnp.int32(0))
    )
    return flag.astype(jnp.int32), matched.astype(jnp.int32), rounds


def lowerable(num_vertices: int, num_edges: int):
    """A jittable closure over static shapes, plus its example arguments —
    what ``aot.py`` lowers to HLO text."""

    def fn(edge_u, edge_v, valid):
        return ems_match(edge_u, edge_v, valid, num_vertices=num_vertices)

    spec = jax.ShapeDtypeStruct((num_edges,), jnp.int32)
    return fn, (spec, spec, spec)


# The (V, E) variants shipped as AOT artifacts. E must be a multiple of the
# kernel's EDGE_BLOCK (256). Chosen to cover the cross-layer bench sizes.
SHAPE_VARIANTS = [
    (256, 1024),
    (1024, 4096),
    (4096, 16384),
]
