"""AOT compile path: lower the L2 EMS matcher to HLO **text** artifacts the
rust runtime loads via ``HloModuleProto::from_text_file``.

HLO text — not ``.serialize()`` protos — is the interchange format: jax
≥ 0.5 emits 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``
Writes one ``ems_v{V}_e{E}.hlo.txt`` per shape variant plus
``manifest.toml`` (parsed by the rust coordinator's TOML-subset reader).
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import SHAPE_VARIANTS, lowerable


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(num_vertices: int, num_edges: int) -> str:
    fn, args = lowerable(num_vertices, num_edges)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    entries = []
    for v, e in SHAPE_VARIANTS:
        name = f"ems_v{v}_e{e}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        text = lower_variant(v, e)
        with open(path, "w") as f:
            f.write(text)
        entries.append((name, v, e))
        print(f"wrote {path} ({len(text)} chars)")

    manifest = os.path.join(args.out_dir, "manifest.toml")
    with open(manifest, "w") as f:
        f.write("# AOT artifact manifest — read by rust/src/runtime\n")
        for name, v, e in entries:
            f.write("\n[[artifact]]\n")
            f.write(f'path = "{name}"\n')
            f.write(f"vertices = {v}\n")
            f.write(f"edges = {e}\n")
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
