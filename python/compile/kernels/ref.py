"""Pure-jnp / numpy oracles for L1/L2 correctness.

``segment_min_ref`` is the scatter-min ground truth for the Pallas kernel;
``ems_match_ref`` is a step-by-step numpy implementation of the tensorized
EMS matcher; ``greedy_mm_ref`` is a python SGMM used to cross-check
maximality of any matching.
"""

import jax.numpy as jnp
import numpy as np

BIG = np.int32(2**30)


def segment_min_ref(edge_u, edge_v, prio, num_vertices: int):
    """Scatter-min ground truth (pure jnp, no Pallas)."""
    prop = jnp.full((num_vertices,), BIG, dtype=jnp.int32)
    prop = prop.at[edge_u].min(prio)
    prop = prop.at[edge_v].min(prio)
    return prop


def ems_match_ref(edge_u, edge_v, valid, num_vertices: int):
    """Numpy reference of the full EMS/IDMM matcher (edge-id priorities).

    Returns (match_flag[E] int32, matched[V] int32, rounds).
    """
    edge_u = np.asarray(edge_u)
    edge_v = np.asarray(edge_v)
    e = edge_u.shape[0]
    active = np.asarray(valid, dtype=bool) & (edge_u != edge_v)
    matched = np.zeros(num_vertices, dtype=bool)
    match_flag = np.zeros(e, dtype=bool)
    ids = np.arange(e, dtype=np.int64)
    rounds = 0
    while active.any():
        rounds += 1
        prop = np.full(num_vertices, BIG, dtype=np.int64)
        np.minimum.at(prop, edge_u[active], ids[active])
        np.minimum.at(prop, edge_v[active], ids[active])
        win = active & (prop[edge_u] == ids) & (prop[edge_v] == ids)
        match_flag |= win
        matched[edge_u[win]] = True
        matched[edge_v[win]] = True
        active &= ~matched[edge_u] & ~matched[edge_v]
    return match_flag.astype(np.int32), matched.astype(np.int32), rounds


def greedy_mm_ref(edge_u, edge_v, valid, num_vertices: int):
    """Sequential greedy MM (python SGMM) — used to cross-check maximality
    and compare matching sizes."""
    matched = np.zeros(num_vertices, dtype=bool)
    flags = np.zeros(len(edge_u), dtype=np.int32)
    for i, (u, v, ok) in enumerate(zip(edge_u, edge_v, valid)):
        if not ok or u == v:
            continue
        if not matched[u] and not matched[v]:
            matched[u] = True
            matched[v] = True
            flags[i] = 1
    return flags, matched.astype(np.int32)


def check_matching(edge_u, edge_v, valid, match_flag, matched, num_vertices: int):
    """Assert validity + maximality of a matching over the padded edge set.

    Raises AssertionError on violation.
    """
    edge_u = np.asarray(edge_u)
    edge_v = np.asarray(edge_v)
    valid = np.asarray(valid).astype(bool)
    match_flag = np.asarray(match_flag).astype(bool)
    matched = np.asarray(matched).astype(bool)
    # matches only on valid, non-loop edges
    assert not (match_flag & ~valid).any(), "matched an invalid (padding) edge"
    assert not (match_flag & (edge_u == edge_v)).any(), "matched a self-loop"
    # no shared endpoints
    degree = np.zeros(num_vertices, dtype=np.int64)
    np.add.at(degree, edge_u[match_flag], 1)
    np.add.at(degree, edge_v[match_flag], 1)
    assert degree.max(initial=0) <= 1, "vertex matched twice"
    # matched[] consistent with match_flag
    expect = np.zeros(num_vertices, dtype=bool)
    expect[edge_u[match_flag]] = True
    expect[edge_v[match_flag]] = True
    assert (expect == matched).all(), "matched[] inconsistent with match_flag"
    # maximality
    live = valid & (edge_u != edge_v) & ~matched[edge_u] & ~matched[edge_v]
    assert not live.any(), "some edge has both endpoints unmatched"
