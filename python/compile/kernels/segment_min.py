"""L1 — Pallas segment-min kernel: the compute hot-spot of one EMS round.

Given the edge arrays ``edge_u``, ``edge_v`` (int32[E]) and per-edge
priorities ``prio`` (int32[E]), compute per-vertex proposals::

    prop[w] = min over incident edges e of prio[e]        (else BIG)

This is the "reserve" phase of the IDMM/EMS family (paper §II-D). On the
paper's CPU it is a scatter-min; on TPU-class hardware the scatter is
reformulated as a dense one-hot compare-and-reduce over
``(edge_block × vertex)`` tiles — VPU-friendly, VMEM-resident — with
``BlockSpec`` tiling edges across the grid (DESIGN.md §Hardware-Adaptation).

The kernel MUST run with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel "no proposal" priority. A plain python int (not a jnp array):
# pallas kernels must not close over concrete jax arrays, and int32 max
# would overflow +1 id encodings some callers use.
BIG = 2**30

# Edges processed per grid step (tile height). 256 edges × V-tile ints stay
# comfortably within a TPU core's VMEM for the shipped shape variants.
EDGE_BLOCK = 256


def _segment_min_kernel(u_ref, v_ref, p_ref, o_ref, *, num_vertices: int):
    """One grid step: partial per-vertex min over an EDGE_BLOCK-edge tile."""
    u = u_ref[...]  # (EB,)
    v = v_ref[...]
    p = p_ref[...]
    # one-hot compare against all vertex ids: (EB, V)
    vid = jax.lax.broadcasted_iota(jnp.int32, (u.shape[0], num_vertices), 1)
    pe = p[:, None]
    vals_u = jnp.where(u[:, None] == vid, pe, BIG)
    vals_v = jnp.where(v[:, None] == vid, pe, BIG)
    o_ref[0, :] = jnp.minimum(jnp.min(vals_u, axis=0), jnp.min(vals_v, axis=0))


def segment_min(edge_u, edge_v, prio, num_vertices: int):
    """Per-vertex min of incident-edge priorities. Returns int32[V].

    Grid: one step per EDGE_BLOCK of edges; each step writes a partial
    (1, V) row; the cross-block reduction is a plain ``jnp.min`` that XLA
    fuses with downstream consumers.
    """
    e = edge_u.shape[0]
    if e % EDGE_BLOCK != 0:
        raise ValueError(f"edge count {e} must be a multiple of {EDGE_BLOCK}")
    nblocks = e // EDGE_BLOCK
    partials = pl.pallas_call(
        partial(_segment_min_kernel, num_vertices=num_vertices),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((EDGE_BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, num_vertices), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, num_vertices), jnp.int32),
        interpret=True,  # CPU-PJRT execution; see module docstring
    )(edge_u, edge_v, prio)
    return jnp.min(partials, axis=0)


def vmem_bytes_estimate(num_vertices: int) -> int:
    """Estimated VMEM working set per grid step (DESIGN.md §Perf/L1):
    three int32 edge tiles + two (EB, V) one-hot intermediates + the
    (1, V) output row."""
    tile_in = 3 * EDGE_BLOCK * 4
    onehot = 2 * EDGE_BLOCK * num_vertices * 4
    out_row = num_vertices * 4
    return tile_in + onehot + out_row
