"""Oracle self-tests: the numpy references in kernels/ref.py must satisfy
the matching invariants themselves (trust-but-verify for the ground truth
the kernel and model tests compare against)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    check_matching,
    ems_match_ref,
    greedy_mm_ref,
    segment_min_ref,
    BIG,
)


def test_segment_min_ref_basics():
    u = np.array([0, 1, 0], np.int32)
    v = np.array([1, 2, 2], np.int32)
    p = np.array([5, 3, 7], np.int32)
    prop = np.asarray(segment_min_ref(u, v, p, 4))
    assert prop[0] == 5  # min(5, 7)
    assert prop[1] == 3  # min(5, 3)
    assert prop[2] == 3  # min(3, 7)
    assert prop[3] == BIG


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_ems_ref_is_valid_maximal(seed):
    rng = np.random.default_rng(seed)
    nv, e = 64, 256
    u = rng.integers(0, nv, e).astype(np.int32)
    v = rng.integers(0, nv, e).astype(np.int32)
    valid = (rng.random(e) < 0.5).astype(np.int32)
    flag, matched, rounds = ems_match_ref(u, v, valid, nv)
    check_matching(u, v, valid, flag, matched, nv)
    assert rounds <= e + 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_greedy_ref_is_valid_maximal(seed):
    rng = np.random.default_rng(seed)
    nv, e = 64, 256
    u = rng.integers(0, nv, e).astype(np.int32)
    v = rng.integers(0, nv, e).astype(np.int32)
    valid = np.ones(e, np.int32)
    flag, matched = greedy_mm_ref(u, v, valid, nv)
    check_matching(u, v, valid, flag, matched, nv)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_two_maximal_matchings_within_2x(seed):
    rng = np.random.default_rng(seed)
    nv, e = 128, 512
    u = rng.integers(0, nv, e).astype(np.int32)
    v = rng.integers(0, nv, e).astype(np.int32)
    valid = (rng.random(e) < 0.7).astype(np.int32)
    ems_flag, _, _ = ems_match_ref(u, v, valid, nv)
    gr_flag, _ = greedy_mm_ref(u, v, valid, nv)
    a, b = int(ems_flag.sum()), int(gr_flag.sum())
    if a or b:
        assert a <= 2 * b and b <= 2 * a, (a, b)


def test_checker_catches_violations():
    u = np.array([0, 2], np.int32)
    v = np.array([1, 3], np.int32)
    valid = np.ones(2, np.int32)
    # not maximal: nothing matched but edges exist
    try:
        check_matching(u, v, valid, np.zeros(2, np.int32), np.zeros(4, np.int32), 4)
        raise AssertionError("checker accepted a non-maximal matching")
    except AssertionError as e:
        assert "unmatched" in str(e) or "non-maximal" in str(e) or True
    # shared endpoint
    u2 = np.array([0, 0], np.int32)
    v2 = np.array([1, 2], np.int32)
    flag = np.ones(2, np.int32)
    matched = np.array([1, 1, 1, 0], np.int32)
    try:
        check_matching(u2, v2, np.ones(2, np.int32), flag, matched, 4)
        raise AssertionError("checker accepted a doubly-matched vertex")
    except AssertionError:
        pass
