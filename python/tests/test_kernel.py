"""L1 kernel vs oracle — the core correctness signal for the Pallas path.

Hypothesis sweeps edge counts, vertex counts, endpoint distributions and
priority patterns; every case asserts exact equality against the pure-jnp
scatter-min reference.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import segment_min_ref
from compile.kernels.segment_min import BIG, EDGE_BLOCK, segment_min, vmem_bytes_estimate


def run_both(u, v, p, nv):
    u = jnp.asarray(u, jnp.int32)
    v = jnp.asarray(v, jnp.int32)
    p = jnp.asarray(p, jnp.int32)
    got = np.asarray(segment_min(u, v, p, nv))
    want = np.asarray(segment_min_ref(u, v, p, nv))
    return got, want


def test_single_block_simple():
    e, nv = EDGE_BLOCK, 8
    u = np.zeros(e, np.int32)
    v = np.ones(e, np.int32)
    p = np.arange(e, dtype=np.int32)
    got, want = run_both(u, v, p, nv)
    np.testing.assert_array_equal(got, want)
    assert got[0] == 0 and got[1] == 0
    assert (got[2:] == BIG).all()


def test_multi_block_reduction():
    # vertex 3 gets its min from the second block
    e, nv = 2 * EDGE_BLOCK, 16
    u = np.full(e, 3, np.int32)
    v = np.full(e, 5, np.int32)
    p = np.arange(e, 0, -1, dtype=np.int32)  # min is in the LAST slot
    got, want = run_both(u, v, p, nv)
    np.testing.assert_array_equal(got, want)
    assert got[3] == 1 and got[5] == 1


def test_rejects_unaligned_edge_count():
    with pytest.raises(ValueError):
        segment_min(
            jnp.zeros(100, jnp.int32),
            jnp.zeros(100, jnp.int32),
            jnp.zeros(100, jnp.int32),
            4,
        )


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(min_value=1, max_value=4),
    nv=st.sampled_from([4, 16, 64, 256, 1024]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_reference_random(nblocks, nv, seed):
    rng = np.random.default_rng(seed)
    e = nblocks * EDGE_BLOCK
    u = rng.integers(0, nv, e).astype(np.int32)
    v = rng.integers(0, nv, e).astype(np.int32)
    p = rng.integers(0, 2**20, e).astype(np.int32)
    got, want = run_both(u, v, p, nv)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_big_sentinel_untouched_vertices(seed):
    rng = np.random.default_rng(seed)
    nv = 128
    e = EDGE_BLOCK
    # only touch even vertices
    u = (2 * rng.integers(0, nv // 2, e)).astype(np.int32)
    v = (2 * rng.integers(0, nv // 2, e)).astype(np.int32)
    p = rng.integers(0, 1000, e).astype(np.int32)
    got, _ = run_both(u, v, p, nv)
    assert (got[1::2] == BIG).all()


def test_duplicate_endpoints_take_min():
    nv = 4
    e = EDGE_BLOCK
    u = np.zeros(e, np.int32)
    v = np.zeros(e, np.int32)  # degenerate u == v: still a segment-min input
    p = np.full(e, 77, np.int32)
    p[13] = 5
    got, want = run_both(u, v, p, nv)
    np.testing.assert_array_equal(got, want)
    assert got[0] == 5


def test_vmem_estimate_within_budget():
    # DESIGN.md §Perf/L1: largest shipped variant must fit VMEM (~16 MiB)
    assert vmem_bytes_estimate(4096) < 16 * 1024 * 1024
