"""L2 model properties: the tensorized EMS matcher must produce valid,
maximal matchings on random padded edge sets, agree with the numpy
reference, and terminate. Hypothesis sweeps graph shapes and densities."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import check_matching, ems_match_ref, greedy_mm_ref
from compile.model import ems_match


def random_instance(rng, nv, e, density):
    n_valid = int(e * density)
    u = rng.integers(0, nv, e).astype(np.int32)
    v = rng.integers(0, nv, e).astype(np.int32)
    valid = np.zeros(e, np.int32)
    valid[:n_valid] = 1
    return u, v, valid


def run_model(u, v, valid, nv):
    flag, matched, rounds = ems_match(
        jnp.asarray(u), jnp.asarray(v), jnp.asarray(valid), num_vertices=nv
    )
    return np.asarray(flag), np.asarray(matched), int(rounds)


def test_tiny_path():
    # path 0-1-2-3 padded to one block
    nv, e = 256, 1024
    u = np.zeros(e, np.int32)
    v = np.zeros(e, np.int32)
    valid = np.zeros(e, np.int32)
    for i, (a, b) in enumerate([(0, 1), (1, 2), (2, 3)]):
        u[i], v[i], valid[i] = a, b, 1
    flag, matched, rounds = run_model(u, v, valid, nv)
    check_matching(u, v, valid, flag, matched, nv)
    # edge-id priority: (0,1) and (2,3) win
    assert flag[0] == 1 and flag[1] == 0 and flag[2] == 1
    assert rounds >= 1


def test_empty_input_zero_rounds():
    nv, e = 256, 1024
    z = np.zeros(e, np.int32)
    flag, matched, rounds = run_model(z, z, z, nv)
    assert flag.sum() == 0 and matched.sum() == 0 and rounds == 0


def test_self_loops_never_match():
    nv, e = 256, 1024
    u = np.arange(e, dtype=np.int32) % nv
    v = u.copy()
    valid = np.ones(e, np.int32)
    flag, matched, _ = run_model(u, v, valid, nv)
    assert flag.sum() == 0 and matched.sum() == 0


def test_agrees_with_numpy_reference():
    rng = np.random.default_rng(7)
    nv, e = 256, 1024
    u, v, valid = random_instance(rng, nv, e, 0.5)
    flag, matched, rounds = run_model(u, v, valid, nv)
    ref_flag, ref_matched, ref_rounds = ems_match_ref(u, v, valid, nv)
    np.testing.assert_array_equal(flag, ref_flag)
    np.testing.assert_array_equal(matched, ref_matched)
    assert rounds == ref_rounds


@settings(max_examples=15, deadline=None)
@given(
    density=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_valid_maximal_random(density, seed):
    rng = np.random.default_rng(seed)
    nv, e = 256, 1024
    u, v, valid = random_instance(rng, nv, e, density)
    flag, matched, _ = run_model(u, v, valid, nv)
    check_matching(u, v, valid, flag, matched, nv)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_matching_size_comparable_to_greedy(seed):
    # Any two maximal matchings differ by at most 2x in size.
    rng = np.random.default_rng(seed)
    nv, e = 256, 1024
    u, v, valid = random_instance(rng, nv, e, 0.6)
    flag, _, _ = run_model(u, v, valid, nv)
    gflag, _ = greedy_mm_ref(u, v, valid, nv)
    ours, greedy = int(flag.sum()), int(gflag.sum())
    if greedy == 0:
        assert ours == 0
    else:
        assert greedy / 2 <= ours <= 2 * greedy


def test_larger_variant_shape():
    rng = np.random.default_rng(3)
    nv, e = 1024, 4096
    u, v, valid = random_instance(rng, nv, e, 0.4)
    flag, matched, _ = run_model(u, v, valid, nv)
    check_matching(u, v, valid, flag, matched, nv)
