"""AOT smoke tests: each shape variant lowers to parseable HLO text with
the expected entry signature; the eager function the HLO was lowered from
produces a valid maximal matching (the text→PJRT reload path itself is
exercised by the rust integration test, rust/tests/integration_runtime.rs)."""

import numpy as np
import pytest

from compile.aot import lower_variant
from compile.kernels.ref import check_matching
from compile.model import SHAPE_VARIANTS, lowerable


@pytest.mark.parametrize("nv,ne", SHAPE_VARIANTS[:2])  # keep CI fast
def test_lowering_produces_hlo_text(nv, ne):
    text = lower_variant(nv, ne)
    assert "HloModule" in text
    assert "while" in text.lower()  # the EMS fixed-point loop survived
    # three s32[E] parameters
    assert text.count(f"s32[{ne}]") >= 3


def test_lowered_fn_produces_valid_matching():
    import jax.numpy as jnp

    nv, ne = SHAPE_VARIANTS[0]
    fn, _ = lowerable(nv, ne)
    rng = np.random.default_rng(11)
    u = rng.integers(0, nv, ne).astype(np.int32)
    v = rng.integers(0, nv, ne).astype(np.int32)
    valid = (rng.random(ne) < 0.5).astype(np.int32)
    flag, matched, rounds = fn(jnp.asarray(u), jnp.asarray(v), jnp.asarray(valid))
    check_matching(u, v, valid, np.asarray(flag), np.asarray(matched), nv)
    assert int(rounds) >= 1


def test_manifest_generation(tmp_path):
    # run the writer on one variant by monkeypatching the variant list
    import compile.aot as aot
    import compile.model as model

    old = model.SHAPE_VARIANTS
    try:
        model.SHAPE_VARIANTS = [(256, 1024)]
        import sys

        argv = sys.argv
        sys.argv = ["aot", "--out-dir", str(tmp_path)]
        try:
            aot.main()
        finally:
            sys.argv = argv
    finally:
        model.SHAPE_VARIANTS = old
    manifest = (tmp_path / "manifest.toml").read_text()
    assert "[[artifact]]" in manifest
    assert 'path = "ems_v256_e1024.hlo.txt"' in manifest
    assert (tmp_path / "ems_v256_e1024.hlo.txt").exists()
