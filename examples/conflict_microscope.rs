//! Conflict microscope: reproduce the paper's Table II analysis on demand —
//! run the APRAM simulator at several thread counts over adversarial and
//! friendly topologies and print the JIT-conflict distributions.
//!
//! ```bash
//! cargo run --release --example conflict_microscope
//! ```

use skipper::apram::{simulate_skipper, SimConfig};
use skipper::graph::gen::{barabasi_albert, erdos_renyi, grid, simple};
use skipper::instrument::conflicts::BUCKET_LABELS;
use skipper::util::benchlib::Table;

fn main() {
    let cases: Vec<(&str, skipper::graph::CsrGraph)> = vec![
        ("star-8k (adversarial)", simple::star(8192)),
        ("grid-128x128 (max locality)", grid::generate(128, 128, false)),
        ("er-16k (no locality)", erdos_renyi::generate(16_384, 131_072, 5)),
        ("ba-16k (hubs)", barabasi_albert::generate(16_384, 8, 6)),
    ];

    let mut header = vec!["graph", "t", "max", "total", "#edges", "avg"];
    header.extend(BUCKET_LABELS);
    let mut table = Table::new(&header);

    for (name, g) in &cases {
        for &threads in &[16usize, 64] {
            // paper method: 5 runs, keep the run with most conflicting edges
            let worst = (0..5)
                .map(|r| {
                    simulate_skipper(
                        g,
                        &SimConfig {
                            threads,
                            blocks_per_thread: 16,
                            seed: 0xC0 + r,
                        },
                    )
                    .conflicts
                })
                .max_by_key(|c| c.edges_with_conflicts)
                .unwrap();
            let mut row = vec![
                name.to_string(),
                threads.to_string(),
                worst.max_per_edge.to_string(),
                worst.total.to_string(),
                worst.edges_with_conflicts.to_string(),
                format!("{:.1}", worst.avg_per_conflicting_edge()),
            ];
            row.extend(worst.buckets.iter().map(|b| {
                if *b == 0 { String::new() } else { b.to_string() }
            }));
            table.row(&row);
        }
    }
    println!("JIT conflicts under the APRAM interleaving simulator (cf. paper Table II)");
    println!("{}", table.render());
    println!("observations: conflicts concentrate on the star's hub; locality +");
    println!("the dispersed scheduler keep real-graph conflict ratios ≪ 0.1% of |E|.");
}
