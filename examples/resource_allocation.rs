//! Domain example: resource allocation via bipartite maximal matching
//! (the paper's intro names "optimizing resource allocation" as a core MM
//! application).
//!
//! Tasks on the left, workers on the right, an edge = "worker can run
//! task". A maximal matching is a conflict-free assignment in which no
//! compatible (task, worker) pair is left idle. We sweep compatibility
//! densities and report assignment rates.
//!
//! ```bash
//! cargo run --release --example resource_allocation
//! ```

use skipper::graph::gen::simple::bipartite_random;
use skipper::matching::skipper::Skipper;
use skipper::matching::{verify, MaximalMatcher};
use skipper::util::benchlib::Table;

fn main() {
    let tasks = 50_000;
    let workers = 40_000;
    let mut t = Table::new(&[
        "compat edges", "assignments", "tasks assigned", "workers busy", "time(ms)",
    ]);
    for &m_edges in &[60_000usize, 150_000, 400_000, 1_200_000] {
        let g = bipartite_random(tasks, workers, m_edges, 7 + m_edges as u64);
        let t0 = std::time::Instant::now();
        let m = Skipper::new(4).run(&g);
        let dt = t0.elapsed().as_secs_f64();
        verify::check(&g, &m).expect("valid maximal assignment");
        // every match pairs one task (id < tasks) with one worker
        for (a, b) in m.iter() {
            let (lo, hi) = (a.min(b), a.max(b));
            assert!((lo as usize) < tasks && (hi as usize) >= tasks, "cross edge");
        }
        t.row(&[
            m_edges.to_string(),
            m.len().to_string(),
            format!("{:.1}%", 100.0 * m.len() as f64 / tasks as f64),
            format!("{:.1}%", 100.0 * m.len() as f64 / workers as f64),
            format!("{:.1}", dt * 1e3),
        ]);
    }
    println!("bipartite assignment: {tasks} tasks x {workers} workers");
    println!("{}", t.render());
    println!("maximality ⇒ no compatible (task, worker) pair is left idle.");
}
