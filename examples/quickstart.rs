//! Quickstart: generate a graph, run Skipper, verify the matching.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use skipper::graph::gen::{rmat, GenConfig};
use skipper::matching::skipper::Skipper;
use skipper::matching::{verify, MaximalMatcher};

fn main() {
    // A Graph500-style RMAT graph: 2^14 vertices, ~131k edges.
    let g = rmat::generate(&GenConfig {
        scale: 14,
        avg_degree: 8,
        seed: 42,
    });
    println!(
        "graph: |V|={} |E|={} (max degree {})",
        g.num_vertices(),
        g.num_undirected_edges(),
        g.max_degree()
    );

    // Skipper with 4 threads: single pass over edges, one byte per vertex.
    let skipper = Skipper::new(4);
    let t0 = std::time::Instant::now();
    let report = skipper.run_with_conflicts(&g);
    let dt = t0.elapsed();

    println!(
        "skipper: |M|={} edges matched in {:.3} ms",
        report.matching.len(),
        dt.as_secs_f64() * 1e3
    );
    println!("JIT conflicts: {}", report.conflicts.table_row());

    verify::check(&g, &report.matching).expect("valid maximal matching");
    println!("verified: valid + maximal ✓");

    // Compare with the sequential greedy reference.
    let sgmm = skipper::matching::sgmm::Sgmm.run(&g);
    println!(
        "SGMM reference: |M|={} ({}% of Skipper's size)",
        sgmm.len(),
        100 * report.matching.len() / sgmm.len().max(1)
    );
}
