//! Domain example: pairwise collaboration analysis on a social network
//! (one of the applications the paper's introduction motivates).
//!
//! A Barabási–Albert graph models a follower network with hubs. Maximal
//! matching pairs users for a collaboration program such that nobody is
//! paired twice, and no eligible pair is left unpaired. We compare hub
//! coverage and pairing rates between Skipper and the EMS baselines.
//!
//! ```bash
//! cargo run --release --example social_pairing
//! ```

use skipper::graph::gen::barabasi_albert;
use skipper::matching::ems::sidmm::Sidmm;
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::{verify, MaximalMatcher, Matching};
use skipper::util::benchlib::Table;

fn pairing_stats(name: &str, g: &skipper::graph::CsrGraph, m: &Matching, secs: f64, t: &mut Table) {
    verify::check(g, m).expect("valid maximal matching");
    let n = g.num_vertices();
    let paired = 2 * m.len();
    // hub coverage: fraction of the 100 highest-degree users that got paired
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
    let mut matched = vec![false; n];
    for (u, v) in m.iter() {
        matched[u as usize] = true;
        matched[v as usize] = true;
    }
    let hubs = &by_degree[..100.min(n)];
    let hub_cov = hubs.iter().filter(|&&v| matched[v as usize]).count();
    t.row(&[
        name.into(),
        m.len().to_string(),
        format!("{:.1}%", 100.0 * paired as f64 / n as f64),
        format!("{}/{}", hub_cov, hubs.len()),
        format!("{:.1} ms", secs * 1e3),
    ]);
}

fn main() {
    let g = barabasi_albert::generate(200_000, 6, 2024);
    println!(
        "follower network: |V|={} |E|={} max-degree={}",
        g.num_vertices(),
        g.num_undirected_edges(),
        g.max_degree()
    );

    let mut t = Table::new(&["Algorithm", "pairs", "paired users", "hub coverage", "time"]);
    let timed = |f: &dyn Fn() -> Matching| {
        let t0 = std::time::Instant::now();
        let m = f();
        (m, t0.elapsed().as_secs_f64())
    };

    let (m, s) = timed(&|| Skipper::new(4).run(&g));
    pairing_stats("Skipper(t=4)", &g, &m, s, &mut t);
    let (m, s) = timed(&|| Sgmm.run(&g));
    pairing_stats("SGMM", &g, &m, s, &mut t);
    let (m, s) = timed(&|| Sidmm::default().run(&g));
    pairing_stats("SIDMM", &g, &m, s, &mut t);

    println!("{}", t.render());
    println!("note: hubs can only be paired once — maximality guarantees every");
    println!("unpaired user has no unpaired neighbor left.");
}
