//! End-to-end driver (deliverable (b) / DESIGN.md §5): exercises the FULL
//! three-layer stack on a real small workload and reports the paper's
//! headline metric.
//!
//! 1. generates the 7-dataset analogue suite (small scale),
//! 2. calibrates the cost model against a real SGMM run on this host,
//! 3. runs SGMM (measured), SIDMM + Skipper (measured work + APRAM
//!    simulation at t=64), verifying every matching,
//! 4. loads the AOT artifacts (L2 JAX model + L1 Pallas kernel, compiled
//!    to HLO text) through the PJRT runtime and cross-checks the XLA EMS
//!    matcher against the rust IDMM on the same graph,
//! 5. prints Table-I-style rows and the headline geomean speedup.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//! Results are recorded in EXPERIMENTS.md.

use skipper::coordinator::calibrate::calibrate;
use skipper::coordinator::datasets::Scale;
use skipper::coordinator::experiments::{collect_suite, PAPER_THREADS};
use skipper::graph::gen::{rmat, GenConfig};
use skipper::matching::ems::idmm::Idmm;
use skipper::matching::{verify, MaximalMatcher};
use skipper::runtime::XlaEmsMatcher;
use skipper::util::benchlib::Table;
use skipper::util::stats::geomean;

fn main() {
    let scale_env = std::env::var("SKIPPER_E2E_SCALE").unwrap_or_else(|_| "small".into());
    let scale = Scale::parse(&scale_env).expect("SKIPPER_E2E_SCALE");

    println!("== [1/3] calibrating cost model on this host ==");
    let cost = calibrate();
    println!(
        "   {:.2} ns/access, {:.0} ns L3-miss penalty, {}x memory concurrency",
        cost.ns_per_access, cost.l3_miss_penalty_ns, cost.mem_concurrency
    );

    println!("== [2/3] L3: full suite, all layers of measurement ({scale_env} scale) ==");
    let metrics = collect_suite(scale, "data", 3);
    let mut t = Table::new(&[
        "Dataset", "|V|", "|E|", "SGMM(s)", "SIDMM t64(s)", "Skipper t64(s)", "Speedup", "cnf edges",
    ]);
    let mut speedups = Vec::new();
    for m in &metrics {
        let sidmm = m.sidmm_par_seconds(&cost, PAPER_THREADS);
        let skipper = m.skipper_par_seconds(&cost, PAPER_THREADS);
        let sp = sidmm / skipper;
        speedups.push(sp);
        t.row(&[
            m.spec.paper_name.into(),
            m.v.to_string(),
            (m.e_slots / 2).to_string(),
            format!("{:.4}", m.sgmm_wall_s),
            format!("{sidmm:.4}"),
            format!("{skipper:.4}"),
            format!("{sp:.1}x"),
            m.conflicts64.edges_with_conflicts.to_string(),
        ]);
    }
    println!("{}", t.render());
    let headline = geomean(&speedups).unwrap_or(f64::NAN);
    println!(
        "HEADLINE: Skipper vs SIDMM geomean speedup = {headline:.1}x  (paper: 8.0x, range 4.9-15.6x)\n"
    );

    println!("== [3/3] L1+L2 via PJRT: AOT XLA EMS matcher cross-check ==");
    match XlaEmsMatcher::from_default_artifacts() {
        Err(e) => {
            println!("   artifacts missing ({e:#}); run `make artifacts` for the full stack");
            std::process::exit(1);
        }
        Ok(matcher) => {
            let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 3, seed: 77 });
            let t0 = std::time::Instant::now();
            let (xm, rounds) = matcher.match_graph(&g).expect("xla run");
            let dt = t0.elapsed().as_secs_f64();
            verify::check(&g, &xm).expect("xla matching invalid");
            let rust_m = Idmm::default().run(&g);
            assert_eq!(
                xm.to_sorted_vec(),
                rust_m.to_sorted_vec(),
                "XLA EMS must equal rust IDMM bit-for-bit"
            );
            println!(
                "   XLA-EMS (Pallas segment-min + JAX while_loop, {} rounds) on |V|={} |E|={}: {:.3}s",
                rounds,
                g.num_vertices(),
                g.num_undirected_edges(),
                dt
            );
            println!("   matches rust IDMM exactly ({} edges) ✓", xm.len());
        }
    }
    println!("\nall layers compose: L3 rust coordinator + L2 JAX model + L1 Pallas kernel ✓");
}
