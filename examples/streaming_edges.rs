//! Domain example: the streaming ingest→match pipeline end-to-end.
//!
//! Three acts, one algorithm:
//!
//! 1. **Stream off disk** — write an RMAT graph to the `.skg` binary format
//!    once, then compute a maximal matching by *streaming the file through
//!    Skipper chunk-by-chunk*: the CSR is never resident, topology memory
//!    is the chunk window plus one byte of state per vertex.
//! 2. **Stream out of thin air** — match edges straight off the synthetic
//!    generator; the "graph" never exists anywhere.
//! 3. **Stream as updates** — the same pipeline fed in-memory batches is
//!    exactly the incremental maintenance scenario (paper §V-C).
//!
//! ```bash
//! cargo run --release --example streaming_edges
//! ```

use skipper::graph::builder::{build, BuildOptions};
use skipper::graph::gen::{rmat, GenConfig};
use skipper::graph::io::binary;
use skipper::graph::EdgeList;
use skipper::graph::stream::{SkgEdgeSource, SyntheticEdgeSource};
use skipper::matching::incremental::IncrementalMatcher;
use skipper::matching::streaming::StreamingSkipper;
use skipper::matching::verify;
use skipper::util::benchlib::Table;
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;

fn main() {
    // ---- act 1: stream a .skg file, never materializing the CSR ----------
    let cfg = GenConfig { scale: 16, avg_degree: 8, seed: 99 };
    let g = rmat::generate(&cfg); // materialized ONCE, only to write + verify
    let path = std::env::temp_dir().join("streaming_edges_demo.skg");
    let path = path.to_str().unwrap().to_string();
    binary::write_file(&path, &g).expect("write .skg");
    println!(
        "wrote {path}: |V|={} slots={} ({} B as CSR)\n",
        g.num_vertices(),
        g.num_edge_slots(),
        g.memory_bytes()
    );

    let mut t = Table::new(&["chunk edges", "threads", "|M|", "s", "Medges/s", "peak B", "vs CSR"]);
    for (chunk, threads) in [(1024usize, 2usize), (4096, 2), (4096, 4), (16384, 4)] {
        let source = SkgEdgeSource::open(&path).expect("open .skg");
        let sk = StreamingSkipper::new(threads).with_chunk_edges(chunk);
        let t0 = std::time::Instant::now();
        let rep = sk.run(source).expect("stream run");
        let dt = t0.elapsed().as_secs_f64();
        verify::check(&g, &rep.matching).expect("streamed matching is maximal");
        t.row(&[
            chunk.to_string(),
            threads.to_string(),
            rep.matching.len().to_string(),
            format!("{dt:.3}"),
            format!("{:.2}", rep.edges_streamed as f64 / dt.max(1e-9) / 1e6),
            rep.peak_topology_bytes().to_string(),
            format!("{:.1}x less", rep.csr_equivalent_bytes() as f64
                / rep.peak_topology_bytes().max(1) as f64),
        ]);
    }
    println!("[1] matching streamed off disk (every run verified maximal):\n{}", t.render());

    // ---- act 2: no file, no graph — edges sampled on demand ---------------
    let (n, m) = (1 << 17, 1 << 20);
    let t0 = std::time::Instant::now();
    let rep = StreamingSkipper::new(4)
        .run(SyntheticEdgeSource::erdos_renyi(n, m, 7))
        .expect("generator stream");
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "[2] matched {} generator edges with no graph anywhere: |M|={} in {dt:.3}s, peak topology {} B",
        rep.edges_streamed,
        rep.matching.len(),
        rep.peak_topology_bytes()
    );

    // ---- act 3: batches = the incremental scenario ------------------------
    let n = 100_000;
    let mut rng = Xoshiro256pp::new(99);
    let mut inc = IncrementalMatcher::new(n, 4);
    let mut all_edges: Vec<(VertexId, VertexId)> = Vec::new();
    for _ in 0..10 {
        let edges: Vec<(VertexId, VertexId)> = (0..50_000)
            .map(|_| (rng.next_usize(n) as VertexId, rng.next_usize(n) as VertexId))
            .collect();
        all_edges.extend(&edges);
        inc.insert_batch(&edges);
    }
    // verify the incrementally-maintained matching against the union graph
    let mut el = EdgeList::new(n);
    for &(u, v) in &all_edges {
        el.push(u, v);
    }
    let union = build(&el, BuildOptions::default());
    verify::check(&union, &inc.to_matching()).expect("incrementally-maintained matching is maximal");
    println!(
        "[3] incremental twin: {} edges over 10 batches -> |M|={} (same core, same pipeline; verified maximal)",
        all_edges.len(),
        inc.matching().len()
    );
    println!("\nsingle pass over edges — streamed, generated, or batched. ✓");
    let _ = std::fs::remove_file(&path);
}
