//! Domain example: maintaining a maximal matching over a stream of edge
//! batches with [`IncrementalMatcher`] — the paper's §V-C observation that
//! Skipper is "incremental in expectation" made concrete. Think: a dating/
//! mentoring service pairing users as connection suggestions arrive.
//!
//! ```bash
//! cargo run --release --example streaming_edges
//! ```

use skipper::graph::builder::{build, BuildOptions};
use skipper::graph::EdgeList;
use skipper::matching::incremental::IncrementalMatcher;
use skipper::matching::verify;
use skipper::util::benchlib::Table;
use skipper::util::rng::Xoshiro256pp;
use skipper::VertexId;

fn main() {
    let n = 100_000;
    let batches = 20;
    let batch_size = 40_000;
    let mut rng = Xoshiro256pp::new(99);
    let mut inc = IncrementalMatcher::new(n, 4);
    let mut all_edges: Vec<(VertexId, VertexId)> = Vec::new();

    let mut t = Table::new(&["batch", "new edges", "new matches", "total matches", "ms"]);
    for b in 0..batches {
        let edges: Vec<(VertexId, VertexId)> = (0..batch_size)
            .map(|_| {
                (
                    rng.next_usize(n) as VertexId,
                    rng.next_usize(n) as VertexId,
                )
            })
            .collect();
        let t0 = std::time::Instant::now();
        let added = inc.insert_batch(&edges);
        let dt = t0.elapsed().as_secs_f64();
        all_edges.extend(&edges);
        t.row(&[
            b.to_string(),
            edges.len().to_string(),
            added.to_string(),
            inc.matching().len().to_string(),
            format!("{:.1}", dt * 1e3),
        ]);
    }
    println!("incremental maximal matching over {batches} batches of {batch_size} edges");
    println!("{}", t.render());

    // verify against the full accumulated graph
    let mut el = EdgeList::new(n);
    for &(u, v) in &all_edges {
        el.push(u, v);
    }
    let g = build(&el, BuildOptions::default());
    verify::check(&g, &inc.matching()).expect("incrementally-maintained matching is maximal");
    println!(
        "verified against the union graph (|V|={}, |E|={}): maximal ✓",
        g.num_vertices(),
        g.num_undirected_edges()
    );
    println!("no batch ever re-touched previously processed edges — single pass, streamed.");
}
