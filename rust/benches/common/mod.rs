//! Shared bench plumbing: scale selection via `SKIPPER_BENCH_SCALE`
//! (default `tiny` so `cargo bench` completes quickly; the EXPERIMENTS.md
//! runs use `small`/`medium` through the CLI).
#![allow(dead_code)] // each bench binary uses a subset of these helpers

use skipper::coordinator::datasets::Scale;

pub fn bench_scale() -> Scale {
    let s = std::env::var("SKIPPER_BENCH_SCALE").unwrap_or_else(|_| "tiny".into());
    Scale::parse(&s).expect("SKIPPER_BENCH_SCALE")
}

pub fn cache_dir() -> String {
    std::env::var("SKIPPER_BENCH_CACHE").unwrap_or_else(|_| "data".into())
}

pub fn table2_runs() -> usize {
    std::env::var("SKIPPER_BENCH_T2RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}
