//! Ablation bench (DESIGN.md design-choice list): the thread-dispersed
//! locality-preserving scheduler vs interleaved and shared-queue
//! assignments — measuring JIT conflicts (APRAM sim, t=64) and real-thread
//! wall time, plus the block-granularity sweep (Skipper's only internal
//! constant).

mod common;

use skipper::apram::{simulate_skipper, SimConfig};
use skipper::coordinator::datasets::{generate_cached, spec_by_name};
use skipper::matching::skipper::Skipper;
use skipper::matching::MaximalMatcher;
use skipper::par::scheduler::Assignment;
use skipper::util::benchlib::{bench, BenchConfig, Table};

fn main() {
    let scale = common::bench_scale();
    let cache = common::cache_dir();
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_seconds: 4.0,
    };

    println!("— assignment policy ablation (conflicts from APRAM sim t=64; wall from real threads) —");
    let mut t = Table::new(&["dataset", "policy", "cnf edges", "cnf total", "wall t=4 (ms)"]);
    for name in ["g500s", "clueweb12s", "twitter10s"] {
        let spec = spec_by_name(name).unwrap();
        let g = generate_cached(spec, scale, &cache);
        for (policy, label) in [
            (Assignment::DispersedContiguous, "dispersed (paper)"),
            (Assignment::Interleaved, "interleaved"),
            (Assignment::SharedQueue, "shared-queue"),
        ] {
            // conflicts: virtual 64 threads with matching block layout
            let sim = simulate_skipper(&g, &SimConfig::new(64));
            let wall = bench(&format!("{name}/{label}"), &cfg, || {
                Skipper::new(4).with_assignment(policy).run(&g)
            });
            t.row(&[
                spec.paper_name.into(),
                label.into(),
                sim.conflicts.edges_with_conflicts.to_string(),
                sim.conflicts.total.to_string(),
                format!("{:.1}", wall.median_s * 1e3),
            ]);
        }
    }
    println!("{}", t.render());

    println!("— block granularity sweep (blocks per thread) —");
    let spec = spec_by_name("g500s").unwrap();
    let g = generate_cached(spec, scale, &cache);
    let mut t = Table::new(&["blocks/thread", "wall t=4 (ms)", "sim steals t=64"]);
    for bpt in [1usize, 4, 16, 64, 256] {
        let mut sk = Skipper::new(4);
        sk.blocks_per_thread = bpt;
        let wall = bench(&format!("bpt={bpt}"), &cfg, || sk.run(&g));
        let sim = simulate_skipper(
            &g,
            &SimConfig {
                threads: 64,
                blocks_per_thread: bpt,
                seed: 0xB1,
            },
        );
        t.row(&[
            bpt.to_string(),
            format!("{:.1}", wall.median_s * 1e3),
            sim.steals.to_string(),
        ]);
    }
    println!("{}", t.render());
}
