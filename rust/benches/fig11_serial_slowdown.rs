//! Bench: regenerate paper Fig 11 — serial slowdown of SIDMM and Skipper
//! relative to SGMM. Unlike the simulated parallel figures, every number
//! here is a REAL single-thread wall-clock measurement on this host,
//! repeated via the benchlib harness for stability.

mod common;

use skipper::coordinator::datasets::{generate_cached, SUITE};
use skipper::matching::ems::sidmm::Sidmm;
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::MaximalMatcher;
use skipper::util::benchlib::{bench, BenchConfig, Table};
use skipper::util::stats::geomean;

fn main() {
    let scale = common::bench_scale();
    let cache = common::cache_dir();
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_seconds: 5.0,
    };
    let mut t = Table::new(&["Dataset", "SGMM(s)", "SIDMM-1t(s)", "Skipper-1t(s)", "SIDMM slow", "Skipper slow"]);
    let (mut ss, mut ks) = (Vec::new(), Vec::new());
    for spec in &SUITE {
        let g = generate_cached(spec, scale, &cache);
        let sgmm = bench(&format!("sgmm/{}", spec.name), &cfg, || Sgmm.run(&g)).median_s;
        let sidmm = bench(&format!("sidmm/{}", spec.name), &cfg, || {
            Sidmm::default().run(&g)
        })
        .median_s;
        let skip = bench(&format!("skipper1t/{}", spec.name), &cfg, || {
            Skipper::new(1).run(&g)
        })
        .median_s;
        let s_slow = sidmm / sgmm;
        let k_slow = skip / sgmm;
        ss.push(s_slow);
        ks.push(k_slow);
        t.row(&[
            spec.paper_name.into(),
            format!("{sgmm:.4}"),
            format!("{sidmm:.4}"),
            format!("{skip:.4}"),
            format!("{s_slow:.1}"),
            format!("{k_slow:.2}"),
        ]);
    }
    println!(
        "Fig 11 — serial slowdown, measured (paper: SIDMM 7.3-16.8 gm 10.7, Skipper 1.1-2.2 gm 1.4)\n{}\ngeomeans: SIDMM {:.1}  Skipper {:.2}",
        t.render(),
        geomean(&ss).unwrap_or(f64::NAN),
        geomean(&ks).unwrap_or(f64::NAN)
    );
}
