//! Bench: regenerate paper Fig 7 — memory accesses per edge for SGMM,
//! SIDMM and Skipper (counting-probe instrumented runs).

mod common;

use skipper::coordinator::experiments::{collect_suite, fig7};

fn main() {
    let scale = common::bench_scale();
    let metrics = collect_suite(scale, &common::cache_dir(), 1);
    println!("{}", fig7(&metrics));
}
