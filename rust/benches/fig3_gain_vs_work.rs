//! Bench: regenerate paper Fig 3 — SIDMM's parallelization gain plotted
//! against its memory-access overhead relative to SGMM.

mod common;

use skipper::coordinator::calibrate::calibrate;
use skipper::coordinator::experiments::{collect_suite, fig3};

fn main() {
    let scale = common::bench_scale();
    let cost = calibrate();
    let metrics = collect_suite(scale, &common::cache_dir(), 1);
    println!("{}", fig3(&metrics, &cost));
}
