//! Bench: cross-layer comparison — the AOT XLA EMS matcher (L1 Pallas
//! kernel + L2 JAX while-loop, compiled HLO executed via PJRT) vs the L3
//! rust matchers on padded small graphs. Also reports per-call latency of
//! the compiled executable (compile-once, execute-many).

use skipper::coordinator::experiments::xla_ems;
use skipper::graph::gen::{rmat, GenConfig};
use skipper::runtime::XlaEmsMatcher;
use skipper::util::benchlib::{bench, BenchConfig};

fn main() {
    match xla_ems("data") {
        Ok(report) => println!("{report}"),
        Err(e) => {
            eprintln!("[xla_ems] SKIP: {e} (run `make artifacts`)");
            return;
        }
    }
    // per-call latency of the compiled executable (request-path cost)
    let matcher = XlaEmsMatcher::from_default_artifacts().expect("artifacts");
    let g = rmat::generate(&GenConfig { scale: 8, avg_degree: 3, seed: 9 });
    let exe = matcher
        .executable_for(g.num_vertices(), g.num_undirected_edges())
        .expect("variant");
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 10,
        max_seconds: 5.0,
    };
    let r = bench("xla-ems/execute-v256", &cfg, || exe.run_graph(&g).unwrap());
    println!("{}", r.row());
}
