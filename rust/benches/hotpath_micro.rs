//! Hot-path microbenches for the perf pass (§Perf in EXPERIMENTS.md):
//! - SGMM end-to-end throughput (edges/s),
//! - Skipper 1-thread end-to-end throughput,
//! - Skipper multi-thread wall,
//! - APRAM simulator throughput (simulated ops/s of the host),
//! - cache-simulator replay throughput.

mod common;

use skipper::apram::{simulate_skipper, SimConfig};
use skipper::cachesim::Hierarchy;
use skipper::coordinator::datasets::{generate_cached, spec_by_name};
use skipper::instrument::TracingProbe;
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::MaximalMatcher;
use skipper::util::benchlib::{bench, BenchConfig};

fn main() {
    let scale = common::bench_scale();
    let cache = common::cache_dir();
    let spec = spec_by_name("g500s").unwrap();
    let g = generate_cached(spec, scale, &cache);
    let slots = g.num_edge_slots() as f64;
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_seconds: 6.0,
    };

    let r = bench("sgmm/e2e", &cfg, || Sgmm.run(&g));
    println!("{}   ({:.1} M edge-slots/s)", r.row(), slots / r.median_s / 1e6);

    let r = bench("skipper-1t/e2e", &cfg, || Skipper::new(1).run(&g));
    println!("{}   ({:.1} M edge-slots/s)", r.row(), slots / r.median_s / 1e6);

    let r = bench("skipper-4t/e2e", &cfg, || Skipper::new(4).run(&g));
    println!("{}   ({:.1} M edge-slots/s)", r.row(), slots / r.median_s / 1e6);

    let r = bench("apram-sim-64t/e2e", &cfg, || {
        simulate_skipper(&g, &SimConfig::new(64))
    });
    let ops = simulate_skipper(&g, &SimConfig::new(64)).total_ops() as f64;
    println!("{}   ({:.1} M sim-ops/s)", r.row(), ops / r.median_s / 1e6);

    // cache sim replay throughput on an SGMM trace
    let mut trace = TracingProbe::default();
    let _ = Sgmm.run_probed(&g, &mut trace);
    let n_ev = trace.events.len() as f64;
    let r = bench("cachesim/replay-sgmm", &cfg, || Hierarchy::replay(&trace));
    println!("{}   ({:.1} M events/s)", r.row(), n_ev / r.median_s / 1e6);
}
