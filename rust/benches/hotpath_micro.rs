//! Hot-path microbenches for the perf pass (§Perf in EXPERIMENTS.md):
//! - SGMM end-to-end throughput (edges/s),
//! - Skipper 1-thread end-to-end throughput,
//! - Skipper multi-thread wall,
//! - APRAM simulator throughput (simulated ops/s of the host),
//! - cache-simulator replay throughput,
//! - adjacency layout sweep: flat vs blocked sidecar iteration wall and
//!   simulated L3 miss rate over an identically fragmented RMAT state,
//! - NUMA locality sweep: the blocked sidecar first-touched on each node
//!   while the sweep stays pinned to node 0 — local vs remote arena rows
//!   (single row + note on single-node hosts).

mod common;

use skipper::apram::{simulate_skipper, SimConfig};
use skipper::cachesim::{Geometry, Hierarchy};
use skipper::coordinator::datasets::{generate_cached, spec_by_name};
use skipper::dynamic::churn::ChurnGen;
use skipper::dynamic::{AdjLayout, DynamicAdjacency};
use skipper::instrument::{NoProbe, TracingProbe};
use skipper::matching::sgmm::Sgmm;
use skipper::matching::skipper::Skipper;
use skipper::matching::MaximalMatcher;
use skipper::par::topology::{self, Topology};
use skipper::util::benchlib::{bench, BenchConfig};

fn main() {
    let scale = common::bench_scale();
    let cache = common::cache_dir();
    let spec = spec_by_name("g500s").unwrap();
    let g = generate_cached(spec, scale, &cache);
    let slots = g.num_edge_slots() as f64;
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_seconds: 6.0,
    };

    let r = bench("sgmm/e2e", &cfg, || Sgmm.run(&g));
    println!("{}   ({:.1} M edge-slots/s)", r.row(), slots / r.median_s / 1e6);

    let r = bench("skipper-1t/e2e", &cfg, || Skipper::new(1).run(&g));
    println!("{}   ({:.1} M edge-slots/s)", r.row(), slots / r.median_s / 1e6);

    let r = bench("skipper-4t/e2e", &cfg, || Skipper::new(4).run(&g));
    println!("{}   ({:.1} M edge-slots/s)", r.row(), slots / r.median_s / 1e6);

    let r = bench("apram-sim-64t/e2e", &cfg, || {
        simulate_skipper(&g, &SimConfig::new(64))
    });
    let ops = simulate_skipper(&g, &SimConfig::new(64)).total_ops() as f64;
    println!("{}   ({:.1} M sim-ops/s)", r.row(), ops / r.median_s / 1e6);

    // cache sim replay throughput on an SGMM trace
    let mut trace = TracingProbe::default();
    let _ = Sgmm.run_probed(&g, &mut trace);
    let n_ev = trace.events.len() as f64;
    let r = bench("cachesim/replay-sgmm", &cfg, || Hierarchy::replay(&trace));
    println!("{}   ({:.1} M events/s)", r.row(), n_ev / r.median_s / 1e6);

    // adjacency layout sweep: same fragmented RMAT sidecar per layout —
    // full population inserted, every third edge deleted, half of those
    // re-inserted, leaving tombstones in the flat Vecs and recycled
    // blocks in the arena. Wall is the real iteration sweep; the L3
    // column replays the sweep's actual resident addresses (headers,
    // slot words, chain links) through the set-associative simulator
    // sized to the working set — the Fig-8 methodology applied to the
    // dynamic sidecar instead of the matchers.
    let adj_exp: u32 = match scale {
        skipper::coordinator::datasets::Scale::Tiny => 12,
        skipper::coordinator::datasets::Scale::Small => 15,
        skipper::coordinator::datasets::Scale::Medium => 18,
        skipper::coordinator::datasets::Scale::Large => 20,
    };
    let churn_gen = ChurnGen::Rmat { scale: adj_exp, avg_degree: 8 };
    let adj_n = churn_gen.num_vertices();
    let population = churn_gen.population(11);
    println!("adjacency layout sweep (fragmented rmat |V|={adj_n}, sweep wall + simulated L3):");
    for layout in [
        AdjLayout::Flat,
        AdjLayout::Blocked { block_bytes: 64 },
        AdjLayout::Blocked { block_bytes: 256 },
    ] {
        let mut adj = DynamicAdjacency::with_layout(adj_n, layout);
        for &(u, v) in &population {
            adj.insert(u, v);
        }
        for (i, &(u, v)) in population.iter().enumerate() {
            if i % 3 == 0 {
                adj.delete(u, v);
            }
        }
        for (i, &(u, v)) in population.iter().enumerate() {
            if i % 6 == 0 {
                adj.insert(u, v);
            }
        }
        let r = bench(&format!("adj-sweep/{}", layout.name()), &cfg, || {
            adj.probe_sweep(&mut NoProbe)
        });
        let mut trace = TracingProbe::default();
        let visited = adj.probe_sweep(&mut trace);
        let stats =
            Hierarchy::replay_with(&trace, Geometry::for_working_set(adj.memory_bytes()));
        println!(
            "{}   ({:.1} M half-edges/s, L3 miss {:.1}%, {:.1} MB resident)",
            r.row(),
            visited as f64 / r.median_s / 1e6,
            100.0 * stats.l3_miss_rate(),
            adj.memory_bytes() as f64 / 1e6,
        );
    }

    // NUMA locality sweep: the same fragmented blocked sidecar, but the
    // arena is allocated and first-touched on a chosen node's core while
    // the sweep runs pinned to node 0 — "local" rows touch memory on the
    // sweeping node, "remote" rows (only on multi-socket hosts) cross the
    // interconnect on every block. This is the microcosm of what the
    // engine's socket-local shard placement (`--pin`) avoids.
    let topo = Topology::discover();
    println!(
        "numa locality sweep ({} node(s), {} cpu(s); sweep pinned to node 0):",
        topo.num_nodes(),
        topo.num_cpus()
    );
    let sweep_cpu = topo.nodes.first().and_then(|node| node.cpus.first().copied());
    match sweep_cpu {
        Some(cpu) if topology::pin_current_thread(cpu) => {
            for node in &topo.nodes {
                let Some(&build_cpu) = node.cpus.first() else { continue };
                let n = adj_n;
                let population = population.clone();
                // allocate + first-touch the sidecar on the builder node
                let adj = std::thread::spawn(move || {
                    let _ = topology::pin_current_thread(build_cpu);
                    let mut adj =
                        DynamicAdjacency::with_layout(n, AdjLayout::Blocked { block_bytes: 64 });
                    for &(u, v) in &population {
                        adj.insert(u, v);
                    }
                    for (i, &(u, v)) in population.iter().enumerate() {
                        if i % 3 == 0 {
                            adj.delete(u, v);
                        }
                    }
                    adj
                })
                .join()
                .expect("builder thread");
                let locality = if node.id == topo.nodes[0].id { "local" } else { "remote" };
                let r = bench(&format!("adj-sweep/node{}-{locality}", node.id), &cfg, || {
                    adj.probe_sweep(&mut NoProbe)
                });
                let visited = adj.probe_sweep(&mut NoProbe);
                println!(
                    "{}   ({:.1} M half-edges/s, arena on node {})",
                    r.row(),
                    visited as f64 / r.median_s / 1e6,
                    node.id,
                );
            }
            let _ = topology::unpin_current_thread(&topo);
            if topo.num_nodes() == 1 {
                println!("  (single node: no remote rows — run on a multi-socket host for the cross-node delta)");
            }
        }
        _ => println!("  (pinning unavailable on this host: sweep skipped)"),
    }
}
