//! Bench: fully dynamic churn throughput and repair cost.
//!
//! Four questions, measured on an RMAT population at
//! `SKIPPER_BENCH_SCALE`-dependent size:
//!   1. insert-only epochs (the §V-C incremental regime) — updates/s,
//!   2. 50/50 insert/delete epochs — updates/s including repair sweeps,
//!   3. repair scaling — how repair work grows with the delete batch size
//!      (the sublinearity claim: fraction of live edges, not |E|),
//!   4. engine-shard scaling — the same 50/50 churn at P = 1/2/4/8 vertex
//!      shards under both dispatch policies (forked threads per epoch vs
//!      the persistent worker pool), reporting epoch throughput, the
//!      mutate-phase wall time, and its spawn-vs-run decomposition,
//!   5. small-epoch dispatch — tiny batches where the per-epoch spawn cost
//!      dominates: the regime the pool exists for, forked vs pooled mutate
//!      p50 side by side,
//!   6. adjacency layout sweep — the same 50/50 churn at P=8 pooled
//!      workers over flat per-vertex `Vec`s vs the cache-line block arena;
//!      set `SKIPPER_BENCH_RECORD_DIR` to also emit canonical
//!      `skipper-bench/v1` records for `skipper-cli report`,
//!   7. topology pinning sweep — the same 50/50 churn at P=8 pooled
//!      workers, pin policy the only variable: unpinned vs compact (pack
//!      one node first) vs spread (round-robin nodes), with socket-local
//!      first-touch arenas and huge-page-advised slabs; also records when
//!      `SKIPPER_BENCH_RECORD_DIR` is set.

mod common;

use skipper::coordinator::datasets::Scale;
use skipper::coordinator::registry;
use skipper::dynamic::churn::{run_churn, ChurnConfig, ChurnGen};
use skipper::dynamic::{
    AdjLayout, DynamicMatcher, PinPolicy, ShardExec, ShardedDynamicMatcher, Update,
};
use skipper::util::benchlib::{bench, BenchConfig};
use skipper::util::rng::Xoshiro256pp;
use skipper::util::stats::percentile;
use std::path::Path;

fn main() {
    let scale = common::bench_scale();
    let exp: u32 = match scale {
        Scale::Tiny => 12,
        Scale::Small => 15,
        Scale::Medium => 18,
        Scale::Large => 20,
    };
    let gen = ChurnGen::Rmat { scale: exp, avg_degree: 8 };
    let n = gen.num_vertices();
    let population = gen.population(7);
    eprintln!(
        "[dynamic_churn] rmat {}: |V|={n} population={} edges",
        scale.name(),
        population.len()
    );
    let cfg = BenchConfig { warmup_iters: 1, min_iters: 3, max_seconds: 8.0 };
    let threads = 4;
    let batch = 20_000.min(population.len() / 4).max(1);

    // 1. insert-only epochs over the whole population
    let r = bench("dynamic/insert-only-t4", &cfg, || {
        let mut m = DynamicMatcher::new(n, threads);
        for chunk in population.chunks(batch) {
            let ups: Vec<Update> = chunk.iter().map(|&(u, v)| Update::Insert(u, v)).collect();
            m.apply_epoch(&ups).expect("insert epoch");
        }
        m.matched_vertices()
    });
    println!(
        "{}  ({:.2} Mupdates/s)",
        r.row(),
        population.len() as f64 / r.median_s / 1e6
    );

    // 2. 50/50 churn epochs against a warm engine
    let mut warm = DynamicMatcher::new(n, threads);
    let warm_ups: Vec<Update> = population.iter().map(|&(u, v)| Update::Insert(u, v)).collect();
    warm.apply_epoch(&warm_ups).expect("warmup");
    let mut rng = Xoshiro256pp::new(99);
    let live: Vec<(u32, u32)> = warm.live_edge_iter().collect();
    let churn_epochs = 5usize;
    let r = bench("dynamic/churn-50-50-t4", &cfg, || {
        let mut total_repair = 0u64;
        for e in 0..churn_epochs {
            let mut ups: Vec<Update> = Vec::with_capacity(batch);
            for i in 0..batch / 2 {
                let (u, v) = live[(rng.next_usize(live.len()) + e + i) % live.len()];
                ups.push(Update::Delete(u, v));
                ups.push(Update::Insert(u, v));
            }
            let rep = warm.apply_epoch(&ups).expect("churn epoch");
            total_repair += rep.repair_edges as u64;
        }
        total_repair
    });
    println!(
        "{}  ({:.2} Mupdates/s)",
        r.row(),
        (churn_epochs * batch) as f64 / r.median_s / 1e6
    );

    // 3. repair scaling with delete-batch size
    println!("repair scaling (delete batch -> repair edges / live edges):");
    for del in [100usize, 1000, 10_000] {
        let mut m = DynamicMatcher::new(n, threads);
        m.apply_epoch(&warm_ups).expect("warmup");
        let live: Vec<(u32, u32)> = m.live_edge_iter().collect();
        let del = del.min(live.len());
        let ups: Vec<Update> = (0..del).map(|i| {
            let (u, v) = live[(i * 7919) % live.len()];
            Update::Delete(u, v)
        }).collect();
        let rep = m.apply_epoch(&ups).expect("delete epoch");
        println!(
            "  del={del:>6}: repair_edges={:>8} live={:>9} frac={:.5}",
            rep.repair_edges,
            rep.live_edges,
            rep.repair_fraction()
        );
    }

    // 4. engine-shard sweep: identical 50/50 churn at P = 1/2/4/8 under
    // both dispatch policies. The mutate column is the proof-of-refactor:
    // it is the phase that ran on one thread before vertex partitioning;
    // the run/spawn split shows what forking vs waking the workers costs.
    println!("engine-shard sweep (50/50 churn, batch={batch}, {churn_epochs} epochs/iter):");
    for shards in [1usize, 2, 4, 8] {
        for exec in [ShardExec::Fork, ShardExec::Pool] {
            let engine = ShardedDynamicMatcher::with_exec(n, threads, shards, exec);
            engine.apply_epoch(&warm_ups).expect("warmup");
            let live: Vec<(u32, u32)> = engine.live_edges();
            let mut rng = Xoshiro256pp::new(101);
            let mut epoch_s = Vec::new();
            let mut mutate_s = Vec::new();
            let mut run_s = Vec::new();
            let iters = 3usize;
            for e in 0..iters * churn_epochs {
                let mut ups: Vec<Update> = Vec::with_capacity(batch);
                for i in 0..batch / 2 {
                    let (u, v) = live[(rng.next_usize(live.len()) + e + i) % live.len()];
                    ups.push(Update::Delete(u, v));
                    ups.push(Update::Insert(u, v));
                }
                let rep = engine.apply_epoch(&ups).expect("churn epoch");
                epoch_s.push(rep.wall_s);
                mutate_s.push(rep.mutate_wall_s);
                run_s.push(rep.mutate_run_s);
            }
            let wall: f64 = epoch_s.iter().sum();
            let mutate: f64 = mutate_s.iter().sum();
            let run: f64 = run_s.iter().sum();
            let updates = (epoch_s.len() * batch) as f64;
            println!(
                "  P={shards} {:<4}: {:>7.2} Mupdates/s  epoch={:>8.2}ms  mutate={:>8.2}ms = run {:>7.2}ms + spawn {:>6.3}ms ({:>4.1}% of epoch)",
                exec.name(),
                updates / wall.max(1e-9) / 1e6,
                wall / epoch_s.len() as f64 * 1e3,
                mutate / mutate_s.len() as f64 * 1e3,
                run / run_s.len() as f64 * 1e3,
                (mutate - run).max(0.0) / mutate_s.len() as f64 * 1e3,
                100.0 * mutate / wall.max(1e-9),
            );
        }
    }

    // 5. small-epoch dispatch: the spawn-cost regime. Hundreds of tiny
    // epochs against a warm engine — mutate p50 under the forked baseline
    // vs the persistent pool is the headline number the pool exists to
    // improve ("measure first" per the ROADMAP: this IS the measurement).
    println!("small-epoch dispatch (tiny batches, P=4, mutate p50 forked vs pooled):");
    for small_batch in [16usize, 128, 1024] {
        let mut line = format!("  batch={small_batch:>5}:");
        for exec in [ShardExec::Fork, ShardExec::Pool] {
            let engine = ShardedDynamicMatcher::with_exec(n, threads, 4, exec);
            engine.apply_epoch(&warm_ups).expect("warmup");
            let live: Vec<(u32, u32)> = engine.live_edges();
            let mut rng = Xoshiro256pp::new(202);
            let mut mutate_s = Vec::new();
            let mut run_s = Vec::new();
            for e in 0..120 {
                let mut ups: Vec<Update> = Vec::with_capacity(small_batch);
                for i in 0..small_batch / 2 {
                    let (u, v) = live[(rng.next_usize(live.len()) + e + i) % live.len()];
                    ups.push(Update::Delete(u, v));
                    ups.push(Update::Insert(u, v));
                }
                let rep = engine.apply_epoch(&ups).expect("small epoch");
                mutate_s.push(rep.mutate_wall_s);
                run_s.push(rep.mutate_run_s);
            }
            let mutate_p50 = percentile(&mutate_s, 50.0);
            let run_p50 = percentile(&run_s, 50.0);
            line.push_str(&format!(
                "  {}: mutate p50={:>7.1}us (run {:>6.1}us, spawn {:>6.1}us)",
                exec.name(),
                mutate_p50 * 1e6,
                run_p50 * 1e6,
                (mutate_p50 - run_p50).max(0.0) * 1e6,
            ));
        }
        println!("{line}");
    }

    // 6. adjacency layout sweep: identical seeded 50/50 churn at P=8
    // pooled workers, storage layout the only variable — the deltas are
    // attributable to cache behaviour alone. With SKIPPER_BENCH_RECORD_DIR
    // set, each row also writes a canonical BENCH record so CI can publish
    // the trajectory and gate regressions via `skipper-cli report`.
    let record_dir = std::env::var("SKIPPER_BENCH_RECORD_DIR").ok();
    println!("adjacency layout sweep (50/50 churn, P=8 pool, batch={batch}):");
    for layout in [
        AdjLayout::Flat,
        AdjLayout::Blocked { block_bytes: 64 },
        AdjLayout::Blocked { block_bytes: 256 },
    ] {
        let ccfg = ChurnConfig {
            epochs: 3 * churn_epochs,
            batch,
            delete_frac: 0.5,
            warmup_epochs: 2,
            threads,
            engine_shards: 8,
            pool: true,
            layout,
            ..ChurnConfig::new(gen)
        };
        let summary = run_churn(&ccfg, |_| {}).expect("layout churn");
        let wall: f64 = summary.epoch_wall_s.iter().sum();
        let updates = (summary.epochs * ccfg.batch) as f64;
        println!(
            "  layout={:<10}: {:>7.2} Mupdates/s  epoch p50={:>8.2}ms  mutate p50={:>8.2}ms  adj={:>6.1}MB",
            layout.name(),
            updates / wall.max(1e-9) / 1e6,
            percentile(&summary.epoch_wall_s, 50.0) * 1e3,
            percentile(&summary.epoch_mutate_s, 50.0) * 1e3,
            summary.final_adjacency_bytes as f64 / 1e6,
        );
        if let Some(dir) = &record_dir {
            let rec = registry::churn_record(&ccfg, &summary);
            let path = Path::new(dir).join(format!("{}_{}.json", rec.bench, layout.name()));
            rec.write_file(&path).expect("bench record write");
            eprintln!("  recorded -> {}", path.display());
        }
    }

    // 7. topology pinning sweep: identical seeded 50/50 churn at P=8
    // pooled workers, pin policy the only variable. On a single-node host
    // (the CI runner) the rows measure pinning's overhead-free degradation;
    // on a multi-socket box the compact/spread deltas show what
    // socket-local first-touch placement buys. Final |M| is asserted
    // identical — placement must never change decisions.
    let topo = skipper::par::topology::Topology::discover();
    println!(
        "topology pinning sweep (50/50 churn, P=8 pool, batch={batch}, {} node(s)/{} cpu(s)):",
        topo.num_nodes(),
        topo.num_cpus()
    );
    let mut pin_finals = Vec::new();
    for pin in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Spread] {
        let ccfg = ChurnConfig {
            epochs: 3 * churn_epochs,
            batch,
            delete_frac: 0.5,
            warmup_epochs: 2,
            threads,
            engine_shards: 8,
            pool: true,
            pin,
            ..ChurnConfig::new(gen)
        };
        let summary = run_churn(&ccfg, |_| {}).expect("pin churn");
        let wall: f64 = summary.epoch_wall_s.iter().sum();
        let updates = (summary.epochs * ccfg.batch) as f64;
        pin_finals.push(summary.final_matched_vertices);
        println!(
            "  pin={:<8}: {:>7.2} Mupdates/s  epoch p50={:>8.2}ms  mutate p50={:>8.2}ms  |M|={}",
            pin.name(),
            updates / wall.max(1e-9) / 1e6,
            percentile(&summary.epoch_wall_s, 50.0) * 1e3,
            percentile(&summary.epoch_mutate_s, 50.0) * 1e3,
            summary.final_matched_vertices / 2,
        );
        if let Some(dir) = &record_dir {
            let rec = registry::churn_record(&ccfg, &summary);
            let path = Path::new(dir).join(format!("{}_pin_{}.json", rec.bench, pin.name()));
            rec.write_file(&path).expect("bench record write");
            eprintln!("  recorded -> {}", path.display());
        }
    }
    assert!(
        pin_finals.windows(2).all(|w| w[0] == w[1]),
        "pin policies diverged on the same schedule: {pin_finals:?}"
    );
}
