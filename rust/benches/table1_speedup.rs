//! Bench: regenerate paper Table I — Skipper vs SIDMM execution time and
//! speedup across the suite (SIDMM/Skipper at simulated t=64; cost model
//! calibrated on this host). `SKIPPER_BENCH_SCALE=small|medium` for the
//! full-size run recorded in EXPERIMENTS.md.

mod common;

use skipper::coordinator::calibrate::calibrate;
use skipper::coordinator::experiments::{collect_suite, table1};

fn main() {
    let scale = common::bench_scale();
    eprintln!("[table1] calibrating...");
    let cost = calibrate();
    eprintln!("[table1] collecting suite at {} scale...", scale.name());
    let metrics = collect_suite(scale, &common::cache_dir(), 1);
    println!("{}", table1(&metrics, &cost));
}
