//! Bench: durability costs on rmat-warmed engines.
//!
//! Four questions, at `SKIPPER_BENCH_SCALE`-dependent size:
//!   1. snapshot write and load+restore throughput — how fast the engine's
//!      durable state (live adjacency + matching) streams to and from disk,
//!   2. WAL append latency per churn epoch, buffered vs fsync vs grouped
//!      fsync (`Wal::append_epochs`, one `sync_data` per 4 epochs) — the
//!      price of the write-ahead guarantee on the flusher's critical path,
//!   3. cold crash recovery — snapshot restore + WAL replay + maximality
//!      audit, as a function of the replayed epoch count,
//!   4. replication ship throughput — epochs/s and payload MB/s from a
//!      `Shipper` to an acking follower over loopback, with the local WAL
//!      append buffered vs fsync'd on the publish path.
//!
//! With `SKIPPER_BENCH_RECORD_DIR=dir` set, the run additionally writes a
//! perf-registry candidate record (`dir/persist_rmat<scale>.json`) holding
//! every section's wall-clock metrics plus the WAL append/fsync latency
//! percentiles read back from the process-global metrics registry — the
//! same histograms a live `serve` exports over `METRICS` — and a second
//! record (`dir/ship_loopback.json`) carrying §4's replication throughput
//! alone, so the ship trajectory (`BENCH_ship_loopback.json`) gates
//! independently. Publish or gate them with `skipper-cli report`.

mod common;

use skipper::coordinator::datasets::Scale;
use skipper::coordinator::registry::BenchRecord;
use skipper::obs::metrics;
use std::collections::BTreeMap;
use skipper::dynamic::churn::{recycle_batch, ChurnGen};
use skipper::dynamic::{ShardedDynamicMatcher, Update};
use skipper::persist::recovery;
use skipper::persist::ship::{ShipReader, Shipper};
use skipper::persist::snapshot::{self, SnapshotData};
use skipper::persist::wal::{Wal, WalOptions};
use skipper::util::benchlib::{bench, BenchConfig};
use skipper::util::rng::Xoshiro256pp;
use skipper::util::stats::percentile;
use std::path::{Path, PathBuf};
use std::time::Instant;

fn fresh_dir(base: &Path, tag: &str) -> PathBuf {
    let dir = base.join(tag);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("bench dir");
    dir
}

fn main() {
    let scale = common::bench_scale();
    let exp: u32 = match scale {
        Scale::Tiny => 12,
        Scale::Small => 15,
        Scale::Medium => 18,
        Scale::Large => 20,
    };
    let gen = ChurnGen::Rmat { scale: exp, avg_degree: 8 };
    let n = gen.num_vertices();
    let population = gen.population(7);
    let base = std::env::temp_dir().join(format!("skipper_bench_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).expect("bench dir");
    eprintln!(
        "[persist] rmat {}: |V|={n} population={} edges",
        scale.name(),
        population.len()
    );
    let cfg = BenchConfig { warmup_iters: 1, min_iters: 3, max_seconds: 8.0 };
    let threads = 4;
    let record_dir = std::env::var("SKIPPER_BENCH_RECORD_DIR").ok();
    let mut met: BTreeMap<String, f64> = BTreeMap::new();

    // warm engine once; every section snapshots/logs this state
    let engine = ShardedDynamicMatcher::new(n, threads, 1);
    let warm_ups: Vec<Update> = population.iter().map(|&(u, v)| Update::Insert(u, v)).collect();
    engine.apply_epoch(&warm_ups).expect("warmup");
    let data = SnapshotData::capture(&engine);
    let live: Vec<(u32, u32)> = engine.live_edges();

    // 1a. snapshot write throughput
    let snap_dir = fresh_dir(&base, "snap");
    let path = snap_dir.join(snapshot::file_name(data.epoch));
    let mut bytes = 0u64;
    let r = bench("persist/snapshot-write", &cfg, || {
        bytes = snapshot::write_file(&path, &data).expect("snapshot write");
        bytes
    });
    println!(
        "{}  ({:.1} MB at {:.0} MB/s)",
        r.row(),
        bytes as f64 / 1e6,
        bytes as f64 / r.median_s / 1e6
    );
    met.insert("snapshot_write_s".to_string(), r.median_s);
    met.insert("snapshot_write_bytes_per_s".to_string(), bytes as f64 / r.median_s.max(1e-9));
    met.insert("snapshot_bytes".to_string(), bytes as f64);

    // 1b. snapshot load + exact-matching restore into a fresh engine
    let r = bench("persist/snapshot-load-restore", &cfg, || {
        let snap = snapshot::read_file(&path).expect("snapshot read");
        let fresh = ShardedDynamicMatcher::new(n, threads, 1);
        recovery::restore_into(&fresh, &snap).expect("restore");
        fresh.matched_vertices()
    });
    println!(
        "{}  ({:.0} MB/s)",
        r.row(),
        bytes as f64 / r.median_s / 1e6
    );
    met.insert("snapshot_load_restore_s".to_string(), r.median_s);

    // 2. WAL append latency per churn epoch, buffered vs fsync vs grouped
    // fsync (4 coalesced epochs per `sync_data` via `Wal::append_epochs`;
    // latency per epoch = group latency / 4, the flusher's amortised view).
    let batch = 4096.min(live.len()).max(2);
    let epochs = 64usize;
    for (tag, fsync, group) in
        [("buffered", false, 1usize), ("fsync", true, 1), ("fsync-grp4", true, 4)]
    {
        let dir = fresh_dir(&base, &format!("wal_{tag}"));
        let (mut wal, _) = Wal::open(&dir, WalOptions { fsync, ..WalOptions::default() })
            .expect("wal open");
        let mut rng = Xoshiro256pp::new(99);
        let mut lat_s = Vec::with_capacity(epochs);
        for g in 0..epochs / group {
            let batches: Vec<Vec<Update>> = (0..group)
                .map(|j| recycle_batch(&live, &mut rng, g * group + j, batch))
                .collect();
            let t0 = Instant::now();
            if group == 1 {
                wal.append_epoch(g as u64 + 1, &batches[0]).expect("wal append");
            } else {
                let recs: Vec<(u64, &[Update])> = batches
                    .iter()
                    .enumerate()
                    .map(|(j, b)| ((g * group + j) as u64 + 1, b.as_slice()))
                    .collect();
                wal.append_epochs(&recs).expect("wal group append");
            }
            lat_s.push(t0.elapsed().as_secs_f64() / group as f64);
        }
        println!(
            "persist/wal-append-{tag:<10} batch={batch}: p50={:>8.1}us/epoch  p99={:>8.1}us  ({:.1} MB logged)",
            percentile(&lat_s, 50.0) * 1e6,
            percentile(&lat_s, 99.0) * 1e6,
            wal.bytes_appended() as f64 / 1e6
        );
        met.insert(format!("wal_append_{tag}_p50_s"), percentile(&lat_s, 50.0));
        met.insert(format!("wal_append_{tag}_p99_s"), percentile(&lat_s, 99.0));
    }

    // the same latencies as the observability registry saw them: every
    // append above also recorded into the process-global histograms that a
    // live `serve` exports over METRICS, so the registry record carries the
    // full-history percentiles alongside the per-section medians
    for (metric, name) in [
        ("wal_append_hist", "skipper_wal_append_seconds"),
        ("wal_fsync_hist", "skipper_wal_fsync_seconds"),
    ] {
        let h = metrics::global().histogram_secs(name, "");
        if h.count() > 0 {
            met.insert(format!("{metric}_p50_s"), h.percentile(50.0) as f64 * 1e-9);
            met.insert(format!("{metric}_p99_s"), h.percentile(99.0) as f64 * 1e-9);
        }
    }

    // 3. cold recovery vs replayed WAL length
    for k in [4usize, 32] {
        let dir = fresh_dir(&base, &format!("recover_{k}"));
        let snap_dir = recovery::snapshot_dir(&dir);
        std::fs::create_dir_all(&snap_dir).expect("snap dir");
        snapshot::write_file(&snap_dir.join(snapshot::file_name(data.epoch)), &data)
            .expect("snapshot write");
        let (mut wal, _) =
            Wal::open(&recovery::wal_dir(&dir), WalOptions::default()).expect("wal open");
        let mut rng = Xoshiro256pp::new(7);
        for e in 0..k {
            let ups = recycle_batch(&live, &mut rng, e, batch);
            wal.append_epoch(data.epoch + e as u64 + 1, &ups).expect("wal append");
        }
        drop(wal);
        let r = bench(&format!("persist/recover-{k}-epochs"), &cfg, || {
            let fresh = ShardedDynamicMatcher::new(n, threads, 1);
            let (_, report) =
                recovery::recover(&fresh, &dir, WalOptions::default()).expect("recover");
            assert_eq!(report.replayed_epochs, k as u64);
            fresh.num_live_edges()
        });
        println!("{}", r.row());
        met.insert(format!("recover_{k}_epochs_s"), r.median_s);
    }
    // 4. replication ship throughput over loopback: a Shipper publishing
    // churn epochs, one raw ShipReader draining and acking them on its own
    // thread. Buffered vs per-epoch fsync of the local WAL on the publish
    // path — the flusher ships right after its local append, so the fsync
    // row is the replicated-commit rate a durable primary sustains.
    let ship_epochs = 64u64;
    // ship metrics also feed their own `ship_loopback` record (committed
    // as BENCH_ship_loopback.json) so the replication trajectory gates
    // independently of the snapshot/WAL/recovery sections
    let mut ship_met: BTreeMap<String, f64> = BTreeMap::new();
    if std::net::TcpListener::bind("127.0.0.1:0").is_ok() {
        for (tag, fsync) in [("buffered", false), ("fsync", true)] {
            let dir = fresh_dir(&base, &format!("ship_{tag}"));
            let (mut wal, _) = Wal::open(&dir, WalOptions { fsync, ..WalOptions::default() })
                .expect("wal open");
            let reg = metrics::Registry::new();
            let shipper = Shipper::bind("127.0.0.1:0", n, 0, &reg).expect("ship bind");
            let addr = shipper.local_addr().to_string();
            let consumer = std::thread::spawn(move || {
                let mut reader = ShipReader::connect(&addr, 0).expect("follow");
                let mut drained = 0u64;
                while let Some(frame) = reader.next_frame().expect("frame") {
                    reader.ack(frame.rec.epoch).expect("ack");
                    drained += 1;
                }
                drained
            });
            let mut rng = Xoshiro256pp::new(41);
            let t0 = Instant::now();
            for e in 0..ship_epochs {
                let ups = recycle_batch(&live, &mut rng, e as usize, batch);
                wal.append_epoch(e + 1, &ups).expect("wal append");
                shipper.publish(e + 1, &ups);
            }
            // the clock stops when the follower has acked the tip
            let deadline = Instant::now() + std::time::Duration::from_secs(30);
            while shipper.stats().acked < ship_epochs {
                assert!(Instant::now() < deadline, "follower never caught up");
                std::thread::yield_now();
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let shipped_bytes = shipper.stats().bytes_shipped;
            shipper.shutdown();
            let drained = consumer.join().expect("consumer");
            assert_eq!(drained, ship_epochs, "every published epoch must arrive");
            println!(
                "persist/ship-{tag:<9} batch={batch}: {:>8.0} epochs/s  {:>7.1} MB/s over loopback (acked)",
                ship_epochs as f64 / dt,
                shipped_bytes as f64 / dt / 1e6
            );
            met.insert(format!("ship_{tag}_epochs_per_s"), ship_epochs as f64 / dt);
            met.insert(format!("ship_{tag}_bytes_per_s"), shipped_bytes as f64 / dt);
            ship_met.insert(format!("ship_{tag}_epochs_per_s"), ship_epochs as f64 / dt);
            ship_met.insert(format!("ship_{tag}_bytes_per_s"), shipped_bytes as f64 / dt);
        }
    } else {
        eprintln!("[persist] skipping ship section: no loopback in this sandbox");
    }

    if let Some(dir) = record_dir {
        let dir = PathBuf::from(dir);
        std::fs::create_dir_all(&dir).expect("record dir");
        let mut config = BTreeMap::new();
        config.insert("workload".to_string(), "persist_bench".to_string());
        config.insert("scale".to_string(), scale.name().to_string());
        config.insert("n".to_string(), n.to_string());
        config.insert("threads".to_string(), threads.to_string());
        config.insert("batch".to_string(), batch.to_string());
        config.insert("epochs".to_string(), epochs.to_string());
        let rec = BenchRecord::new(format!("persist_rmat{exp}"), config, met);
        let path = dir.join(format!("persist_rmat{exp}.json"));
        rec.write_file(&path).expect("record write");
        println!(
            "recorded bench {} (config {}) -> {}; publish or gate it with `skipper-cli report`",
            rec.bench,
            rec.config_hash(),
            path.display()
        );
        if !ship_met.is_empty() {
            let mut config = BTreeMap::new();
            config.insert("workload".to_string(), "ship_loopback".to_string());
            config.insert("scale".to_string(), scale.name().to_string());
            config.insert("n".to_string(), n.to_string());
            config.insert("batch".to_string(), batch.to_string());
            config.insert("ship_epochs".to_string(), ship_epochs.to_string());
            let rec = BenchRecord::new("ship_loopback".to_string(), config, ship_met);
            let path = dir.join("ship_loopback.json");
            rec.write_file(&path).expect("record write");
            println!(
                "recorded bench {} (config {}) -> {}; publish or gate it with `skipper-cli report`",
                rec.bench,
                rec.config_hash(),
                path.display()
            );
        }
    }
    let _ = std::fs::remove_dir_all(&base);
}
