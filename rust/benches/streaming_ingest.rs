//! Bench: streaming ingest→match throughput vs the materialized-CSR path.
//!
//! Three measurements per suite graph (g500s at `SKIPPER_BENCH_SCALE`):
//!   1. CSR driver on the in-memory graph (the paper's configuration),
//!   2. streamed matching off the on-disk `.skg` (ingest overlaps matching),
//!   3. streamed matching at several chunk sizes (queue hand-off overhead).
//!
//! Also prints the peak topology-resident bytes of each mode — the
//! streaming pipeline's reason to exist.

mod common;

use skipper::coordinator::datasets::{generate_cached_path, spec_by_name};
use skipper::graph::stream::SkgEdgeSource;
use skipper::matching::skipper::Skipper;
use skipper::matching::streaming::StreamingSkipper;
use skipper::matching::MaximalMatcher;
use skipper::util::benchlib::{bench, BenchConfig};

fn main() {
    let scale = common::bench_scale();
    let cache = common::cache_dir();
    let spec = spec_by_name("g500s").unwrap();
    let (g, path) = generate_cached_path(spec, scale, &cache).expect("dataset cache");
    let slots = g.num_edge_slots() as f64;
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_seconds: 8.0,
    };

    eprintln!(
        "[streaming_ingest] g500s {}: |V|={} slots={} csr_bytes={}",
        scale.name(),
        g.num_vertices(),
        g.num_edge_slots(),
        g.memory_bytes()
    );

    let threads = 4;
    let r = bench("csr/skipper-t4", &cfg, || Skipper::new(threads).run(&g));
    println!("{}  ({:.1} Medges/s)", r.row(), slots / r.median_s / 1e6);

    let sk = StreamingSkipper::new(threads);
    let r = bench("stream/skg-t4", &cfg, || {
        sk.run(SkgEdgeSource::open(&path).expect("skg")).expect("stream")
    });
    println!("{}  ({:.1} Medges/s)", r.row(), slots / r.median_s / 1e6);

    for chunk in [1024usize, 4096, 16384, 65536] {
        let sk = StreamingSkipper::new(threads).with_chunk_edges(chunk);
        let name = format!("stream/skg-t4-chunk{chunk}");
        let r = bench(&name, &cfg, || {
            sk.run(SkgEdgeSource::open(&path).expect("skg")).expect("stream")
        });
        println!("{}  ({:.1} Medges/s)", r.row(), slots / r.median_s / 1e6);
    }

    let rep = StreamingSkipper::new(threads)
        .run(SkgEdgeSource::open(&path).expect("skg"))
        .expect("stream");
    println!(
        "memory: stream peak {} B (state {} + buffers {}) vs CSR {} B -> {:.1}x reduction",
        rep.peak_topology_bytes(),
        rep.state_bytes,
        rep.chunk_buffer_bytes,
        g.memory_bytes(),
        g.memory_bytes() as f64 / rep.peak_topology_bytes().max(1) as f64
    );
}
