//! Bench: regenerate paper Fig 8 — L3 misses relative to SGMM, via the
//! set-associative cache simulator replaying instrumented traces.

mod common;

use skipper::coordinator::experiments::{collect_suite, fig8};

fn main() {
    let scale = common::bench_scale();
    let metrics = collect_suite(scale, &common::cache_dir(), 1);
    println!("{}", fig8(&metrics));
}
