//! Bench: regenerate paper Table II — JIT conflict characteristics at
//! t=64 and t=16 (APRAM simulation, max-conflict run of N).

mod common;

use skipper::coordinator::experiments::{collect_suite, table2};

fn main() {
    let scale = common::bench_scale();
    eprintln!("[table2] collecting suite at {} scale...", scale.name());
    let metrics = collect_suite(scale, &common::cache_dir(), common::table2_runs());
    println!("{}", table2(&metrics));
}
