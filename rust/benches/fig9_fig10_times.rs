//! Bench: regenerate paper Figs 9 & 10 — absolute execution times and
//! parallelization gain of SGMM (measured), SIDMM and Skipper (simulated
//! t=64 via the calibrated cost model).

mod common;

use skipper::coordinator::calibrate::calibrate;
use skipper::coordinator::experiments::{collect_suite, fig10, fig9};

fn main() {
    let scale = common::bench_scale();
    let cost = calibrate();
    let metrics = collect_suite(scale, &common::cache_dir(), 1);
    println!("{}", fig9(&metrics, &cost));
    println!("{}", fig10(&metrics, &cost));
}
