//! Roofline-style cost model converting measured work (access counts,
//! cache-simulated miss counts, iteration counts) into simulated wall-clock
//! for `t`-thread executions on the paper's class of hardware.
//!
//! ```text
//! t_par = max(compute_term, bandwidth_term) + sync_term
//!   compute_term   = (accesses / t) · ns_per_access       — cores scale
//!   bandwidth_term = l3_misses · miss_penalty / mem_concurrency
//!                                                         — DRAM does not
//!   sync_term      = iterations · barrier_us              — EMS-only
//! ```
//!
//! This is exactly the effect the paper's §VI-D discusses: memory-bound
//! parallel algorithms do not scale with cores because channels and L3 are
//! shared. `ns_per_access` is calibrated against a real single-thread SGMM
//! run on the host (see `coordinator::calibrate`), so simulated absolute
//! times are anchored to measurements and *ratios* are driven by measured
//! work.

#[derive(Clone, Copy, Debug)]
/// Analytic cost model turning counted memory accesses + simulated L3
/// misses into seconds (the APRAM performance model of DESIGN.md §3).
pub struct CostModel {
    /// Cost of a cache-resident memory access (ns).
    pub ns_per_access: f64,
    /// Extra cost of an L3 miss → DRAM (ns).
    pub l3_miss_penalty_ns: f64,
    /// Sustained number of concurrent DRAM transactions the memory system
    /// serves (≈ channels × banks-level parallelism; 16 for 2×8-channel
    /// DDR5 per the paper's testbed).
    pub mem_concurrency: f64,
    /// Cost of one parallel-for barrier / iteration handoff (µs) — an
    /// OpenMP-class barrier across 64 threads on a 2-socket Xeon.
    pub barrier_us: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            ns_per_access: 1.0,
            l3_miss_penalty_ns: 80.0,
            mem_concurrency: 16.0,
            barrier_us: 10.0,
        }
    }
}

/// Work profile of one algorithm execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkProfile {
    /// Counted loads + stores.
    pub accesses: u64,
    /// Cache-simulated L3 misses.
    pub l3_misses: u64,
    /// Synchronized iterations (EMS algorithms); 0 for Skipper/SGMM.
    pub iterations: u64,
}

impl CostModel {
    /// Calibrate `ns_per_access` so that the model reproduces a measured
    /// sequential run: `seconds = accesses·ns + misses·penalty`.
    pub fn calibrated(measured_seconds: f64, profile: &WorkProfile) -> Self {
        let mut m = Self::default();
        let miss_ns = profile.l3_misses as f64 * m.l3_miss_penalty_ns * 1e-9;
        let remaining = (measured_seconds - miss_ns).max(measured_seconds * 0.1);
        if profile.accesses > 0 {
            m.ns_per_access = remaining / profile.accesses as f64 * 1e9;
        }
        m
    }

    /// Simulated sequential time (seconds).
    pub fn seq_seconds(&self, p: &WorkProfile) -> f64 {
        p.accesses as f64 * self.ns_per_access * 1e-9
            + p.l3_misses as f64 * self.l3_miss_penalty_ns * 1e-9
    }

    /// Simulated `t`-thread time (seconds), roofline of compute vs memory
    /// bandwidth plus synchronization.
    pub fn par_seconds(&self, p: &WorkProfile, threads: usize) -> f64 {
        let t = threads.max(1) as f64;
        let compute = p.accesses as f64 / t * self.ns_per_access * 1e-9;
        let bandwidth =
            p.l3_misses as f64 * self.l3_miss_penalty_ns / self.mem_concurrency.min(t) * 1e-9;
        let sync = p.iterations as f64 * self.barrier_us * 1e-6;
        compute.max(bandwidth) + sync
    }

    /// Simulated time for a Skipper virtual-thread run: the makespan is the
    /// maximum per-thread op count (threads run unsynchronized — APRAM), and
    /// memory bandwidth still bounds below.
    pub fn skipper_seconds(
        &self,
        makespan_ops: u64,
        total_l3_misses: u64,
        threads: usize,
    ) -> f64 {
        let t = threads.max(1) as f64;
        let compute = makespan_ops as f64 * self.ns_per_access * 1e-9;
        let bandwidth =
            total_l3_misses as f64 * self.l3_miss_penalty_ns / self.mem_concurrency.min(t) * 1e-9;
        compute.max(bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_roundtrips() {
        let p = WorkProfile { accesses: 1_000_000, l3_misses: 10_000, iterations: 0 };
        let m = CostModel::calibrated(0.5, &p);
        let t = m.seq_seconds(&p);
        assert!((t - 0.5).abs() / 0.5 < 1e-9, "calibrated {t}");
    }

    #[test]
    fn parallel_faster_than_sequential() {
        let m = CostModel::default();
        let p = WorkProfile { accesses: 100_000_000, l3_misses: 100_000, iterations: 0 };
        assert!(m.par_seconds(&p, 64) < m.seq_seconds(&p));
    }

    #[test]
    fn bandwidth_bound_limits_scaling() {
        // Miss-heavy profile: 64 threads gain little over 16 (the paper's
        // SIDMM non-scaling effect).
        let m = CostModel::default();
        let p = WorkProfile { accesses: 10_000_000, l3_misses: 8_000_000, iterations: 0 };
        let t16 = m.par_seconds(&p, 16);
        let t64 = m.par_seconds(&p, 64);
        assert!(t64 > t16 * 0.9, "t64 {t64} t16 {t16}");
    }

    #[test]
    fn sync_term_charges_iterations() {
        let m = CostModel::default();
        let a = WorkProfile { accesses: 1000, l3_misses: 0, iterations: 0 };
        let b = WorkProfile { accesses: 1000, l3_misses: 0, iterations: 100 };
        let diff = m.par_seconds(&b, 8) - m.par_seconds(&a, 8);
        assert!((diff - 100.0 * 10.0e-6).abs() < 1e-9);
    }

    #[test]
    fn skipper_time_uses_makespan() {
        let m = CostModel::default();
        let fast = m.skipper_seconds(1_000_000, 0, 64);
        let slow = m.skipper_seconds(2_000_000, 0, 64);
        assert!((slow / fast - 2.0).abs() < 1e-9);
    }

    #[test]
    fn negative_calibration_clamped() {
        // pathological: misses alone exceed the measured time
        let p = WorkProfile { accesses: 100, l3_misses: u64::MAX / 1000, iterations: 0 };
        let m = CostModel::calibrated(0.001, &p);
        assert!(m.ns_per_access > 0.0);
    }
}
