//! Virtual-thread execution of Skipper (Algorithm 1) under a seeded
//! interleaving scheduler.
//!
//! Each virtual thread owns a contiguous run of scheduler blocks (the
//! thread-dispersed locality-preserving assignment) and advances through a
//! five-phase per-edge state machine; one `step` ≈ one shared-memory
//! operation. The scheduler picks a random runnable thread per tick —
//! the APRAM assumption of no synchronized lockstep.

use crate::graph::CsrGraph;
use crate::instrument::conflicts::ConflictStats;
use crate::matching::skipper::{ACC, MCHD, RSVD};
use crate::matching::Matching;
use crate::par::scheduler::split_equal_edges;
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// APRAM simulation knobs (virtual threads, scheduler shape, seed).
pub struct SimConfig {
    /// Simulated (virtual) thread count.
    pub threads: usize,
    /// Scheduler blocks per virtual thread.
    pub blocks_per_thread: usize,
    /// Interleaving seed — every schedule is reproducible.
    pub seed: u64,
}

impl SimConfig {
    /// Default configuration for `threads` virtual threads.
    pub fn new(threads: usize) -> Self {
        Self {
            threads,
            blocks_per_thread: 16,
            seed: 0xA11CE,
        }
    }
}

#[derive(Debug)]
/// Outcome of one simulated Skipper run: the matching, conflict
/// telemetry, and per-virtual-thread operation counts.
pub struct SimReport {
    /// The computed maximal matching.
    pub matching: Matching,
    /// JIT-conflict telemetry across the simulated run.
    pub conflicts: ConflictStats,
    /// Shared-memory operations executed per virtual thread.
    pub per_thread_ops: Vec<u64>,
    /// Work-steal events between virtual threads.
    pub steals: u64,
}

impl SimReport {
    /// Simulated makespan: the maximum per-thread operation count.
    pub fn makespan_ops(&self) -> u64 {
        self.per_thread_ops.iter().copied().max().unwrap_or(0)
    }

    /// Total operations across all virtual threads.
    pub fn total_ops(&self) -> u64 {
        self.per_thread_ops.iter().sum()
    }

    /// Load balance: max/mean per-thread ops (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let total = self.total_ops();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.per_thread_ops.len() as f64;
        self.makespan_ops() as f64 / mean
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// Find the next edge to process (vertex iteration + vertex-level skip).
    NextEdge,
    /// Algorithm 1 line 10.
    CheckStates,
    /// Lines 11–12.
    TryReserve,
    /// Lines 13–16.
    TryMatch,
    /// Lines 17–18.
    Release,
}

struct VThread {
    cur_block: Option<(VertexId, VertexId)>,
    v: VertexId,
    /// Next neighbor index within v's list.
    ei: usize,
    /// True once v's state was checked on entry.
    v_entered: bool,
    phase: Phase,
    u: VertexId,
    w: VertexId,
    edge_conflicts: u64,
    ops: u64,
    done: bool,
}

/// Run the simulation. Deterministic given `cfg.seed`.
pub fn simulate_skipper(g: &CsrGraph, cfg: &SimConfig) -> SimReport {
    let t = cfg.threads.max(1);
    let blocks = split_equal_edges(g, t * cfg.blocks_per_thread.max(1));
    let nb = blocks.len();
    let per = nb.div_ceil(t);
    let mut cursors: Vec<usize> = (0..t).map(|tid| (tid * per).min(nb)).collect();
    let ranges: Vec<(usize, usize)> = (0..t)
        .map(|tid| ((tid * per).min(nb), ((tid + 1) * per).min(nb)))
        .collect();

    let mut state: Vec<u8> = vec![ACC; g.num_vertices()];
    let mut matches: Vec<(VertexId, VertexId)> = Vec::new();
    let mut conflicts = ConflictStats::default();
    let mut steals = 0u64;

    let mut threads: Vec<VThread> = (0..t)
        .map(|_tid| VThread {
            cur_block: None,
            v: 0,
            ei: 0,
            v_entered: false,
            phase: Phase::NextEdge,
            u: 0,
            w: 0,
            edge_conflicts: 0,
            ops: 0,
            done: false,
        })
        .collect();

    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut alive: Vec<usize> = (0..t).collect();

    while !alive.is_empty() {
        let pick = rng.next_usize(alive.len());
        let tid = alive[pick];
        step(
            g,
            &mut threads[tid],
            tid,
            &mut state,
            &mut matches,
            &mut conflicts,
            &mut cursors,
            &ranges,
            &blocks,
            &mut steals,
        );
        if threads[tid].done {
            alive.swap_remove(pick);
        }
    }

    SimReport {
        matching: Matching::from_pairs(matches),
        conflicts,
        per_thread_ops: threads.iter().map(|th| th.ops).collect(),
        steals,
    }
}

#[allow(clippy::too_many_arguments)]
fn step(
    g: &CsrGraph,
    th: &mut VThread,
    tid: usize,
    state: &mut [u8],
    matches: &mut Vec<(VertexId, VertexId)>,
    conflicts: &mut ConflictStats,
    cursors: &mut [usize],
    ranges: &[(usize, usize)],
    blocks: &[(VertexId, VertexId)],
    steals: &mut u64,
) {
    match th.phase {
        // One scheduler tick of NextEdge performs at most ONE shared-state
        // read (the vertex-entry state check); purely-local transitions
        // (block claims, vertex/edge cursor advances, self-loop skips,
        // immutable topology reads) are batched into the same tick — they
        // are invisible to other threads, so collapsing them preserves the
        // set of observable interleavings while speeding the simulation up
        // (§Perf).
        Phase::NextEdge => loop {
            // ensure we have a block
            let be = match th.cur_block {
                Some((_, be)) => be,
                None => match claim_block(tid, cursors, ranges, blocks, steals) {
                    Some(b) => {
                        th.cur_block = Some(b);
                        th.v = b.0;
                        th.ei = 0;
                        th.v_entered = false;
                        b.1
                    }
                    None => {
                        th.done = true;
                        return;
                    }
                },
            };
            if th.v >= be {
                th.cur_block = None;
                continue; // claim the next block within this tick
            }
            if !th.v_entered {
                // vertex-level skip: one SHARED state read -> ends the tick
                th.ops += 1;
                th.v_entered = true;
                th.ei = 0;
                if state[th.v as usize] == MCHD {
                    th.v += 1;
                    th.v_entered = false;
                }
                return;
            }
            let deg = g.degree(th.v);
            if th.ei >= deg {
                th.v += 1;
                th.v_entered = false;
                continue;
            }
            // fetch next neighbor: immutable topology read (charged as an
            // op for the cost model, but not a shared-state interaction)
            th.ops += 1;
            let y = g.neighbors(th.v)[th.ei];
            th.ei += 1;
            let x = th.v;
            if x == y {
                continue; // self-loop skipped (lines 6–7)
            }
            th.u = x.min(y);
            th.w = x.max(y);
            th.edge_conflicts = 0;
            th.phase = Phase::CheckStates;
            return;
        },
        Phase::CheckStates => {
            // line 10: two state reads
            th.ops += 2;
            if state[th.u as usize] == MCHD || state[th.w as usize] == MCHD {
                conflicts.record_edge(th.edge_conflicts);
                finish_edge(g, th, state);
            } else {
                th.phase = Phase::TryReserve;
            }
        }
        Phase::TryReserve => {
            // line 11: one CAS
            th.ops += 1;
            if state[th.u as usize] == ACC {
                state[th.u as usize] = RSVD;
                th.phase = Phase::TryMatch;
            } else {
                th.edge_conflicts += 1;
                th.phase = Phase::CheckStates;
            }
        }
        Phase::TryMatch => {
            // line 13 read; line 14 CAS when not MCHD
            th.ops += 1;
            match state[th.w as usize] {
                MCHD => th.phase = Phase::Release,
                ACC => {
                    th.ops += 1; // the CAS itself
                    state[th.w as usize] = MCHD;
                    state[th.u as usize] = MCHD; // line 15 (plain store)
                    th.ops += 1;
                    matches.push((th.u, th.w)); // line 16
                    conflicts.record_edge(th.edge_conflicts);
                    finish_edge(g, th, state);
                }
                _rsvd => {
                    th.ops += 1; // failed CAS
                    th.edge_conflicts += 1;
                    // spin: stay in TryMatch
                }
            }
        }
        Phase::Release => {
            // lines 17–18: plain store, back to line 10
            th.ops += 1;
            state[th.u as usize] = ACC;
            th.phase = Phase::CheckStates;
        }
    }
}

fn finish_edge(g: &CsrGraph, th: &mut VThread, state: &[u8]) {
    th.phase = Phase::NextEdge;
    // mid-list skip: if the current vertex just got matched, drop the rest
    // of its neighbor list (mirrors the real implementation).
    if state[th.v as usize] == MCHD {
        th.ei = g.degree(th.v);
    }
}

fn claim_block(
    tid: usize,
    cursors: &mut [usize],
    ranges: &[(usize, usize)],
    blocks: &[(VertexId, VertexId)],
    steals: &mut u64,
) -> Option<(VertexId, VertexId)> {
    let (_, hi) = ranges[tid];
    if cursors[tid] < hi {
        let b = blocks[cursors[tid]];
        cursors[tid] += 1;
        return Some(b);
    }
    // steal from the victim with the most remaining blocks
    let mut best: Option<(usize, usize)> = None;
    for v in 0..ranges.len() {
        if v == tid {
            continue;
        }
        let rem = ranges[v].1.saturating_sub(cursors[v]);
        if rem > 0 && best.map(|(_, r)| rem > r).unwrap_or(true) {
            best = Some((v, rem));
        }
    }
    let (victim, _) = best?;
    let b = blocks[cursors[victim]];
    cursors[victim] += 1;
    *steals += 1;
    Some(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{barabasi_albert, grid, rmat, simple, GenConfig};
    use crate::matching::{verify, MaximalMatcher};

    fn sim(g: &CsrGraph, t: usize, seed: u64) -> SimReport {
        simulate_skipper(g, &SimConfig { threads: t, blocks_per_thread: 8, seed })
    }

    #[test]
    fn produces_valid_maximal_matchings() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 1 });
        for t in [1, 4, 16, 64] {
            let r = sim(&g, t, 7);
            verify::check(&g, &r.matching).unwrap();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 2 });
        let a = sim(&g, 16, 5);
        let b = sim(&g, 16, 5);
        assert_eq!(a.matching.to_sorted_vec(), b.matching.to_sorted_vec());
        assert_eq!(a.conflicts, b.conflicts);
        assert_eq!(a.per_thread_ops, b.per_thread_ops);
    }

    #[test]
    fn single_thread_no_conflicts() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 3 });
        let r = sim(&g, 1, 1);
        assert_eq!(r.conflicts.total, 0);
    }

    #[test]
    fn conflicts_rare_and_decrease_with_fewer_threads() {
        // Paper Table II: conflicting edges ≪ |E|, and t=16 sees fewer
        // conflicts than t=64.
        let g = rmat::generate(&GenConfig { scale: 12, avg_degree: 8, seed: 4 });
        let r64 = sim(&g, 64, 9);
        let r16 = sim(&g, 16, 9);
        let ratio = r64.conflicts.edges_with_conflicts as f64 / g.num_edge_slots() as f64;
        assert!(ratio < 0.02, "conflict ratio {ratio}");
        assert!(
            r16.conflicts.total <= r64.conflicts.total,
            "t=16 {} > t=64 {}",
            r16.conflicts.total,
            r64.conflicts.total
        );
    }

    #[test]
    fn star_graph_conflicts_heavily() {
        // All edges share vertex 0 — the adversarial case where JIT
        // conflicts must appear and the matching still stays correct.
        let g = simple::star(2048);
        let r = sim(&g, 32, 11);
        verify::check(&g, &r.matching).unwrap();
        assert_eq!(r.matching.len(), 1);
    }

    #[test]
    fn high_locality_graph_low_conflicts() {
        // §V-B: the dispersed scheduler keeps threads in independent
        // neighborhoods on high-locality inputs.
        let g = grid::generate(128, 128, false);
        let r = sim(&g, 64, 13);
        verify::check(&g, &r.matching).unwrap();
        let ratio = r.conflicts.edges_with_conflicts as f64 / g.num_edge_slots() as f64;
        assert!(ratio < 0.01, "grid conflict ratio {ratio}");
    }

    #[test]
    fn work_is_balanced() {
        let g = barabasi_albert::generate(8192, 8, 5);
        let r = sim(&g, 16, 3);
        assert!(r.imbalance() < 1.6, "imbalance {}", r.imbalance());
    }

    #[test]
    fn total_ops_linear_in_edges() {
        // §V-B: expected total work O(|E| + |V|).
        let g = rmat::generate(&GenConfig { scale: 12, avg_degree: 8, seed: 6 });
        let r = sim(&g, 64, 2);
        let per_slot = r.total_ops() as f64 / g.num_edge_slots() as f64;
        assert!(per_slot < 6.0, "ops per edge slot {per_slot}");
    }

    #[test]
    fn matching_size_comparable_to_sgmm() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 7 });
        let s = crate::matching::sgmm::Sgmm.run(&g);
        let r = sim(&g, 64, 1);
        let ratio = r.matching.len() as f64 / s.len() as f64;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn stealing_engages_on_skewed_graphs() {
        let g = barabasi_albert::generate(4096, 16, 9);
        let r = sim(&g, 8, 2);
        // skewed degree distribution should force at least some steals
        assert!(r.steals > 0 || r.imbalance() < 1.2);
    }
}
