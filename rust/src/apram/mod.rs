//! APRAM virtual-thread simulator (DESIGN.md §3).
//!
//! The paper evaluates on 64 hardware threads; this sandbox has one core.
//! Real `std::thread` runs still validate correctness, but 64-thread
//! *behaviour* — JIT-conflict frequency (Table II), per-thread work balance,
//! and parallel makespan (Table I, Figs 9/10) — is reproduced here by
//! executing Skipper's per-edge state machine over `t` **virtual threads**
//! whose shared-memory operations are interleaved one at a time by a seeded
//! scheduler. CAS semantics are preserved exactly (the simulation is
//! sequential, so every step is atomic by construction), which makes the
//! conflict statistics faithful to the algorithm rather than to the host.
//!
//! [`cost`] converts op counts + cache-simulated miss rates into simulated
//! wall-clock via a roofline-style model calibrated against real
//! single-thread runs on this machine.

pub mod cost;
pub mod skipper_sim;

pub use skipper_sim::{simulate_skipper, SimConfig, SimReport};
