//! Epoch write-ahead log: length-prefixed, CRC-checked records in
//! append-only segment files with rotation and torn-tail truncation.
//!
//! ## On-disk format
//!
//! A WAL directory holds segment files `wal-<seq:08>.log`, written and
//! replayed in `seq` order. Each segment is
//!
//! ```text
//! magic "SKPWAL01"                                   (8 bytes)
//! record*     where record =
//!   payload_len: u32 LE | crc32(payload): u32 LE | payload
//! payload =
//!   epoch: u64 LE | count: u32 LE | count × (op: u8, u: u32 LE, v: u32 LE)
//! ```
//!
//! `op` is 0 for insert, 1 for delete. Everything is little-endian, the
//! conventions of [`crate::graph::io::binary`].
//!
//! ## Crash behavior
//!
//! A crash mid-append leaves a *torn tail*: a trailing record whose prefix,
//! payload, or CRC is incomplete. [`Wal::open`] scans every segment; a torn
//! tail is legal only in the newest segment, where it is physically
//! truncated away before appending resumes (invariant: everything after
//! `open` returns is a valid record prefix of what was written). A
//! corrupt record in an *older* segment means lost history and fails the
//! open loudly rather than silently replaying a gapped log.

use super::crc32;
use crate::dynamic::Update;
use crate::obs::{metrics, trace};
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// Per-segment magic, first 8 bytes of every WAL segment file.
pub const WAL_MAGIC: &[u8; 8] = b"SKPWAL01";

/// Hard cap on one record's payload — anything larger is treated as tail
/// corruption rather than an allocation request.
const MAX_PAYLOAD_BYTES: u32 = 1 << 28;

/// Tuning knobs for a [`Wal`].
#[derive(Clone, Copy, Debug)]
pub struct WalOptions {
    /// `fsync` after every appended record (durable against power loss;
    /// without it records are flushed to the OS but not forced to media).
    pub fsync: bool,
    /// Rotate to a fresh segment once the active one exceeds this size.
    pub segment_bytes: u64,
}

impl Default for WalOptions {
    fn default() -> Self {
        Self { fsync: false, segment_bytes: 8 << 20 }
    }
}

/// One replayable WAL record: an epoch's update batch in arrival order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WalEpoch {
    /// The engine epoch number this batch was applied as.
    pub epoch: u64,
    /// The batch, in arrival order.
    pub updates: Vec<Update>,
}

/// Bookkeeping for one segment file.
#[derive(Clone, Debug)]
struct Segment {
    seq: u64,
    path: PathBuf,
    /// Records stored (0 = header only).
    records: u64,
    /// Highest epoch stored (meaningless when `records == 0`).
    last_epoch: u64,
    /// Valid bytes (header + records).
    bytes: u64,
}

/// Append-only epoch log over a directory of rotated segment files. See
/// the module docs for the format and crash semantics.
pub struct Wal {
    dir: PathBuf,
    opts: WalOptions,
    /// Older, immutable segments (rotation targets for pruning).
    closed: Vec<Segment>,
    active: Segment,
    writer: BufWriter<File>,
    epochs_appended: u64,
    bytes_appended: u64,
    /// Append / fsync latency histograms, registered once at open against
    /// the global metrics registry (shared by every `Wal` in the process).
    append_hist: Arc<metrics::Histogram>,
    fsync_hist: Arc<metrics::Histogram>,
}

fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:08}.log"))
}

/// Outcome of scanning one segment: full-segment bookkeeping (every valid
/// record counts, whether or not it was handed to the sink), the byte
/// length of the valid prefix, and whether a torn tail follows it.
struct Scan {
    records: u64,
    last_epoch: u64,
    valid_bytes: u64,
    torn: bool,
}

impl Scan {
    fn cut(self, torn: bool) -> Self {
        Self { torn, ..self }
    }
}

/// Scan one segment, validating every record and handing those with
/// `epoch > floor` to `sink` one at a time — nothing is buffered, so a
/// long log never has to fit in memory; a sink error aborts the scan.
fn scan_segment(
    path: &Path,
    floor: u64,
    sink: &mut dyn FnMut(WalEpoch) -> Result<(), String>,
) -> Result<Scan, String> {
    let mut scan = Scan {
        records: 0,
        last_epoch: 0,
        valid_bytes: 0,
        torn: false,
    };
    let mut f = File::open(path).map_err(|e| format!("open {}: {e}", path.display()))?;
    let mut magic = [0u8; 8];
    match f.read_exact(&mut magic) {
        Ok(()) if &magic == WAL_MAGIC => {}
        // short or wrong header: the whole file is a torn tail
        _ => return Ok(scan.cut(true)),
    }
    scan.valid_bytes = 8;
    let mut prefix = [0u8; 8];
    loop {
        // record prefix: len + crc
        match read_exact_or_eof(&mut f, &mut prefix) {
            ReadOutcome::Eof => return Ok(scan),
            ReadOutcome::Partial => return Ok(scan.cut(true)),
            ReadOutcome::Full => {}
        }
        let len = u32::from_le_bytes(prefix[0..4].try_into().unwrap());
        let crc = u32::from_le_bytes(prefix[4..8].try_into().unwrap());
        if len > MAX_PAYLOAD_BYTES {
            return Ok(scan.cut(true));
        }
        let mut payload = vec![0u8; len as usize];
        match read_exact_or_eof(&mut f, &mut payload) {
            ReadOutcome::Full => {}
            _ => return Ok(scan.cut(true)),
        }
        if crc32(&payload) != crc {
            return Ok(scan.cut(true));
        }
        match decode_payload(&payload) {
            Some(rec) => {
                scan.records += 1;
                scan.last_epoch = rec.epoch;
                if rec.epoch > floor {
                    sink(rec)?;
                }
            }
            None => return Ok(scan.cut(true)),
        }
        scan.valid_bytes += 8 + len as u64;
    }
}

enum ReadOutcome {
    Full,
    Eof,
    Partial,
}

fn read_exact_or_eof(f: &mut File, buf: &mut [u8]) -> ReadOutcome {
    let mut got = 0usize;
    while got < buf.len() {
        match f.read(&mut buf[got..]) {
            Ok(0) => return if got == 0 { ReadOutcome::Eof } else { ReadOutcome::Partial },
            Ok(n) => got += n,
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return ReadOutcome::Partial,
        }
    }
    ReadOutcome::Full
}

/// Encode one epoch batch as a WAL record payload (the bytes covered by
/// the record CRC). Shared with the replication shipper, whose stream
/// frames carry exactly this encoding so followers replay what a local
/// recovery would.
pub(crate) fn encode_payload(epoch: u64, updates: &[Update]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(12 + 9 * updates.len());
    buf.extend_from_slice(&epoch.to_le_bytes());
    buf.extend_from_slice(&(updates.len() as u32).to_le_bytes());
    for &u in updates {
        let (op, a, b) = match u {
            Update::Insert(a, b) => (0u8, a, b),
            Update::Delete(a, b) => (1u8, a, b),
        };
        buf.push(op);
        buf.extend_from_slice(&a.to_le_bytes());
        buf.extend_from_slice(&b.to_le_bytes());
    }
    buf
}

/// Decode a WAL record payload back into its epoch batch; `None` means
/// the bytes are not a well-formed record (wrong length arithmetic or an
/// unknown op byte). The inverse of [`encode_payload`].
pub(crate) fn decode_payload(payload: &[u8]) -> Option<WalEpoch> {
    if payload.len() < 12 {
        return None;
    }
    let epoch = u64::from_le_bytes(payload[0..8].try_into().unwrap());
    let count = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
    if payload.len() != 12 + 9 * count {
        return None;
    }
    let mut updates = Vec::with_capacity(count);
    for i in 0..count {
        let off = 12 + 9 * i;
        let op = payload[off];
        let a = u32::from_le_bytes(payload[off + 1..off + 5].try_into().unwrap());
        let b = u32::from_le_bytes(payload[off + 5..off + 9].try_into().unwrap());
        updates.push(match op {
            0 => Update::Insert(a, b),
            1 => Update::Delete(a, b),
            _ => return None,
        });
    }
    Some(WalEpoch { epoch, updates })
}

impl Wal {
    /// Open (or create) the WAL in `dir`: scan every segment in `seq`
    /// order, truncate a torn tail off the newest one, position for
    /// appending, and return every valid record for replay. Convenient for
    /// tests and tools; recovery uses [`open_replaying`](Self::open_replaying)
    /// so a long log is never buffered whole.
    pub fn open(dir: &Path, opts: WalOptions) -> Result<(Wal, Vec<WalEpoch>), String> {
        let mut all = Vec::new();
        let wal = Self::open_replaying(dir, opts, 0, &mut |rec| {
            all.push(rec);
            Ok(())
        })?;
        Ok((wal, all))
    }

    /// Like [`open`](Self::open), but streams each valid record with
    /// `epoch > replay_floor` into `sink` as it is scanned, one at a time —
    /// recovery applies epochs straight from the scan, so replay memory is
    /// one record, not the whole log. Records at or below the floor are
    /// still CRC-validated (they count for torn-tail detection and segment
    /// bookkeeping) but never materialized. A sink error aborts the open.
    pub fn open_replaying(
        dir: &Path,
        opts: WalOptions,
        replay_floor: u64,
        sink: &mut dyn FnMut(WalEpoch) -> Result<(), String>,
    ) -> Result<Wal, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
        let mut seqs: Vec<u64> = Vec::new();
        let entries =
            std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
        for entry in entries {
            let entry = entry.map_err(|e| format!("read {}: {e}", dir.display()))?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(seq) = name
                .strip_prefix("wal-")
                .and_then(|s| s.strip_suffix(".log"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                seqs.push(seq);
            }
        }
        seqs.sort_unstable();

        let mut closed: Vec<Segment> = Vec::new();
        let mut active: Option<Segment> = None;
        for (i, &seq) in seqs.iter().enumerate() {
            let path = segment_path(dir, seq);
            let scan = scan_segment(&path, replay_floor, sink)?;
            let last = i + 1 == seqs.len();
            if scan.torn && !last {
                return Err(format!(
                    "wal segment {} is corrupt mid-log (not the newest segment); refusing to replay a gapped history",
                    path.display()
                ));
            }
            let seg = Segment {
                seq,
                path: path.clone(),
                records: scan.records,
                last_epoch: scan.last_epoch,
                bytes: scan.valid_bytes.max(8),
            };
            if last {
                if scan.torn {
                    // physically drop the torn tail so appends resume on a
                    // clean record boundary
                    // valid_bytes is 0 for a bad/short header: cut to zero
                    // so the header gets rewritten below
                    let f = OpenOptions::new()
                        .write(true)
                        .open(&path)
                        .map_err(|e| format!("truncate {}: {e}", path.display()))?;
                    f.set_len(scan.valid_bytes)
                        .map_err(|e| format!("truncate {}: {e}", path.display()))?;
                    f.sync_all().ok();
                }
                active = Some(seg);
            } else {
                closed.push(seg);
            }
        }

        let active = match active {
            Some(seg) => seg,
            None => create_segment(dir, 1)?,
        };
        let mut file = OpenOptions::new()
            .write(true)
            .open(&active.path)
            .map_err(|e| format!("open {}: {e}", active.path.display()))?;
        // a fresh scan-derived segment may have had a missing/short header
        // (valid_bytes clamped to 8 above): rewrite it so appends land on a
        // well-formed file
        if file
            .metadata()
            .map_err(|e| format!("stat {}: {e}", active.path.display()))?
            .len()
            < 8
        {
            file.set_len(0).map_err(|e| e.to_string())?;
            file.write_all(WAL_MAGIC).map_err(|e| e.to_string())?;
            file.sync_all().ok();
        }
        file.seek(SeekFrom::Start(active.bytes))
            .map_err(|e| format!("seek {}: {e}", active.path.display()))?;
        let reg = metrics::global();
        Ok(Wal {
            dir: dir.to_path_buf(),
            opts,
            closed,
            active,
            writer: BufWriter::new(file),
            epochs_appended: 0,
            bytes_appended: 0,
            append_hist: reg.histogram_secs(
                "skipper_wal_append_seconds",
                "WAL record encode+write+flush latency (excluding fsync)",
            ),
            fsync_hist: reg.histogram_secs(
                "skipper_wal_fsync_seconds",
                "WAL sync_data latency (only recorded when fsync is on)",
            ),
        })
    }

    /// Append one epoch record (rotating segments as configured), flush it
    /// to the OS, and `fsync` when the options demand. Returns the bytes
    /// this record occupies on disk. Batches whose encoding exceeds the
    /// scanner's record cap (~29.8M updates) are rejected up front — a
    /// record the next open would classify as a torn tail must never be
    /// written, let alone acknowledged.
    pub fn append_epoch(&mut self, epoch: u64, updates: &[Update]) -> Result<u64, String> {
        let bytes = self.append_record(epoch, updates)?;
        self.sync_if_configured()?;
        Ok(bytes)
    }

    /// Append several epoch records as one durable **group**: every record
    /// is written and flushed to the OS, then a *single* `sync_data` covers
    /// the whole batch (when the options demand fsync at all). The per-call
    /// `sync_data` is the dominant cost of `--fsync` — hundreds of
    /// microseconds to milliseconds of device round-trip per record —
    /// so a flusher that coalesces `k` epochs amortizes it `k`-fold while
    /// keeping the same guarantee *for the group*: after this returns, all
    /// `k` epochs are on media; a crash mid-call can lose the tail of the
    /// group (torn or unsynced records), never a prefix-gap. Returns the
    /// total bytes appended.
    pub fn append_epochs(&mut self, batch: &[(u64, &[Update])]) -> Result<u64, String> {
        if batch.is_empty() {
            return Ok(0);
        }
        let mut total = 0u64;
        for &(epoch, updates) in batch {
            total += self.append_record(epoch, updates)?;
        }
        self.sync_if_configured()?;
        Ok(total)
    }

    /// Write + OS-flush one record without forcing it to media — the shared
    /// body of [`append_epoch`](Self::append_epoch) (which syncs per
    /// record) and [`append_epochs`](Self::append_epochs) (which syncs per
    /// group).
    fn append_record(&mut self, epoch: u64, updates: &[Update]) -> Result<u64, String> {
        let t_obs = Instant::now();
        let mut span = trace::span_epoch("wal_append", "wal", epoch, 0);
        let payload_len = 12u64 + 9 * updates.len() as u64;
        if payload_len > MAX_PAYLOAD_BYTES as u64 {
            return Err(format!(
                "epoch {epoch} batch of {} updates encodes to {payload_len} bytes, above the \
                 {MAX_PAYLOAD_BYTES}-byte record cap the scanner accepts — refusing to write a \
                 record the next open would truncate as a torn tail",
                updates.len()
            ));
        }
        if self.active.bytes >= self.opts.segment_bytes {
            self.rotate()?;
        }
        debug_assert!(
            self.active.records == 0 || epoch > self.active.last_epoch,
            "wal epochs must be appended in increasing order"
        );
        let payload = encode_payload(epoch, updates);
        let crc = crc32(&payload);
        self.writer
            .write_all(&(payload.len() as u32).to_le_bytes())
            .and_then(|_| self.writer.write_all(&crc.to_le_bytes()))
            .and_then(|_| self.writer.write_all(&payload))
            .and_then(|_| self.writer.flush())
            .map_err(|e| format!("wal append: {e}"))?;
        let bytes = 8 + payload.len() as u64;
        self.active.bytes += bytes;
        self.active.records += 1;
        self.active.last_epoch = epoch;
        self.epochs_appended += 1;
        self.bytes_appended += bytes;
        if let Some(s) = span.as_mut() {
            s.set_arg(bytes);
        }
        self.append_hist.record_duration(t_obs.elapsed());
        Ok(bytes)
    }

    /// `sync_data` the active segment when the options demand fsync.
    fn sync_if_configured(&mut self) -> Result<(), String> {
        if self.opts.fsync {
            let t_obs = Instant::now();
            let _span = trace::span("wal_fsync", "wal", 0);
            self.writer
                .get_ref()
                .sync_data()
                .map_err(|e| format!("wal fsync: {e}"))?;
            self.fsync_hist.record_duration(t_obs.elapsed());
        }
        Ok(())
    }

    /// Close the active segment and start a fresh one.
    fn rotate(&mut self) -> Result<(), String> {
        self.writer.flush().map_err(|e| format!("wal rotate: {e}"))?;
        self.writer.get_ref().sync_data().ok();
        let next = create_segment(&self.dir, self.active.seq + 1)?;
        let mut file = OpenOptions::new()
            .write(true)
            .open(&next.path)
            .map_err(|e| format!("open {}: {e}", next.path.display()))?;
        file.seek(SeekFrom::Start(next.bytes))
            .map_err(|e| format!("seek {}: {e}", next.path.display()))?;
        let prev = std::mem::replace(&mut self.active, next);
        self.closed.push(prev);
        self.writer = BufWriter::new(file);
        Ok(())
    }

    /// Delete segments entirely covered by a snapshot at `snapshot_epoch`
    /// (their last record's epoch is ≤ it). If the *active* segment is
    /// fully covered it is rotated out first, so the WAL is left holding
    /// exactly the epochs a recovery would still need.
    pub fn prune_below(&mut self, snapshot_epoch: u64) {
        if self.active.records > 0 && self.active.last_epoch <= snapshot_epoch {
            if let Err(e) = self.rotate() {
                eprintln!("wal prune: rotate failed: {e}");
                return;
            }
        }
        self.closed.retain(|seg| {
            let covered = seg.records == 0 || seg.last_epoch <= snapshot_epoch;
            if covered {
                if let Err(e) = std::fs::remove_file(&seg.path) {
                    eprintln!("wal prune: remove {}: {e}", seg.path.display());
                }
            }
            !covered
        });
    }

    /// Epoch records appended since this handle was opened.
    #[inline]
    pub fn epochs_appended(&self) -> u64 {
        self.epochs_appended
    }

    /// Bytes appended since this handle was opened.
    #[inline]
    pub fn bytes_appended(&self) -> u64 {
        self.bytes_appended
    }

    /// Segment files currently on disk (closed + active).
    #[inline]
    pub fn num_segments(&self) -> usize {
        self.closed.len() + 1
    }
}

fn create_segment(dir: &Path, seq: u64) -> Result<Segment, String> {
    let path = segment_path(dir, seq);
    let mut f = File::create(&path).map_err(|e| format!("create {}: {e}", path.display()))?;
    f.write_all(WAL_MAGIC)
        .map_err(|e| format!("write {}: {e}", path.display()))?;
    f.sync_all().ok();
    Ok(Segment { seq, path, records: 0, last_epoch: 0, bytes: 8 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_ID: AtomicU64 = AtomicU64::new(0);

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "skipper_wal_{}_{}_{}",
            std::process::id(),
            tag,
            DIR_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn batch(epoch: u64) -> Vec<Update> {
        vec![
            Update::Insert(epoch as u32, epoch as u32 + 1),
            Update::Delete(epoch as u32 + 2, epoch as u32 + 3),
        ]
    }

    #[test]
    fn append_reopen_replays_everything_in_order() {
        let dir = fresh_dir("roundtrip");
        {
            let (mut wal, existing) = Wal::open(&dir, WalOptions::default()).unwrap();
            assert!(existing.is_empty());
            for e in 1..=10u64 {
                wal.append_epoch(e, &batch(e)).unwrap();
            }
            assert_eq!(wal.epochs_appended(), 10);
        } // dropped without any shutdown ceremony — the crash model
        let (_, replay) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(replay.len(), 10);
        for (i, rec) in replay.iter().enumerate() {
            assert_eq!(rec.epoch, i as u64 + 1);
            assert_eq!(rec.updates, batch(rec.epoch));
        }
    }

    #[test]
    fn torn_tail_is_truncated_and_appends_resume() {
        let dir = fresh_dir("torn");
        {
            let (mut wal, _) = Wal::open(&dir, WalOptions::default()).unwrap();
            for e in 1..=5u64 {
                wal.append_epoch(e, &batch(e)).unwrap();
            }
        }
        // chop bytes off the tail: the last record becomes torn
        let seg = segment_path(&dir, 1);
        let len = std::fs::metadata(&seg).unwrap().len();
        let f = OpenOptions::new().write(true).open(&seg).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);
        let (mut wal, replay) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(replay.len(), 4, "torn record 5 dropped");
        assert_eq!(replay.last().unwrap().epoch, 4);
        // appends resume cleanly after the truncation point
        wal.append_epoch(5, &batch(5)).unwrap();
        drop(wal);
        let (_, replay) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(replay.len(), 5);
        assert_eq!(replay.last().unwrap().epoch, 5);
    }

    #[test]
    fn corrupted_crc_cuts_the_tail() {
        let dir = fresh_dir("crc");
        {
            let (mut wal, _) = Wal::open(&dir, WalOptions::default()).unwrap();
            for e in 1..=3u64 {
                wal.append_epoch(e, &batch(e)).unwrap();
            }
        }
        // flip one payload byte of the last record
        let seg = segment_path(&dir, 1);
        let mut bytes = std::fs::read(&seg).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&seg, &bytes).unwrap();
        let (_, replay) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(replay.len(), 2, "record with bad CRC rejected");
    }

    #[test]
    fn rotation_spans_segments_and_prune_drops_covered_ones() {
        let dir = fresh_dir("rotate");
        let opts = WalOptions { segment_bytes: 128, ..WalOptions::default() };
        let (mut wal, _) = Wal::open(&dir, opts).unwrap();
        for e in 1..=20u64 {
            wal.append_epoch(e, &batch(e)).unwrap();
        }
        assert!(wal.num_segments() > 1, "tiny segment limit must rotate");
        drop(wal);
        let (mut wal, replay) = Wal::open(&dir, opts).unwrap();
        assert_eq!(replay.len(), 20, "replay crosses segment boundaries");
        // a snapshot at epoch 20 covers everything, active segment included
        wal.prune_below(20);
        drop(wal);
        let (_, replay) = Wal::open(&dir, opts).unwrap();
        assert!(replay.is_empty(), "fully covered log replays nothing");
    }

    #[test]
    fn prune_keeps_uncovered_epochs() {
        let dir = fresh_dir("prune_partial");
        let opts = WalOptions { segment_bytes: 64, ..WalOptions::default() };
        let (mut wal, _) = Wal::open(&dir, opts).unwrap();
        for e in 1..=12u64 {
            wal.append_epoch(e, &batch(e)).unwrap();
        }
        wal.prune_below(6);
        drop(wal);
        let (_, replay) = Wal::open(&dir, opts).unwrap();
        // whole segments only: everything > 6 survives, possibly with a few
        // covered epochs that share a segment with uncovered ones
        assert!(replay.iter().any(|r| r.epoch == 12));
        assert!(replay.iter().all(|r| r.epoch >= 1));
        let uncovered: Vec<u64> =
            replay.iter().map(|r| r.epoch).filter(|&e| e > 6).collect();
        assert_eq!(uncovered, (7..=12).collect::<Vec<u64>>());
    }

    #[test]
    fn fsync_mode_appends_and_replays() {
        let dir = fresh_dir("fsync");
        let opts = WalOptions { fsync: true, ..WalOptions::default() };
        let (mut wal, _) = Wal::open(&dir, opts).unwrap();
        wal.append_epoch(1, &batch(1)).unwrap();
        wal.append_epoch(2, &[]).unwrap(); // empty batch is legal
        drop(wal);
        let (_, replay) = Wal::open(&dir, opts).unwrap();
        assert_eq!(replay.len(), 2);
        assert!(replay[1].updates.is_empty());
    }

    #[test]
    fn group_append_replays_identically_to_per_epoch_appends() {
        let (solo, grouped) = (fresh_dir("group_solo"), fresh_dir("group"));
        let opts = WalOptions { fsync: true, ..WalOptions::default() };
        {
            let (mut wal, _) = Wal::open(&solo, opts).unwrap();
            for e in 1..=6u64 {
                wal.append_epoch(e, &batch(e)).unwrap();
            }
        }
        {
            let (mut wal, _) = Wal::open(&grouped, opts).unwrap();
            let batches: Vec<Vec<Update>> = (1..=6u64).map(batch).collect();
            let group: Vec<(u64, &[Update])> = batches
                .iter()
                .enumerate()
                .map(|(i, b)| (i as u64 + 1, b.as_slice()))
                .collect();
            let bytes = wal.append_epochs(&group).unwrap();
            assert!(bytes > 0);
            assert_eq!(wal.epochs_appended(), 6);
            assert_eq!(wal.append_epochs(&[]).unwrap(), 0);
        }
        // byte-identical logs: grouping changes only when fsync happens
        assert_eq!(
            std::fs::read(segment_path(&solo, 1)).unwrap(),
            std::fs::read(segment_path(&grouped, 1)).unwrap()
        );
        let (_, replay) = Wal::open(&grouped, opts).unwrap();
        assert_eq!(replay.len(), 6);
        assert_eq!(replay.last().unwrap().epoch, 6);
    }

    #[test]
    fn group_append_rotates_segments_mid_group() {
        let dir = fresh_dir("group_rotate");
        let opts = WalOptions { segment_bytes: 128, fsync: true, ..WalOptions::default() };
        let (mut wal, _) = Wal::open(&dir, opts).unwrap();
        let batches: Vec<Vec<Update>> = (1..=20u64).map(batch).collect();
        let group: Vec<(u64, &[Update])> = batches
            .iter()
            .enumerate()
            .map(|(i, b)| (i as u64 + 1, b.as_slice()))
            .collect();
        wal.append_epochs(&group).unwrap();
        assert!(wal.num_segments() > 1, "tiny segment limit must rotate");
        drop(wal);
        let (_, replay) = Wal::open(&dir, opts).unwrap();
        assert_eq!(replay.len(), 20, "replay crosses segment boundaries");
    }

    #[test]
    fn empty_group_append_is_a_noop() {
        let dir = fresh_dir("group_empty");
        // fsync on: if the empty group reached sync_if_configured it would
        // still be "legal", but the contract is stronger — no record, no
        // fsync, no observable effect at all
        let opts = WalOptions { fsync: true, ..WalOptions::default() };
        let (mut wal, _) = Wal::open(&dir, opts).unwrap();
        wal.append_epoch(1, &batch(1)).unwrap();
        let seg = segment_path(&dir, 1);
        let len_before = std::fs::metadata(&seg).unwrap().len();
        let mtime_before = std::fs::metadata(&seg).unwrap().modified().unwrap();
        assert_eq!(wal.append_epochs(&[]).unwrap(), 0);
        assert_eq!(wal.epochs_appended(), 1, "no record appended");
        assert_eq!(wal.bytes_appended(), len_before - 8, "no bytes appended");
        assert_eq!(
            std::fs::metadata(&seg).unwrap().len(),
            len_before,
            "segment untouched by an empty group"
        );
        assert_eq!(
            std::fs::metadata(&seg).unwrap().modified().unwrap(),
            mtime_before,
            "empty group must not even touch (fsync) the segment"
        );
        drop(wal);
        let (_, replay) = Wal::open(&dir, opts).unwrap();
        assert_eq!(replay.len(), 1);
    }

    #[test]
    fn group_spanning_rotation_is_byte_identical_to_solo_appends() {
        let (solo, grouped) = (fresh_dir("rotspan_solo"), fresh_dir("rotspan_group"));
        let opts = WalOptions { segment_bytes: 128, fsync: true, ..WalOptions::default() };
        {
            let (mut wal, _) = Wal::open(&solo, opts).unwrap();
            for e in 1..=20u64 {
                wal.append_epoch(e, &batch(e)).unwrap();
            }
            assert!(wal.num_segments() > 1, "tiny segment limit must rotate");
        }
        let segments = {
            let (mut wal, _) = Wal::open(&grouped, opts).unwrap();
            let batches: Vec<Vec<Update>> = (1..=20u64).map(batch).collect();
            let group: Vec<(u64, &[Update])> = batches
                .iter()
                .enumerate()
                .map(|(i, b)| (i as u64 + 1, b.as_slice()))
                .collect();
            wal.append_epochs(&group).unwrap();
            assert!(wal.num_segments() > 1, "group must span a rotation");
            wal.num_segments() as u64
        };
        // rotation points are a function of bytes alone, so every segment
        // file must match its solo twin byte for byte
        for seq in 1..=segments {
            assert_eq!(
                std::fs::read(segment_path(&solo, seq)).unwrap(),
                std::fs::read(segment_path(&grouped, seq)).unwrap(),
                "segment {seq} diverges between solo and grouped appends"
            );
        }
        let (_, replay) = Wal::open(&grouped, opts).unwrap();
        assert_eq!(replay.len(), 20);
    }

    #[test]
    fn torn_tail_inside_group_truncates_to_last_whole_record() {
        let dir = fresh_dir("group_torn");
        let opts = WalOptions { fsync: true, ..WalOptions::default() };
        {
            let (mut wal, _) = Wal::open(&dir, opts).unwrap();
            let batches: Vec<Vec<Update>> = (1..=5u64).map(batch).collect();
            let group: Vec<(u64, &[Update])> = batches
                .iter()
                .enumerate()
                .map(|(i, b)| (i as u64 + 1, b.as_slice()))
                .collect();
            wal.append_epochs(&group).unwrap();
        }
        // tear the file mid-way through record 4 of the group: records 1-3
        // stay whole, 4 becomes a torn tail, 5 is gone entirely
        let seg = segment_path(&dir, 1);
        let record = 8 + 12 + 9 * batch(1).len() as u64; // prefix + payload
        let torn_at = 8 + 3 * record + record / 2;
        let f = OpenOptions::new().write(true).open(&seg).unwrap();
        f.set_len(torn_at).unwrap();
        drop(f);
        let (mut wal, replay) = Wal::open(&dir, opts).unwrap();
        assert_eq!(replay.len(), 3, "only the whole records before the tear replay");
        assert_eq!(replay.last().unwrap().epoch, 3);
        // appends resume on the clean boundary left by the truncation
        wal.append_epoch(4, &batch(4)).unwrap();
        drop(wal);
        let (_, replay) = Wal::open(&dir, opts).unwrap();
        assert_eq!(replay.iter().map(|r| r.epoch).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn corrupt_middle_segment_fails_loudly() {
        let dir = fresh_dir("gap");
        let opts = WalOptions { segment_bytes: 64, ..WalOptions::default() };
        let (mut wal, _) = Wal::open(&dir, opts).unwrap();
        for e in 1..=12u64 {
            wal.append_epoch(e, &batch(e)).unwrap();
        }
        assert!(wal.num_segments() >= 3);
        drop(wal);
        // corrupt the FIRST segment: replaying would skip history
        let seg = segment_path(&dir, 1);
        let mut bytes = std::fs::read(&seg).unwrap();
        let mid = bytes.len() / 2;
        bytes.truncate(mid);
        std::fs::write(&seg, &bytes).unwrap();
        let err = match Wal::open(&dir, opts) {
            Ok(_) => panic!("gapped log must not open"),
            Err(e) => e,
        };
        assert!(err.contains("corrupt"), "{err}");
    }
}
