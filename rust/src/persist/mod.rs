//! Durability for the dynamic matching engine: epoch write-ahead log,
//! snapshots, and crash recovery.
//!
//! Skipper's one-byte-per-vertex design makes the engine's durable state
//! unusually small: the live adjacency, the `partner[]` matching, and the
//! epoch counter are everything a restart needs — the core's state bytes
//! are *derived* (a matched vertex is `MCHD`, everything else `ACC` at a
//! quiescent point), so they are never persisted. Batch-dynamic epochs
//! (Ghaffari & Trygub, *Parallel Dynamic Maximal Matching*) are the natural
//! unit of logging, and the external-memory lineage (Birn et al.) shows
//! matching state streams to disk cheaply; this module combines both:
//!
//! * [`wal`] — a length-prefixed, CRC-checked append-only log of epoch
//!   update batches, with segment rotation and torn-tail truncation on
//!   open. The service's flusher appends each epoch's updates *before*
//!   applying them, so every applied epoch is on disk first.
//! * [`snapshot`] — a binary snapshot of the durable state (vertex
//!   universe, live edge set, `partner[]` matching), CRC-trailed and
//!   published atomically via tmp-file + rename, written by a background
//!   thread from a consistent barrier copy.
//! * [`recovery`] — the boot path: load the newest valid snapshot, replay
//!   WAL epochs through the real engine epoch machinery, verify
//!   maximality, then go live.
//!
//! ## Durability invariants
//!
//! 1. **WAL-before-apply:** an epoch's updates reach the log (flushed, and
//!    fsynced under `--fsync`) before the engine applies them. A crash
//!    between log and apply replays an epoch the pre-crash process never
//!    finished — identical to the uninterrupted run having applied it.
//! 2. **Epoch contiguity:** WAL records carry contiguous, strictly
//!    increasing epoch numbers; recovery refuses a gapped history (replay
//!    must start at `snapshot_epoch + 1` and step by one) and resumes the
//!    engine's epoch counter at `max(snapshot_epoch, last WAL epoch)` so
//!    post-recovery appends stay contiguous across any number of crashes.
//!    The flip side: a failed WAL append is fatal to the service — an
//!    applied-but-unlogged epoch would be exactly such a gap.
//! 3. **Atomic snapshots:** a snapshot file is complete and CRC-valid or
//!    it does not exist under its final name (tmp + rename); a torn
//!    snapshot write is invisible to recovery, which falls back to the
//!    previous one.
//! 4. **Prune-after-publish, lagged by one:** the newest **two** snapshots
//!    are retained and WAL segments are deleted only once the
//!    *predecessor* snapshot covers their last epoch, so both the newest
//!    snapshot and its fallback reconstruct every applied epoch from the
//!    remaining WAL.
//! 5. **Single writer:** a `LOCK` file (PID + liveness check) makes a
//!    second server on the same data dir fail at boot instead of
//!    truncating the holder's in-flight WAL record as a torn tail.
//!
//! The service wiring (flusher-side logging overlapped with the router
//! exactly like the epoch pipeline, `SNAPSHOT`/`SHUTDOWN` commands, STATS
//! durability counters) lives in [`crate::service::server`]; the
//! architecture chapter is `docs/ARCHITECTURE.md`.

pub mod recovery;
pub mod ship;
pub mod snapshot;
pub mod wal;

use crate::dynamic::{ShardedDynamicMatcher, Update};
use recovery::RecoveryReport;
use snapshot::{SnapshotData, SnapshotWriter};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use wal::{Wal, WalOptions};

/// IEEE CRC-32 lookup table, built at compile time (the crate vendors its
/// own checksum because it is dependency-free).
const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC32_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 over `data` — guards every WAL record and snapshot body.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Lifetime durability counters, shared between the flusher (writer), the
/// background snapshotter, and `STATS` (reader). All relaxed: these are
/// monitoring counters, not synchronization.
#[derive(Debug, Default)]
pub struct DurabilityCounters {
    /// Epoch records appended to the WAL since boot.
    pub wal_epochs: AtomicU64,
    /// Bytes appended to the WAL since boot.
    pub wal_bytes: AtomicU64,
    /// Epoch of the newest durably published snapshot (0 = none yet).
    pub last_snapshot_epoch: AtomicU64,
    /// WAL epochs replayed by recovery at boot.
    pub recovery_replayed: AtomicU64,
}

/// Configuration of one durable service instance (the CLI spellings are
/// `--data-dir`, `--no-wal`, `--fsync`, `--snapshot-every`).
#[derive(Clone, Debug)]
pub struct DurableOptions {
    /// Root directory holding `wal/` and `snapshots/`.
    pub data_dir: PathBuf,
    /// Append epoch batches to the WAL (`--no-wal` disables logging but
    /// recovery still replays any log found on disk).
    pub wal: bool,
    /// `fsync` every WAL append (durable against power loss, not just
    /// process death).
    pub fsync: bool,
    /// Automatically snapshot every this many applied epochs (0 = only on
    /// `SNAPSHOT` commands and shutdown).
    pub snapshot_every: u64,
}

/// Advisory single-writer lock on a data dir: a `LOCK` file holding the
/// owner's PID, taken with an atomic `create_new` and removed on drop. Two
/// live servers appending to one WAL would corrupt each other (the second
/// open truncates the first's in-flight record as a "torn tail"), so a
/// second opener fails loudly while the holder is alive. A lock naming a
/// provably dead process (the `kill -9` path, checked via `/proc/<pid>`)
/// is stolen with a warning; anything short of that proof — a live or
/// unknown-liveness holder, or an unreadable lock that may belong to a
/// concurrent booter mid-write — refuses, telling the operator what to
/// remove if the holder is really gone.
struct DirLock {
    path: PathBuf,
}

/// Is `pid` an existing process? `None` when the platform offers no way
/// to tell (no `/proc`).
fn process_alive(pid: u32) -> Option<bool> {
    let proc_root = Path::new("/proc");
    if !proc_root.exists() {
        return None;
    }
    Some(proc_root.join(pid.to_string()).exists())
}

impl DirLock {
    fn acquire(data_dir: &Path) -> Result<Self, String> {
        std::fs::create_dir_all(data_dir)
            .map_err(|e| format!("mkdir {}: {e}", data_dir.display()))?;
        let path = data_dir.join("LOCK");
        for attempt in 0..2 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write as _;
                    let _ = write!(f, "{}", std::process::id());
                    let _ = f.sync_all();
                    return Ok(Self { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let holder = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|s| s.trim().parse::<u32>().ok());
                    match holder {
                        // a lock is stolen ONLY when it names a provably
                        // dead process; an empty/unreadable lock may be a
                        // concurrent booter between its create_new and its
                        // PID write, and stealing it would put two live
                        // servers on one WAL — refuse and let the operator
                        // (or the next boot, once the PID lands) decide
                        Some(pid) if process_alive(pid) == Some(false) => {
                            if attempt == 0 {
                                // steal by RENAME, not remove: rename is
                                // atomic, so of N concurrent booters that
                                // all observed the dead holder, exactly one
                                // wins it — the losers' renames fail and
                                // their retry sees the winner's fresh lock.
                                // A bare remove here could delete a LOCK the
                                // winner already re-created (TOCTOU).
                                let aside =
                                    data_dir.join(format!("LOCK.stale.{}", std::process::id()));
                                if std::fs::rename(&path, &aside).is_ok() {
                                    eprintln!(
                                        "durability: removing stale lock {} (holder {pid} is gone)",
                                        path.display()
                                    );
                                    let _ = std::fs::remove_file(&aside);
                                }
                            }
                        }
                        Some(pid) => {
                            return Err(format!(
                                "data dir {} is locked by process {pid} ({}); two servers on one WAL would corrupt it — remove {} if that process is really gone",
                                data_dir.display(),
                                if process_alive(pid).is_some() { "alive" } else { "liveness unknown on this platform" },
                                path.display()
                            ));
                        }
                        None => {
                            return Err(format!(
                                "data dir {} holds an unreadable lock {} — either another server is booting right now, or a crash left it empty; retry, or remove it if no server is running",
                                data_dir.display(),
                                path.display()
                            ));
                        }
                    }
                }
                Err(e) => return Err(format!("lock {}: {e}", path.display())),
            }
        }
        Err(format!(
            "data dir {} lock contended — another server grabbed it first",
            data_dir.display()
        ))
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// The service-facing durability bundle: recovery at open, WAL appends and
/// snapshot scheduling per epoch, and the final snapshot at shutdown. Owned
/// by the service's flush executor, so every call happens at an epoch
/// barrier — the engine is quiescent whenever state is captured.
pub struct DurableService {
    wal: Wal,
    log_enabled: bool,
    writer: SnapshotWriter,
    counters: Arc<DurabilityCounters>,
    snapshot_every: u64,
    report: RecoveryReport,
    /// Newest published snapshot the WAL has already been pruned against.
    /// Pruning lags one snapshot behind publication so the predecessor
    /// snapshot stays fully replayable — the corrupt-newest fallback in
    /// recovery needs the WAL from `predecessor + 1` onward.
    seen_published: u64,
    /// Held for the service's lifetime; declared last so it releases only
    /// after the WAL handle and the snapshot writer have shut down.
    _lock: DirLock,
}

impl DurableService {
    /// Lock `opts.data_dir` (creating it if absent), recover `engine`, and
    /// open the WAL for appending. On return the engine holds the durable
    /// state, verified maximal, and the recovery counters are populated.
    /// Fails if another live server holds the data dir.
    pub fn open(opts: &DurableOptions, engine: &ShardedDynamicMatcher) -> Result<Self, String> {
        let lock = DirLock::acquire(&opts.data_dir)?;
        let counters = Arc::new(DurabilityCounters::default());
        let wal_opts = WalOptions { fsync: opts.fsync, ..WalOptions::default() };
        let (wal, report) = recovery::recover(engine, &opts.data_dir, wal_opts)?;
        counters
            .recovery_replayed
            .store(report.replayed_epochs, Ordering::Relaxed);
        if let Some(e) = report.snapshot_epoch {
            counters.last_snapshot_epoch.store(e, Ordering::Relaxed);
        }
        let writer = SnapshotWriter::spawn(
            recovery::snapshot_dir(&opts.data_dir),
            Arc::clone(&counters),
        );
        Ok(Self {
            wal,
            log_enabled: opts.wal,
            writer,
            counters,
            snapshot_every: opts.snapshot_every,
            seen_published: report.snapshot_epoch.unwrap_or(0),
            report,
            _lock: lock,
        })
    }

    /// Is WAL logging active? (Recovery replays an existing log either way;
    /// this only gates new appends.)
    #[inline]
    pub fn log_enabled(&self) -> bool {
        self.log_enabled
    }

    /// What recovery did at boot.
    #[inline]
    pub fn recovery(&self) -> &RecoveryReport {
        &self.report
    }

    /// The shared durability counters (for `STATS`).
    #[inline]
    pub fn counters(&self) -> &Arc<DurabilityCounters> {
        &self.counters
    }

    /// Append one epoch's update batch to the WAL (no-op when logging is
    /// disabled). Called by the flusher *before* the epoch is applied.
    pub fn log_epoch(&mut self, epoch: u64, updates: &[Update]) -> Result<(), String> {
        if !self.log_enabled || updates.is_empty() {
            return Ok(());
        }
        let bytes = self.wal.append_epoch(epoch, updates)?;
        self.counters.wal_epochs.fetch_add(1, Ordering::Relaxed);
        self.counters.wal_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Append several coalesced epochs as one durable group — a single
    /// `sync_data` covers the whole batch under `--fsync` (see
    /// [`Wal::append_epochs`]), so a flusher that drains `k` queued epochs
    /// pays one device round-trip instead of `k`. Empty batches are skipped
    /// (they have nothing to replay).
    pub fn log_epochs(&mut self, batch: &[(u64, &[Update])]) -> Result<(), String> {
        if !self.log_enabled {
            return Ok(());
        }
        let group: Vec<(u64, &[Update])> = batch
            .iter()
            .filter(|(_, ups)| !ups.is_empty())
            .copied()
            .collect();
        if group.is_empty() {
            return Ok(());
        }
        let bytes = self.wal.append_epochs(&group)?;
        self.counters
            .wal_epochs
            .fetch_add(group.len() as u64, Ordering::Relaxed);
        self.counters.wal_bytes.fetch_add(bytes, Ordering::Relaxed);
        Ok(())
    }

    /// Is the background snapshot writer mid-write? Callers check this
    /// before building a barrier copy, so a busy writer costs nothing.
    pub fn snapshot_busy(&self) -> bool {
        self.writer.is_busy()
    }

    /// Post-apply hook: schedule an automatic snapshot when the cadence
    /// says so, and prune WAL segments — lagging one snapshot behind
    /// publication, so the retained predecessor snapshot (see
    /// [`snapshot::prune_keep`]) keeps a fully replayable WAL behind it
    /// and the corrupt-newest recovery fallback can actually recover.
    pub fn after_epoch(&mut self, engine: &ShardedDynamicMatcher) {
        let epoch = engine.epochs_applied();
        if self.snapshot_every > 0 && epoch % self.snapshot_every == 0 {
            if self.writer.is_busy() {
                eprintln!(
                    "snapshot: writer busy, skipping automatic snapshot at epoch {epoch}"
                );
            } else if !self.writer.request(SnapshotData::capture(engine)) {
                // lost the tiny is_busy/try_send race: same outcome
                eprintln!(
                    "snapshot: writer busy, skipping automatic snapshot at epoch {epoch}"
                );
            }
        }
        let published = self.counters.last_snapshot_epoch.load(Ordering::Relaxed);
        if published > self.seen_published {
            let floor = self.seen_published;
            self.seen_published = published;
            if floor > 0 {
                self.wal.prune_below(floor);
            }
        }
    }

    /// Hand a barrier-consistent copy to the background snapshot writer
    /// (the `SNAPSHOT` command). Returns false when the writer is still
    /// busy with a previous snapshot (the request is skipped, not queued;
    /// probe [`snapshot_busy`](Self::snapshot_busy) first to skip the
    /// capture too).
    pub fn request_snapshot(&mut self, data: SnapshotData) -> bool {
        self.writer.request(data)
    }

    /// Graceful shutdown: write a final snapshot of the engine's current
    /// state synchronously, then prune the WAL its *predecessor* covers —
    /// a subsequent boot recovers from the final snapshot alone with zero
    /// WAL replay (the epochs kept between the two retained snapshots are
    /// all covered, hence skipped), while a bit-rotted final snapshot can
    /// still fall back to the predecessor and replay forward.
    ///
    /// Returns the epoch of the newest *durably published* snapshot after
    /// the attempt — normally the final epoch, but the previous one (or 0)
    /// when the final write failed (e.g. disk full), so callers never
    /// report a snapshot that does not exist; nothing is pruned in that
    /// case.
    pub fn shutdown(mut self, engine: &ShardedDynamicMatcher) -> u64 {
        let data = SnapshotData::capture(engine);
        let epoch = data.epoch;
        let prev = self.counters.last_snapshot_epoch.load(Ordering::Relaxed);
        self.writer.finish(Some(data));
        let published = self.counters.last_snapshot_epoch.load(Ordering::Relaxed);
        if published >= epoch && prev > 0 {
            self.wal.prune_below(prev);
        }
        published
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard IEEE CRC-32 check values
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn data_dir_lock_refuses_second_opener_and_steals_stale_locks() {
        let dir = std::env::temp_dir().join(format!(
            "skipper_dirlock_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let opts = DurableOptions {
            data_dir: dir.clone(),
            wal: true,
            fsync: false,
            snapshot_every: 0,
        };
        let e1 = ShardedDynamicMatcher::new(8, 1, 1);
        let d1 = DurableService::open(&opts, &e1).unwrap();
        // a second live opener must fail loudly, not corrupt the WAL
        let e2 = ShardedDynamicMatcher::new(8, 1, 1);
        let err = match DurableService::open(&opts, &e2) {
            Ok(_) => panic!("second opener must be refused"),
            Err(e) => e,
        };
        assert!(err.contains("locked by process"), "{err}");
        drop(d1);
        assert!(!dir.join("LOCK").exists(), "lock released on drop");
        // a stale lock from a crashed process (dead pid) is stolen — only
        // checkable where /proc can prove the holder is gone
        if Path::new("/proc").exists() {
            std::fs::write(dir.join("LOCK"), format!("{}", u32::MAX)).unwrap();
            let e3 = ShardedDynamicMatcher::new(8, 1, 1);
            let d3 = DurableService::open(&opts, &e3).unwrap();
            drop(d3);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crc32_detects_single_bit_flips() {
        let data = b"epoch 7: INSERT 0 1 DELETE 2 3".to_vec();
        let base = crc32(&data);
        for byte in 0..data.len() {
            for bit in 0..8 {
                let mut corrupt = data.clone();
                corrupt[byte] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), base, "flip at {byte}:{bit} undetected");
            }
        }
    }
}
