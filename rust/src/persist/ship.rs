//! WAL shipping: the replication transport between a primary and its
//! followers.
//!
//! The epoch WAL is already a totally-ordered, CRC-checked, replayable
//! stream — this module ships it over TCP. The primary runs a [`Shipper`]
//! that retains every committed epoch record (encoded exactly as the WAL
//! record payload, see [`crate::persist::wal`]) in an in-memory backlog and
//! streams it to any number of followers; each follower runs a
//! [`ShipReader`] that replays frames through the real engine and acks each
//! applied epoch back on the same socket.
//!
//! ## Wire format
//!
//! Everything is little-endian. The handshake:
//!
//! ```text
//! follower → primary:  magic "SKPSHIP1" (8) | last_epoch: u64 (8)
//! primary → follower:  magic "SKPSHIP1" (8) | num_vertices: u64 (8) | base_epoch: u64 (8)
//! ```
//!
//! `last_epoch` is the highest epoch the follower has already applied
//! (0 for a fresh standby); the primary resumes the stream at
//! `last_epoch + 1`. `base_epoch` is the replication horizon: the primary's
//! backlog covers epochs `base_epoch + 1` onward, so a follower whose
//! `last_epoch < base_epoch` cannot catch up over the stream and must
//! bootstrap from a copy of the primary's data dir instead — the follower
//! fails the connect loudly in that case.
//!
//! After the handshake the primary sends **frames**, each carrying its
//! current tip epoch (for follower lag accounting) and one WAL record
//! payload:
//!
//! ```text
//! frame:   tip: u64 (8) | payload_len: u32 (4) | crc32(payload): u32 (4) | payload
//! payload: epoch: u64 | count: u32 | count × (op: u8, u: u32, v: u32)
//! ```
//!
//! and the follower replies with **acks**, one `u64` epoch number per
//! applied epoch. An epoch is *acked* only after the follower has durably
//! logged (when it keeps its own WAL) and applied it — the same
//! WAL-before-apply invariant the primary itself honors.
//!
//! ## Failure model
//!
//! A `kill -9` of the primary closes its sockets; followers observe EOF
//! mid-stream, keep everything they have applied, and wait for promotion.
//! Because frames carry contiguous epochs and followers enforce the same
//! epoch-contiguity invariant as recovery, "the follower with the longest
//! contiguous log" is simply the one with the highest applied epoch — no
//! follower can ever hold a gapped prefix.

use super::crc32;
use super::wal::{decode_payload, encode_payload, WalEpoch};
use crate::dynamic::Update;
use crate::obs::metrics;
use crate::obs::trace;
use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Handshake magic, first 8 bytes in each direction.
pub const SHIP_MAGIC: &[u8; 8] = b"SKPSHIP1";

/// Hard cap on one frame's payload — mirrors the WAL scanner's record cap
/// so a malicious or corrupt length prefix is rejected, not allocated.
const MAX_FRAME_PAYLOAD: u32 = 1 << 28;

/// How long a freshly accepted connection gets to complete its handshake
/// before the primary gives up on it.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Most recent publish timestamps retained for ack-latency measurement.
const ACK_CLOCK_DEPTH: usize = 4096;

/// One decoded replication frame: the primary's tip epoch at send time and
/// the epoch record itself.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShipFrame {
    /// The primary's newest committed epoch when this frame was sent —
    /// `tip - rec.epoch` is the follower's instantaneous lag in epochs.
    pub tip: u64,
    /// The shipped epoch record, byte-identical to the WAL's.
    pub rec: WalEpoch,
}

/// A point-in-time view of the primary's replication state, for `STATS`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShipStats {
    /// Live follower connections.
    pub followers: u64,
    /// Newest committed (published) epoch.
    pub tip: u64,
    /// Lowest epoch acked by every live follower (equals `tip` when all
    /// followers are caught up, and when there are no followers at all).
    pub acked: u64,
    /// `tip - acked`.
    pub lag_epochs: u64,
    /// Backlog bytes not yet acked by the slowest live follower.
    pub lag_bytes: u64,
    /// Frames sent across all followers since bind.
    pub records_shipped: u64,
    /// Frame payload bytes sent across all followers since bind.
    pub bytes_shipped: u64,
}

/// One live follower connection, tracked by the shipper.
struct FollowerSlot {
    peer: SocketAddr,
    /// Highest epoch this follower has acked.
    acked: AtomicU64,
    alive: AtomicBool,
    /// Kept so shutdown can close the socket and unblock both threads.
    stream: TcpStream,
}

/// State shared between `publish` (flusher thread), the accept loop, and
/// the per-follower sender/ack threads.
struct ShipInner {
    num_vertices: u64,
    /// The backlog covers epochs `base + 1 ..= base + log.len()`.
    base: u64,
    /// Encoded record payloads, in epoch order, plus the cumulative payload
    /// byte count through each entry (for lag-in-bytes accounting).
    log: Mutex<(Vec<Arc<[u8]>>, Vec<u64>)>,
    /// Signaled on publish and on shutdown.
    cond: Condvar,
    tip: AtomicU64,
    shutdown: AtomicBool,
    followers: Mutex<Vec<Arc<FollowerSlot>>>,
    records_shipped: AtomicU64,
    bytes_shipped: AtomicU64,
    /// `(epoch, publish instant)` ring for ack-latency measurement.
    ack_clock: Mutex<VecDeque<(u64, Instant)>>,
    send_hist: Arc<metrics::Histogram>,
    ack_hist: Arc<metrics::Histogram>,
    lag_gauge: Arc<metrics::Gauge>,
    followers_gauge: Arc<metrics::Gauge>,
}

impl ShipInner {
    /// Recompute the primary-side lag gauge: tip minus the slowest live
    /// follower's ack (0 with no followers — nothing is waiting on us).
    fn refresh_lag(&self) {
        let tip = self.tip.load(Ordering::Acquire);
        let min_acked = self
            .followers
            .lock()
            .unwrap()
            .iter()
            .filter(|f| f.alive.load(Ordering::Relaxed))
            .map(|f| f.acked.load(Ordering::Relaxed))
            .min();
        let lag = match min_acked {
            Some(a) => tip.saturating_sub(a),
            None => 0,
        };
        self.lag_gauge.set(lag);
    }
}

/// The primary side of replication: a TCP listener plus an in-memory
/// backlog of every epoch committed since bind. The service's flusher
/// calls [`publish`](Shipper::publish) once per committed epoch (after the
/// local WAL append); follower connections are handled entirely on
/// background threads, so a slow or dead follower never blocks the epoch
/// pipeline — it just accumulates lag.
pub struct Shipper {
    inner: Arc<ShipInner>,
    local_addr: SocketAddr,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl Shipper {
    /// Bind the replication listener on `addr` and start accepting
    /// followers. `base_epoch` is the primary's current applied epoch —
    /// the backlog (and therefore the replication horizon) starts right
    /// after it. Instruments are registered against `reg`, so they land in
    /// the serving instance's `METRICS` scrape.
    pub fn bind(
        addr: &str,
        num_vertices: usize,
        base_epoch: u64,
        reg: &metrics::Registry,
    ) -> Result<Shipper, String> {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("replicate bind {addr}: {e}"))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| format!("replicate addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("replicate listener: {e}"))?;
        let inner = Arc::new(ShipInner {
            num_vertices: num_vertices as u64,
            base: base_epoch,
            log: Mutex::new((Vec::new(), Vec::new())),
            cond: Condvar::new(),
            tip: AtomicU64::new(base_epoch),
            shutdown: AtomicBool::new(false),
            followers: Mutex::new(Vec::new()),
            records_shipped: AtomicU64::new(0),
            bytes_shipped: AtomicU64::new(0),
            ack_clock: Mutex::new(VecDeque::new()),
            send_hist: reg.histogram_secs(
                "skipper_ship_send_seconds",
                "Replication frame encode+write latency, per frame per follower",
            ),
            ack_hist: reg.histogram_secs(
                "skipper_ship_ack_seconds",
                "Publish-to-ack round trip per epoch (first follower to ack)",
            ),
            lag_gauge: reg.gauge(
                "skipper_replica_lag_epochs",
                "Committed epochs not yet acked by the slowest live follower",
            ),
            followers_gauge: reg.gauge(
                "skipper_replica_followers",
                "Live follower connections on the replication listener",
            ),
        });
        let accept_inner = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("ship-accept".into())
            .spawn(move || accept_loop(listener, accept_inner))
            .map_err(|e| format!("replicate accept thread: {e}"))?;
        Ok(Shipper {
            inner,
            local_addr,
            threads: Mutex::new(vec![accept]),
        })
    }

    /// The bound replication listener address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Publish one committed epoch to the backlog and wake every sender.
    /// Called by the flusher right after the epoch is locally durable;
    /// epochs must arrive contiguously (`base + 1`, `base + 2`, ...), which
    /// the service's epoch counter guarantees.
    pub fn publish(&self, epoch: u64, updates: &[Update]) {
        let payload: Arc<[u8]> = encode_payload(epoch, updates).into();
        let bytes = payload.len() as u64;
        {
            let mut log = self.inner.log.lock().unwrap();
            debug_assert_eq!(
                epoch,
                self.inner.base + log.0.len() as u64 + 1,
                "published epochs must be contiguous"
            );
            let total = log.1.last().copied().unwrap_or(0) + bytes;
            log.0.push(payload);
            log.1.push(total);
        }
        self.inner.tip.store(epoch, Ordering::Release);
        {
            let mut clock = self.inner.ack_clock.lock().unwrap();
            if clock.len() == ACK_CLOCK_DEPTH {
                clock.pop_front();
            }
            clock.push_back((epoch, Instant::now()));
        }
        self.inner.refresh_lag();
        self.inner.cond.notify_all();
    }

    /// A point-in-time replication summary for `STATS`.
    pub fn stats(&self) -> ShipStats {
        let tip = self.inner.tip.load(Ordering::Acquire);
        let followers: Vec<u64> = self
            .inner
            .followers
            .lock()
            .unwrap()
            .iter()
            .filter(|f| f.alive.load(Ordering::Relaxed))
            .map(|f| f.acked.load(Ordering::Relaxed))
            .collect();
        let acked = followers.iter().copied().min().unwrap_or(tip);
        let lag_bytes = {
            let log = self.inner.log.lock().unwrap();
            let total = log.1.last().copied().unwrap_or(0);
            let idx = acked.saturating_sub(self.inner.base) as usize;
            let covered = if idx == 0 { 0 } else { log.1[idx.min(log.1.len()) - 1] };
            total - covered
        };
        ShipStats {
            followers: followers.len() as u64,
            tip,
            acked,
            lag_epochs: tip.saturating_sub(acked),
            lag_bytes,
            records_shipped: self.inner.records_shipped.load(Ordering::Relaxed),
            bytes_shipped: self.inner.bytes_shipped.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting, close every follower socket, and join the
    /// background threads. Followers observe a clean EOF — from their side
    /// indistinguishable from a primary crash, which is the point: failover
    /// has a single code path.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.cond.notify_all();
        for f in self.inner.followers.lock().unwrap().iter() {
            let _ = f.stream.shutdown(Shutdown::Both);
        }
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

impl Drop for Shipper {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Accept loop: poll the nonblocking listener, handshake each follower on
/// its own thread so a slow client can't stall admission.
fn accept_loop(listener: TcpListener, inner: Arc<ShipInner>) {
    while !inner.shutdown.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let conn_inner = Arc::clone(&inner);
                // detached: the thread exits when its socket closes, which
                // Shipper::shutdown forces for every registered follower
                if let Err(e) = std::thread::Builder::new()
                    .name(format!("ship-{peer}"))
                    .spawn(move || follower_conn(stream, peer, conn_inner))
                {
                    eprintln!("replicate: spawn for {peer}: {e}");
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(e) => {
                eprintln!("replicate: accept: {e}");
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Handshake one follower, then stream frames to it (this thread) while a
/// sibling thread consumes its acks.
fn follower_conn(stream: TcpStream, peer: SocketAddr, inner: Arc<ShipInner>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT));
    let mut hello = [0u8; 16];
    let mut rd = &stream;
    if rd.read_exact(&mut hello).is_err() || &hello[0..8] != SHIP_MAGIC {
        eprintln!("replicate: {peer}: bad handshake, dropping");
        return;
    }
    let last_epoch = u64::from_le_bytes(hello[8..16].try_into().unwrap());
    let hs_span = trace::span("ship_handshake", "ship", last_epoch);
    let mut reply = Vec::with_capacity(24);
    reply.extend_from_slice(SHIP_MAGIC);
    reply.extend_from_slice(&inner.num_vertices.to_le_bytes());
    reply.extend_from_slice(&inner.base.to_le_bytes());
    if (&stream).write_all(&reply).is_err() {
        return;
    }
    if last_epoch < inner.base {
        // behind the horizon: header already told the follower why
        eprintln!(
            "replicate: {peer}: follower at epoch {last_epoch} is behind the \
             replication horizon ({}), dropping — bootstrap it from a data-dir copy",
            inner.base
        );
        return;
    }
    drop(hs_span); // close the handshake span before the long-lived stream
    let _ = stream.set_read_timeout(None);
    let slot = Arc::new(FollowerSlot {
        peer,
        acked: AtomicU64::new(last_epoch),
        alive: AtomicBool::new(true),
        stream: match stream.try_clone() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("replicate: {peer}: clone: {e}");
                return;
            }
        },
    });
    inner.followers.lock().unwrap().push(Arc::clone(&slot));
    inner.followers_gauge.inc(1);
    inner.refresh_lag();
    eprintln!("replicate: follower {peer} joined at epoch {last_epoch}");

    // ack reader sibling
    let ack_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let ack_inner = Arc::clone(&inner);
    let ack_slot = Arc::clone(&slot);
    let ack_thread = std::thread::Builder::new()
        .name(format!("ship-ack-{peer}"))
        .spawn(move || ack_loop(ack_stream, ack_slot, ack_inner));

    send_loop(&stream, &slot, &inner, last_epoch);

    slot.alive.store(false, Ordering::Release);
    let _ = stream.shutdown(Shutdown::Both);
    if let Ok(t) = ack_thread {
        let _ = t.join();
    }
    inner
        .followers
        .lock()
        .unwrap()
        .retain(|f| !Arc::ptr_eq(f, &slot));
    inner.followers_gauge.dec(1);
    inner.refresh_lag();
    eprintln!(
        "replicate: follower {peer} left at acked epoch {}",
        slot.acked.load(Ordering::Relaxed)
    );
}

/// Stream backlog frames to one follower, waiting on the publish condvar
/// when caught up.
fn send_loop(stream: &TcpStream, slot: &FollowerSlot, inner: &ShipInner, start_after: u64) {
    let mut next_idx = (start_after - inner.base) as usize;
    let mut out = stream;
    loop {
        let chunk: Vec<Arc<[u8]>> = {
            let mut log = inner.log.lock().unwrap();
            while log.0.len() <= next_idx {
                if inner.shutdown.load(Ordering::Acquire) || !slot.alive.load(Ordering::Acquire) {
                    return;
                }
                log = inner.cond.wait(log).unwrap();
            }
            log.0[next_idx..].to_vec()
        };
        let tip = inner.tip.load(Ordering::Acquire);
        for (i, payload) in chunk.iter().enumerate() {
            // backlog index -> epoch: the entry at log.0[k] holds base+k+1
            let epoch = inner.base + (next_idx + i) as u64 + 1;
            let _sp = trace::span_epoch("ship_send", "ship", epoch, payload.len() as u64);
            let t_send = Instant::now();
            let mut frame = Vec::with_capacity(16 + payload.len());
            frame.extend_from_slice(&tip.to_le_bytes());
            frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            frame.extend_from_slice(&crc32(payload).to_le_bytes());
            frame.extend_from_slice(payload);
            if out.write_all(&frame).is_err() {
                slot.alive.store(false, Ordering::Release);
                return;
            }
            inner.send_hist.record_duration(t_send.elapsed());
            inner.records_shipped.fetch_add(1, Ordering::Relaxed);
            inner
                .bytes_shipped
                .fetch_add(payload.len() as u64, Ordering::Relaxed);
        }
        if out.flush().is_err() {
            slot.alive.store(false, Ordering::Release);
            return;
        }
        next_idx += chunk.len();
    }
}

/// Consume one follower's acks, updating its slot and the lag gauge.
fn ack_loop(stream: TcpStream, slot: Arc<FollowerSlot>, inner: Arc<ShipInner>) {
    let mut rd = &stream;
    let mut buf = [0u8; 8];
    loop {
        if rd.read_exact(&mut buf).is_err() {
            slot.alive.store(false, Ordering::Release);
            inner.cond.notify_all(); // unblock the sender so it can exit
            return;
        }
        let epoch = u64::from_le_bytes(buf);
        let _sp = trace::span_epoch("ship_ack", "ship", epoch, 0);
        slot.acked.store(epoch, Ordering::Release);
        // ack latency: measured against the publish instant, recorded only
        // for epochs still in the clock window
        let published_at = {
            let clock = inner.ack_clock.lock().unwrap();
            clock.iter().find(|(e, _)| *e == epoch).map(|(_, t)| *t)
        };
        if let Some(t) = published_at {
            inner.ack_hist.record_duration(t.elapsed());
        }
        inner.refresh_lag();
    }
}

/// The follower side of the replication stream: handshake on connect, then
/// a blocking frame iterator plus an ack writer. The caller (the replica
/// service) owns the apply loop; this type only speaks the wire format.
pub struct ShipReader {
    stream: TcpStream,
    /// The primary's vertex universe, from the handshake — the follower's
    /// engine must match or replayed vertex ids would be meaningless.
    pub num_vertices: u64,
    /// The primary's replication horizon: its backlog starts after this
    /// epoch.
    pub base_epoch: u64,
}

/// A cloned handle that can abort a blocked [`ShipReader::next_frame`]
/// from another thread (the `PROMOTE` path).
pub struct ShipAbort {
    stream: TcpStream,
}

impl ShipAbort {
    /// Close both directions of the stream; the blocked reader observes
    /// EOF and returns `Ok(None)`.
    pub fn abort(&self) {
        let _ = self.stream.shutdown(Shutdown::Both);
    }
}

impl ShipReader {
    /// Connect to a primary's replication listener and handshake,
    /// announcing that every epoch up to `last_epoch` is already applied
    /// locally. Fails when the primary's universe size or replication
    /// horizon is incompatible.
    pub fn connect(addr: &str, last_epoch: u64) -> Result<ShipReader, String> {
        let _hs_span = trace::span("ship_handshake", "ship", last_epoch);
        let stream = TcpStream::connect(addr).map_err(|e| format!("follow {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        let mut hello = Vec::with_capacity(16);
        hello.extend_from_slice(SHIP_MAGIC);
        hello.extend_from_slice(&last_epoch.to_le_bytes());
        (&stream)
            .write_all(&hello)
            .map_err(|e| format!("follow {addr}: handshake write: {e}"))?;
        let mut reply = [0u8; 24];
        (&stream)
            .read_exact(&mut reply)
            .map_err(|e| format!("follow {addr}: handshake read: {e}"))?;
        if &reply[0..8] != SHIP_MAGIC {
            return Err(format!("follow {addr}: not a skipper replication listener"));
        }
        let num_vertices = u64::from_le_bytes(reply[8..16].try_into().unwrap());
        let base_epoch = u64::from_le_bytes(reply[16..24].try_into().unwrap());
        if last_epoch < base_epoch {
            return Err(format!(
                "follow {addr}: this follower is at epoch {last_epoch} but the primary's \
                 replication horizon starts after epoch {base_epoch} — bootstrap the follower \
                 from a copy of the primary's data dir first"
            ));
        }
        Ok(ShipReader { stream, num_vertices, base_epoch })
    }

    /// A handle that can unblock [`next_frame`](Self::next_frame) from
    /// another thread by closing the stream.
    pub fn abort_handle(&self) -> Result<ShipAbort, String> {
        Ok(ShipAbort {
            stream: self.stream.try_clone().map_err(|e| format!("clone: {e}"))?,
        })
    }

    /// Block for the next frame. `Ok(None)` means the stream ended cleanly
    /// at a frame boundary — the primary died or shut down; everything
    /// applied so far is a contiguous prefix of its log. `Err` means a
    /// malformed frame (bad CRC, oversized or truncated payload), which a
    /// TCP stream should never deliver.
    pub fn next_frame(&mut self) -> Result<Option<ShipFrame>, String> {
        let mut head = [0u8; 16];
        let mut got = 0usize;
        while got < head.len() {
            match (&self.stream).read(&mut head[got..]) {
                Ok(0) if got == 0 => return Ok(None),
                Ok(0) => return Err("replication stream truncated mid-frame".into()),
                Ok(n) => got += n,
                Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) if got == 0 => return Ok(None), // closed under us (abort/kill)
                Err(e) => return Err(format!("replication stream read: {e}")),
            }
        }
        let tip = u64::from_le_bytes(head[0..8].try_into().unwrap());
        let len = u32::from_le_bytes(head[8..12].try_into().unwrap());
        let crc = u32::from_le_bytes(head[12..16].try_into().unwrap());
        if len > MAX_FRAME_PAYLOAD {
            return Err(format!("replication frame payload of {len} bytes exceeds cap"));
        }
        let mut payload = vec![0u8; len as usize];
        (&self.stream)
            .read_exact(&mut payload)
            .map_err(|e| format!("replication stream payload: {e}"))?;
        if crc32(&payload) != crc {
            return Err("replication frame CRC mismatch".into());
        }
        match decode_payload(&payload) {
            Some(rec) => Ok(Some(ShipFrame { tip, rec })),
            None => Err("replication frame payload undecodable".into()),
        }
    }

    /// Ack one applied epoch back to the primary. Errors are reported but
    /// non-fatal to the caller's replay loop: a dead primary can no longer
    /// hear acks, yet the applied state is still exactly what promotion
    /// needs.
    pub fn ack(&mut self, epoch: u64) -> Result<(), String> {
        (&self.stream)
            .write_all(&epoch.to_le_bytes())
            .map_err(|e| format!("replication ack: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loopback_available() -> bool {
        std::net::TcpListener::bind("127.0.0.1:0").is_ok()
    }

    #[test]
    fn ship_roundtrip_frames_and_acks() {
        if !loopback_available() {
            eprintln!("skipping ship_roundtrip_frames_and_acks: no loopback");
            return;
        }
        let reg = metrics::Registry::new();
        let shipper = Shipper::bind("127.0.0.1:0", 64, 0, &reg).unwrap();
        let addr = shipper.local_addr().to_string();
        let mut reader = ShipReader::connect(&addr, 0).unwrap();
        assert_eq!(reader.num_vertices, 64);
        assert_eq!(reader.base_epoch, 0);
        shipper.publish(1, &[Update::Insert(0, 1), Update::Delete(2, 3)]);
        shipper.publish(2, &[Update::Insert(4, 5)]);
        let f1 = reader.next_frame().unwrap().unwrap();
        assert_eq!(f1.rec.epoch, 1);
        assert_eq!(f1.rec.updates, vec![Update::Insert(0, 1), Update::Delete(2, 3)]);
        reader.ack(1).unwrap();
        let f2 = reader.next_frame().unwrap().unwrap();
        assert_eq!(f2.rec.epoch, 2);
        assert_eq!(f2.tip, 2);
        reader.ack(2).unwrap();
        // acks drain the lag
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let s = shipper.stats();
            if s.acked == 2 && s.followers == 1 {
                assert_eq!(s.lag_epochs, 0);
                assert_eq!(s.lag_bytes, 0);
                break;
            }
            assert!(Instant::now() < deadline, "acks never reached the shipper: {s:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        // shipper shutdown = clean EOF on the follower
        shipper.shutdown();
        assert_eq!(reader.next_frame().unwrap(), None);
    }

    #[test]
    fn late_joiner_catches_up_from_backlog() {
        if !loopback_available() {
            eprintln!("skipping late_joiner_catches_up_from_backlog: no loopback");
            return;
        }
        let reg = metrics::Registry::new();
        let shipper = Shipper::bind("127.0.0.1:0", 32, 0, &reg).unwrap();
        for e in 1..=5u64 {
            shipper.publish(e, &[Update::Insert(e as u32, e as u32 + 6)]);
        }
        let addr = shipper.local_addr().to_string();
        let mut reader = ShipReader::connect(&addr, 0).unwrap();
        for e in 1..=5u64 {
            let f = reader.next_frame().unwrap().unwrap();
            assert_eq!(f.rec.epoch, e);
            reader.ack(e).unwrap();
        }
        // a partially caught-up joiner resumes mid-backlog
        let mut mid = ShipReader::connect(&addr, 3).unwrap();
        let f = mid.next_frame().unwrap().unwrap();
        assert_eq!(f.rec.epoch, 4, "stream resumes after the announced epoch");
    }

    #[test]
    fn behind_horizon_follower_is_refused() {
        if !loopback_available() {
            eprintln!("skipping behind_horizon_follower_is_refused: no loopback");
            return;
        }
        let reg = metrics::Registry::new();
        // primary booted at epoch 10: backlog starts at 11
        let shipper = Shipper::bind("127.0.0.1:0", 32, 10, &reg).unwrap();
        let addr = shipper.local_addr().to_string();
        let err = match ShipReader::connect(&addr, 4) {
            Ok(_) => panic!("behind-horizon follower must be refused"),
            Err(e) => e,
        };
        assert!(err.contains("horizon"), "{err}");
        // a caught-up follower is fine
        let r = ShipReader::connect(&addr, 10).unwrap();
        assert_eq!(r.base_epoch, 10);
    }

    #[test]
    fn abort_handle_unblocks_a_waiting_reader() {
        if !loopback_available() {
            eprintln!("skipping abort_handle_unblocks_a_waiting_reader: no loopback");
            return;
        }
        let reg = metrics::Registry::new();
        let shipper = Shipper::bind("127.0.0.1:0", 16, 0, &reg).unwrap();
        let addr = shipper.local_addr().to_string();
        let mut reader = ShipReader::connect(&addr, 0).unwrap();
        let abort = reader.abort_handle().unwrap();
        let t = std::thread::spawn(move || reader.next_frame());
        std::thread::sleep(Duration::from_millis(50));
        abort.abort();
        let out = t.join().unwrap().unwrap();
        assert_eq!(out, None, "aborted reader sees a clean end of stream");
        drop(shipper);
    }
}
