//! Binary snapshots of the engine's durable state, published atomically
//! and written by a background thread.
//!
//! ## On-disk format (`snap-<epoch:012>.skps`)
//!
//! Little-endian throughout, following the [`crate::graph::io::binary`]
//! conventions (magic, u64 counts, u32 vertex ids):
//!
//! ```text
//! magic "SKPSNAP1"                     (8 bytes)
//! body:
//!   epoch: u64 | num_vertices: u64 | live_edges: u64 | matched_pairs: u64
//!   live_edges × (u: u32, v: u32)        canonical (min, max)
//!   matched_pairs × (u: u32, v: u32)     canonical (min, max)
//! crc32(body): u32
//! ```
//!
//! A snapshot is written to `<name>.tmp`, fsynced, then renamed into place:
//! under its final name a snapshot is either complete and CRC-valid or
//! absent, so recovery never sees a torn snapshot
//! ([`load_latest`] additionally skips files whose CRC fails, falling back
//! to the previous epoch's file).
//!
//! The matching is stored alongside the live edge set so
//! [`crate::persist::recovery::restore_into`] can rebuild the *exact*
//! pre-crash `partner[]` assignment through ordinary engine epochs — see
//! that module for why two epochs suffice.

use super::{crc32, DurabilityCounters};
use crate::dynamic::ShardedDynamicMatcher;
use crate::obs::{metrics, trace};
use crate::VertexId;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;

/// Snapshot file magic, first 8 bytes of every `.skps` file.
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"SKPSNAP1";

/// A barrier-consistent copy of the engine's durable state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotData {
    /// Engine epoch this state corresponds to.
    pub epoch: u64,
    /// Vertex universe size the engine was built with.
    pub num_vertices: u64,
    /// The live edge set, canonical `(min, max)` pairs.
    pub live_edges: Vec<(VertexId, VertexId)>,
    /// The matching, canonical `(min, max)` pairs (⊆ `live_edges`).
    pub matching: Vec<(VertexId, VertexId)>,
}

impl SnapshotData {
    /// Capture the engine's durable state. Must be called at an epoch
    /// barrier (no epoch in flight) so the copy is consistent; the
    /// service's flush executor and the churn driver both satisfy this by
    /// construction.
    pub fn capture(engine: &ShardedDynamicMatcher) -> Self {
        Self {
            epoch: engine.epochs_applied(),
            num_vertices: engine.num_vertices() as u64,
            live_edges: engine.live_edges(),
            matching: engine.matching_pairs(),
        }
    }
}

fn serialize_body(s: &SnapshotData) -> Vec<u8> {
    let mut body =
        Vec::with_capacity(32 + 8 * (s.live_edges.len() + s.matching.len()));
    body.extend_from_slice(&s.epoch.to_le_bytes());
    body.extend_from_slice(&s.num_vertices.to_le_bytes());
    body.extend_from_slice(&(s.live_edges.len() as u64).to_le_bytes());
    body.extend_from_slice(&(s.matching.len() as u64).to_le_bytes());
    for &(u, v) in s.live_edges.iter().chain(s.matching.iter()) {
        body.extend_from_slice(&u.to_le_bytes());
        body.extend_from_slice(&v.to_le_bytes());
    }
    body
}

/// Write `s` to `path` atomically (tmp + fsync + rename). Returns the
/// file's size in bytes.
pub fn write_file(path: &Path, s: &SnapshotData) -> Result<u64, String> {
    let body = serialize_body(s);
    let crc = crc32(&body);
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| format!("create {}: {e}", tmp.display()))?;
        f.write_all(SNAPSHOT_MAGIC)
            .and_then(|_| f.write_all(&body))
            .and_then(|_| f.write_all(&crc.to_le_bytes()))
            .and_then(|_| f.sync_all())
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| format!("rename {} -> {}: {e}", tmp.display(), path.display()))?;
    // best effort: make the rename itself durable
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(8 + body.len() as u64 + 4)
}

/// Read and validate the snapshot at `path`.
pub fn read_file(path: &Path) -> Result<SnapshotData, String> {
    let bytes =
        std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
    if bytes.len() < 8 + 32 + 4 || &bytes[0..8] != SNAPSHOT_MAGIC {
        return Err(format!("{}: not a snapshot file", path.display()));
    }
    let body = &bytes[8..bytes.len() - 4];
    let stored_crc =
        u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    if crc32(body) != stored_crc {
        return Err(format!("{}: snapshot CRC mismatch", path.display()));
    }
    let epoch = u64::from_le_bytes(body[0..8].try_into().unwrap());
    let num_vertices = u64::from_le_bytes(body[8..16].try_into().unwrap());
    let m = u64::from_le_bytes(body[16..24].try_into().unwrap()) as usize;
    let k = u64::from_le_bytes(body[24..32].try_into().unwrap()) as usize;
    if body.len() != 32 + 8 * (m + k) {
        return Err(format!("{}: snapshot length inconsistent", path.display()));
    }
    let mut pairs = Vec::with_capacity(m + k);
    for i in 0..m + k {
        let off = 32 + 8 * i;
        let u = u32::from_le_bytes(body[off..off + 4].try_into().unwrap());
        let v = u32::from_le_bytes(body[off + 4..off + 8].try_into().unwrap());
        pairs.push((u, v));
    }
    let matching = pairs.split_off(m);
    Ok(SnapshotData { epoch, num_vertices, live_edges: pairs, matching })
}

/// Canonical file name of the snapshot for `epoch`.
pub fn file_name(epoch: u64) -> String {
    format!("snap-{epoch:012}.skps")
}

fn parse_epoch(name: &str) -> Option<u64> {
    name.strip_prefix("snap-")
        .and_then(|s| s.strip_suffix(".skps"))
        .and_then(|s| s.parse::<u64>().ok())
}

/// Load the newest valid snapshot in `dir`, skipping (with a warning) any
/// whose CRC or structure fails — a torn or bit-rotted newest file falls
/// back to its predecessor. `Ok(None)` when the directory holds none.
pub fn load_latest(dir: &Path) -> Result<Option<(PathBuf, SnapshotData)>, String> {
    let mut found: Vec<(u64, PathBuf)> = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(ref e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(format!("read {}: {e}", dir.display())),
    };
    for entry in entries {
        let entry = entry.map_err(|e| format!("read {}: {e}", dir.display()))?;
        if let Some(epoch) = parse_epoch(&entry.file_name().to_string_lossy()) {
            found.push((epoch, entry.path()));
        }
    }
    found.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in found {
        match read_file(&path) {
            Ok(s) => return Ok(Some((path, s))),
            Err(e) => eprintln!("snapshot: skipping invalid {e}"),
        }
    }
    Ok(None)
}

/// Delete all but the `keep` newest snapshots. The writer keeps **two**:
/// the newest plus its predecessor, so [`load_latest`]'s corrupt-newest
/// fallback always has somewhere real to land (the WAL pruner lags one
/// snapshot for the same reason — see
/// [`crate::persist::DurableService::after_epoch`]).
pub fn prune_keep(dir: &Path, keep: usize) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut found: Vec<(u64, PathBuf)> = entries
        .flatten()
        .filter_map(|e| {
            parse_epoch(&e.file_name().to_string_lossy()).map(|epoch| (epoch, e.path()))
        })
        .collect();
    found.sort_unstable_by(|a, b| b.0.cmp(&a.0));
    for (_, path) in found.into_iter().skip(keep) {
        if let Err(e) = std::fs::remove_file(path) {
            eprintln!("snapshot prune: {e}");
        }
    }
}

/// Background snapshot writer: serialization and disk IO happen off the
/// flusher thread, so an automatic snapshot never stalls epoch
/// application — the flusher only pays for the barrier copy. At most one
/// snapshot is in flight; a request arriving while one is being written is
/// skipped (the next cadence point retries with fresher state).
pub struct SnapshotWriter {
    tx: Option<SyncSender<SnapshotData>>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// True from a successful hand-off until the writer finishes that
    /// snapshot — lets callers skip the O(|V|+|E|) state capture entirely
    /// while one is in flight.
    busy: Arc<std::sync::atomic::AtomicBool>,
}

impl SnapshotWriter {
    /// Start the writer thread over `dir`, publishing completion through
    /// `counters.last_snapshot_epoch` and pruning superseded snapshots
    /// (keeping the newest two — see [`prune_keep`]).
    pub fn spawn(dir: PathBuf, counters: Arc<DurabilityCounters>) -> Self {
        let (tx, rx) = sync_channel::<SnapshotData>(1);
        let busy = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let busy_writer = Arc::clone(&busy);
        let reg = metrics::global();
        let write_secs = reg.histogram_secs(
            "skipper_snapshot_write_seconds",
            "Snapshot serialize+write+fsync+rename latency",
        );
        let write_bytes = reg.histogram_raw(
            "skipper_snapshot_bytes",
            "On-disk size of each completed snapshot",
        );
        let handle = std::thread::Builder::new()
            .name("skipper-snapshot".into())
            .spawn(move || {
                while let Ok(data) = rx.recv() {
                    let epoch = data.epoch;
                    let path = dir.join(file_name(epoch));
                    let t_obs = std::time::Instant::now();
                    let mut span = trace::span_epoch("snapshot", "persist", epoch, 0);
                    match write_file(&path, &data) {
                        Ok(bytes) => {
                            write_secs.record_duration(t_obs.elapsed());
                            write_bytes.record(bytes);
                            if let Some(s) = span.as_mut() {
                                s.set_arg(bytes);
                            }
                            counters
                                .last_snapshot_epoch
                                .store(epoch, Ordering::Relaxed);
                            prune_keep(&dir, 2);
                        }
                        Err(e) => eprintln!("snapshot: {e}"),
                    }
                    drop(span);
                    busy_writer.store(false, Ordering::Relaxed);
                }
            })
            .expect("spawn snapshot writer");
        Self { tx: Some(tx), handle: Some(handle), busy }
    }

    /// Is a snapshot currently being serialized/written? Callers use this
    /// to avoid capturing a state copy that would only be discarded.
    pub fn is_busy(&self) -> bool {
        self.busy.load(Ordering::Relaxed)
    }

    /// Hand a snapshot to the writer; false when one is already in flight
    /// (the request is dropped, not queued behind stale state). The busy
    /// flag is claimed *before* the send — claiming after would race the
    /// writer's clear and could latch `busy` true forever, silently
    /// disabling every future snapshot.
    pub fn request(&self, data: SnapshotData) -> bool {
        if self.busy.swap(true, Ordering::Relaxed) {
            return false; // one already in flight
        }
        match self.tx.as_ref().expect("writer finished").try_send(data) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.busy.store(false, Ordering::Relaxed);
                false
            }
        }
    }

    /// Send an optional final snapshot (blocking until the writer accepts
    /// it), then drain and join the writer thread. All snapshots handed
    /// over before this call are durably on disk when it returns.
    pub fn finish(&mut self, final_data: Option<SnapshotData>) {
        if let Some(tx) = self.tx.take() {
            if let Some(data) = final_data {
                let _ = tx.send(data);
            }
            drop(tx); // writer drains the channel and exits
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for SnapshotWriter {
    fn drop(&mut self) {
        self.finish(None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static DIR_ID: AtomicU64 = AtomicU64::new(0);

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "skipper_snap_{}_{}_{}",
            std::process::id(),
            tag,
            DIR_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample(epoch: u64) -> SnapshotData {
        SnapshotData {
            epoch,
            num_vertices: 16,
            live_edges: vec![(0, 1), (1, 2), (4, 5)],
            matching: vec![(0, 1), (4, 5)],
        }
    }

    #[test]
    fn roundtrip_and_no_tmp_left_behind() {
        let dir = fresh_dir("roundtrip");
        let path = dir.join(file_name(7));
        write_file(&path, &sample(7)).unwrap();
        assert_eq!(read_file(&path).unwrap(), sample(7));
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
            .collect();
        assert!(leftovers.is_empty(), "tmp file survived the rename");
    }

    #[test]
    fn corrupt_body_is_rejected() {
        let dir = fresh_dir("corrupt");
        let path = dir.join(file_name(3));
        write_file(&path, &sample(3)).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[20] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_file(&path).unwrap_err();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn load_latest_falls_back_past_a_corrupt_newest() {
        let dir = fresh_dir("fallback");
        write_file(&dir.join(file_name(5)), &sample(5)).unwrap();
        write_file(&dir.join(file_name(9)), &sample(9)).unwrap();
        // corrupt the newest: recovery must fall back to epoch 5
        let newest = dir.join(file_name(9));
        let mut bytes = std::fs::read(&newest).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let (path, data) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(data.epoch, 5);
        assert_eq!(path, dir.join(file_name(5)));
        // empty dir → None
        let empty = fresh_dir("empty");
        assert!(load_latest(&empty).unwrap().is_none());
    }

    #[test]
    fn prune_keep_retains_newest_and_its_fallback() {
        let dir = fresh_dir("prune");
        for e in [2u64, 4, 6] {
            write_file(&dir.join(file_name(e)), &sample(e)).unwrap();
        }
        prune_keep(&dir, 2);
        let (path, data) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(data.epoch, 6);
        assert_eq!(path, dir.join(file_name(6)));
        assert!(dir.join(file_name(4)).exists(), "predecessor kept for fallback");
        assert!(!dir.join(file_name(2)).exists(), "older snapshots pruned");
        // corrupting the newest must still leave a loadable fallback
        let newest = dir.join(file_name(6));
        let mut bytes = std::fs::read(&newest).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let (_, data) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(data.epoch, 4);
    }

    #[test]
    fn background_writer_publishes_and_prunes() {
        let dir = fresh_dir("writer");
        // a stale third snapshot the writer must prune past keep-2
        write_file(&dir.join(file_name(1)), &sample(1)).unwrap();
        let counters = Arc::new(DurabilityCounters::default());
        let mut w = SnapshotWriter::spawn(dir.clone(), Arc::clone(&counters));
        assert!(w.request(sample(4)));
        w.finish(Some(sample(8)));
        assert_eq!(counters.last_snapshot_epoch.load(Ordering::Relaxed), 8);
        assert!(!w.is_busy(), "writer idle after finish");
        let (_, data) = load_latest(&dir).unwrap().unwrap();
        assert_eq!(data.epoch, 8);
        assert!(dir.join(file_name(4)).exists(), "fallback predecessor kept");
        assert!(!dir.join(file_name(1)).exists(), "third-newest pruned");
    }

    #[test]
    fn empty_state_snapshots_roundtrip() {
        let dir = fresh_dir("empty_state");
        let s = SnapshotData {
            epoch: 0,
            num_vertices: 8,
            live_edges: Vec::new(),
            matching: Vec::new(),
        };
        let path = dir.join(file_name(0));
        write_file(&path, &s).unwrap();
        assert_eq!(read_file(&path).unwrap(), s);
    }
}
