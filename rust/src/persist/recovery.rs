//! Crash recovery: load the newest valid snapshot, replay the WAL through
//! the real engine epoch machinery, verify maximality, then go live.
//!
//! ## The recovery state machine
//!
//! ```text
//!           ┌────────────┐  none found        ┌──────────────┐
//!  boot ──▶ │ FindSnap   │──────────────────▶ │ OpenWal      │
//!           └─────┬──────┘                    │ (torn-tail   │
//!        newest   │ CRC-valid                 │  truncation) │
//!        valid    ▼                           └──────┬───────┘
//!           ┌────────────┐                           │ records
//!           │ Restore    │  2 engine epochs          ▼
//!           │ (matching, │─────────────────▶ ┌──────────────┐
//!           │ then rest) │                   │ ReplayWal    │
//!           └────────────┘                   │ epoch >      │
//!                                            │ snap_epoch   │
//!                                            └──────┬───────┘
//!                                                   ▼
//!                                   ┌────────────────────────────┐
//!                                   │ Verify (maximality audit)  │──▶ Live
//!                                   └────────────────────────────┘
//! ```
//!
//! ## Why two epochs restore the exact matching
//!
//! [`restore_into`] rebuilds the snapshot through ordinary
//! [`ShardedDynamicMatcher::apply_epoch`] calls — no private state surgery:
//!
//! 1. **Epoch A** inserts exactly the snapshot's matched pairs. The pairs
//!    are endpoint-disjoint, so every edge meets two free (`ACC`) vertices
//!    and Algorithm 1 matches it *along that edge*, deterministically,
//!    regardless of thread count or processing order — the rebuilt
//!    `partner[]` equals the snapshot's.
//! 2. **Epoch B** inserts the remaining live edges. The snapshot's
//!    matching was maximal over its live set, so every remaining edge has
//!    at least one matched endpoint and the insert sweep matches nothing —
//!    the adjacency fills in, the matching is untouched.
//!
//! The core's one-byte states come out right automatically: a vertex is
//! `MCHD` iff it is matched, which is exactly the state a quiescent engine
//! would hold — nothing else needs persisting.
//!
//! WAL records with `epoch > snapshot_epoch` are then replayed in order
//! through the same `apply_epoch` path, the engine's epoch counter resumes
//! at `max(snapshot_epoch, last replayed epoch)` (so post-recovery WAL
//! appends stay monotone), and a full maximality audit gates going live.

use super::snapshot::{self, SnapshotData};
use super::wal::{Wal, WalOptions};
use crate::dynamic::{ShardedDynamicMatcher, Update};
use crate::obs::trace;
use std::collections::HashSet;
use std::path::{Path, PathBuf};

/// The `snapshots/` directory under a service data dir.
pub fn snapshot_dir(data_dir: &Path) -> PathBuf {
    data_dir.join("snapshots")
}

/// The `wal/` directory under a service data dir.
pub fn wal_dir(data_dir: &Path) -> PathBuf {
    data_dir.join("wal")
}

/// What recovery did at boot.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    /// Epoch of the snapshot restored, if one was found.
    pub snapshot_epoch: Option<u64>,
    /// Live edges restored from the snapshot.
    pub snapshot_live_edges: u64,
    /// WAL epochs replayed on top of the snapshot.
    pub replayed_epochs: u64,
    /// Updates contained in the replayed epochs.
    pub replayed_updates: u64,
    /// The epoch counter the engine resumed at.
    pub resumed_epoch: u64,
}

/// Rebuild a snapshot's state in `engine` (which must be freshly
/// constructed over the same vertex universe) through two ordinary engine
/// epochs — matched pairs first, then the remaining live edges. See the
/// module docs for why this reproduces the exact `partner[]` assignment.
pub fn restore_into(
    engine: &ShardedDynamicMatcher,
    snap: &SnapshotData,
) -> Result<(), String> {
    if snap.num_vertices as usize != engine.num_vertices() {
        return Err(format!(
            "snapshot universe |V|={} does not match engine |V|={}",
            snap.num_vertices,
            engine.num_vertices()
        ));
    }
    if engine.num_live_edges() != 0 || engine.epochs_applied() != 0 {
        return Err("snapshot restore requires a fresh engine".into());
    }
    if !snap.matching.is_empty() {
        let pairs: Vec<Update> = snap
            .matching
            .iter()
            .map(|&(u, v)| Update::Insert(u, v))
            .collect();
        engine.apply_epoch(&pairs)?;
    }
    let matched: HashSet<(u32, u32)> = snap.matching.iter().copied().collect();
    let rest: Vec<Update> = snap
        .live_edges
        .iter()
        .filter(|e| !matched.contains(e))
        .map(|&(u, v)| Update::Insert(u, v))
        .collect();
    if !rest.is_empty() {
        engine.apply_epoch(&rest)?;
    }
    // cross-check the reconstruction against the snapshot's own counts; a
    // mismatch means the snapshot was internally inconsistent (e.g. a
    // non-maximal matching, which epoch B would have extended)
    if engine.num_live_edges() != snap.live_edges.len() as u64 {
        return Err(format!(
            "snapshot restore diverged: {} live edges rebuilt, snapshot holds {}",
            engine.num_live_edges(),
            snap.live_edges.len()
        ));
    }
    if engine.matched_vertices() != 2 * snap.matching.len() {
        return Err(format!(
            "snapshot restore diverged: {} matched vertices rebuilt, snapshot matching has {} pairs",
            engine.matched_vertices(),
            snap.matching.len()
        ));
    }
    debug_assert_eq!(
        {
            let mut got = engine.matching_pairs();
            got.sort_unstable();
            got
        },
        {
            let mut want = snap.matching.clone();
            want.sort_unstable();
            want
        },
        "restore must reproduce the snapshot matching exactly"
    );
    Ok(())
}

/// The full boot path over `data_dir`: restore the newest valid snapshot
/// (if any) into the fresh `engine`, open the WAL (truncating a torn
/// tail), replay every record newer than the snapshot, resume the epoch
/// counter, and verify maximality. Returns the opened WAL positioned for
/// appending plus the report.
pub fn recover(
    engine: &ShardedDynamicMatcher,
    data_dir: &Path,
    wal_opts: WalOptions,
) -> Result<(Wal, RecoveryReport), String> {
    let snap_dir = snapshot_dir(data_dir);
    std::fs::create_dir_all(&snap_dir)
        .map_err(|e| format!("mkdir {}: {e}", snap_dir.display()))?;
    let mut report = RecoveryReport::default();
    // umbrella span over the whole boot path; the phase spans below nest
    // inside it in the trace, mirroring the module's state-machine diagram
    let _recovery_span = trace::span("recovery", "recovery", 0);

    // FindSnap → Restore
    let found = {
        let _span = trace::span("recovery_find_snap", "recovery", 0);
        snapshot::load_latest(&snap_dir)?
    };
    if let Some((path, snap)) = found {
        let _span = trace::span("recovery_restore", "recovery", snap.epoch);
        restore_into(engine, &snap)
            .map_err(|e| format!("restore {}: {e}", path.display()))?;
        report.snapshot_epoch = Some(snap.epoch);
        report.snapshot_live_edges = snap.live_edges.len() as u64;
    }
    let snap_epoch = report.snapshot_epoch.unwrap_or(0);

    // OpenWal → ReplayWal. Every applied epoch is logged (WAL-before-
    // apply), so the replayable epochs are *contiguous* from
    // `snapshot_epoch + 1`: a gap means history was lost — e.g. the
    // snapshot that justified pruning those epochs later failed its CRC
    // and recovery fell back past it — and replaying across it would
    // silently serve a diverged live set. Refuse instead. Records stream
    // out of the scan one at a time and are applied immediately (covered
    // ones, epoch ≤ snapshot, are CRC-validated but never materialized),
    // so replay memory is one epoch regardless of log length.
    let mut last_replayed = snap_epoch;
    let wal = {
        let _span = trace::span("recovery_replay_wal", "recovery", snap_epoch);
        let report = &mut report;
        let last_replayed = &mut last_replayed;
        Wal::open_replaying(&wal_dir(data_dir), wal_opts, snap_epoch, &mut |rec| {
            if rec.epoch != *last_replayed + 1 {
                return Err(format!(
                    "wal epoch {} follows {}: epochs {}..{} are missing (out-of-order or pruned \
                     alongside a snapshot that no longer loads) — refusing to replay a gapped history",
                    rec.epoch,
                    *last_replayed,
                    *last_replayed + 1,
                    rec.epoch.saturating_sub(1)
                ));
            }
            engine
                .apply_epoch(&rec.updates)
                .map_err(|e| format!("replay wal epoch {}: {e}", rec.epoch))?;
            report.replayed_epochs += 1;
            report.replayed_updates += rec.updates.len() as u64;
            *last_replayed = rec.epoch;
            Ok(())
        })?
    };

    // resume the durable epoch timeline (restore/replay consumed engine
    // epochs of their own; the durable numbering is what must continue)
    report.resumed_epoch = last_replayed.max(snap_epoch);
    engine.set_epoch_base(report.resumed_epoch);

    // Verify → Live
    {
        let _span = trace::span("recovery_verify", "recovery", report.resumed_epoch);
        engine
            .verify()
            .map_err(|e| format!("recovery produced an invalid matching: {e}"))?;
    }
    Ok((wal, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    static DIR_ID: AtomicU64 = AtomicU64::new(0);

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "skipper_recovery_{}_{}_{}",
            std::process::id(),
            tag,
            DIR_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn restore_reproduces_the_exact_matching() {
        // path 0-1-2-3-4 plus an isolated matched pair (6,7): matching
        // (0,1), (2,3), (6,7); edges (1,2), (3,4) unmatched
        let snap = SnapshotData {
            epoch: 42,
            num_vertices: 8,
            live_edges: vec![(0, 1), (1, 2), (2, 3), (3, 4), (6, 7)],
            matching: vec![(0, 1), (2, 3), (6, 7)],
        };
        for shards in [1usize, 4] {
            let engine = ShardedDynamicMatcher::new(8, 2, shards);
            restore_into(&engine, &snap).unwrap();
            let mut pairs = engine.matching_pairs();
            pairs.sort_unstable();
            assert_eq!(pairs, snap.matching, "P={shards}");
            assert_eq!(engine.num_live_edges(), 5, "P={shards}");
            engine.verify().unwrap();
        }
    }

    #[test]
    fn restore_rejects_wrong_universe_and_dirty_engine() {
        let snap = SnapshotData {
            epoch: 1,
            num_vertices: 8,
            live_edges: vec![(0, 1)],
            matching: vec![(0, 1)],
        };
        let wrong = ShardedDynamicMatcher::new(16, 1, 1);
        assert!(restore_into(&wrong, &snap).unwrap_err().contains("|V|"));
        let dirty = ShardedDynamicMatcher::new(8, 1, 1);
        dirty.apply_epoch(&[Update::Insert(2, 3)]).unwrap();
        assert!(restore_into(&dirty, &snap).unwrap_err().contains("fresh"));
    }

    #[test]
    fn recover_from_empty_dir_is_a_fresh_start() {
        let dir = fresh_dir("fresh");
        let engine = ShardedDynamicMatcher::new(8, 1, 1);
        let (_wal, report) = recover(&engine, &dir, WalOptions::default()).unwrap();
        assert_eq!(report.snapshot_epoch, None);
        assert_eq!(report.replayed_epochs, 0);
        assert_eq!(report.resumed_epoch, 0);
        assert_eq!(engine.epochs_applied(), 0);
    }

    #[test]
    fn recover_replays_wal_on_top_of_snapshot() {
        let dir = fresh_dir("replay");
        // first life: snapshot at epoch 2, then two more logged epochs
        {
            let engine = ShardedDynamicMatcher::new(16, 1, 4);
            let (mut wal, _) = recover(&engine, &dir, WalOptions::default()).unwrap();
            let e1 = vec![Update::Insert(0, 1), Update::Insert(2, 3)];
            wal.append_epoch(1, &e1).unwrap();
            engine.apply_epoch(&e1).unwrap();
            let e2 = vec![Update::Insert(4, 5)];
            wal.append_epoch(2, &e2).unwrap();
            engine.apply_epoch(&e2).unwrap();
            snapshot::write_file(
                &snapshot_dir(&dir).join(snapshot::file_name(2)),
                &SnapshotData::capture(&engine),
            )
            .unwrap();
            let e3 = vec![Update::Delete(0, 1), Update::Insert(8, 9)];
            wal.append_epoch(3, &e3).unwrap();
            engine.apply_epoch(&e3).unwrap();
            let e4 = vec![Update::Delete(4, 5)];
            wal.append_epoch(4, &e4).unwrap();
            engine.apply_epoch(&e4).unwrap();
        } // crash: no final snapshot
        let engine = ShardedDynamicMatcher::new(16, 1, 4);
        let (_wal, report) = recover(&engine, &dir, WalOptions::default()).unwrap();
        assert_eq!(report.snapshot_epoch, Some(2));
        assert_eq!(report.replayed_epochs, 2, "epochs 3 and 4 replayed");
        assert_eq!(report.resumed_epoch, 4);
        assert_eq!(engine.epochs_applied(), 4, "counter resumes the timeline");
        let mut live = engine.live_edges();
        live.sort_unstable();
        assert_eq!(live, vec![(2, 3), (8, 9)]);
        engine.verify().unwrap();
        // the next life logs epoch 5 without tripping the monotonicity check
    }

    #[test]
    fn out_of_order_wal_is_refused() {
        let dir = fresh_dir("order");
        {
            let (mut wal, _) =
                Wal::open(&wal_dir(&dir), WalOptions::default()).unwrap();
            wal.append_epoch(3, &[Update::Insert(0, 1)]).unwrap();
            // bypass the debug assertion by reopening
            drop(wal);
            let (mut wal, _) =
                Wal::open(&wal_dir(&dir), WalOptions { segment_bytes: 1, ..WalOptions::default() })
                    .unwrap();
            // segment_bytes=1 forces rotation, so the out-of-order record
            // lands in a fresh segment and survives the scan
            wal.append_epoch(2, &[Update::Insert(2, 3)]).unwrap();
        }
        let engine = ShardedDynamicMatcher::new(8, 1, 1);
        let err = match recover(&engine, &dir, WalOptions::default()) {
            Ok(_) => panic!("out-of-order wal must not recover"),
            Err(e) => e,
        };
        assert!(err.contains("gapped history"), "{err}");
    }

    #[test]
    fn gapped_wal_after_a_lost_snapshot_is_refused() {
        // epochs 1..4 logged and applied, snapshot at 2 published, WAL
        // segments ≤ 2 pruned — then the snapshot file is lost (the
        // corrupt-newest fallback scenario): recovery must refuse to
        // replay 3..4 onto an empty engine rather than serve a state
        // missing the first two epochs
        let dir = fresh_dir("lost_snap");
        {
            let engine = ShardedDynamicMatcher::new(16, 1, 1);
            let opts = WalOptions { segment_bytes: 1, ..WalOptions::default() };
            let (mut wal, _) = Wal::open(&wal_dir(&dir), opts).unwrap();
            for e in 1..=4u64 {
                let ups = vec![Update::Insert(2 * e as u32 - 2, 2 * e as u32 - 1)];
                wal.append_epoch(e, &ups).unwrap();
                engine.apply_epoch(&ups).unwrap();
            }
            let snap_dir = snapshot_dir(&dir);
            std::fs::create_dir_all(&snap_dir).unwrap();
            snapshot::write_file(
                &snap_dir.join(snapshot::file_name(2)),
                &SnapshotData {
                    epoch: 2,
                    num_vertices: 16,
                    live_edges: vec![(0, 1), (2, 3)],
                    matching: vec![(0, 1), (2, 3)],
                },
            )
            .unwrap();
            wal.prune_below(2);
        }
        // the snapshot vanishes (corruption fallback / deletion)
        std::fs::remove_file(snapshot_dir(&dir).join(snapshot::file_name(2))).unwrap();
        let engine = ShardedDynamicMatcher::new(16, 1, 1);
        let err = match recover(&engine, &dir, WalOptions::default()) {
            Ok(_) => panic!("gapped wal must not recover"),
            Err(e) => e,
        };
        assert!(err.contains("missing"), "{err}");
        // with the snapshot intact the same dir recovers fine
    }
}
