//! Incremental Skipper (paper §V-C: "Skipper is also **incremental in
//! expectation**"): because an edge's fate is decided in one JIT-resolved
//! step that never revisits other edges, a maximal matching can be
//! *maintained* under edge insertions by running the same per-edge state
//! machine on just the new edges — no recomputation over the old graph.
//!
//! Since the streaming refactor this is a thin veneer over the shared
//! machinery: one long-lived [`SkipperCore`] holds the vertex states across
//! batches, and each `insert_batch` pushes the new edges through the
//! [`StreamingSkipper`] chunk driver via a
//! [`BatchEdgeSource`](crate::graph::stream::BatchEdgeSource) — the
//! batch-update scenario is literally the streaming pipeline with an
//! in-memory source.

use super::core::SkipperCore;
use super::streaming::StreamingSkipper;
use super::{MatchArena, Matching, BUFFER_EDGES};
use crate::graph::stream::BatchEdgeSource;
use crate::VertexId;

/// Insert-only maintenance of a maximal matching: one long-lived core,
/// batches pushed through the streaming driver.
pub struct IncrementalMatcher {
    core: SkipperCore,
    driver: StreamingSkipper,
    matches: Vec<(VertexId, VertexId)>,
}

impl IncrementalMatcher {
    /// Matcher over `0..num_vertices` with `threads` sweep threads.
    pub fn new(num_vertices: usize, threads: usize) -> Self {
        Self {
            core: SkipperCore::new(num_vertices),
            driver: StreamingSkipper::new(threads),
            matches: Vec::new(),
        }
    }

    /// Size of the vertex universe.
    pub fn num_vertices(&self) -> usize {
        self.core.num_vertices()
    }

    /// Current matching (all batches so far), borrowed — no per-call copy
    /// of the pair vector.
    pub fn matching(&self) -> &[(VertexId, VertexId)] {
        &self.matches
    }

    /// Owned [`Matching`] for callers that need one (e.g. the `verify`
    /// helpers); this is the only place the pairs are cloned.
    pub fn to_matching(&self) -> Matching {
        Matching::from_pairs(self.matches.clone())
    }

    /// Is `v` matched after the batches applied so far?
    pub fn is_matched(&self, v: VertexId) -> bool {
        self.core.is_matched(v)
    }

    /// Insert a batch of edges; returns the number of new matches. Edges
    /// may reference any vertex `< num_vertices`; self-loops are skipped.
    /// The maximality invariant after the call: every edge inserted so far
    /// has at least one matched endpoint.
    pub fn insert_batch(&mut self, edges: &[(VertexId, VertexId)]) -> usize {
        let arena = MatchArena::with_capacity(
            edges.len().min(self.core.num_vertices())
                + (self.driver.threads + 1) * BUFFER_EDGES,
        );
        // Size chunks so even a small batch spreads across all consumers
        // instead of landing in one default-sized chunk.
        let driver = StreamingSkipper {
            chunk_edges: edges
                .len()
                .div_ceil(self.driver.threads)
                .clamp(1, self.driver.chunk_edges),
            ..self.driver
        };
        driver
            .run_with_core(
                &self.core,
                &arena,
                // dedup: a client repeating an insert within the batch gets
                // one edge processed, not several counted.
                BatchEdgeSource::new(self.core.num_vertices(), edges).with_dedup(),
            )
            .expect("batch insertion failed");
        let new = arena.into_matching();
        let added = new.len();
        self.matches.extend(new.iter());
        added
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{build, BuildOptions};
    use crate::graph::gen::{erdos_renyi, simple};
    use crate::graph::EdgeList;
    use crate::instrument::NoProbe;
    use crate::matching::verify;
    use crate::util::rng::Xoshiro256pp;

    /// Validate the incremental matching against the union of all edges
    /// inserted so far.
    fn check_against(edges: &[(VertexId, VertexId)], n: usize, inc: &IncrementalMatcher) {
        let mut el = EdgeList::new(n);
        for &(u, v) in edges {
            el.push(u, v);
        }
        let g = build(&el, BuildOptions::default());
        verify::check(&g, &inc.to_matching()).expect("incremental matching invalid");
    }

    #[test]
    fn single_batch_equals_skipper() {
        let g = simple::path(64);
        let edges: Vec<_> = crate::matching::ems::canonical_edges(&g);
        let mut inc = IncrementalMatcher::new(64, 2);
        inc.insert_batch(&edges);
        check_against(&edges, 64, &inc);
    }

    #[test]
    fn multiple_batches_maintain_maximality() {
        let n = 2000;
        let mut rng = Xoshiro256pp::new(42);
        let mut inc = IncrementalMatcher::new(n, 4);
        let mut all: Vec<(VertexId, VertexId)> = Vec::new();
        for batch in 0..10 {
            let edges: Vec<(VertexId, VertexId)> = (0..500)
                .map(|_| {
                    (
                        rng.next_usize(n) as VertexId,
                        rng.next_usize(n) as VertexId,
                    )
                })
                .collect();
            let before = inc.matching().len();
            let added = inc.insert_batch(&edges);
            all.extend(&edges);
            check_against(&all, n, &inc);
            assert_eq!(inc.matching().len(), before + added, "batch {batch}");
        }
    }

    #[test]
    fn inserting_covered_edges_adds_nothing() {
        let mut inc = IncrementalMatcher::new(4, 2);
        assert_eq!(inc.insert_batch(&[(0, 1)]), 1);
        // both endpoints of (0,1) matched; (0,2),(1,3) can still match 2,3?
        // (0,2): 0 is matched -> no. (2,3): both free -> match.
        assert_eq!(inc.insert_batch(&[(0, 2)]), 0);
        assert_eq!(inc.insert_batch(&[(2, 3)]), 1);
        assert_eq!(inc.matching().len(), 2);
        assert!(inc.is_matched(0) && inc.is_matched(3));
    }

    #[test]
    fn self_loops_ignored() {
        let mut inc = IncrementalMatcher::new(3, 1);
        assert_eq!(inc.insert_batch(&[(1, 1), (1, 1)]), 0);
        assert!(!inc.is_matched(1));
    }

    #[test]
    fn incremental_matches_batch_rerun_size_band() {
        // maintaining incrementally should produce a matching within the
        // 2-approx band of recomputing from scratch
        let n = 4096;
        let g = erdos_renyi::generate(n, 4 * n, 7);
        let edges = crate::matching::ems::canonical_edges(&g);
        let mut inc = IncrementalMatcher::new(n, 4);
        for chunk in edges.chunks(1000) {
            inc.insert_batch(chunk);
        }
        let scratch = crate::matching::sgmm::Sgmm
            .run_probed(&g, &mut NoProbe)
            .len();
        let m = inc.matching().len();
        assert!(m * 2 >= scratch && scratch * 2 >= m, "{m} vs {scratch}");
        verify::check(&g, &inc.to_matching()).unwrap();
    }
}
