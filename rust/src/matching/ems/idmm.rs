//! Internally-Deterministic MM (Blelloch, Fineman, Gibbons, Shun, PPoPP'12)
//! — the parallel-reservation EMS instance (paper §II-D).
//!
//! Each iteration: *reserve* — every live edge writes its priority into both
//! endpoints, keeping the minimum; *commit* — an edge whose priority is the
//! minimum at both endpoints becomes a match; live edges with a matched
//! endpoint are pruned. Deterministic given the priority array.

use super::canonical_edges;
use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::matching::{MaximalMatcher, Matching};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Debug)]
/// Incremental deterministic maximal matching (EMS baseline).
pub struct Idmm {
    /// Edge priorities; `None` uses edge IDs (the IDMM default). A random
    /// permutation gives the expected O(log) round count.
    pub priorities: Option<Vec<u32>>,
}

impl Default for Idmm {
    fn default() -> Self {
        Self { priorities: None }
    }
}

impl Idmm {
    /// Random edge priorities → expected O(log) rounds.
    pub fn with_random_priorities(num_edges: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256pp::new(seed);
        Self {
            priorities: Some(rng.permutation(num_edges)),
        }
    }

    /// Run with an access probe; returns the matching and round count.
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> (Matching, usize) {
        let edges = canonical_edges(g);
        // extraction itself reads the topology once
        probe.load(address::offsets(0));
        for i in 0..g.num_edge_slots() as u64 {
            probe.load(address::neighbors(i));
        }
        let mut matched = vec![false; g.num_vertices()];
        let mut matches = Vec::new();
        let mut active: Vec<u32> = (0..edges.len() as u32).collect();
        let pri = |e: u32| -> u32 {
            match &self.priorities {
                Some(p) => p[e as usize],
                None => e,
            }
        };
        let mut reserve: Vec<u32> = vec![u32::MAX; g.num_vertices()];
        let mut rounds = 0usize;
        while !active.is_empty() {
            rounds += 1;
            // reserve phase
            for &e in &active {
                let (u, v) = edges[e as usize];
                let p = pri(e);
                probe.load(address::aux(e as u64));
                probe.rmw(address::state(u as u64)); // priority write-min
                probe.rmw(address::state(v as u64));
                if p < reserve[u as usize] {
                    reserve[u as usize] = p;
                }
                if p < reserve[v as usize] {
                    reserve[v as usize] = p;
                }
            }
            // commit phase
            for &e in &active {
                let (u, v) = edges[e as usize];
                let p = pri(e);
                probe.load(address::state(u as u64));
                probe.load(address::state(v as u64));
                if reserve[u as usize] == p && reserve[v as usize] == p {
                    matched[u as usize] = true;
                    matched[v as usize] = true;
                    probe.store(address::state_bit(u as u64));
                    probe.store(address::state_bit(v as u64));
                    probe.store(address::matches(matches.len() as u64));
                    matches.push((u, v));
                }
            }
            // prune + reset reservations of surviving endpoints
            let mut next: Vec<u32> = Vec::with_capacity(active.len());
            for &e in &active {
                let (u, v) = edges[e as usize];
                probe.load(address::state_bit(u as u64));
                probe.load(address::state_bit(v as u64));
                if !matched[u as usize] && !matched[v as usize] {
                    next.push(e);
                    probe.store(address::aux2(e as u64));
                }
                reserve[u as usize] = u32::MAX;
                reserve[v as usize] = u32::MAX;
                probe.store(address::state(u as u64));
                probe.store(address::state(v as u64));
            }
            active = next;
        }
        (Matching::from_pairs(matches), rounds)
    }
}

impl MaximalMatcher for Idmm {
    fn name(&self) -> String {
        "IDMM".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe).0
    }
}

/// Expose one reserve/commit round over an explicit edge set — shared by
/// SIDMM (which runs IDMM on sampled edges) and PBMM (which runs it on
/// priority-prefix batches). Returns matches found this round; `live`
/// is pruned in place.
pub fn idmm_rounds_on_edges<P: Probe>(
    edges: &[(VertexId, VertexId)],
    priorities: &[u32],
    matched: &mut [bool],
    reserve: &mut [u32],
    matches: &mut Vec<(VertexId, VertexId)>,
    probe: &mut P,
) -> usize {
    let mut active: Vec<u32> = (0..edges.len() as u32)
        .filter(|&e| {
            let (u, v) = edges[e as usize];
            !matched[u as usize] && !matched[v as usize]
        })
        .collect();
    let mut rounds = 0;
    while !active.is_empty() {
        rounds += 1;
        for &e in &active {
            let (u, v) = edges[e as usize];
            let p = priorities[e as usize];
            probe.rmw(address::state(u as u64));
            probe.rmw(address::state(v as u64));
            if p < reserve[u as usize] {
                reserve[u as usize] = p;
            }
            if p < reserve[v as usize] {
                reserve[v as usize] = p;
            }
        }
        for &e in &active {
            let (u, v) = edges[e as usize];
            let p = priorities[e as usize];
            probe.load(address::state(u as u64));
            probe.load(address::state(v as u64));
            if reserve[u as usize] == p && reserve[v as usize] == p {
                matched[u as usize] = true;
                matched[v as usize] = true;
                probe.store(address::state_bit(u as u64));
                probe.store(address::state_bit(v as u64));
                probe.store(address::matches(matches.len() as u64));
                matches.push((u, v));
            }
        }
        let mut next = Vec::with_capacity(active.len());
        for &e in &active {
            let (u, v) = edges[e as usize];
            probe.load(address::state_bit(u as u64));
            probe.load(address::state_bit(v as u64));
            reserve[u as usize] = u32::MAX;
            reserve[v as usize] = u32::MAX;
            probe.store(address::state(u as u64));
            probe.store(address::state(v as u64));
            if !matched[u as usize] && !matched[v as usize] {
                next.push(e);
            }
        }
        active = next;
    }
    rounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::matching::verify;

    #[test]
    fn path_deterministic() {
        let g = simple::path(7);
        let m = Idmm::default().run(&g);
        verify::check(&g, &m).unwrap();
        // edge ids along the path: (0,1)=0 wins, (2,3)=2 wins, (4,5)=4 wins
        assert_eq!(m.to_sorted_vec(), vec![(0, 1), (2, 3), (4, 5)]);
    }

    #[test]
    fn valid_on_rmat() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 1 });
        let m = Idmm::default().run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn random_priorities_still_maximal() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 2 });
        let ne = super::canonical_edges(&g).len();
        let m = Idmm::with_random_priorities(ne, 99).run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn deterministic_given_priorities() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 3 });
        let ne = super::canonical_edges(&g).len();
        let a = Idmm::with_random_priorities(ne, 7).run(&g);
        let b = Idmm::with_random_priorities(ne, 7).run(&g);
        assert_eq!(a.to_sorted_vec(), b.to_sorted_vec());
    }

    #[test]
    fn round_count_reported() {
        let g = simple::path(64);
        let (_, rounds) = Idmm::default().run_probed(&g, &mut NoProbe);
        assert!(rounds >= 1);
    }
}
