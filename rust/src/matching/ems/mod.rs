//! The Endpoints-Mutual-Selection (EMS) baseline family (paper §II-C/D).
//!
//! All of these algorithms share the two-step structure the paper critiques:
//! a *selection* step where each vertex/edge picks a candidate and a
//! *refinement* step where mutually-selected edges commit — iterated with
//! graph pruning until maximal. They exist here to reproduce the paper's
//! comparisons (SIDMM is the evaluated comparator; the others populate the
//! related-work ablations).

pub mod auer_bisseling;
pub mod birn;
pub mod idmm;
pub mod israeli_itai;
pub mod pbmm;
pub mod sidmm;

use crate::graph::CsrGraph;
use crate::VertexId;

/// Canonical (u < v) edge array extracted from a symmetric CSR graph.
/// Self-loops are dropped (no MM algorithm can match them).
pub fn canonical_edges(g: &CsrGraph) -> Vec<(VertexId, VertexId)> {
    let mut edges = Vec::with_capacity(g.num_edge_slots() / 2);
    for (v, u) in g.iter_edges() {
        if v < u {
            edges.push((v, u));
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::simple;

    #[test]
    fn canonical_edges_unique_and_ordered() {
        let g = simple::cycle(6);
        let e = canonical_edges(&g);
        assert_eq!(e.len(), 6);
        for &(u, v) in &e {
            assert!(u < v);
        }
        let mut dedup = e.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), e.len());
    }
}
