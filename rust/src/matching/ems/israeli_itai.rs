//! Israeli & Itai (1986): the original randomized EMS algorithm (paper
//! §II-D). Each iteration every live vertex selects a random live incident
//! edge; mutually-selected edges match; matched vertices and their edges
//! are pruned.

use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::matching::{MaximalMatcher, Matching};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Israeli–Itai random-selection matching (EMS baseline).
pub struct IsraeliItai {
    /// Selection seed.
    pub seed: u64,
}

impl Default for IsraeliItai {
    fn default() -> Self {
        Self { seed: 0x15A3 }
    }
}

impl IsraeliItai {
    /// Run with an access probe; returns the matching and iteration count.
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> (Matching, usize) {
        let n = g.num_vertices();
        let mut rng = Xoshiro256pp::new(self.seed);
        let mut matched = vec![false; n];
        let mut selection: Vec<VertexId> = vec![VertexId::MAX; n];
        let mut matches: Vec<(VertexId, VertexId)> = Vec::new();
        let mut live: Vec<VertexId> = (0..n as VertexId).collect();
        let mut iterations = 0usize;

        while !live.is_empty() {
            iterations += 1;
            // selection step: pick a random live neighbor
            let mut any_selection = false;
            for &v in &live {
                selection[v as usize] = VertexId::MAX;
                probe.load(address::offsets(v as u64));
                probe.load(address::offsets(v as u64 + 1));
                let base = g.offsets()[v as usize];
                // reservoir-sample a live neighbor
                let mut count = 0u64;
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    probe.load(address::neighbors(base + i as u64));
                    if u == v {
                        continue;
                    }
                    probe.load(address::state_bit(u as u64));
                    if !matched[u as usize] {
                        count += 1;
                        if rng.next_below(count) == 0 {
                            selection[v as usize] = u;
                        }
                    }
                }
                probe.store(address::aux(v as u64));
                if selection[v as usize] != VertexId::MAX {
                    any_selection = true;
                }
            }
            if !any_selection {
                break; // no live edges remain
            }
            // refinement step: mutual selections become matches
            for &v in &live {
                let u = selection[v as usize];
                probe.load(address::aux(v as u64));
                if u == VertexId::MAX || u < v {
                    continue; // count each pair once (from the lower side)
                }
                probe.load(address::aux(u as u64));
                if selection[u as usize] == v && !matched[v as usize] && !matched[u as usize] {
                    matched[v as usize] = true;
                    matched[u as usize] = true;
                    probe.store(address::state_bit(v as u64));
                    probe.store(address::state_bit(u as u64));
                    probe.store(address::matches(matches.len() as u64));
                    matches.push((v, u));
                }
            }
            // prune: drop matched vertices and vertices with no live neighbor
            live.retain(|&v| {
                probe.load(address::state_bit(v as u64));
                if matched[v as usize] {
                    return false;
                }
                let has_live = g
                    .neighbors(v)
                    .iter()
                    .any(|&u| u != v && !matched[u as usize]);
                has_live
            });
        }
        (Matching::from_pairs(matches), iterations)
    }
}

impl MaximalMatcher for IsraeliItai {
    fn name(&self) -> String {
        "Israeli-Itai".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::matching::verify;

    #[test]
    fn valid_on_small_graphs() {
        for g in [simple::path(11), simple::cycle(10), simple::star(15), simple::complete(9)] {
            let m = IsraeliItai::default().run(&g);
            verify::check(&g, &m).unwrap();
        }
    }

    #[test]
    fn valid_on_rmat() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 2 });
        let m = IsraeliItai::default().run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn geometric_convergence() {
        // Randomized mutual selection converges in few iterations.
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 3 });
        let (_, iters) = IsraeliItai::default().run_probed(&g, &mut NoProbe);
        assert!(iters < 60, "took {iters} iterations");
    }
}
