//! Birn, Osipov, Sanders, Schulz, Sitchinava (Euro-Par'13) — "local max"
//! matching (paper §II-D): each iteration assigns random weights to live
//! edges; an edge that is the heaviest incident edge at *both* endpoints is
//! matched; covered edges are pruned.

use super::canonical_edges;
use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::matching::{MaximalMatcher, Matching};
use crate::util::rng::SplitMix64;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Birn et al. local-max edge matching (EMS baseline).
pub struct Birn {
    /// Stateless per-iteration weight seed.
    pub seed: u64,
}

impl Default for Birn {
    fn default() -> Self {
        Self { seed: 0xB19 }
    }
}

/// Per-(iteration, edge) random weight: stateless hash so no per-edge
/// weight array must persist across iterations. Ties are broken by edge id
/// (weights embed the id in the low bits).
fn weight(seed: u64, iter: u64, edge: u32) -> u64 {
    let mut h = SplitMix64::new(seed ^ (iter << 32) ^ edge as u64);
    (h.next_u64() & !0xFFFF_FFFF) | edge as u64
}

impl Birn {
    /// Run with an access probe; returns the matching and iteration count.
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> (Matching, usize) {
        let edges = canonical_edges(g);
        let n = g.num_vertices();
        let mut matched = vec![false; n];
        let mut best: Vec<u64> = vec![0; n];
        let mut matches: Vec<(VertexId, VertexId)> = Vec::new();
        let mut active: Vec<u32> = (0..edges.len() as u32).collect();
        let mut iterations = 0usize;

        while !active.is_empty() {
            iterations += 1;
            // heaviest incident edge per endpoint
            for &e in &active {
                let (u, v) = edges[e as usize];
                let w = weight(self.seed, iterations as u64, e);
                probe.rmw(address::state(u as u64));
                probe.rmw(address::state(v as u64));
                if w > best[u as usize] {
                    best[u as usize] = w;
                }
                if w > best[v as usize] {
                    best[v as usize] = w;
                }
            }
            // commit local maxima
            for &e in &active {
                let (u, v) = edges[e as usize];
                let w = weight(self.seed, iterations as u64, e);
                probe.load(address::state(u as u64));
                probe.load(address::state(v as u64));
                if best[u as usize] == w && best[v as usize] == w {
                    matched[u as usize] = true;
                    matched[v as usize] = true;
                    probe.store(address::state_bit(u as u64));
                    probe.store(address::state_bit(v as u64));
                    probe.store(address::matches(matches.len() as u64));
                    matches.push((u, v));
                }
            }
            // prune + reset
            let mut next = Vec::with_capacity(active.len());
            for &e in &active {
                let (u, v) = edges[e as usize];
                best[u as usize] = 0;
                best[v as usize] = 0;
                probe.store(address::state(u as u64));
                probe.store(address::state(v as u64));
                probe.load(address::state_bit(u as u64));
                probe.load(address::state_bit(v as u64));
                if !matched[u as usize] && !matched[v as usize] {
                    next.push(e);
                }
            }
            active = next;
        }
        (Matching::from_pairs(matches), iterations)
    }
}

impl MaximalMatcher for Birn {
    fn name(&self) -> String {
        "Birn-LocalMax".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::matching::verify;

    #[test]
    fn weights_unique_per_edge() {
        let a = weight(1, 1, 10);
        let b = weight(1, 1, 11);
        assert_ne!(a, b);
        // id tiebreak survives in low bits
        assert_eq!(a as u32, 10);
    }

    #[test]
    fn valid_on_small_graphs() {
        for g in [simple::path(12), simple::cycle(13), simple::star(14), simple::complete(7)] {
            let m = Birn::default().run(&g);
            verify::check(&g, &m).unwrap();
        }
    }

    #[test]
    fn valid_on_rmat() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 4 });
        let m = Birn::default().run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn converges_quickly() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 5 });
        let (_, iters) = Birn::default().run_probed(&g, &mut NoProbe);
        assert!(iters < 40, "took {iters} iterations");
    }
}
