//! Auer & Bisseling (2012) red/blue GPU matching (paper §II-D): each
//! iteration randomly colors live vertices red or blue; blue vertices
//! propose to a random live red neighbor; each red vertex accepts the
//! lowest-id proposal; matched and dead vertices leave the graph.

use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::matching::{MaximalMatcher, Matching};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Auer–Bisseling red/blue proposal matching (EMS baseline).
pub struct AuerBisseling {
    /// Coloring/proposal seed.
    pub seed: u64,
}

impl Default for AuerBisseling {
    fn default() -> Self {
        Self { seed: 0xAB }
    }
}

impl AuerBisseling {
    /// Run with an access probe; returns the matching and iteration count.
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> (Matching, usize) {
        let n = g.num_vertices();
        let mut rng = Xoshiro256pp::new(self.seed);
        let mut matched = vec![false; n];
        let mut proposal: Vec<VertexId> = vec![VertexId::MAX; n]; // red <- min blue proposer
        let mut matches: Vec<(VertexId, VertexId)> = Vec::new();
        let mut live: Vec<VertexId> = (0..n as VertexId).collect();
        let mut blue = vec![false; n];
        let mut iterations = 0usize;

        while !live.is_empty() {
            iterations += 1;
            // color step
            for &v in &live {
                blue[v as usize] = rng.next_u64() & 1 == 0;
                probe.store(address::aux(v as u64));
            }
            // proposal step: blue v proposes to a random live red neighbor
            let mut any_proposal = false;
            for &v in &live {
                if !blue[v as usize] {
                    continue;
                }
                probe.load(address::offsets(v as u64));
                probe.load(address::offsets(v as u64 + 1));
                let base = g.offsets()[v as usize];
                let mut target = VertexId::MAX;
                let mut count = 0u64;
                for (i, &u) in g.neighbors(v).iter().enumerate() {
                    probe.load(address::neighbors(base + i as u64));
                    if u == v {
                        continue;
                    }
                    probe.load(address::state_bit(u as u64));
                    probe.load(address::aux(u as u64));
                    if !matched[u as usize] && !blue[u as usize] {
                        count += 1;
                        if rng.next_below(count) == 0 {
                            target = u;
                        }
                    }
                }
                if target != VertexId::MAX {
                    // accept lowest proposer id (deterministic tie-break)
                    probe.rmw(address::aux2(target as u64));
                    if v < proposal[target as usize] {
                        proposal[target as usize] = v;
                    }
                    any_proposal = true;
                }
            }
            // accept step: red vertex matches its chosen proposer
            if any_proposal {
                for &v in &live {
                    if blue[v as usize] {
                        continue;
                    }
                    probe.load(address::aux2(v as u64));
                    let p = proposal[v as usize];
                    if p != VertexId::MAX && !matched[v as usize] && !matched[p as usize] {
                        matched[v as usize] = true;
                        matched[p as usize] = true;
                        probe.store(address::state_bit(v as u64));
                        probe.store(address::state_bit(p as u64));
                        probe.store(address::matches(matches.len() as u64));
                        matches.push((v.min(p), v.max(p)));
                    }
                    proposal[v as usize] = VertexId::MAX;
                    probe.store(address::aux2(v as u64));
                }
            }
            // prune: matched vertices and vertices with no live neighbors
            live.retain(|&v| {
                probe.load(address::state_bit(v as u64));
                if matched[v as usize] {
                    return false;
                }
                g.neighbors(v).iter().any(|&u| u != v && !matched[u as usize])
            });
        }
        (Matching::from_pairs(matches), iterations)
    }
}

impl MaximalMatcher for AuerBisseling {
    fn name(&self) -> String {
        "Auer-Bisseling".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::matching::verify;

    #[test]
    fn valid_on_small_graphs() {
        for g in [simple::path(13), simple::cycle(11), simple::star(18), simple::complete(6)] {
            let m = AuerBisseling::default().run(&g);
            verify::check(&g, &m).unwrap();
        }
    }

    #[test]
    fn valid_on_rmat() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 6 });
        let m = AuerBisseling::default().run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn bipartite_graphs_match_well() {
        let g = simple::bipartite_random(200, 200, 2000, 3);
        let m = AuerBisseling::default().run(&g);
        verify::check(&g, &m).unwrap();
        assert!(m.len() > 50);
    }

    #[test]
    fn converges() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 7 });
        let (_, iters) = AuerBisseling::default().run_probed(&g, &mut NoProbe);
        assert!(iters < 80, "took {iters} iterations");
    }
}
