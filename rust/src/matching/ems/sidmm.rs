//! Sampling-based Internally-Deterministic MM — the GBBS "RandomGreedy"
//! comparator the paper evaluates against (§II-D, §VI).
//!
//! Each iteration performs the two-pass sampling the paper describes:
//!
//! 1. **Pass 1** — build a live-degree offsets array: for every unmatched
//!    vertex, count unmatched neighbors.
//! 2. **Pass 2** — draw sample positions uniformly over the live-edge count,
//!    map each position back to a `(v, u)` pair by walking the offsets
//!    array and scanning the owning vertex's neighbor list.
//!
//! The sampled edges are matched with IDMM reserve/commit rounds; matched
//! vertices go inactive and the process repeats. The repeated passes over
//! vertices and neighbor lists are exactly the overhead Figures 3/7 charge
//! to SIDMM (17–27 accesses per edge).

use super::idmm::idmm_rounds_on_edges;
use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::matching::{MaximalMatcher, Matching};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Sampling-based IDMM — the paper’s GBBS comparator.
pub struct Sidmm {
    /// Samples drawn per iteration; 0 → `max(|V|/8, 512)` (a GBBS-style
    /// "small constant fraction of n": smaller samples mean more sampling
    /// iterations — the work-inefficiency the paper's Figs 3/7 measure).
    pub samples_per_iter: usize,
    /// Sampling seed.
    pub seed: u64,
}

impl Default for Sidmm {
    fn default() -> Self {
        Self {
            samples_per_iter: 0,
            seed: 0x51D3,
        }
    }
}

#[derive(Debug, Default, Clone, Copy)]
/// Work counters of one SIDMM run (feeds the Fig 3 overhead plot).
pub struct SidmmTelemetry {
    /// Sampling iterations.
    pub iterations: usize,
    /// Rounds of the final IDMM cleanup.
    pub idmm_rounds: usize,
    /// Total edges drawn by sampling.
    pub sampled_edges: u64,
}

impl Sidmm {
    /// Run with an access probe; returns the matching and work telemetry.
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> (Matching, SidmmTelemetry) {
        let n = g.num_vertices();
        let k_default = (n / 8).max(512);
        let k_target = if self.samples_per_iter == 0 {
            k_default
        } else {
            self.samples_per_iter
        };
        let mut rng = Xoshiro256pp::new(self.seed);
        let mut matched = vec![false; n];
        let mut matches: Vec<(VertexId, VertexId)> = Vec::new();
        let mut reserve: Vec<u32> = vec![u32::MAX; n];
        let mut live_off: Vec<u64> = vec![0; n + 1];
        let mut tel = SidmmTelemetry::default();

        loop {
            tel.iterations += 1;
            // ---- Pass 1: live-degree offsets ----
            for v in 0..n {
                probe.load(address::state_bit(v as u64));
                let mut c = 0u64;
                if !matched[v] {
                    probe.load(address::offsets(v as u64));
                    probe.load(address::offsets(v as u64 + 1));
                    let base = g.offsets()[v];
                    for (i, &u) in g.neighbors(v as VertexId).iter().enumerate() {
                        probe.load(address::neighbors(base + i as u64));
                        if u as usize != v {
                            probe.load(address::state_bit(u as u64));
                            if !matched[u as usize] {
                                c += 1;
                            }
                        }
                    }
                }
                live_off[v + 1] = live_off[v] + c;
                probe.store(address::aux(v as u64 + 1));
                probe.load(address::aux(v as u64));
            }
            let total_live = live_off[n];
            if total_live == 0 {
                break;
            }
            // ---- Sample positions ----
            let k = (k_target as u64).min(total_live) as usize;
            let mut positions: Vec<u64> = (0..k).map(|_| rng.next_below(total_live)).collect();
            positions.sort_unstable();
            positions.dedup();
            // ---- Pass 2: map positions to edges ----
            let mut sample: Vec<(VertexId, VertexId)> = Vec::with_capacity(positions.len());
            let mut v = 0usize;
            for &pos in &positions {
                while live_off[v + 1] <= pos {
                    v += 1;
                    probe.load(address::aux(v as u64));
                }
                let mut rank = pos - live_off[v];
                probe.load(address::offsets(v as u64));
                probe.load(address::offsets(v as u64 + 1));
                let base = g.offsets()[v];
                let mut picked: Option<VertexId> = None;
                for (i, &u) in g.neighbors(v as VertexId).iter().enumerate() {
                    probe.load(address::neighbors(base + i as u64));
                    if u as usize == v {
                        continue;
                    }
                    probe.load(address::state_bit(u as u64));
                    if !matched[u as usize] {
                        if rank == 0 {
                            picked = Some(u);
                            break;
                        }
                        rank -= 1;
                    }
                }
                let u = picked.expect("live rank maps to a live neighbor");
                sample.push((v as VertexId, u));
                probe.store(address::aux2(sample.len() as u64));
            }
            tel.sampled_edges += sample.len() as u64;
            // ---- IDMM on the sample (random priorities: the sample order
            //      is already a uniform draw; use positions within it) ----
            let priorities: Vec<u32> = (0..sample.len() as u32).collect();
            tel.idmm_rounds += idmm_rounds_on_edges(
                &sample,
                &priorities,
                &mut matched,
                &mut reserve,
                &mut matches,
                probe,
            );
        }
        (Matching::from_pairs(matches), tel)
    }
}

impl MaximalMatcher for Sidmm {
    fn name(&self) -> String {
        "SIDMM".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::instrument::CountingProbe;
    use crate::matching::verify;

    #[test]
    fn valid_on_small_graphs() {
        for g in [simple::path(9), simple::cycle(12), simple::star(20), simple::complete(10)] {
            let m = Sidmm::default().run(&g);
            verify::check(&g, &m).unwrap();
        }
    }

    #[test]
    fn valid_on_rmat() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 4 });
        let m = Sidmm::default().run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 5 });
        let a = Sidmm { seed: 1, ..Default::default() }.run(&g);
        let b = Sidmm { seed: 1, ..Default::default() }.run(&g);
        assert_eq!(a.to_sorted_vec(), b.to_sorted_vec());
    }

    #[test]
    fn access_overhead_exceeds_sgmm() {
        // The paper's core motivation claim (Fig 3/7): SIDMM does an order
        // of magnitude more memory accesses than SGMM.
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 6 });
        let mut ps = CountingProbe::default();
        let _ = crate::matching::sgmm::Sgmm.run_probed(&g, &mut ps);
        let mut pd = CountingProbe::default();
        let (m, tel) = Sidmm::default().run_probed(&g, &mut pd);
        verify::check(&g, &m).unwrap();
        assert!(tel.iterations > 1);
        let ratio = pd.total() as f64 / ps.total() as f64;
        assert!(ratio > 5.0, "SIDMM/SGMM access ratio = {ratio}");
    }

    #[test]
    fn small_sample_size_still_terminates() {
        let g = rmat::generate(&GenConfig { scale: 9, avg_degree: 6, seed: 7 });
        let m = Sidmm { samples_per_iter: 64, seed: 3 }.run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        assert_eq!(Sidmm::default().run(&g).len(), 0);
    }
}
