//! Prefix-Batched MM (Blelloch, Fineman, Shun, PACT'12 — paper §II-D).
//!
//! Takes a fixed random priority over edges. Each iteration processes the
//! carry-over of still-live edges plus the next `granularity`-sized batch of
//! fresh edges in priority order, committing edges that are local priority
//! minima at both endpoints. The `granularity` parameter trades parallelism
//! against work efficiency — the tuning knob the paper contrasts with
//! Skipper's parameter-free design.

use super::canonical_edges;
use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::matching::{MaximalMatcher, Matching};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

#[derive(Clone, Copy, Debug)]
/// Priority-based maximal matching (EMS baseline).
pub struct Pbmm {
    /// Fresh edges admitted per iteration; 0 → `max(|E|/50, 256)` (the
    /// PBMM paper's suggested fraction).
    pub granularity: usize,
    /// Priority-permutation seed.
    pub seed: u64,
}

impl Default for Pbmm {
    fn default() -> Self {
        Self {
            granularity: 0,
            seed: 0x9B,
        }
    }
}

impl Pbmm {
    /// Run with an access probe; returns the matching and round count.
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> (Matching, usize) {
        let edges = canonical_edges(g);
        let ne = edges.len();
        let mut rng = Xoshiro256pp::new(self.seed);
        // random priority = position in a shuffled order
        let order = rng.permutation(ne);
        let gran = if self.granularity == 0 {
            (ne / 50).max(256)
        } else {
            self.granularity
        };
        let n = g.num_vertices();
        let mut matched = vec![false; n];
        let mut reserve: Vec<u32> = vec![u32::MAX; n];
        let mut matches: Vec<(VertexId, VertexId)> = Vec::new();
        let mut carry: Vec<u32> = Vec::new(); // edge ids (= priority ranks)
        let mut cursor = 0usize;
        let mut iterations = 0usize;

        while cursor < ne || !carry.is_empty() {
            iterations += 1;
            // batch = carry + next `gran` fresh edges (by priority order)
            let fresh_end = (cursor + gran).min(ne);
            let mut batch: Vec<u32> = std::mem::take(&mut carry);
            for rank in cursor..fresh_end {
                batch.push(rank as u32);
                probe.load(address::aux(rank as u64));
            }
            cursor = fresh_end;
            // drop already-covered edges
            batch.retain(|&rank| {
                let (u, v) = edges[order[rank as usize] as usize];
                probe.load(address::state_bit(u as u64));
                probe.load(address::state_bit(v as u64));
                !matched[u as usize] && !matched[v as usize]
            });
            // reserve: min rank per endpoint
            for &rank in &batch {
                let (u, v) = edges[order[rank as usize] as usize];
                probe.rmw(address::state(u as u64));
                probe.rmw(address::state(v as u64));
                if rank < reserve[u as usize] {
                    reserve[u as usize] = rank;
                }
                if rank < reserve[v as usize] {
                    reserve[v as usize] = rank;
                }
            }
            // commit: local minima at both endpoints
            for &rank in &batch {
                let (u, v) = edges[order[rank as usize] as usize];
                probe.load(address::state(u as u64));
                probe.load(address::state(v as u64));
                if reserve[u as usize] == rank && reserve[v as usize] == rank {
                    matched[u as usize] = true;
                    matched[v as usize] = true;
                    probe.store(address::state_bit(u as u64));
                    probe.store(address::state_bit(v as u64));
                    probe.store(address::matches(matches.len() as u64));
                    matches.push((u, v));
                }
            }
            // prune + carry the survivors; reset touched reservations
            for &rank in &batch {
                let (u, v) = edges[order[rank as usize] as usize];
                reserve[u as usize] = u32::MAX;
                reserve[v as usize] = u32::MAX;
                probe.store(address::state(u as u64));
                probe.store(address::state(v as u64));
                probe.load(address::state_bit(u as u64));
                probe.load(address::state_bit(v as u64));
                if !matched[u as usize] && !matched[v as usize] {
                    carry.push(rank);
                    probe.store(address::aux2(carry.len() as u64));
                }
            }
        }
        (Matching::from_pairs(matches), iterations)
    }
}

impl MaximalMatcher for Pbmm {
    fn name(&self) -> String {
        "PBMM".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::matching::verify;

    #[test]
    fn valid_on_small_graphs() {
        for g in [simple::path(10), simple::cycle(9), simple::star(16), simple::complete(8)] {
            let m = Pbmm::default().run(&g);
            verify::check(&g, &m).unwrap();
        }
    }

    #[test]
    fn valid_on_rmat_various_granularity() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 8 });
        for gran in [64, 1024, usize::MAX / 2] {
            let m = Pbmm { granularity: gran, seed: 5 }.run(&g);
            verify::check(&g, &m).unwrap();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 9 });
        let a = Pbmm { granularity: 500, seed: 11 }.run(&g);
        let b = Pbmm { granularity: 500, seed: 11 }.run(&g);
        assert_eq!(a.to_sorted_vec(), b.to_sorted_vec());
    }

    #[test]
    fn granularity_bounds_iterations() {
        let g = rmat::generate(&GenConfig { scale: 9, avg_degree: 6, seed: 1 });
        let (_, iters_small) = Pbmm { granularity: 64, seed: 2 }.run_probed(&g, &mut NoProbe);
        let (_, iters_large) =
            Pbmm { granularity: usize::MAX / 2, seed: 2 }.run_probed(&g, &mut NoProbe);
        assert!(iters_small > iters_large);
    }
}
