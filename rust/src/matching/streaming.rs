//! **StreamingSkipper** — the chunk driver over [`SkipperCore`]: maximal
//! matching computed *while edges stream in*, without ever materializing a
//! CSR graph (ISSUE: the semi-external regime of Birn et al. and the
//! batch-update scenario of Ghaffari & Trygub, obtained nearly for free
//! from Skipper's JIT conflict resolution).
//!
//! Pipeline: one producer thread pulls chunks from an
//! [`EdgeSource`](crate::graph::stream::EdgeSource) (disk reader, generator,
//! or in-memory batch) into a [`BoundedQueue`]; `threads` consumer threads
//! pop chunks and drive them through the shared per-edge state machine.
//! Ingest I/O thus overlaps matching, and back-pressure caps resident
//! topology at `queue · chunk` edges plus Skipper's one byte of state per
//! vertex — independent of |E|.
//!
//! Chunk buffers are recycled through a pool, so steady-state streaming
//! performs no allocation at all.

use super::core::SkipperCore;
use super::{MatchArena, Matching};
use crate::graph::stream::EdgeSource;
use crate::instrument::conflicts::ConflictStats;
use crate::instrument::NoProbe;
use crate::par::pump::{BoundedQueue, CloseOnDrop};
use crate::VertexId;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Default edges per chunk: big enough to amortize queue hand-off, small
/// enough that a handful of in-flight chunks stay far below any real CSR.
pub const DEFAULT_CHUNK_EDGES: usize = 4096;

/// Streaming-driver configuration.
#[derive(Clone, Copy, Debug)]
pub struct StreamingSkipper {
    /// Consumer (matcher) threads; the ingest producer runs on the calling
    /// thread in addition to these.
    pub threads: usize,
    /// Edges per chunk.
    pub chunk_edges: usize,
    /// Bounded-queue capacity in chunks (back-pressure window).
    pub queue_chunks: usize,
}

/// Telemetry of one streaming run against an existing core/arena.
#[derive(Clone, Debug, Default)]
pub struct StreamStats {
    /// JIT-conflict telemetry of the run.
    pub conflicts: ConflictStats,
    /// Edges pulled from the source.
    pub edges_streamed: u64,
    /// Chunks handed across the queue.
    pub chunks: u64,
    /// Chunk buffers ever allocated (the recycling pool's high-water mark).
    pub buffers_allocated: usize,
}

/// Full result of a from-scratch streaming run.
pub struct StreamReport {
    /// The computed maximal matching.
    pub matching: Matching,
    /// JIT-conflict telemetry of the run.
    pub conflicts: ConflictStats,
    /// Edges pulled from the source.
    pub edges_streamed: u64,
    /// Chunks handed across the queue.
    pub chunks: u64,
    /// The source’s exclusive vertex-id bound.
    pub vertex_bound: usize,
    /// Skipper state bytes (= vertex bound; one byte per vertex).
    pub state_bytes: usize,
    /// Bytes in chunk buffers at the pool's high-water mark.
    pub chunk_buffer_bytes: usize,
}

impl StreamReport {
    /// Peak topology-resident bytes of the streaming run: per-vertex state
    /// plus every chunk buffer ever in flight. (The match arena is output,
    /// not topology, mirroring `CsrGraph::memory_bytes` which also counts
    /// topology only.)
    pub fn peak_topology_bytes(&self) -> usize {
        self.state_bytes + self.chunk_buffer_bytes
    }

    /// Bytes a CSR of the same stream would hold resident: `(|V|+1)` 8-byte
    /// offsets plus one 4-byte slot per streamed pair. Conservative for
    /// text/mtx sources, exact for `.skg` (which streams stored slots).
    pub fn csr_equivalent_bytes(&self) -> usize {
        (self.vertex_bound + 1) * std::mem::size_of::<crate::EdgeIdx>()
            + self.edges_streamed as usize * std::mem::size_of::<VertexId>()
    }
}

impl StreamingSkipper {
    /// Driver with `threads` consumers and default chunking.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        Self {
            threads,
            chunk_edges: DEFAULT_CHUNK_EDGES,
            queue_chunks: 2 * threads,
        }
    }

    /// Override edges per chunk (clamped ≥ 1).
    pub fn with_chunk_edges(mut self, chunk_edges: usize) -> Self {
        self.chunk_edges = chunk_edges.max(1);
        self
    }

    /// Override the bounded-queue capacity in chunks (clamped ≥ 1).
    pub fn with_queue_chunks(mut self, queue_chunks: usize) -> Self {
        self.queue_chunks = queue_chunks.max(1);
        self
    }

    /// Match every edge the source delivers, from scratch.
    pub fn run<S: EdgeSource>(&self, source: S) -> Result<StreamReport, String> {
        let core = SkipperCore::new(source.vertex_bound());
        let arena = core.arena(self.threads);
        let stats = self.run_with_core(&core, &arena, source)?;
        Ok(StreamReport {
            matching: arena.into_matching(),
            conflicts: stats.conflicts,
            edges_streamed: stats.edges_streamed,
            chunks: stats.chunks,
            vertex_bound: core.num_vertices(),
            state_bytes: core.state_bytes(),
            chunk_buffer_bytes: stats.buffers_allocated * self.chunk_edges
                * std::mem::size_of::<(VertexId, VertexId)>(),
        })
    }

    /// Drive a source through an existing core + arena — the building block
    /// [`super::incremental::IncrementalMatcher`] uses to keep state alive
    /// across batches.
    pub fn run_with_core<S: EdgeSource>(
        &self,
        core: &SkipperCore,
        arena: &MatchArena,
        mut source: S,
    ) -> Result<StreamStats, String> {
        let bound = source.vertex_bound();
        if bound > core.num_vertices() {
            return Err(format!(
                "source vertex bound {bound} exceeds core size {}",
                core.num_vertices()
            ));
        }

        let full: BoundedQueue<Vec<(VertexId, VertexId)>> =
            BoundedQueue::new(self.queue_chunks);
        let pool: Mutex<Vec<Vec<(VertexId, VertexId)>>> = Mutex::new(Vec::new());
        let allocated = AtomicUsize::new(0);
        let mut producer_err: Option<String> = None;
        let mut edges_streamed = 0u64;
        let mut chunks = 0u64;

        let consumers = self.threads.max(1);
        let per_thread: Vec<ConflictStats> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..consumers)
                .map(|_| {
                    let full = &full;
                    let pool = &pool;
                    s.spawn(move || {
                        // If this consumer panics, closing the queue
                        // unblocks the producer instead of deadlocking.
                        let _guard = CloseOnDrop(full);
                        let mut writer = arena.writer();
                        let mut stats = ConflictStats::default();
                        while let Some(chunk) = full.pop() {
                            core.process_chunk(&chunk, &mut writer, &mut stats, &mut NoProbe);
                            pool.lock().unwrap().push(chunk);
                        }
                        stats
                    })
                })
                .collect();

            // Ingest producer: runs right here on the calling thread.
            loop {
                let mut buf = pool.lock().unwrap().pop().unwrap_or_else(|| {
                    allocated.fetch_add(1, Ordering::Relaxed);
                    Vec::with_capacity(self.chunk_edges)
                });
                match source.next_chunk(&mut buf, self.chunk_edges) {
                    Ok(0) => break,
                    Ok(n) => {
                        // Guard the state-array indexing: a misbehaving
                        // source must fail loudly, not corrupt memory.
                        if let Some(&(u, v)) = buf
                            .iter()
                            .find(|&&(u, v)| u as usize >= bound || v as usize >= bound)
                        {
                            producer_err = Some(format!(
                                "source emitted edge ({u},{v}) beyond its vertex bound {bound}"
                            ));
                            break;
                        }
                        edges_streamed += n as u64;
                        chunks += 1;
                        if full.push(buf).is_err() {
                            // a consumer died and closed the queue
                            break;
                        }
                    }
                    Err(e) => {
                        producer_err = Some(e);
                        break;
                    }
                }
            }
            full.close();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        if let Some(e) = producer_err {
            return Err(format!("edge stream failed: {e}"));
        }
        let mut conflicts = ConflictStats::default();
        for s in &per_thread {
            conflicts.merge(s);
        }
        Ok(StreamStats {
            conflicts,
            edges_streamed,
            chunks,
            buffers_allocated: allocated.load(Ordering::Relaxed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{build, BuildOptions};
    use crate::graph::gen::{erdos_renyi, rmat, GenConfig};
    use crate::graph::stream::{BatchEdgeSource, CsrEdgeSource, SyntheticEdgeSource};
    use crate::graph::EdgeList;
    use crate::matching::verify;

    #[test]
    fn streamed_matching_is_maximal_on_csr_stream() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 5 });
        for t in [1, 2, 4] {
            let rep = StreamingSkipper::new(t)
                .with_chunk_edges(1000)
                .run(CsrEdgeSource::new(&g))
                .unwrap();
            verify::check(&g, &rep.matching).unwrap();
            assert_eq!(rep.edges_streamed, g.num_edge_slots() as u64);
        }
    }

    #[test]
    fn streamed_matching_is_maximal_on_batch_stream() {
        let el = erdos_renyi::edges(3000, 12_000, 17);
        let g = build(&el, BuildOptions::default());
        let rep = StreamingSkipper::new(3)
            .with_chunk_edges(512)
            .run(BatchEdgeSource::new(el.num_vertices, &el.edges))
            .unwrap();
        verify::check(&g, &rep.matching).unwrap();
        assert_eq!(rep.edges_streamed, el.edges.len() as u64);
        assert!(rep.chunks >= (el.edges.len() / 512) as u64);
    }

    #[test]
    fn single_consumer_sees_no_conflicts() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 2 });
        let rep = StreamingSkipper::new(1).run(CsrEdgeSource::new(&g)).unwrap();
        assert_eq!(rep.conflicts.total, 0);
    }

    #[test]
    fn synthetic_source_never_materializes_yet_verifies() {
        // match straight off the generator, then rebuild the same graph for
        // verification only
        let (n, m, seed) = (5000usize, 20_000usize, 23u64);
        let rep = StreamingSkipper::new(2)
            .run(SyntheticEdgeSource::erdos_renyi(n, m, seed))
            .unwrap();
        let g = erdos_renyi::generate(n, m, seed);
        verify::check(&g, &rep.matching).unwrap();
    }

    #[test]
    fn peak_memory_beats_csr_equivalent() {
        let g = rmat::generate(&GenConfig { scale: 13, avg_degree: 8, seed: 7 });
        let rep = StreamingSkipper::new(2)
            .with_chunk_edges(2048)
            .run(CsrEdgeSource::new(&g))
            .unwrap();
        assert!(
            rep.peak_topology_bytes() < rep.csr_equivalent_bytes(),
            "stream {} >= csr {}",
            rep.peak_topology_bytes(),
            rep.csr_equivalent_bytes()
        );
        // csr_equivalent_bytes is exact for slot streams
        assert_eq!(rep.csr_equivalent_bytes(), g.memory_bytes());
    }

    #[test]
    fn buffer_pool_bounds_allocation() {
        let rep = StreamingSkipper::new(2)
            .with_chunk_edges(256)
            .run(SyntheticEdgeSource::erdos_renyi(2000, 50_000, 3))
            .unwrap();
        let sk = StreamingSkipper::new(2);
        // pool high-water: queue window + one per consumer + producer's
        assert!(
            rep.chunks as usize >= rep.buffers_allocated,
            "more buffers than chunks"
        );
        assert!(
            rep.buffers_allocated <= sk.queue_chunks + sk.threads + 2,
            "pool leaked: {} buffers",
            rep.buffers_allocated
        );
    }

    #[test]
    fn out_of_bound_source_fails_loudly() {
        struct Lying;
        impl crate::graph::stream::EdgeSource for Lying {
            fn vertex_bound(&self) -> usize {
                2
            }
            fn next_chunk(
                &mut self,
                chunk: &mut Vec<(u32, u32)>,
                _max: usize,
            ) -> Result<usize, String> {
                chunk.clear();
                chunk.push((0, 9));
                Ok(1)
            }
        }
        let err = StreamingSkipper::new(1).run(Lying).unwrap_err();
        assert!(err.contains("beyond its vertex bound"), "{err}");
    }

    #[test]
    fn empty_stream_yields_empty_matching() {
        let el = EdgeList::new(10);
        let rep = StreamingSkipper::new(2)
            .run(BatchEdgeSource::new(10, &el.edges))
            .unwrap();
        assert_eq!(rep.matching.len(), 0);
        assert_eq!(rep.edges_streamed, 0);
    }

    #[test]
    fn run_with_core_accumulates_across_batches() {
        let core = SkipperCore::new(6);
        let arena = core.arena(2);
        let sk = StreamingSkipper::new(2);
        let b1 = [(0u32, 1u32)];
        sk.run_with_core(&core, &arena, BatchEdgeSource::new(6, &b1)).unwrap();
        assert!(core.is_matched(0) && core.is_matched(1));
        let b2 = [(1u32, 2u32), (2, 3)];
        sk.run_with_core(&core, &arena, BatchEdgeSource::new(6, &b2)).unwrap();
        assert!(core.is_matched(2) && core.is_matched(3));
        let m = arena.into_matching();
        assert_eq!(m.to_sorted_vec(), vec![(0, 1), (2, 3)]);
    }
}
