//! Matching validation (paper §II-B): the output is a correct maximal
//! matching iff (a) no two output edges share an endpoint and every output
//! edge exists in the graph, and (b) every graph edge has at least one
//! matched endpoint.
//!
//! [`check`] validates against a materialized [`CsrGraph`] — correct for the
//! one-shot and insert-only regimes, where the graph is the union of every
//! edge ever seen. Under *deletions* that union over-approximates the live
//! graph, so [`verify_maximal_dynamic`] checks the same two conditions
//! against an explicit live edge set instead: the matching must be a subset
//! of the edges that still exist, and maximality is required only over
//! those.

use super::Matching;
use crate::graph::CsrGraph;
use crate::par::par_for_range;
use crate::VertexId;
use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};

/// Full validity + maximality check. Returns a description of the first
/// violation found.
pub fn check(g: &CsrGraph, m: &Matching) -> Result<(), String> {
    let n = g.num_vertices();
    let mut matched = vec![false; n];
    for (u, v) in m.iter() {
        if u as usize >= n || v as usize >= n {
            return Err(format!("match ({u},{v}) out of range (|V|={n})"));
        }
        if u == v {
            return Err(format!("self-loop ({u},{u}) in matching"));
        }
        if !has_edge(g, u, v) {
            return Err(format!("match ({u},{v}) is not a graph edge"));
        }
        if matched[u as usize] {
            return Err(format!("vertex {u} matched twice"));
        }
        if matched[v as usize] {
            return Err(format!("vertex {v} matched twice"));
        }
        matched[u as usize] = true;
        matched[v as usize] = true;
    }
    // maximality: every non-loop edge must have a matched endpoint
    for (v, u) in g.iter_edges() {
        if v != u && !matched[v as usize] && !matched[u as usize] {
            return Err(format!("edge ({v},{u}) unmatched on both endpoints"));
        }
    }
    Ok(())
}

/// Maximality check against an edge set *after deletions* — the fully
/// dynamic regime, where the insert-only union graph [`check`] assumes no
/// longer describes what exists. `live_edges` is consumed in a single pass
/// (an adjacency iterator is fine; duplicates and both orientations are
/// tolerated); `matching` holds the claimed pairs.
///
/// Verifies, in order: every matched pair is in range, loop-free, and
/// endpoint-disjoint; every live edge has at least one matched endpoint
/// (maximality); and every matched pair was actually seen among the live
/// edges (matching ⊆ live — a deleted edge may not stay matched).
pub fn verify_maximal_dynamic<I>(
    num_vertices: usize,
    live_edges: I,
    matching: &[(VertexId, VertexId)],
) -> Result<(), String>
where
    I: IntoIterator<Item = (VertexId, VertexId)>,
{
    let n = num_vertices;
    let mut matched = vec![false; n];
    let mut unseen: HashSet<(VertexId, VertexId)> = HashSet::with_capacity(matching.len());
    for &(u, v) in matching {
        if u as usize >= n || v as usize >= n {
            return Err(format!("match ({u},{v}) out of range (|V|={n})"));
        }
        if u == v {
            return Err(format!("self-loop ({u},{u}) in matching"));
        }
        if matched[u as usize] {
            return Err(format!("vertex {u} matched twice"));
        }
        if matched[v as usize] {
            return Err(format!("vertex {v} matched twice"));
        }
        matched[u as usize] = true;
        matched[v as usize] = true;
        unseen.insert((u.min(v), u.max(v)));
    }
    for (u, v) in live_edges {
        if u as usize >= n || v as usize >= n {
            return Err(format!("live edge ({u},{v}) out of range (|V|={n})"));
        }
        if u == v {
            continue;
        }
        if !matched[u as usize] && !matched[v as usize] {
            return Err(format!("live edge ({u},{v}) unmatched on both endpoints"));
        }
        unseen.remove(&(u.min(v), u.max(v)));
    }
    if let Some(&(u, v)) = unseen.iter().next() {
        return Err(format!("match ({u},{v}) is not a live edge"));
    }
    Ok(())
}

/// Parallel maximality scan used by large experiment runs: counts violating
/// edges instead of returning the first.
pub fn count_maximality_violations(g: &CsrGraph, m: &Matching, threads: usize) -> u64 {
    let n = g.num_vertices();
    let mut matched = vec![false; n];
    for (u, v) in m.iter() {
        matched[u as usize] = true;
        matched[v as usize] = true;
    }
    let violations = AtomicU64::new(0);
    par_for_range(threads, n, |_tid, s, e| {
        let mut local = 0u64;
        for v in s..e {
            if matched[v] {
                continue;
            }
            for &u in g.neighbors(v as VertexId) {
                if u as usize != v && !matched[u as usize] {
                    local += 1;
                }
            }
        }
        violations.fetch_add(local, Ordering::Relaxed);
    });
    violations.load(Ordering::Relaxed)
}

fn has_edge(g: &CsrGraph, u: VertexId, v: VertexId) -> bool {
    // neighbor lists from the builder are sorted; fall back to scan if not
    let ns = g.neighbors(u);
    if ns.len() > 16 && ns.windows(2).all(|w| w[0] <= w[1]) {
        ns.binary_search(&v).is_ok()
    } else {
        ns.contains(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::simple;
    use crate::matching::Matching;

    #[test]
    fn accepts_valid_maximal() {
        let g = simple::path(4); // 0-1-2-3
        let m = Matching::from_pairs(vec![(0, 1), (2, 3)]);
        assert!(check(&g, &m).is_ok());
        assert_eq!(count_maximality_violations(&g, &m, 2), 0);
    }

    #[test]
    fn rejects_shared_endpoint() {
        let g = simple::path(3);
        let m = Matching::from_pairs(vec![(0, 1), (1, 2)]);
        let err = check(&g, &m).unwrap_err();
        assert!(err.contains("matched twice"), "{err}");
    }

    #[test]
    fn rejects_non_edge() {
        let g = simple::path(4);
        let m = Matching::from_pairs(vec![(0, 3)]);
        assert!(check(&g, &m).unwrap_err().contains("not a graph edge"));
    }

    #[test]
    fn rejects_non_maximal() {
        let g = simple::path(4);
        let m = Matching::from_pairs(vec![(1, 2)]);
        // edge (0,1)? endpoint 1 matched. edge (2,3)? endpoint 2 matched.
        // path 0-1-2-3 with only (1,2) IS maximal. Use the empty matching:
        let empty = Matching::from_pairs(vec![]);
        assert!(check(&g, &empty).unwrap_err().contains("unmatched on both"));
        assert!(check(&g, &m).is_ok());
        assert!(count_maximality_violations(&g, &empty, 2) > 0);
    }

    #[test]
    fn rejects_self_loop_and_out_of_range() {
        let g = simple::path(4);
        assert!(check(&g, &Matching::from_pairs(vec![(2, 2)])).is_err());
        assert!(check(&g, &Matching::from_pairs(vec![(0, 9)])).is_err());
    }

    #[test]
    fn dynamic_verifier_accepts_live_set_after_deletions() {
        // union graph was the path 0-1-2-3; edge (1,2) was deleted.
        let live = vec![(0u32, 1u32), (2, 3)];
        assert!(verify_maximal_dynamic(4, live.iter().copied(), &[(0, 1), (2, 3)]).is_ok());
        // the static verifier over the union would also accept this, but the
        // dynamic one must reject a matching that kept the deleted edge:
        let err = verify_maximal_dynamic(4, live.iter().copied(), &[(1, 2)]).unwrap_err();
        assert!(err.contains("unmatched on both") || err.contains("not a live edge"), "{err}");
    }

    #[test]
    fn dynamic_verifier_rejects_matched_pair_not_live() {
        // (0,1) was deleted but the matching still claims it; (2,3) keeps
        // the remaining edge covered, so the failure is subset, not
        // maximality.
        let live = vec![(2u32, 3u32)];
        let err = verify_maximal_dynamic(4, live, &[(0, 1), (2, 3)]).unwrap_err();
        assert!(err.contains("not a live edge"), "{err}");
    }

    #[test]
    fn dynamic_verifier_rejects_uncovered_live_edge() {
        let live = vec![(0u32, 1u32), (2, 3)];
        let err = verify_maximal_dynamic(4, live, &[(0, 1)]).unwrap_err();
        assert!(err.contains("unmatched on both"), "{err}");
    }

    #[test]
    fn dynamic_verifier_tolerates_both_orientations_and_duplicates() {
        let live = vec![(0u32, 1u32), (1, 0), (0, 1)];
        assert!(verify_maximal_dynamic(2, live, &[(1, 0)]).is_ok());
    }

    #[test]
    fn dynamic_verifier_rejects_double_matching_and_loops() {
        assert!(verify_maximal_dynamic(3, vec![(0u32, 1u32)], &[(0, 1), (1, 2)])
            .unwrap_err()
            .contains("matched twice"));
        assert!(verify_maximal_dynamic(3, Vec::<(u32, u32)>::new(), &[(1, 1)])
            .unwrap_err()
            .contains("self-loop"));
    }

    #[test]
    fn star_maximal_is_single_edge() {
        let g = simple::star(8);
        let m = Matching::from_pairs(vec![(0, 3)]);
        assert!(check(&g, &m).is_ok());
    }
}
