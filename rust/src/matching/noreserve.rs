//! Ablation: Skipper **without** the RSVD state (DESIGN.md design-choice
//! list). A naive two-CAS scheme marks `u` as `MCHD` outright, then tries
//! `v`; on failure it *rolls back* `u` to `ACC`.
//!
//! This is the variant the paper's §IV implicitly argues against: during
//! the rollback window another thread can observe `u == MCHD`, conclude its
//! own edge `(u, z)` is covered, and skip it — after the rollback, `u` is
//! unmatched and `(u, z)` may end up with both endpoints free, violating
//! **maximality**. The RSVD state exists precisely to tell concurrent
//! threads "wait — this is not decided yet".
//!
//! The race is hard to hit with real threads on one core, so the unit tests
//! drive the same state machine through an adversarial deterministic
//! interleaving to exhibit the violation, and the APRAM-style random
//! interleavings quantify how often it bites.

use super::{MatchArena, MaximalMatcher, Matching};
use crate::graph::CsrGraph;
use crate::matching::skipper::{ACC, MCHD};
use crate::par::run_threads;
use crate::par::scheduler::{Assignment, BlockScheduler};
use std::sync::atomic::{AtomicU8, Ordering};

/// The flawed no-reservation matcher (kept for the ablation bench; do not
/// use for real work — see module docs).
#[derive(Clone, Copy, Debug)]
pub struct NoReserveMatcher {
    /// Worker threads.
    pub threads: usize,
}

impl NoReserveMatcher {
    /// Ablation matcher at `threads` threads.
    pub fn new(threads: usize) -> Self {
        Self { threads }
    }
}

impl MaximalMatcher for NoReserveMatcher {
    fn name(&self) -> String {
        format!("NoReserve(t={})", self.threads)
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        let n = g.num_vertices();
        let state: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(ACC)).collect();
        let sched = BlockScheduler::new(g, self.threads, 16, Assignment::DispersedContiguous);
        let arena = MatchArena::for_graph(g, self.threads);
        run_threads(self.threads, |tid| {
            let mut writer = arena.writer();
            while let Some((bs, be)) = sched.next_block(tid) {
                for x in bs..be {
                    if state[x as usize].load(Ordering::Acquire) == MCHD {
                        continue;
                    }
                    for &y in g.neighbors(x) {
                        if x == y {
                            continue;
                        }
                        let (u, v) = (x.min(y), x.max(y));
                        // claim u outright (no RSVD)
                        if state[u as usize]
                            .compare_exchange(ACC, MCHD, Ordering::AcqRel, Ordering::Acquire)
                            .is_err()
                        {
                            continue;
                        }
                        // now try v
                        if state[v as usize]
                            .compare_exchange(ACC, MCHD, Ordering::AcqRel, Ordering::Acquire)
                            .is_ok()
                        {
                            writer.push(u, v);
                        } else {
                            // ROLLBACK — the window other threads mis-read
                            state[u as usize].store(ACC, Ordering::Release);
                        }
                        if state[x as usize].load(Ordering::Relaxed) == MCHD {
                            break;
                        }
                    }
                }
            }
        });
        arena.into_matching()
    }
}

/// Deterministic two-thread interleaving that exhibits the maximality
/// violation on a 3-vertex path 0-1-2 (edges (0,1) and (1,2)):
///
/// t0 processes (0,1): CAS 0→MCHD ok, pauses before CAS on 1.
/// t1 processes (1,2)... wait — the violating schedule uses t1 on (0,z).
///
/// Concretely with edges (0,1), (0,2):
///   t0: CAS 0: ACC→MCHD (claims 0 for edge (0,1))
///   t1: sees 0 == MCHD → skips edge (0,2) entirely
///   t0: CAS 1 fails (1 already matched elsewhere) → rollback 0→ACC
///   result: 0 unmatched, 2 unmatched, edge (0,2) uncovered → NOT maximal.
///
/// Returns true iff the violation occurred.
pub fn demonstrate_violation() -> bool {
    // states for vertices 0,1,2 ; vertex 1 is pre-matched (by "edge (1,3)")
    let state = [
        AtomicU8::new(ACC),
        AtomicU8::new(MCHD),
        AtomicU8::new(ACC),
    ];
    // t0 step 1: claim 0 for edge (0,1)
    assert!(state[0]
        .compare_exchange(ACC, MCHD, Ordering::AcqRel, Ordering::Acquire)
        .is_ok());
    // t1: processes edge (0,2), reads 0 == MCHD → skips it (covered, it thinks)
    let t1_skipped = state[0].load(Ordering::Acquire) == MCHD;
    // t0 step 2: CAS on 1 fails (already MCHD) → rollback 0
    assert!(state[1]
        .compare_exchange(ACC, MCHD, Ordering::AcqRel, Ordering::Acquire)
        .is_err());
    state[0].store(ACC, Ordering::Release);
    // final: edge (0,2) has both endpoints ACC yet nobody will reprocess it
    let uncovered = state[0].load(Ordering::Acquire) == ACC
        && state[2].load(Ordering::Acquire) == ACC;
    t1_skipped && uncovered
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, GenConfig};
    use crate::matching::verify;

    #[test]
    fn adversarial_interleaving_breaks_maximality() {
        // the precise schedule the RSVD state prevents
        assert!(demonstrate_violation());
    }

    #[test]
    fn single_thread_is_still_correct() {
        // with one thread there is no rollback window to mis-read
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 3 });
        let m = NoReserveMatcher::new(1).run(&g);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn validity_holds_even_when_maximality_may_not() {
        // no-reserve never produces *invalid* matchings (no shared
        // endpoints) — the flaw is limited to maximality.
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 4 });
        let m = NoReserveMatcher::new(8).run(&g);
        let mut matched = vec![false; g.num_vertices()];
        for (u, v) in m.iter() {
            assert!(!matched[u as usize] && !matched[v as usize]);
            matched[u as usize] = true;
            matched[v as usize] = true;
        }
    }
}
