//! **SkipperCore** — the per-edge state machine of Algorithm 1, factored out
//! of any particular edge-delivery mechanism.
//!
//! The core owns exactly the algorithm's shared state: the one-byte-per-
//! vertex array (`ACC`/`RSVD`/`MCHD`). Everything else — where edges come
//! from and where matches go — is the driver's business:
//!
//! * [`super::skipper::Skipper`] walks a materialized CSR graph through the
//!   thread-dispersed [`crate::par::scheduler::BlockScheduler`];
//! * [`super::streaming::StreamingSkipper`] consumes `(u, v)` chunks pulled
//!   from any [`crate::graph::stream::EdgeSource`] — a file, a generator,
//!   or an in-memory batch — without ever building a CSR;
//! * [`super::incremental::IncrementalMatcher`] keeps one core alive across
//!   edge-insertion batches;
//! * [`crate::dynamic::DynamicMatcher`] keeps one core alive under mixed
//!   inserts *and deletes*, releasing the endpoints of deleted matched
//!   pairs (`release`) and re-running this same state machine over their
//!   surviving incident edges.
//!
//! All drivers share [`process_edge`] (Algorithm 1 lines 6–18), so JIT
//! conflict resolution, telemetry, and the correctness argument are
//! identical regardless of how edges arrive. This is what makes the paper's
//! "single pass over edges" literal: the fate of an edge is decided the
//! moment it is seen, never revisited, so *any* one-shot delivery order is
//! a valid execution.

use super::{MatchArena, MatchWriter, BUFFER_EDGES};
use crate::instrument::conflicts::ConflictStats;
use crate::instrument::{address, Probe};
use crate::VertexId;
use std::sync::atomic::{AtomicU8, Ordering};

/// Vertex states (paper §IV, one byte per vertex).
pub const ACC: u8 = 0;
/// Reserved by a thread mid-`process_edge` (transient).
pub const RSVD: u8 = 1;
/// Matched (final for the static pass; the dynamic engine may release).
pub const MCHD: u8 = 2;

/// The shared algorithm state: one byte per vertex, nothing else.
///
/// # Example
///
/// Drive a chunk of edges through the Algorithm-1 state machine and
/// harvest the matching from the arena (on one thread the chunk order is
/// the match order, so the path `0-1-2-3` matches `(0,1)` and `(2,3)`):
///
/// ```
/// use skipper::instrument::{conflicts::ConflictStats, NoProbe};
/// use skipper::matching::core::SkipperCore;
///
/// let core = SkipperCore::new(4);
/// let arena = core.arena(1);
/// let mut writer = arena.writer();
/// let mut stats = ConflictStats::default();
/// core.process_chunk(&[(0, 1), (1, 2), (2, 3)], &mut writer, &mut stats, &mut NoProbe);
/// drop(writer);
///
/// assert!(core.is_matched(0) && core.is_matched(3));
/// assert_eq!(arena.into_matching().len(), 2);
/// ```
pub struct SkipperCore {
    state: Vec<AtomicU8>,
}

impl SkipperCore {
    /// Fresh core with all `num_vertices` vertices `ACC`.
    pub fn new(num_vertices: usize) -> Self {
        Self {
            state: (0..num_vertices).map(|_| AtomicU8::new(ACC)).collect(),
        }
    }

    #[inline]
    /// Size of the vertex universe.
    pub fn num_vertices(&self) -> usize {
        self.state.len()
    }

    /// Resident bytes of algorithm state — the paper's headline: |V| bytes,
    /// independent of |E|.
    #[inline]
    pub fn state_bytes(&self) -> usize {
        self.state.len()
    }

    /// Acquire-load check used for the vertex-level skip in the CSR driver
    /// and for user-facing queries.
    #[inline]
    pub fn is_matched(&self, v: VertexId) -> bool {
        self.state[v as usize].load(Ordering::Acquire) == MCHD
    }

    /// Relaxed check for the mid-neighbor-list skip (advisory only).
    #[inline]
    pub fn is_matched_relaxed(&self, v: VertexId) -> bool {
        self.state[v as usize].load(Ordering::Relaxed) == MCHD
    }

    /// Free a vertex back to `ACC` — the dynamic engine's delete path: when
    /// a matched edge is removed from the live graph, both endpoints are
    /// released and re-enter the Algorithm-1 state machine via the repair
    /// sweep. **Quiescent-only**: callers must guarantee no concurrent
    /// `process_edge` is running (the dynamic engine applies deletes
    /// strictly between its parallel matching phases). No vertex is `RSVD`
    /// between phases — every reservation in `process_edge` resolves to
    /// `MCHD` or back to `ACC` before the call returns.
    #[inline]
    pub fn release(&self, v: VertexId) {
        self.state[v as usize].store(ACC, Ordering::Release);
    }

    /// A match arena sized for this core's worst case (≤ |V|/2 matches)
    /// plus one private buffer of slack per writer.
    pub fn arena(&self, num_threads: usize) -> MatchArena {
        MatchArena::with_capacity(
            self.num_vertices() / 2 + (num_threads + 1) * BUFFER_EDGES,
        )
    }

    /// Process one edge (Algorithm 1 lines 6–18); returns the JIT-conflict
    /// count. Both endpoints must be `< num_vertices()`.
    #[inline]
    pub fn process_edge<P: Probe>(
        &self,
        x: VertexId,
        y: VertexId,
        writer: &mut MatchWriter<'_>,
        probe: &mut P,
    ) -> u64 {
        process_edge(&self.state, x, y, writer, probe)
    }

    /// Drive one chunk of edges through the state machine, recording
    /// per-edge conflict telemetry. This is the whole inner loop of the
    /// chunk/streaming driver.
    pub fn process_chunk<P: Probe>(
        &self,
        edges: &[(VertexId, VertexId)],
        writer: &mut MatchWriter<'_>,
        stats: &mut ConflictStats,
        probe: &mut P,
    ) {
        for &(x, y) in edges {
            let conflicts = self.process_edge(x, y, writer, probe);
            stats.record_edge(conflicts);
        }
    }
}

/// Process one edge (Algorithm 1 lines 6–18). Returns the number of JIT
/// conflicts (failed CASes) encountered.
#[inline]
pub fn process_edge<P: Probe>(
    state: &[AtomicU8],
    x: VertexId,
    y: VertexId,
    writer: &mut MatchWriter<'_>,
    probe: &mut P,
) -> u64 {
    // Lines 6–7: skip self-loops.
    if x == y {
        return 0;
    }
    // Lines 8–9: reserve the lower endpoint first (deadlock avoidance).
    let (u, v) = if x < y { (x, y) } else { (y, x) };
    let su = &state[u as usize];
    let sv = &state[v as usize];
    let mut conflicts = 0u64;

    // Line 10: while neither endpoint is matched.
    loop {
        probe.load(address::state(u as u64));
        probe.load(address::state(v as u64));
        if su.load(Ordering::Acquire) == MCHD || sv.load(Ordering::Acquire) == MCHD {
            return conflicts;
        }
        // Lines 11–12: try to reserve u.
        probe.rmw(address::state(u as u64));
        if su
            .compare_exchange(ACC, RSVD, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            conflicts += 1;
            std::hint::spin_loop();
            continue; // re-evaluate line 10
        }
        // u is exclusively ours. Lines 13–16: try to match v.
        let mut matched = false;
        loop {
            probe.load(address::state(v as u64));
            if sv.load(Ordering::Acquire) == MCHD {
                break;
            }
            probe.rmw(address::state(v as u64));
            match sv.compare_exchange(ACC, MCHD, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => {
                    // Line 15: we hold u's reservation — plain store suffices.
                    su.store(MCHD, Ordering::Release);
                    probe.store(address::state(u as u64));
                    // Line 16: race-free private buffer write.
                    writer.push(u, v);
                    probe.store(address::matches(0));
                    matched = true;
                    break;
                }
                Err(_) => {
                    // v is RSVD by another thread (or just flipped): JIT
                    // conflict — wait a few cycles for certainty.
                    conflicts += 1;
                    std::hint::spin_loop();
                }
            }
        }
        if matched {
            return conflicts;
        }
        // Lines 17–18: v was matched elsewhere; release u (plain store —
        // the reservation is ours).
        su.store(ACC, Ordering::Release);
        probe.store(address::state(u as u64));
        // Loop back to line 10: it will observe v == MCHD and exit.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instrument::NoProbe;

    #[test]
    fn core_matches_a_path_sequentially() {
        let core = SkipperCore::new(4);
        let arena = core.arena(1);
        let mut w = arena.writer();
        let mut stats = ConflictStats::default();
        core.process_chunk(&[(0, 1), (1, 2), (2, 3)], &mut w, &mut stats, &mut NoProbe);
        drop(w);
        assert!(core.is_matched(0) && core.is_matched(1));
        assert!(core.is_matched(2) && core.is_matched(3));
        assert_eq!(stats.total, 0, "no conflicts single-threaded");
        let m = arena.into_matching();
        assert_eq!(m.to_sorted_vec(), vec![(0, 1), (2, 3)]);
    }

    #[test]
    fn core_skips_self_loops_and_covered_edges() {
        let core = SkipperCore::new(3);
        let arena = core.arena(1);
        let mut w = arena.writer();
        assert_eq!(core.process_edge(1, 1, &mut w, &mut NoProbe), 0);
        assert!(!core.is_matched(1));
        core.process_edge(0, 1, &mut w, &mut NoProbe);
        // (1,2) is covered by 1; 2 must stay free
        core.process_edge(1, 2, &mut w, &mut NoProbe);
        assert!(!core.is_matched(2));
        drop(w);
        assert_eq!(arena.into_matching().len(), 1);
    }

    #[test]
    fn release_reopens_a_matched_vertex() {
        let core = SkipperCore::new(4);
        let arena = core.arena(1);
        let mut w = arena.writer();
        core.process_edge(0, 1, &mut w, &mut NoProbe);
        assert!(core.is_matched(0) && core.is_matched(1));
        core.release(0);
        core.release(1);
        assert!(!core.is_matched(0) && !core.is_matched(1));
        // the freed pair can re-match through the normal state machine
        core.process_edge(1, 2, &mut w, &mut NoProbe);
        assert!(core.is_matched(1) && core.is_matched(2));
        assert!(!core.is_matched(0));
    }

    #[test]
    fn state_bytes_is_one_per_vertex() {
        assert_eq!(SkipperCore::new(12345).state_bytes(), 12345);
    }

    #[test]
    fn edge_order_never_breaks_maximality_over_union() {
        // any delivery order decides every edge exactly once
        let edges = [(0u32, 1u32), (2, 3), (1, 2), (0, 3), (0, 2), (1, 3)];
        let mut orders = vec![edges.to_vec()];
        let mut rev = edges.to_vec();
        rev.reverse();
        orders.push(rev);
        for order in orders {
            let core = SkipperCore::new(4);
            let arena = core.arena(1);
            let mut w = arena.writer();
            let mut stats = ConflictStats::default();
            core.process_chunk(&order, &mut w, &mut stats, &mut NoProbe);
            drop(w);
            // every edge has a matched endpoint
            for &(u, v) in &order {
                assert!(core.is_matched(u) || core.is_matched(v), "({u},{v})");
            }
        }
    }
}
