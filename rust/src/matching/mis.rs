//! Maximal Independent Set via the Skipper technique — an extension
//! demonstrating that JIT conflict resolution generalizes beyond matching
//! (the greedy-MIS/greedy-MM duality of Blelloch et al., PACT'12, which the
//! paper builds on).
//!
//! Per-vertex states: `ACC` (undecided), `RSVD` (a thread is deciding it),
//! `IN` (in the set), `OUT` (dominated by an IN neighbor). To decide vertex
//! `v`, a thread reserves `v`, scans `N_v`: if any neighbor is `IN`, `v`
//! becomes `OUT`; if all neighbors are `OUT`/`ACC`/`RSVD`-by-lower-rank...
//!
//! The subtlety vs matching: membership depends on *all* neighbors, so the
//! single-CAS trick does not carry over directly. We keep the paper's
//! asynchronous flavor with a deterministic priority rule (lower vertex ID
//! wins): a vertex joins the set iff no lower-ID neighbor joins. A thread
//! decides `v` only after all lower-ID neighbors are decided, spinning
//! briefly otherwise — conflicts are as rare as Skipper's for the same
//! reason (two threads must race on adjacent vertices).

use crate::graph::CsrGraph;
use crate::par::run_threads;
use crate::par::scheduler::{Assignment, BlockScheduler};
use crate::VertexId;
use std::sync::atomic::{AtomicU8, Ordering};

/// Not yet decided.
pub const UNDECIDED: u8 = 0;
/// In the independent set.
pub const IN: u8 = 1;
/// Excluded by an IN neighbor.
pub const OUT: u8 = 2;

#[derive(Clone, Copy, Debug)]
/// Maximal-independent-set variant of the Skipper reservation scheme.
pub struct SkipperMis {
    /// Worker threads.
    pub threads: usize,
    /// Scheduler blocks per thread.
    pub blocks_per_thread: usize,
}

impl SkipperMis {
    /// Default configuration at `threads` threads.
    pub fn new(threads: usize) -> Self {
        Self {
            threads,
            blocks_per_thread: 16,
        }
    }

    /// Compute the lexicographically-first MIS (lower ID wins). Returns the
    /// membership array.
    pub fn run(&self, g: &CsrGraph) -> Vec<bool> {
        let n = g.num_vertices();
        let state: Vec<AtomicU8> = (0..n).map(|_| AtomicU8::new(UNDECIDED)).collect();
        let sched = BlockScheduler::new(
            g,
            self.threads,
            self.blocks_per_thread,
            Assignment::DispersedContiguous,
        );
        run_threads(self.threads, |tid| {
            while let Some((bs, be)) = sched.next_block(tid) {
                for v in bs..be {
                    decide(g, &state, v);
                }
            }
        });
        state
            .iter()
            .map(|s| s.load(Ordering::Acquire) == IN)
            .collect()
    }
}

/// Decide vertex `v`: IN iff no lower-ID neighbor is IN. Waits (spinning)
/// for undecided lower-ID neighbors — the JIT-wait analogous to Skipper's
/// RSVD spin; bounded because vertex 0's decision never waits and decisions
/// propagate in ID order.
fn decide(g: &CsrGraph, state: &[AtomicU8], v: VertexId) {
    if state[v as usize].load(Ordering::Acquire) != UNDECIDED {
        return;
    }
    let mut verdict = IN;
    for &u in g.neighbors(v) {
        if u >= v {
            continue; // only lower-ID neighbors matter for the lex-first MIS
        }
        // wait for u's decision (recursively helping keeps it wait-free-ish:
        // decide(u) ourselves instead of spinning idle)
        loop {
            match state[u as usize].load(Ordering::Acquire) {
                IN => {
                    verdict = OUT;
                    break;
                }
                OUT => break,
                _ => decide(g, state, u), // help
            }
        }
        if verdict == OUT {
            break;
        }
    }
    // multiple threads may decide v concurrently — they reach the same
    // verdict (the rule is deterministic), so a plain race is benign; CAS
    // keeps the transition single-shot.
    let _ = state[v as usize].compare_exchange(
        UNDECIDED,
        verdict,
        Ordering::AcqRel,
        Ordering::Acquire,
    );
}

/// Sequential reference: lexicographically-first MIS.
pub fn lex_mis_seq(g: &CsrGraph) -> Vec<bool> {
    let n = g.num_vertices();
    let mut in_set = vec![false; n];
    let mut out = vec![false; n];
    for v in 0..n as VertexId {
        if out[v as usize] {
            continue;
        }
        in_set[v as usize] = true;
        for &u in g.neighbors(v) {
            if u != v {
                out[u as usize] = true;
            }
        }
    }
    in_set
}

/// Validate: independent (no two IN vertices adjacent) + maximal (every
/// OUT vertex has an IN neighbor).
pub fn check_mis(g: &CsrGraph, in_set: &[bool]) -> Result<(), String> {
    for (v, u) in g.iter_edges() {
        if v != u && in_set[v as usize] && in_set[u as usize] {
            return Err(format!("adjacent IN vertices {v},{u}"));
        }
    }
    for v in 0..g.num_vertices() as VertexId {
        if !in_set[v as usize]
            && !g.neighbors(v).iter().any(|&u| u != v && in_set[u as usize])
        {
            return Err(format!("vertex {v} is OUT with no IN neighbor"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{erdos_renyi, rmat, simple, GenConfig};

    #[test]
    fn path_lex_first() {
        let g = simple::path(7);
        let mis = SkipperMis::new(2).run(&g);
        check_mis(&g, &mis).unwrap();
        // lex-first on a path: 0, 2, 4, 6
        assert_eq!(mis, vec![true, false, true, false, true, false, true]);
    }

    #[test]
    fn matches_sequential_reference() {
        for seed in [1u64, 2, 3] {
            let g = erdos_renyi::generate(800, 3200, seed);
            let seq = lex_mis_seq(&g);
            for t in [1, 4, 8] {
                let par = SkipperMis::new(t).run(&g);
                assert_eq!(par, seq, "seed {seed} t {t}");
            }
        }
    }

    #[test]
    fn valid_on_rmat() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 9 });
        let mis = SkipperMis::new(4).run(&g);
        check_mis(&g, &mis).unwrap();
    }

    #[test]
    fn star_mis_is_center_only() {
        let g = simple::star(50);
        let mis = SkipperMis::new(4).run(&g);
        check_mis(&g, &mis).unwrap();
        assert!(mis[0]);
        assert_eq!(mis.iter().filter(|&&b| b).count(), 1);
    }

    #[test]
    fn complete_graph_single_member() {
        let g = simple::complete(20);
        let mis = SkipperMis::new(4).run(&g);
        check_mis(&g, &mis).unwrap();
        assert_eq!(mis.iter().filter(|&&b| b).count(), 1);
        assert!(mis[0]);
    }

    #[test]
    fn checker_rejects_bad_sets() {
        let g = simple::path(4);
        assert!(check_mis(&g, &[true, true, false, false]).is_err()); // adjacent
        assert!(check_mis(&g, &[false, false, false, false]).is_err()); // not maximal
    }
}
