//! Sequential Greedy Maximal Matching (paper §II-B) — the work-efficiency
//! reference. A single bit of status per vertex; iterates vertices in CSR
//! order; when an edge is selected, the remaining neighbors of the current
//! vertex are skipped ("the next neighbors of the current vertex do not
//! need to be processed"), which is why SGMM touches only 0.3–0.8 memory
//! words per edge (paper §VI-C).

use super::{MaximalMatcher, Matching};
use crate::graph::CsrGraph;
use crate::instrument::{address, NoProbe, Probe};
use crate::util::bitset::Bitset;
use crate::VertexId;

#[derive(Default, Clone, Copy, Debug)]
/// Sequential greedy maximal matching (the work-efficiency reference).
pub struct Sgmm;

impl Sgmm {
    /// Run with an access-counting probe (the Figs 3/7 measurement path).
    pub fn run_probed<P: Probe>(&self, g: &CsrGraph, probe: &mut P) -> Matching {
        let n = g.num_vertices();
        let mut status = Bitset::new(n);
        let mut matches: Vec<(VertexId, VertexId)> = Vec::with_capacity(n / 2);
        for v in 0..n as VertexId {
            probe.load(address::state_bit(v as u64));
            if status.get(v as usize) {
                continue;
            }
            probe.load(address::offsets(v as u64));
            probe.load(address::offsets(v as u64 + 1));
            let base = g.offsets()[v as usize];
            for (i, &u) in g.neighbors(v).iter().enumerate() {
                probe.load(address::neighbors(base + i as u64));
                if u == v {
                    continue; // self-loop
                }
                probe.load(address::state_bit(u as u64));
                if !status.get(u as usize) {
                    status.set(v as usize);
                    status.set(u as usize);
                    probe.store(address::state_bit(v as u64));
                    probe.store(address::state_bit(u as u64));
                    probe.store(address::matches(matches.len() as u64));
                    matches.push((v, u));
                    break; // skip v's remaining neighbors
                }
            }
        }
        Matching::from_pairs(matches)
    }
}

impl MaximalMatcher for Sgmm {
    fn name(&self) -> String {
        "SGMM".into()
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        self.run_probed(g, &mut NoProbe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{rmat, simple, GenConfig};
    use crate::instrument::CountingProbe;
    use crate::matching::verify;

    #[test]
    fn path_matches_greedily() {
        let g = simple::path(6);
        let m = Sgmm.run(&g);
        assert_eq!(m.to_sorted_vec(), vec![(0, 1), (2, 3), (4, 5)]);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn star_single_edge() {
        let g = simple::star(30);
        let m = Sgmm.run(&g);
        assert_eq!(m.len(), 1);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn complete_graph_perfect_on_even() {
        let g = simple::complete(8);
        let m = Sgmm.run(&g);
        assert_eq!(m.len(), 4);
        verify::check(&g, &m).unwrap();
    }

    #[test]
    fn rmat_valid_and_maximal() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 5 });
        let m = Sgmm.run(&g);
        verify::check(&g, &m).unwrap();
        assert!(m.len() > 0);
    }

    #[test]
    fn deterministic() {
        let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 9 });
        assert_eq!(Sgmm.run(&g).to_sorted_vec(), Sgmm.run(&g).to_sorted_vec());
    }

    #[test]
    fn access_count_in_paper_band() {
        // Paper §VI-C: SGMM performs 0.3–0.8 memory accesses per edge slot.
        // (Table/figures normalize by |E| = edge slots of the symmetric graph.)
        let g = rmat::generate(&GenConfig { scale: 13, avg_degree: 16, seed: 2 });
        let mut p = CountingProbe::default();
        let m = Sgmm.run_probed(&g, &mut p);
        verify::check(&g, &m).unwrap();
        let per_edge = p.total() as f64 / g.num_edge_slots() as f64;
        assert!(per_edge < 1.5, "SGMM accesses/edge = {per_edge}");
    }

    #[test]
    fn empty_graph() {
        let g = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        assert_eq!(Sgmm.run(&g).len(), 0);
    }
}
