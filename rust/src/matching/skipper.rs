//! **Skipper** (paper §IV, Algorithm 1): asynchronous maximal matching with
//! a single pass over edges and Just-In-Time conflict resolution — the
//! CSR/BlockScheduler *driver* over the shared [`SkipperCore`] state
//! machine (see [`super::core`] for the core/driver split).
//!
//! Per-vertex state is one byte: `ACC(0)`, `RSVD(1)`, `MCHD(2)`. Matching an
//! edge `(u,v)` with `u < v` (deadlock avoidance, lines 8–9):
//!
//! 1. line 10 — while neither endpoint is `MCHD`;
//! 2. lines 11–12 — CAS `u: ACC→RSVD`; on failure re-check (another thread
//!    holds `u`, or `u` just got matched);
//! 3. lines 13–16 — spin on `v`: CAS `v: ACC→MCHD`; on success plain-write
//!    `u = MCHD` (we hold the reservation — no CAS needed) and emit the
//!    match;
//! 4. lines 17–18 — if `v` was matched by someone else, plain-write
//!    `u = ACC` (release).
//!
//! A *JIT conflict* is a failing CAS at line 11 or 14 (Table II's
//! definition). Edges are dispatched by the thread-dispersed
//! locality-preserving scheduler (§IV-C) and matches go to private
//! 1024-edge buffers carved from a shared arena.

pub use super::core::{process_edge, ACC, MCHD, RSVD};
use super::core::SkipperCore;
use super::{MatchArena, MaximalMatcher, Matching};
use crate::graph::CsrGraph;
use crate::instrument::conflicts::ConflictStats;
use crate::instrument::{address, NoProbe, Probe};
use crate::par::scheduler::{Assignment, BlockScheduler};
use crate::par::run_threads_collect;

/// Skipper configuration. The paper stresses there are **no tuning
/// parameters**; `blocks_per_thread` only shapes the scheduler's work
/// granularity and the default is used everywhere.
#[derive(Clone, Copy, Debug)]
pub struct Skipper {
    /// Worker threads.
    pub threads: usize,
    /// Scheduler blocks per thread (work granularity only).
    pub blocks_per_thread: usize,
    /// Block-to-thread assignment policy (§IV-C).
    pub assignment: Assignment,
}

impl Skipper {
    /// The paper’s configuration at `threads` threads.
    pub fn new(threads: usize) -> Self {
        Self {
            threads,
            blocks_per_thread: 16,
            assignment: Assignment::DispersedContiguous,
        }
    }

    /// Override the scheduler assignment policy (ablation benches).
    pub fn with_assignment(mut self, a: Assignment) -> Self {
        self.assignment = a;
        self
    }

    /// Full run returning the matching plus JIT-conflict telemetry and one
    /// probe per thread.
    pub fn run_instrumented<P: Probe + Default + Send>(
        &self,
        g: &CsrGraph,
    ) -> (Matching, ConflictStats, Vec<P>) {
        let n = g.num_vertices();
        // Lines 1–4: state array, all ACC. One byte per vertex.
        let core = SkipperCore::new(n);
        let sched = BlockScheduler::new(g, self.threads, self.blocks_per_thread, self.assignment);
        let arena = MatchArena::for_graph(g, self.threads);

        let per_thread = run_threads_collect(self.threads, |tid| {
            let mut probe = P::default();
            let mut stats = ConflictStats::default();
            let mut writer = arena.writer();
            while let Some((bs, be)) = sched.next_block(tid) {
                for x in bs..be {
                    // Vertex-level skip: if x is already matched, none of its
                    // remaining edges can select it; the edges stay covered
                    // by x itself (maximality) and are still visible from
                    // their other endpoints.
                    probe.load(address::state(x as u64));
                    if core.is_matched(x) {
                        continue;
                    }
                    probe.load(address::offsets(x as u64));
                    probe.load(address::offsets(x as u64 + 1));
                    let base = g.offsets()[x as usize];
                    for (i, &y) in g.neighbors(x).iter().enumerate() {
                        probe.load(address::neighbors(base + i as u64));
                        let conflicts = core.process_edge(x, y, &mut writer, &mut probe);
                        stats.record_edge(conflicts);
                        // If x got matched meanwhile, skip its remaining edges.
                        if core.is_matched_relaxed(x) {
                            probe.load(address::state(x as u64));
                            break;
                        }
                    }
                }
            }
            (stats, probe)
        });

        let mut stats = ConflictStats::default();
        let mut probes = Vec::with_capacity(self.threads);
        for (s, p) in per_thread {
            stats.merge(&s);
            probes.push(p);
        }
        (arena.into_matching(), stats, probes)
    }
}

/// Result bundle for experiment drivers.
pub struct SkipperReport {
    /// The computed matching.
    pub matching: Matching,
    /// JIT-conflict telemetry of the run.
    pub conflicts: ConflictStats,
}

impl Skipper {
    /// Run with conflict telemetry but no access counting (the hot
    /// configuration used by benches).
    pub fn run_with_conflicts(&self, g: &CsrGraph) -> SkipperReport {
        let (matching, conflicts, _) = self.run_instrumented::<NoProbe>(g);
        SkipperReport { matching, conflicts }
    }
}

impl MaximalMatcher for Skipper {
    fn name(&self) -> String {
        format!("Skipper(t={})", self.threads)
    }

    fn run(&self, g: &CsrGraph) -> Matching {
        let (matching, _, _) = self.run_instrumented::<NoProbe>(g);
        matching
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen::{barabasi_albert, rmat, simple, GenConfig};
    use crate::instrument::CountingProbe;
    use crate::matching::verify;

    fn check_on(g: &CsrGraph, threads: usize) -> Matching {
        let m = Skipper::new(threads).run(g);
        verify::check(g, &m).unwrap();
        m
    }

    #[test]
    fn single_thread_small_graphs() {
        for g in [simple::path(9), simple::cycle(8), simple::star(17), simple::complete(9)] {
            check_on(&g, 1);
        }
    }

    #[test]
    fn multi_thread_small_graphs() {
        for g in [simple::path(64), simple::cycle(65), simple::star(64), simple::complete(24)] {
            for t in [2, 4, 8] {
                check_on(&g, t);
            }
        }
    }

    #[test]
    fn star_contention_yields_one_edge() {
        // Worst case: every edge shares vertex 0.
        let g = simple::star(512);
        for t in [1, 4, 16] {
            let m = check_on(&g, t);
            assert_eq!(m.len(), 1);
        }
    }

    #[test]
    fn rmat_many_threads() {
        let g = rmat::generate(&GenConfig { scale: 12, avg_degree: 8, seed: 4 });
        let m = check_on(&g, 8);
        // matching size should be in the same ballpark as SGMM's
        let s = super::super::sgmm::Sgmm.run(&g);
        let ratio = m.len() as f64 / s.len() as f64;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn hub_graph_under_contention() {
        let g = barabasi_albert::generate(4096, 4, 7);
        check_on(&g, 8);
    }

    #[test]
    fn works_on_directed_nonsymmetrized_input() {
        // §V-C: Skipper doesn't require both edge copies. Build a directed
        // CSR (each undirected edge stored once) and verify against the
        // symmetric version of the same topology.
        use crate::graph::builder::{build, to_edge_list, BuildOptions};
        let sym = rmat::generate(&GenConfig { scale: 10, avg_degree: 6, seed: 8 });
        let el = to_edge_list(&sym);
        let directed = build(
            &el,
            BuildOptions { symmetrize: false, dedup: true, drop_self_loops: true },
        );
        let m = Skipper::new(4).run(&directed);
        // verify maximality against the *symmetric* graph
        verify::check(&sym, &m).unwrap();
    }

    #[test]
    fn conflicts_are_rare_on_big_graphs() {
        // §V-B: conflicting edges / |E| << 1.
        let g = rmat::generate(&GenConfig { scale: 13, avg_degree: 8, seed: 6 });
        let rep = Skipper::new(8).run_with_conflicts(&g);
        let ratio = rep.conflicts.edges_with_conflicts as f64 / g.num_edge_slots() as f64;
        assert!(ratio < 0.01, "conflict ratio {ratio}");
    }

    #[test]
    fn single_thread_has_no_conflicts() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 3 });
        let rep = Skipper::new(1).run_with_conflicts(&g);
        assert_eq!(rep.conflicts.total, 0);
    }

    #[test]
    fn access_count_near_paper_band() {
        // §VI-C: Skipper needs 1.2–3.4 accesses per edge; allow slack for
        // the different normalization of our generated graphs.
        let g = rmat::generate(&GenConfig { scale: 13, avg_degree: 16, seed: 2 });
        let sk = Skipper::new(1);
        let (_, _, probes) = sk.run_instrumented::<CountingProbe>(&g);
        let total = CountingProbe::merge(&probes).total();
        let per_edge = total as f64 / g.num_edge_slots() as f64;
        assert!(per_edge < 5.0, "Skipper accesses/edge = {per_edge}");
    }

    #[test]
    fn empty_and_trivial_graphs() {
        let empty = CsrGraph::from_parts(vec![0], vec![]).unwrap();
        assert_eq!(Skipper::new(2).run(&empty).len(), 0);
        let single = CsrGraph::from_parts(vec![0, 0], vec![]).unwrap();
        assert_eq!(Skipper::new(2).run(&single).len(), 0);
    }

    #[test]
    fn self_loops_skipped() {
        use crate::graph::builder::{build, BuildOptions};
        use crate::graph::EdgeList;
        let mut el = EdgeList::new(4);
        el.push(0, 0);
        el.push(0, 1);
        el.push(2, 2);
        el.push(2, 3);
        let g = build(
            &el,
            BuildOptions { symmetrize: true, dedup: true, drop_self_loops: false },
        );
        let m = Skipper::new(2).run(&g);
        assert_eq!(m.to_sorted_vec(), vec![(0, 1), (2, 3)]);
    }

    #[test]
    fn all_assignments_produce_valid_matchings() {
        let g = rmat::generate(&GenConfig { scale: 11, avg_degree: 8, seed: 12 });
        for a in [Assignment::DispersedContiguous, Assignment::Interleaved, Assignment::SharedQueue] {
            let m = Skipper::new(4).with_assignment(a).run(&g);
            verify::check(&g, &m).unwrap();
        }
    }
}
