//! Maximal-matching algorithms and output plumbing.
//!
//! ## Core / driver split
//!
//! Skipper's per-edge state machine lives in [`core::SkipperCore`]: the
//! one-byte-per-vertex state array plus `process_edge` (Algorithm 1 lines
//! 6–18). The core is deliberately ignorant of *where edges come from*;
//! three drivers feed it:
//!
//! * [`skipper::Skipper`] — the paper's configuration: a materialized CSR
//!   graph walked through the thread-dispersed block scheduler
//!   (`par::scheduler`), with vertex-level skips and full conflict/access
//!   telemetry;
//! * [`streaming::StreamingSkipper`] — the chunk driver: edges pulled from
//!   any [`crate::graph::stream::EdgeSource`] (disk readers, generators,
//!   in-memory batches) through a bounded queue, so matching overlaps
//!   ingest I/O and no CSR is ever built;
//! * [`incremental::IncrementalMatcher`] — one long-lived core fed
//!   edge-insertion batches, maintaining maximality across updates.
//!
//! Because the core decides each edge exactly once and never revisits it,
//! all drivers inherit the same correctness argument, and any one-shot
//! delivery order (CSR order, stream order, batch order) is a valid
//! execution of the same algorithm.
//!
//! ## Output plumbing
//!
//! The output container reproduces the paper's buffer scheme (§IV-C): one
//! arena sized for the worst case is allocated up front; each thread
//! bump-allocates private 1024-edge buffers from it and writes matches
//! sequentially; unfilled tail slots carry the `-1` sentinel and are skipped
//! on read-out.

pub mod core;
pub mod ems;
pub mod incremental;
pub mod mis;
pub mod noreserve;
pub mod sgmm;
pub mod skipper;
pub mod streaming;
pub mod verify;

use crate::graph::CsrGraph;
use crate::{VertexId, INVALID_VERTEX};
use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Per-thread buffer granularity (paper: "Each thread requests a 1024-edge
/// buffer").
pub const BUFFER_EDGES: usize = 1024;

/// Finished matching: the arena with sentinel-padded per-thread buffers.
#[derive(Clone, Debug)]
pub struct Matching {
    slots: Vec<(VertexId, VertexId)>,
    num_matches: usize,
}

impl Matching {
    /// Wrap a dense list of matches (sequential algorithms).
    pub fn from_pairs(pairs: Vec<(VertexId, VertexId)>) -> Self {
        let num_matches = pairs.len();
        Self {
            slots: pairs,
            num_matches,
        }
    }

    /// Number of matched edges (invalid sentinel slots excluded).
    pub fn len(&self) -> usize {
        self.num_matches
    }

    /// True when no edges are matched.
    pub fn is_empty(&self) -> bool {
        self.num_matches == 0
    }

    /// Iterate valid matches, skipping sentinel slots (paper §IV-C: "easily
    /// processed by skipping from invalid elements").
    pub fn iter(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        self.slots
            .iter()
            .copied()
            .filter(|&(u, _)| u != INVALID_VERTEX)
    }

    /// Canonicalized (min,max) pairs, sorted — for comparisons in tests.
    pub fn to_sorted_vec(&self) -> Vec<(VertexId, VertexId)> {
        let mut v: Vec<(VertexId, VertexId)> = self
            .iter()
            .map(|(a, b)| (a.min(b), a.max(b)))
            .collect();
        v.sort_unstable();
        v
    }

    /// Total arena slots consumed (valid + sentinel) — used by tests to
    /// assert the buffer-accounting invariants.
    pub fn slots_used(&self) -> usize {
        self.slots.len()
    }
}

/// Shared match arena: threads grab private `BUFFER_EDGES`-sized ranges via
/// an atomic bump pointer; ranges never overlap, so plain writes through the
/// `UnsafeCell` are race-free (mirrors the paper's design).
pub struct MatchArena {
    slots: UnsafeCell<Vec<(VertexId, VertexId)>>,
    next: AtomicUsize,
    capacity: usize,
}

// SAFETY: disjoint ranges are handed to at most one writer each (enforced by
// the atomic bump pointer); readers only exist after all writers joined.
unsafe impl Sync for MatchArena {}

impl MatchArena {
    /// Capacity follows the paper (a |V|-edge block) plus one buffer of slack
    /// per thread so partially-filled final buffers always fit.
    pub fn for_graph(g: &CsrGraph, num_threads: usize) -> Self {
        Self::with_capacity(g.num_vertices() / 2 + (num_threads + 1) * BUFFER_EDGES)
    }

    /// Arena with an explicit slot capacity (sentinel-filled).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            slots: UnsafeCell::new(vec![(INVALID_VERTEX, INVALID_VERTEX); capacity]),
            next: AtomicUsize::new(0),
            capacity,
        }
    }

    /// Claim the next private buffer; returns its non-empty `[start, end)`
    /// range, `end <= capacity`.
    ///
    /// Checked claim: with many concurrent writers the bump pointer can
    /// sail arbitrarily far past `capacity` (each racing `fetch_add`
    /// advances it whether or not the claim is honored), so a claim can
    /// start at or beyond `capacity`. Refusing it has always been the
    /// behavior (the previous `assert!` fired before returning); this makes
    /// the bound check explicit and *first* — no clamped-empty
    /// `[capacity, capacity)` range is ever even computed — and the panic
    /// names the claiming thread, the claimed range, and the capacity so an
    /// exhaustion in a many-thread run is diagnosable.
    fn grab(&self) -> (usize, usize) {
        let start = self.next.fetch_add(BUFFER_EDGES, Ordering::Relaxed);
        if start >= self.capacity {
            panic!(
                "match arena exhausted ({:?} claimed slots {}..{} past capacity {})",
                std::thread::current().id(),
                start,
                start + BUFFER_EDGES,
                self.capacity
            );
        }
        (start, (start + BUFFER_EDGES).min(self.capacity))
    }

    /// A writer for one thread. Each writer must be used by a single thread.
    pub fn writer(&self) -> MatchWriter<'_> {
        MatchWriter {
            arena: self,
            pos: 0,
            end: 0,
        }
    }

    /// Consume the arena into a [`Matching`], truncated to the used prefix.
    pub fn into_matching(self) -> Matching {
        let used = self.next.load(Ordering::Relaxed).min(self.capacity);
        let mut slots = self.slots.into_inner();
        slots.truncate(used);
        let num_matches = slots.iter().filter(|&&(u, _)| u != INVALID_VERTEX).count();
        Matching { slots, num_matches }
    }
}

/// Thread-private sequential writer into the shared arena.
pub struct MatchWriter<'a> {
    arena: &'a MatchArena,
    pos: usize,
    end: usize,
}

impl MatchWriter<'_> {
    #[inline]
    /// Record one matched edge, claiming a fresh private buffer when the
    /// current one is full.
    pub fn push(&mut self, u: VertexId, v: VertexId) {
        if self.pos == self.end {
            let (s, e) = self.arena.grab();
            self.pos = s;
            self.end = e;
        }
        // SAFETY: [pos, end) is exclusively ours (see MatchArena).
        unsafe {
            let base = (*self.arena.slots.get()).as_mut_ptr();
            base.add(self.pos).write((u, v));
        }
        self.pos += 1;
    }
}

/// Common interface for all matching algorithms in this crate.
pub trait MaximalMatcher {
    /// Display name (with configuration), for tables and bench labels.
    fn name(&self) -> String;
    /// Compute a maximal matching of `g`.
    fn run(&self, g: &CsrGraph) -> Matching;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::run_threads;

    #[test]
    fn from_pairs_roundtrip() {
        let m = Matching::from_pairs(vec![(0, 1), (2, 3)]);
        assert_eq!(m.len(), 2);
        assert_eq!(m.to_sorted_vec(), vec![(0, 1), (2, 3)]);
    }

    #[test]
    fn arena_single_thread() {
        let arena = MatchArena::with_capacity(BUFFER_EDGES * 2);
        let mut w = arena.writer();
        for i in 0..10u32 {
            w.push(2 * i, 2 * i + 1);
        }
        drop(w);
        let m = arena.into_matching();
        assert_eq!(m.len(), 10);
        // one buffer grabbed; sentinel padding fills the rest
        assert_eq!(m.slots_used(), BUFFER_EDGES);
        assert_eq!(m.iter().count(), 10);
    }

    #[test]
    fn arena_buffer_rollover() {
        let arena = MatchArena::with_capacity(BUFFER_EDGES * 3);
        let mut w = arena.writer();
        let n = BUFFER_EDGES + 7;
        for i in 0..n as u32 {
            w.push(i, i + 1);
        }
        drop(w);
        let m = arena.into_matching();
        assert_eq!(m.len(), n);
        assert_eq!(m.slots_used(), BUFFER_EDGES * 2);
    }

    #[test]
    fn arena_concurrent_writers_disjoint() {
        let threads = 4;
        let per_thread = BUFFER_EDGES + 123;
        let arena = MatchArena::with_capacity((threads + 1) * (per_thread + BUFFER_EDGES));
        run_threads(threads, |tid| {
            let mut w = arena.writer();
            for i in 0..per_thread as u32 {
                w.push(tid as u32, i);
            }
        });
        let m = arena.into_matching();
        assert_eq!(m.len(), threads * per_thread);
        // every thread's writes all survived
        for tid in 0..threads as u32 {
            assert_eq!(m.iter().filter(|&(u, _)| u == tid).count(), per_thread);
        }
    }

    #[test]
    #[should_panic(expected = "match arena exhausted")]
    fn arena_exhaustion_panics() {
        let arena = MatchArena::with_capacity(BUFFER_EDGES);
        let mut w = arena.writer();
        for i in 0..(BUFFER_EDGES + 1) as u32 {
            w.push(i, i);
        }
    }

    #[test]
    fn overclaim_never_hands_out_empty_range() {
        // Two writers, capacity for one buffer. The second writer's grab
        // lands exactly at `capacity` and must fail loudly (never an empty
        // [capacity, capacity) range), with a diagnosable message.
        let arena = MatchArena::with_capacity(BUFFER_EDGES);
        let mut w1 = arena.writer();
        w1.push(0, 1); // claims [0, BUFFER_EDGES)
        let mut w2 = arena.writer();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w2.push(2, 3);
        }));
        let err = result.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("match arena exhausted"), "{msg}");
        assert!(msg.contains("capacity"), "{msg}");
    }

    #[test]
    fn concurrent_overclaim_fails_loudly_and_valid_writes_survive() {
        // Regression for the racing fetch_add: capacity fits exactly
        // `threads` buffers; every thread fills one, then each tries one
        // more push. All the overflow pushes must panic, and every write
        // that was accepted must survive intact.
        let threads = 4;
        let arena = MatchArena::with_capacity(threads * BUFFER_EDGES);
        let panics = std::sync::atomic::AtomicUsize::new(0);
        let barrier = std::sync::Barrier::new(threads);
        std::thread::scope(|s| {
            for tid in 0..threads as u32 {
                let arena = &arena;
                let panics = &panics;
                let barrier = &barrier;
                s.spawn(move || {
                    let mut w = arena.writer();
                    // exactly fill one private buffer...
                    for i in 0..BUFFER_EDGES as u32 {
                        w.push(tid, i);
                    }
                    // ...wait until the arena is exactly full everywhere...
                    barrier.wait();
                    // ...then every further claim must fail loudly.
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        w.push(tid, BUFFER_EDGES as u32);
                    }));
                    if result.is_err() {
                        panics.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                });
            }
        });
        // every thread's (BUFFER_EDGES+1)-th push overflows
        assert_eq!(panics.load(std::sync::atomic::Ordering::Relaxed), threads);
        let m = arena.into_matching();
        assert_eq!(m.len(), threads * BUFFER_EDGES);
        for tid in 0..threads as u32 {
            assert_eq!(m.iter().filter(|&(u, _)| u == tid).count(), BUFFER_EDGES);
        }
    }

    #[test]
    fn sentinel_slots_skipped() {
        let arena = MatchArena::with_capacity(BUFFER_EDGES * 2);
        {
            let mut w = arena.writer();
            w.push(5, 6);
        }
        let m = arena.into_matching();
        let all: Vec<_> = m.iter().collect();
        assert_eq!(all, vec![(5, 6)]);
    }
}
