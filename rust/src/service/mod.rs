//! Long-running match service over the pooled sharded dynamic engine.
//!
//! Architecture (many clients, a router thread, a flusher thread, and the
//! engine's persistent shard workers):
//!
//! ```text
//! client conns ──parse──▶ ShardedQueue ──drain/route──▶ router thread
//!   (stdio or TCP,          (per-shard                    │ mailbox
//!    thread each)         BoundedQueues +                 ▼ generation N+1
//!      │                    doorbell)           flush jobs (capacity-1
//!      │ QUERY fast path                            hand-off queue)
//!      │                                                  │
//!      │                                          flusher thread: apply
//!      │                                            generation N
//!      │                                 ┌─ parallel mutate (worker pool) ─┐
//!      │                                 │ shard 0 … shard P, parked,      │
//!      │                                 │ doorbell-woken, countdown join  │
//!      └── atomic partner[] reads ──────▶└─────────── barrier ────────────┘
//!                                                shared-core sweeps
//!                                                (insert + repair)
//! ```
//!
//! * [`protocol`] — the line-delimited command/JSON-reply wire format
//!   (specified field by field in `docs/PROTOCOL.md`);
//! * [`server`] — connection front-ends (stdin pipe, TCP), the pipelined
//!   router/flusher coordinator pair, and per-epoch telemetry (repair
//!   fraction, matched count, p50/p99 batch latency, per-phase wall times,
//!   spawn-vs-run and route-overlap decompositions);
//! * [`replica`] — the warm-standby follower: replays a primary's shipped
//!   WAL stream (see [`crate::persist::ship`]) through its own engine,
//!   serves reads lock-free, and takes over as a writable primary on
//!   `PROMOTE`;
//! * this module — the two coordination primitives they share:
//!   [`ShardedQueue`], the front-end fan-in built from
//!   [`BoundedQueue`](crate::par::pump::BoundedQueue)s (per-shard
//!   back-pressure, so one flooding client stalls itself, not the world),
//!   and [`Promise`], a one-shot reply slot (a capacity-1 `BoundedQueue`
//!   underneath).
//!
//! Updates are acknowledged at enqueue time and routed straight into the
//! engine's per-shard mailboxes, which double as the coalescing buffer.
//! With pipelining on (default) the router keeps routing the next mailbox
//! generation while the flusher applies the previous one, so parse/route
//! overlaps matching; `EPOCH`/`STATS` barriers ride the same FIFO hand-off
//! and are answered in order, after everything the same client sent before
//! them. `QUERY` from a connection with nothing pending is answered
//! lock-free from the owner shard's atomic `partner[]` slot, never
//! stalling an in-flight epoch.

pub mod protocol;
pub mod replica;
pub mod server;

use crate::par::pump::BoundedQueue;
use std::sync::Arc;

pub use replica::{serve_follower_lines, serve_follower_tcp, Replica, ReplicaSummary};
pub use server::{serve_lines, serve_tcp, ServiceConfig, ServiceSummary};

/// One-shot reply slot: the engine thread fulfills, the client thread
/// waits. A capacity-1 [`BoundedQueue`] gives blocking hand-off and a
/// `None` (instead of a hang) if the engine shuts down without answering.
pub struct Promise<T> {
    q: BoundedQueue<T>,
}

impl<T> Default for Promise<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Promise<T> {
    /// An unfulfilled promise.
    pub fn new() -> Self {
        Self { q: BoundedQueue::new(1) }
    }

    /// Shared handle, one end for the fulfiller, one for the waiter.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Fulfill the promise. A promise is fulfilled at most once; a second
    /// fulfillment or one after abandonment is dropped.
    pub fn fulfill(&self, value: T) {
        let _ = self.q.try_push(value);
    }

    /// Block until fulfilled; `None` if the fulfilling side abandoned it.
    pub fn wait(&self) -> Option<T> {
        self.q.pop()
    }

    /// Abandon: wake any waiter with `None`.
    pub fn abandon(&self) {
        self.q.close();
    }
}

/// Fan-in queue for client requests: each shard is its own bounded queue
/// (back-pressure is per shard), and a capacity-1 doorbell wakes the single
/// consumer without making any ringer wait.
pub struct ShardedQueue<T> {
    shards: Vec<BoundedQueue<T>>,
    doorbell: BoundedQueue<()>,
}

impl<T> ShardedQueue<T> {
    /// `shards` queues of `per_shard_capacity` each (both clamped ≥ 1).
    pub fn new(shards: usize, per_shard_capacity: usize) -> Self {
        Self {
            shards: (0..shards.max(1))
                .map(|_| BoundedQueue::new(per_shard_capacity))
                .collect(),
            doorbell: BoundedQueue::new(1),
        }
    }

    /// Number of front-end shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Blocking push onto `shard % num_shards`; `Err` once closed. Rings
    /// the doorbell after a successful push.
    pub fn push(&self, shard: usize, item: T) -> Result<(), T> {
        self.shards[shard % self.shards.len()].push(item)?;
        let _ = self.doorbell.try_push(()); // already-rung is fine
        Ok(())
    }

    /// Drain up to `max` items round-robin across shards into `out`
    /// (appended). Non-blocking; returns how many were taken.
    pub fn drain(&self, out: &mut Vec<T>, max: usize) -> usize {
        let mut taken = 0;
        while taken < max {
            let mut any = false;
            for shard in &self.shards {
                if taken >= max {
                    break;
                }
                if let Some(item) = shard.try_pop() {
                    out.push(item);
                    taken += 1;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        taken
    }

    /// Block until someone rings (true) or the queue is closed (false).
    /// Spurious wakes are fine — callers loop around `drain`.
    pub fn wait(&self) -> bool {
        self.doorbell.pop().is_some()
    }

    /// Close every shard and the doorbell: producers start failing,
    /// `drain` still empties the backlog, `wait` returns false.
    pub fn close(&self) {
        for shard in &self.shards {
            shard.close();
        }
        self.doorbell.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promise_roundtrip_and_abandon() {
        let p = Promise::shared();
        p.fulfill(42);
        assert_eq!(p.wait(), Some(42));
        let p2: Arc<Promise<i32>> = Promise::shared();
        p2.abandon();
        assert_eq!(p2.wait(), None);
        // fulfill-after-abandon is a no-op, not a panic
        p2.fulfill(1);
    }

    #[test]
    fn promise_hands_off_across_threads() {
        let p = Promise::shared();
        std::thread::scope(|s| {
            let p2 = Arc::clone(&p);
            s.spawn(move || p2.fulfill("done"));
            assert_eq!(p.wait(), Some("done"));
        });
    }

    #[test]
    fn sharded_drain_is_round_robin_and_bounded() {
        let q: ShardedQueue<u32> = ShardedQueue::new(3, 8);
        for i in 0..9u32 {
            q.push(i as usize, i).unwrap();
        }
        let mut out = Vec::new();
        assert_eq!(q.drain(&mut out, 5), 5);
        // round-robin: one from each shard per cycle
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(q.drain(&mut out, 100), 4);
        assert_eq!(q.drain(&mut out, 100), 0);
    }

    #[test]
    fn doorbell_wakes_consumer_and_close_stops_it() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 4);
        std::thread::scope(|s| {
            let consumer = s.spawn(|| {
                let mut got = Vec::new();
                loop {
                    let mut out = Vec::new();
                    q.drain(&mut out, 16);
                    got.extend(out);
                    if got.len() >= 3 {
                        return got;
                    }
                    if !q.wait() {
                        return got;
                    }
                }
            });
            for i in 0..3 {
                std::thread::sleep(std::time::Duration::from_millis(5));
                q.push(i as usize, i).unwrap();
            }
            let got = consumer.join().unwrap();
            assert_eq!(got.len(), 3);
        });
        q.close();
        assert!(q.push(0, 9).is_err());
        assert!(!q.wait());
    }

    #[test]
    fn per_shard_backpressure_does_not_cross_shards() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 1);
        q.push(0, 10).unwrap(); // shard 0 now full
        // shard 1 must accept immediately even though shard 0 is full
        q.push(1, 20).unwrap();
        let mut out = Vec::new();
        q.drain(&mut out, 10);
        out.sort_unstable();
        assert_eq!(out, vec![10, 20]);
    }
}
