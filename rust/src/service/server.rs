//! The long-running match server: connection front-ends, the pooled
//! sharded engine, and the pipelined epoch coordinator.
//!
//! One [`ShardedDynamicMatcher`] is shared by every thread in the process.
//! Client connections (one thread each in TCP mode; the calling thread in
//! stdio mode) parse lines into [`Command`]s and push requests onto the
//! [`ShardedQueue`]. The **router** thread drains all front-end shards
//! round-robin and routes every update straight into a *generation* of the
//! engine's per-shard mailboxes — the mailboxes *are* the coalescing
//! buffer, so concurrent clients share epochs instead of serializing one
//! engine pass per request.
//!
//! At a barrier (an explicit `EPOCH`, a queue-riding `QUERY`/`STATS`, or
//! the coalescing threshold) the routed generation becomes a flush job.
//! With pipelining on (the default), flush jobs cross a capacity-1 hand-off
//! queue to the **flusher** thread, and the router immediately starts
//! routing the *next* generation into a recycled mailbox set — parse/route
//! work overlaps matching, and the per-epoch overlap is reported in
//! [`EpochReport::route_overlap_s`](crate::dynamic::EpochReport). With
//! pipelining off the same jobs execute inline on the router thread, which
//! is exactly the previous serial coordinator. Either way a flush applies
//! one engine epoch: the mutate phase fans out across the engine's
//! persistent shard workers (or forked threads — see
//! [`ShardExec`](crate::dynamic::ShardExec)), and the insert/repair sweeps
//! run against the shared one-byte-per-vertex core. Barrier jobs ride the
//! same FIFO hand-off as the flushes they follow, so `EPOCH`/`STATS`
//! observe everything their client sent earlier and are answered through
//! one-shot [`Promise`]s in order.
//!
//! `QUERY` has a fast path: when the querying connection has no updates
//! queued since its last barrier, the answer comes straight from the owner
//! shard's atomic `partner[]` slot — lock-free, without stalling (or
//! waiting for) any in-flight epoch. A connection with queued updates still
//! rides the queue, preserving the read-your-writes guarantee.
//!
//! Updates are acknowledged at enqueue time (`{"op":"queued"}`); the
//! per-shard bounded queues push back on flooding clients without stalling
//! the others, and the capacity-1 flush hand-off keeps the router at most
//! one generation ahead of the engine.
//!
//! The wire protocol itself is specified in `docs/PROTOCOL.md`.

use super::protocol::{Command, CrashTarget, Response, StatsSnapshot};
use super::{Promise, ShardedQueue};
use crate::dynamic::{EpochReport, ShardExec, ShardMailboxes, ShardedDynamicMatcher, Update};
use crate::par::pump::{BoundedQueue, CloseOnDrop};
use crate::persist::snapshot::SnapshotData;
use crate::persist::{DurableOptions, DurableService};
use crate::util::stats::percentile;
use crate::VertexId;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Tunables of one service instance (see `skipper-cli serve --help` for
/// the CLI spellings and defaults).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Vertex universe `0..num_vertices` (fixed for the server's lifetime).
    pub num_vertices: usize,
    /// Matcher threads inside the engine's parallel sweeps.
    pub threads: usize,
    /// Engine shards (`P`): the vertex partition of the dynamic engine.
    /// Each epoch's mutate phase runs one worker per shard; `1` is the
    /// single-shard engine.
    pub engine_shards: usize,
    /// Use the persistent shard-worker pool for the engine's per-shard
    /// phases (default). `false` forks one scoped thread per shard per
    /// epoch — the measured baseline (`--no-pool`).
    pub pool: bool,
    /// Pipelined coordinator (default): route the next epoch's updates on
    /// the router thread while the flusher thread applies the current one.
    /// `false` runs flushes inline on the router (`--no-pipeline`).
    pub pipeline: bool,
    /// Front-end queue shards (connections hash onto these).
    pub shards: usize,
    /// Per-shard queue capacity (requests) — the back-pressure window.
    pub shard_capacity: usize,
    /// Max requests coalesced per engine drain round.
    pub epoch_max_requests: usize,
    /// Coalescing threshold: pending updates are applied as an epoch once
    /// this many accumulate, even without an explicit `EPOCH` barrier.
    pub epoch_max_updates: usize,
    /// Durability root holding `wal/` and `snapshots/` (`--data-dir`).
    /// `None` = fully volatile service, no recovery at boot.
    pub data_dir: Option<String>,
    /// Append each epoch's update batch to the WAL before applying it
    /// (default with a data dir; `--no-wal` disables logging — recovery
    /// still replays whatever log is on disk).
    pub wal: bool,
    /// `fsync` every WAL append (`--fsync`): durable against power loss,
    /// not just process death, at per-epoch fsync cost.
    pub wal_fsync: bool,
    /// Automatically snapshot every this many applied epochs
    /// (`--snapshot-every`; 0 = only on `SNAPSHOT` commands and at
    /// shutdown).
    pub snapshot_every: u64,
    /// Accept the debug fault-injection command `CRASH`
    /// (`--debug-commands`) — a testing aid, off by default.
    pub debug_commands: bool,
    /// When a coordinator (router/flusher) thread panics, print a
    /// diagnostic and exit the process (code 70) instead of leaving a
    /// half-dead server with hanging clients. On by default; in-process
    /// tests disable it to observe the panic directly.
    pub exit_on_panic: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            num_vertices: 1 << 20,
            threads: 4,
            engine_shards: 1,
            pool: true,
            pipeline: true,
            shards: 4,
            shard_capacity: 64,
            epoch_max_requests: 256,
            epoch_max_updates: 8192,
            data_dir: None,
            wal: true,
            wal_fsync: false,
            snapshot_every: 0,
            debug_commands: false,
            exit_on_panic: true,
        }
    }
}

impl ServiceConfig {
    /// The engine shard-dispatch policy this config selects.
    pub fn shard_exec(&self) -> ShardExec {
        ShardExec::from_pool_flag(self.pool)
    }
}

/// What the server did over its lifetime — returned to the CLI on exit.
#[derive(Clone, Debug, Default)]
pub struct ServiceSummary {
    /// Engine epochs applied.
    pub epochs: u64,
    /// Insert updates received across all epochs.
    pub total_inserts: u64,
    /// Delete updates received across all epochs.
    pub total_deletes: u64,
    /// Edges re-examined by repair sweeps across all epochs.
    pub total_repair_edges: u64,
    /// Live undirected edges at shutdown.
    pub live_edges: u64,
    /// Matched vertices at shutdown.
    pub matched_vertices: usize,
    /// Final live-set maximality audit.
    pub maximal: bool,
    /// WAL epochs recovery replayed at boot (0 when volatile or clean).
    pub recovery_replayed: u64,
    /// Epoch records appended to the WAL over this run (0 when volatile).
    pub wal_epochs: u64,
    /// Epoch of the newest durably published snapshot at shutdown —
    /// normally the final shutdown snapshot; earlier (or 0) when that
    /// final write failed, and 0 when volatile.
    pub last_snapshot_epoch: u64,
}

enum Request {
    Updates { updates: Vec<Update>, enqueued: Instant },
    Epoch(ReplySlot),
    Query(VertexId, ReplySlot),
    /// `bool`: run the full maximality audit (`STATS full`).
    Stats(bool, ReplySlot),
    /// Barrier + hand the durable state to the background snapshot writer.
    Snapshot(ReplySlot),
    /// Debug fault injection: panic the named coordinator thread.
    Crash(CrashTarget),
    Shutdown,
}

/// Escorts a coordinator thread: if the thread unwinds with a panic while
/// `enabled`, print a diagnostic and exit the whole process — a half-dead
/// server that accepts connections but never answers is strictly worse
/// than a visible crash, and `EngineGuard`'s cleanup cannot reach clients
/// that connect *after* the panic.
struct ExitOnPanic {
    role: &'static str,
    enabled: bool,
}

/// Exit code used when a coordinator thread dies (EX_SOFTWARE).
pub const PANIC_EXIT_CODE: i32 = 70;

impl Drop for ExitOnPanic {
    fn drop(&mut self) {
        if self.enabled && std::thread::panicking() {
            eprintln!(
                "fatal: service {} thread panicked; exiting so clients are not left hanging (panic message above)",
                self.role
            );
            std::process::exit(PANIC_EXIT_CODE);
        }
    }
}

/// The engine's end of a [`Promise`]: guarantees the waiting client wakes
/// even when the slot is dropped unfulfilled (engine panic, shutdown
/// unwind, a dropped request buffer) — dropping abandons the promise, which
/// the client's `wait()` observes as `None`. Abandoning after a fulfill is
/// harmless: the fulfilled value still drains to the waiter.
struct ReplySlot(Arc<Promise<Response>>);

impl ReplySlot {
    fn fulfill(&self, r: Response) {
        self.0.fulfill(r);
    }
}

impl Drop for ReplySlot {
    fn drop(&mut self) {
        self.0.abandon();
    }
}

/// Raises the stop flag, closes the queue, and drops (→ abandons) any
/// queued requests when the coordinator thread exits — normally or by panic
/// — so neither clients nor the accept loop ever wait on a dead engine.
struct EngineGuard<'a> {
    queue: &'a ShardedQueue<Request>,
    stop: &'a AtomicBool,
}

impl Drop for EngineGuard<'_> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.close();
        let mut buf = Vec::new();
        while self.queue.drain(&mut buf, 1024) > 0 {
            buf.clear(); // dropping a ReplySlot wakes its waiter
        }
    }
}

/// Fixed-size ring of recent batch latencies (ms) for p50/p99 reporting.
struct LatencyRing {
    buf: Vec<f64>,
    pos: usize,
}

const LATENCY_RING: usize = 4096;

impl LatencyRing {
    fn new() -> Self {
        Self { buf: Vec::new(), pos: 0 }
    }

    fn push(&mut self, ms: f64) {
        if self.buf.len() < LATENCY_RING {
            self.buf.push(ms);
        } else {
            self.buf[self.pos] = ms;
            self.pos = (self.pos + 1) % LATENCY_RING;
        }
    }

    fn percentile(&self, p: f64) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        percentile(&self.buf, p)
    }
}

#[derive(Default)]
struct Telemetry {
    total_inserts: u64,
    total_deletes: u64,
    total_repair_edges: u64,
    repair_frac_last: f64,
    repair_frac_sum: f64,
    epochs_with_updates: u64,
    total_route_s: f64,
    total_route_overlap_s: f64,
}

/// One routed-but-unflushed generation of updates. The engine's per-shard
/// mailboxes double as the coalescing buffer: updates are routed to their
/// owner shard(s) at drain time, so a flush hands each shard worker its
/// work list with no extra pass. In pipelined mode a second generation is
/// being routed while the previous one is applied.
struct PendingGen {
    mailboxes: ShardMailboxes,
    /// Enqueue stamps of the update requests coalesced into this
    /// generation, for the batch-latency percentiles.
    stamps: Vec<Instant>,
    /// The generation's updates in arrival order, kept only when WAL
    /// logging is on — the flusher writes this flat list (mailboxes
    /// double-store cross-shard updates and lose the global order).
    wal_log: Vec<Update>,
    /// Router wall seconds spent routing this generation.
    route_s: f64,
    /// Portion of `route_s` spent while a flush was running — the
    /// pipelining overlap.
    overlap_s: f64,
}

impl PendingGen {
    fn new(mailboxes: ShardMailboxes) -> Self {
        Self {
            mailboxes,
            stamps: Vec::new(),
            wal_log: Vec::new(),
            route_s: 0.0,
            overlap_s: 0.0,
        }
    }
}

/// Work handed from the router to the flush executor. Barrier jobs carry
/// the generation they must flush first, so FIFO handling reproduces the
/// serial coordinator's semantics exactly — a barrier reply always reflects
/// every update its client sent before it.
enum FlushJob {
    /// Coalescing-threshold flush: apply, no reply.
    Apply(PendingGen),
    Epoch(Option<PendingGen>, ReplySlot),
    Query(Option<PendingGen>, VertexId, ReplySlot),
    Stats(Option<PendingGen>, bool, ReplySlot),
    Snapshot(Option<PendingGen>, ReplySlot),
    /// Debug fault injection: panic on the flush executor's thread.
    Crash,
}

/// The flush executor: owns service telemetry and the latency ring, applies
/// generations to the engine, and answers barrier requests. Runs inline on
/// the router thread when pipelining is off, or on the dedicated flusher
/// thread when it is on.
struct FlushExec<'a> {
    cfg: &'a ServiceConfig,
    engine: &'a ShardedDynamicMatcher,
    /// True while `apply_mailboxes` runs — the router reads it to attribute
    /// route time to the pipelining overlap.
    flushing: &'a AtomicBool,
    /// Drained mailbox generations go back here for the router to reuse.
    spares: &'a BoundedQueue<ShardMailboxes>,
    /// Durability bundle (WAL + snapshotter + counters); `None` when the
    /// service runs volatile. Owned here so every append and every state
    /// capture happens at an epoch barrier on the flush thread.
    dur: Option<DurableService>,
    tel: Telemetry,
    latencies: LatencyRing,
}

impl<'a> FlushExec<'a> {
    fn new(
        cfg: &'a ServiceConfig,
        engine: &'a ShardedDynamicMatcher,
        flushing: &'a AtomicBool,
        spares: &'a BoundedQueue<ShardMailboxes>,
        dur: Option<DurableService>,
    ) -> Self {
        Self {
            cfg,
            engine,
            flushing,
            spares,
            dur,
            tel: Telemetry::default(),
            latencies: LatencyRing::new(),
        }
    }

    fn flush(&mut self, gen: PendingGen) -> Option<EpochReport> {
        let PendingGen { mut mailboxes, mut stamps, wal_log, route_s, overlap_s } = gen;
        if mailboxes.is_empty() {
            // unreachable via take_gen (which never yields an empty
            // generation); a future direct caller would silently lose this
            // generation's stamps and route telemetry — catch it in tests
            debug_assert!(false, "flush() called with an empty generation");
            let _ = self.spares.try_push(mailboxes);
            return None;
        }
        // the overlap-attribution window spans the WHOLE flush — WAL
        // append (which can dominate under --fsync), engine apply, and the
        // post-epoch durability work — so the router's concurrent route
        // time lands in route_overlap_s wherever the flusher actually is
        self.flushing.store(true, Ordering::Relaxed);
        // WAL-before-apply: the epoch this flush is about to run gets the
        // number apply_mailboxes will assign (the flusher is the only
        // epoch applier, so the +1 cannot race). A failed append is fatal:
        // applying (and barrier-acknowledging) updates the log refused
        // would hand clients a gapped history after the next crash, so the
        // durability contract wins over availability — the panic-exit
        // guard turns this into a diagnosed process exit.
        if let Some(dur) = self.dur.as_mut() {
            if let Err(e) = dur.log_epoch(self.engine.epochs_applied() + 1, &wal_log) {
                panic!("wal: refusing to apply an unlogged epoch: {e}");
            }
        }
        let mut report = self.engine.apply_mailboxes(&mut mailboxes);
        report.route_wall_s = route_s;
        report.route_overlap_s = overlap_s;
        let now = Instant::now();
        for s in stamps.drain(..) {
            self.latencies.push(now.duration_since(s).as_secs_f64() * 1e3);
        }
        // recycle the drained mailbox set; a full rack just drops it
        let _ = self.spares.try_push(mailboxes);
        self.tel.total_inserts += report.inserts as u64;
        self.tel.total_deletes += report.deletes as u64;
        self.tel.total_repair_edges += report.repair_edges as u64;
        self.tel.repair_frac_last = report.repair_fraction();
        self.tel.repair_frac_sum += report.repair_fraction();
        self.tel.total_route_s += route_s;
        self.tel.total_route_overlap_s += overlap_s;
        self.tel.epochs_with_updates += 1;
        if let Some(dur) = self.dur.as_mut() {
            // cadence snapshots + lagged WAL pruning
            dur.after_epoch(self.engine);
        }
        self.flushing.store(false, Ordering::Relaxed);
        Some(report)
    }

    fn handle(&mut self, job: FlushJob) {
        match job {
            FlushJob::Apply(gen) => {
                self.flush(gen);
            }
            FlushJob::Epoch(gen, p) => {
                let rep = gen.and_then(|g| self.flush(g));
                p.fulfill(match rep {
                    Some(r) => Response::Epoch(r),
                    // flush of nothing: say so instead of fabricating a
                    // zero-count report under the previous epoch number
                    None => Response::EpochIdle {
                        epochs_applied: self.engine.epochs_applied(),
                        live_edges: self.engine.num_live_edges(),
                        matched_vertices: self.engine.matched_vertices(),
                    },
                });
            }
            FlushJob::Query(gen, v, p) => {
                if let Some(g) = gen {
                    self.flush(g);
                }
                p.fulfill(Response::Query { vertex: v, partner: self.engine.partner(v) });
            }
            FlushJob::Stats(gen, full, p) => {
                if let Some(g) = gen {
                    self.flush(g);
                }
                p.fulfill(Response::Stats(snapshot(
                    self.cfg,
                    self.engine,
                    &self.tel,
                    &self.latencies,
                    full,
                    self.dur.as_ref(),
                )));
            }
            FlushJob::Snapshot(gen, p) => {
                if let Some(g) = gen {
                    self.flush(g);
                }
                p.fulfill(match self.dur.as_mut() {
                    Some(dur) if dur.snapshot_busy() => {
                        // a previous snapshot is still being written: reply
                        // from cheap counters without building the
                        // O(|V|+|E|) barrier copy that would be discarded
                        Response::Snapshot {
                            epoch: self.engine.epochs_applied(),
                            live_edges: self.engine.num_live_edges(),
                            matched_vertices: self.engine.matched_vertices(),
                            accepted: false,
                        }
                    }
                    Some(dur) => {
                        // capture at the barrier; serialization and disk IO
                        // happen on the background writer thread
                        let data = SnapshotData::capture(self.engine);
                        let epoch = data.epoch;
                        let live_edges = data.live_edges.len() as u64;
                        let matched_vertices = 2 * data.matching.len();
                        let accepted = dur.request_snapshot(data);
                        Response::Snapshot { epoch, live_edges, matched_vertices, accepted }
                    }
                    None => Response::Error(
                        "durability is off: restart serve with --data-dir".into(),
                    ),
                });
            }
            FlushJob::Crash => panic!("debug CRASH: deliberate flusher panic"),
        }
    }

    fn summary(mut self) -> ServiceSummary {
        // graceful exit: a final synchronous snapshot makes the next boot a
        // snapshot-only recovery (zero WAL replay)
        let mut recovery_replayed = 0;
        let mut wal_epochs = 0;
        let mut last_snapshot_epoch = 0;
        if let Some(dur) = self.dur.take() {
            recovery_replayed = dur.recovery().replayed_epochs;
            wal_epochs = dur.counters().wal_epochs.load(Ordering::Relaxed);
            last_snapshot_epoch = dur.shutdown(self.engine);
        }
        ServiceSummary {
            epochs: self.engine.epochs_applied(),
            total_inserts: self.tel.total_inserts,
            total_deletes: self.tel.total_deletes,
            total_repair_edges: self.tel.total_repair_edges,
            live_edges: self.engine.num_live_edges(),
            matched_vertices: self.engine.matched_vertices(),
            maximal: self.engine.verify().is_ok(),
            recovery_replayed,
            wal_epochs,
            last_snapshot_epoch,
        }
    }
}

/// Where the router sends flush work: straight into the executor
/// (pipelining off) or across the hand-off queue to the flusher thread.
enum FlushSink<'e, 'q> {
    Inline(FlushExec<'e>),
    Pipe(&'q BoundedQueue<FlushJob>),
}

impl FlushSink<'_, '_> {
    fn send(&mut self, job: FlushJob) {
        match self {
            FlushSink::Inline(ex) => ex.handle(job),
            // a closed hand-off means the flusher died; dropping the job
            // abandons its promises, so waiting clients wake with an error
            // instead of hanging
            FlushSink::Pipe(q) => {
                let _ = q.push(job);
            }
        }
    }
}

/// Spare mailbox generations kept in rotation (one applying, one being
/// routed, plus recycling slack).
const MAILBOX_GENERATIONS: usize = 4;

/// The request router: drain → route into the current mailbox generation →
/// hand flush jobs to the sink at barriers, until the queue closes or a
/// `SHUTDOWN` arrives.
#[allow(clippy::too_many_arguments)] // one call site, mirrors engine_loop's locals
fn route_loop(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    queue: &ShardedQueue<Request>,
    stop: &AtomicBool,
    flushing: &AtomicBool,
    spares: &BoundedQueue<ShardMailboxes>,
    sink: &mut FlushSink<'_, '_>,
    log_wal: bool,
) {
    let _guard = EngineGuard { queue, stop };
    let mut buf: Vec<Request> = Vec::new();
    let mut gen = PendingGen::new(engine.mailboxes());

    // Take the current generation for a flush, swapping in a recycled (or
    // fresh) mailbox set so routing can continue immediately.
    let take_gen = |gen: &mut PendingGen| -> Option<PendingGen> {
        if gen.mailboxes.is_empty() {
            return None;
        }
        let fresh = spares.try_pop().unwrap_or_else(|| engine.mailboxes());
        Some(std::mem::replace(gen, PendingGen::new(fresh)))
    };

    // Route one update batch into the current generation, attributing the
    // route time (and, when a flush is running concurrently, the overlap).
    let route = |gen: &mut PendingGen, updates: &[Update], enqueued: Instant| -> bool {
        let t = Instant::now();
        let res = engine.route_into(updates, &mut gen.mailboxes);
        let dt = t.elapsed().as_secs_f64();
        gen.route_s += dt;
        if flushing.load(Ordering::Relaxed) {
            gen.overlap_s += dt;
        }
        match res {
            Ok(()) => {
                gen.stamps.push(enqueued);
                if log_wal {
                    gen.wal_log.extend_from_slice(updates);
                }
                true
            }
            // Connections validate vertex ranges before enqueueing, so the
            // only failure left is a bug — surface it without killing the
            // service (nothing was routed).
            Err(e) => {
                eprintln!("engine: dropped bad batch: {e}");
                false
            }
        }
    };

    // Updates coalesce in the current generation until a barrier request
    // (EPOCH / queue-riding QUERY / STATS) arrives, the coalescing
    // threshold trips, or the queue closes. Deliberately NO flush-on-idle:
    // a client's `INSERT ... / EPOCH` pair must deterministically see its
    // inserts applied *at the barrier*, not racily swept up in between.
    let mut shutdown = false;
    'outer: loop {
        buf.clear();
        queue.drain(&mut buf, cfg.epoch_max_requests);
        if buf.is_empty() {
            if !queue.wait() {
                break;
            }
            continue;
        }
        for req in buf.drain(..) {
            match req {
                Request::Updates { updates, enqueued } => {
                    if route(&mut gen, &updates, enqueued)
                        && gen.mailboxes.num_updates() >= cfg.epoch_max_updates
                    {
                        if let Some(g) = take_gen(&mut gen) {
                            sink.send(FlushJob::Apply(g));
                        }
                    }
                }
                Request::Epoch(p) => sink.send(FlushJob::Epoch(take_gen(&mut gen), p)),
                Request::Query(v, p) => sink.send(FlushJob::Query(take_gen(&mut gen), v, p)),
                Request::Stats(full, p) => {
                    sink.send(FlushJob::Stats(take_gen(&mut gen), full, p))
                }
                Request::Snapshot(p) => {
                    sink.send(FlushJob::Snapshot(take_gen(&mut gen), p))
                }
                Request::Crash(CrashTarget::Router) => {
                    panic!("debug CRASH: deliberate router panic")
                }
                Request::Crash(CrashTarget::Flusher) => sink.send(FlushJob::Crash),
                Request::Shutdown => {
                    // finish answering the rest of this round first — a
                    // mid-buffer break would strand promises un-fulfilled
                    stop.store(true, Ordering::Relaxed);
                    shutdown = true;
                }
            }
        }
        if shutdown {
            break 'outer;
        }
    }

    // Drain stragglers so no client hangs on an unanswered promise, then
    // hand over any last updates.
    queue.close();
    loop {
        buf.clear();
        if queue.drain(&mut buf, usize::MAX) == 0 {
            break;
        }
        for req in buf.drain(..) {
            match req {
                Request::Updates { updates, enqueued } => {
                    route(&mut gen, &updates, enqueued);
                }
                Request::Epoch(p) | Request::Stats(_, p) | Request::Snapshot(p) => {
                    p.fulfill(Response::Error("server shutting down".into()))
                }
                Request::Crash(_) => {}
                Request::Query(v, p) => {
                    // honor the ordering guarantee even during shutdown: the
                    // client's earlier updates (drained just above) must be
                    // visible to its query
                    sink.send(FlushJob::Query(take_gen(&mut gen), v, p))
                }
                Request::Shutdown => {}
            }
        }
    }
    if let Some(g) = take_gen(&mut gen) {
        sink.send(FlushJob::Apply(g));
    }
}

/// The epoch coordinator: run the router, inline or pipelined against a
/// flusher thread, and produce the lifetime summary. The heavy phases of
/// every flush fan out across the engine's shard workers inside
/// [`ShardedDynamicMatcher::apply_mailboxes`].
fn engine_loop(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    queue: &ShardedQueue<Request>,
    stop: &AtomicBool,
    dur: Option<DurableService>,
) -> ServiceSummary {
    // a router panic must not strand clients on a half-dead server
    let _router_guard = ExitOnPanic { role: "router", enabled: cfg.exit_on_panic };
    let log_wal = dur.as_ref().is_some_and(|d| d.log_enabled());
    let flushing = AtomicBool::new(false);
    let spares: BoundedQueue<ShardMailboxes> = BoundedQueue::new(MAILBOX_GENERATIONS);
    if !cfg.pipeline {
        let mut sink = FlushSink::Inline(FlushExec::new(cfg, engine, &flushing, &spares, dur));
        route_loop(cfg, engine, queue, stop, &flushing, &spares, &mut sink, log_wal);
        match sink {
            FlushSink::Inline(ex) => ex.summary(),
            FlushSink::Pipe(_) => unreachable!("inline sink cannot become a pipe"),
        }
    } else {
        // capacity-1 hand-off: at most one generation queued behind the one
        // being applied, so parse/route overlaps matching without letting
        // the router run unboundedly ahead of the engine
        let jobs: BoundedQueue<FlushJob> = BoundedQueue::new(1);
        std::thread::scope(|s| {
            // if the router panics mid-loop, this unwinds before the scope
            // joins the flusher — closing the hand-off so the flusher can't
            // block forever on an open-but-dead queue (which would deadlock
            // the join and keep the panic-exit diagnostic from running)
            let _close_jobs = CloseOnDrop(&jobs);
            let flusher = {
                let jobs = &jobs;
                let flushing = &flushing;
                let spares = &spares;
                s.spawn(move || {
                    let _flusher_guard =
                        ExitOnPanic { role: "flusher", enabled: cfg.exit_on_panic };
                    // closing on exit (including panic) keeps the router from
                    // blocking on a dead flusher; jobs it then fails to send are
                    // dropped, abandoning their promises and waking the waiters
                    let _close = CloseOnDrop(jobs);
                    let mut ex = FlushExec::new(cfg, engine, flushing, spares, dur);
                    while let Some(job) = jobs.pop() {
                        ex.handle(job);
                    }
                    ex.summary()
                })
            };
            {
                let mut sink = FlushSink::Pipe(&jobs);
                route_loop(cfg, engine, queue, stop, &flushing, &spares, &mut sink, log_wal);
            }
            jobs.close();
            flusher.join().expect("flusher thread panicked")
        })
    }
}

fn snapshot(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    tel: &Telemetry,
    lat: &LatencyRing,
    audit: bool,
    dur: Option<&DurableService>,
) -> StatsSnapshot {
    let (durable, wal_epochs, wal_bytes, last_snapshot_epoch, recovery_replayed) = match dur {
        Some(d) => {
            let c = d.counters();
            (
                true,
                c.wal_epochs.load(Ordering::Relaxed),
                c.wal_bytes.load(Ordering::Relaxed),
                c.last_snapshot_epoch.load(Ordering::Relaxed),
                c.recovery_replayed.load(Ordering::Relaxed),
            )
        }
        None => (false, 0, 0, 0, 0),
    };
    StatsSnapshot {
        epochs: engine.epochs_applied(),
        live_edges: engine.num_live_edges(),
        matched_vertices: engine.matched_vertices(),
        total_inserts: tel.total_inserts,
        total_deletes: tel.total_deletes,
        total_repair_edges: tel.total_repair_edges,
        repair_frac_last: tel.repair_frac_last,
        repair_frac_mean: if tel.epochs_with_updates > 0 {
            tel.repair_frac_sum / tel.epochs_with_updates as f64
        } else {
            0.0
        },
        p50_batch_ms: lat.percentile(50.0),
        p99_batch_ms: lat.percentile(99.0),
        // the O(|V|+|E_live|) walk only on `STATS full` — cheap polls must
        // not stall epochs on big graphs
        maximal: audit.then(|| engine.verify().is_ok()),
        adjacency_bytes: engine.adjacency_bytes(),
        engine_shards: engine.num_shards(),
        // the live fact, not the configured policy: P = 1 runs inline, so
        // no pool exists there even under the default ShardExec::Pool
        pooled: engine.pooled(),
        pipelined: cfg.pipeline,
        route_s: tel.total_route_s,
        route_overlap_s: tel.total_route_overlap_s,
        durable,
        wal_epochs,
        wal_bytes,
        last_snapshot_epoch,
        recovery_replayed,
    }
}

struct ConnOutcome {
    shutdown: bool,
}

/// Serve one client on `reader`/`writer` through shard `shard`.
fn handle_conn<R: BufRead, W: Write>(
    cfg: &ServiceConfig,
    shard: usize,
    engine: &ShardedDynamicMatcher,
    queue: &ShardedQueue<Request>,
    reader: R,
    writer: &mut W,
) -> ConnOutcome {
    let mut outcome = ConnOutcome { shutdown: false };
    let mut reply = |writer: &mut W, resp: &Response| -> bool {
        writeln!(writer, "{}", resp.render()).and_then(|_| writer.flush()).is_ok()
    };
    // Updates this connection queued since its last barrier reply. While
    // clean, a QUERY needs no engine round-trip: read-your-writes is
    // trivially satisfied, so it is answered from the owner shard's atomic
    // partner slot without stalling in-flight epochs.
    let mut dirty = false;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client went away
        };
        let cmd = match Command::parse(&line) {
            Ok(None) => continue,
            Ok(Some(c)) => c,
            Err(e) => {
                if !reply(writer, &Response::Error(e)) {
                    break;
                }
                continue;
            }
        };
        match cmd {
            Command::Updates(updates) => {
                let n = cfg.num_vertices;
                if let Some(bad) = updates.iter().find(|u| {
                    let (Update::Insert(a, b) | Update::Delete(a, b)) = **u;
                    a as usize >= n || b as usize >= n
                }) {
                    let err = format!("{bad:?} out of range (|V|={n})");
                    if !reply(writer, &Response::Error(err)) {
                        break;
                    }
                    continue;
                }
                let count = updates.len();
                let req = Request::Updates { updates, enqueued: Instant::now() };
                if queue.push(shard, req).is_err() {
                    let _ = reply(writer, &Response::Error("server shutting down".into()));
                    break;
                }
                dirty = true;
                if !reply(writer, &Response::Queued { count }) {
                    break;
                }
            }
            Command::Query(v) if !dirty => {
                // fast path: nothing of ours is pending, answer lock-free
                // from the atomic partner state
                let resp = if (v as usize) < cfg.num_vertices {
                    Response::Query { vertex: v, partner: engine.partner(v) }
                } else {
                    Response::Error(format!(
                        "vertex {v} out of range (|V|={})",
                        cfg.num_vertices
                    ))
                };
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Epoch | Command::Stats { .. } | Command::Query(_) | Command::Snapshot => {
                let p = Promise::shared();
                let req = match &cmd {
                    Command::Epoch => Request::Epoch(ReplySlot(Arc::clone(&p))),
                    Command::Stats { full } => Request::Stats(*full, ReplySlot(Arc::clone(&p))),
                    Command::Snapshot => Request::Snapshot(ReplySlot(Arc::clone(&p))),
                    Command::Query(v) => {
                        if *v as usize >= cfg.num_vertices {
                            let err = format!("vertex {v} out of range (|V|={})", cfg.num_vertices);
                            if !reply(writer, &Response::Error(err)) {
                                break;
                            }
                            continue;
                        }
                        Request::Query(*v, ReplySlot(Arc::clone(&p)))
                    }
                    _ => unreachable!(),
                };
                if queue.push(shard, req).is_err() {
                    let _ = reply(writer, &Response::Error("server shutting down".into()));
                    break;
                }
                match p.wait() {
                    Some(resp) => {
                        // a successful barrier reply means the coordinator
                        // flushed everything we queued earlier; an Error
                        // (e.g. the shutdown drain answering without a
                        // flush) proves nothing, so the connection must
                        // stay dirty to preserve read-your-writes
                        if !matches!(resp, Response::Error(_)) {
                            dirty = false;
                        }
                        if !reply(writer, &resp) {
                            break;
                        }
                    }
                    None => {
                        let _ = reply(writer, &Response::Error("server shutting down".into()));
                        break;
                    }
                }
            }
            Command::Crash(target) => {
                if !cfg.debug_commands {
                    if !reply(
                        writer,
                        &Response::Error("CRASH requires --debug-commands".into()),
                    ) {
                        break;
                    }
                    continue;
                }
                // no reply on success: the process is about to die by design
                let _ = queue.push(shard, Request::Crash(target));
            }
            Command::Quit => {
                let _ = reply(writer, &Response::Bye);
                break;
            }
            Command::Shutdown => {
                let _ = queue.push(shard, Request::Shutdown);
                let _ = reply(writer, &Response::ShuttingDown);
                outcome.shutdown = true;
                break;
            }
        }
    }
    outcome
}

/// Open the durability bundle when the config names a data dir: recover
/// the engine (snapshot + WAL replay, verified maximal) and report what
/// happened on stderr.
fn open_durability(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
) -> Result<Option<DurableService>, String> {
    let Some(dir) = &cfg.data_dir else {
        return Ok(None);
    };
    let opts = DurableOptions {
        data_dir: PathBuf::from(dir),
        wal: cfg.wal,
        fsync: cfg.wal_fsync,
        snapshot_every: cfg.snapshot_every,
    };
    let dur = DurableService::open(&opts, engine)?;
    let r = dur.recovery();
    eprintln!(
        "recovery: snapshot epoch {}, replayed {} wal epochs ({} updates); resuming at epoch {} with {} live edges, {} matched",
        r.snapshot_epoch.map_or("none".to_string(), |e| e.to_string()),
        r.replayed_epochs,
        r.replayed_updates,
        r.resumed_epoch,
        engine.num_live_edges(),
        engine.matched_vertices(),
    );
    Ok(Some(dur))
}

/// Serve a single client over any line stream — `skipper-cli serve` on a
/// stdin pipe, and the CI smoke test. Returns when the stream ends or the
/// client sends `QUIT`/`SHUTDOWN`. Errors only at boot (recovery failure);
/// a durable service writes a final snapshot before returning.
pub fn serve_lines<R: BufRead, W: Write>(
    cfg: &ServiceConfig,
    reader: R,
    writer: &mut W,
) -> Result<ServiceSummary, String> {
    let engine = ShardedDynamicMatcher::with_exec(
        cfg.num_vertices,
        cfg.threads,
        cfg.engine_shards,
        cfg.shard_exec(),
    );
    let dur = open_durability(cfg, &engine)?;
    let queue: ShardedQueue<Request> = ShardedQueue::new(cfg.shards, cfg.shard_capacity);
    let stop = AtomicBool::new(false);
    Ok(std::thread::scope(|s| {
        let engine_ref = &engine;
        let queue_ref = &queue;
        let stop_ref = &stop;
        let coordinator =
            s.spawn(move || engine_loop(cfg, engine_ref, queue_ref, stop_ref, dur));
        handle_conn(cfg, 0, &engine, &queue, reader, writer);
        queue.close();
        coordinator.join().expect("engine thread panicked")
    }))
}

/// Serve concurrent clients over TCP. Binds `addr` (use port 0 for an
/// ephemeral port), invokes `on_ready` with the bound address, and runs
/// until a client sends `SHUTDOWN`. Each connection gets its own thread
/// and queue shard.
pub fn serve_tcp(
    cfg: &ServiceConfig,
    addr: &str,
    on_ready: impl FnOnce(SocketAddr),
) -> Result<ServiceSummary, String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("nonblocking: {e}"))?;
    let local = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
    on_ready(local);

    let engine = ShardedDynamicMatcher::with_exec(
        cfg.num_vertices,
        cfg.threads,
        cfg.engine_shards,
        cfg.shard_exec(),
    );
    let dur = open_durability(cfg, &engine)?;
    let queue: ShardedQueue<Request> = ShardedQueue::new(cfg.shards, cfg.shard_capacity);
    let stop = AtomicBool::new(false);
    // every accepted socket, keyed by connection id, so shutdown can
    // unblock handlers parked in a blocking read; each handler removes its
    // own entry on exit — otherwise the dup'd fd would hold the connection
    // established after QUIT (no FIN for the client) and leak one fd per
    // connection
    let open_conns: Mutex<std::collections::HashMap<usize, TcpStream>> =
        Mutex::new(std::collections::HashMap::new());
    let summary = std::thread::scope(|s| {
        let coordinator = {
            let engine_ref = &engine;
            let queue_ref = &queue;
            let stop_ref = &stop;
            s.spawn(move || engine_loop(cfg, engine_ref, queue_ref, stop_ref, dur))
        };
        let mut conn_id = 0usize;
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    conn_id += 1;
                    let shard = conn_id;
                    match stream.try_clone() {
                        Ok(clone) => {
                            open_conns.lock().unwrap().insert(shard, clone);
                        }
                        // without a registry dup this handler could never be
                        // woken at shutdown — refuse the connection instead
                        Err(_) => continue,
                    }
                    let engine = &engine;
                    let queue = &queue;
                    let stop = &stop;
                    let open_conns = &open_conns;
                    s.spawn(move || {
                        // the listener is nonblocking and some platforms
                        // (BSD/macOS) let accepted sockets inherit that —
                        // reads here must block
                        let _ = stream.set_nonblocking(false);
                        let _ = stream.set_nodelay(true);
                        let reader = match stream.try_clone() {
                            Ok(c) => BufReader::new(c),
                            Err(_) => {
                                open_conns.lock().unwrap().remove(&shard);
                                return;
                            }
                        };
                        let mut writer = stream;
                        let out = handle_conn(cfg, shard, engine, queue, reader, &mut writer);
                        // drop our registry dup so closing `writer` really
                        // closes the connection (FIN reaches the client)
                        open_conns.lock().unwrap().remove(&shard);
                        if out.shutdown {
                            stop.store(true, Ordering::Relaxed);
                        }
                    });
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => {
                    eprintln!("accept: {e}");
                    stop.store(true, Ordering::Relaxed);
                }
            }
        }
        // wake handlers blocked mid-read so the scope can actually close
        for (_, c) in open_conns.lock().unwrap().drain() {
            let _ = c.shutdown(Shutdown::Both);
        }
        queue.close();
        coordinator.join().expect("engine thread panicked")
    });
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};

    fn drive(cfg: &ServiceConfig, script: &str) -> (Vec<String>, ServiceSummary) {
        let mut out: Vec<u8> = Vec::new();
        let summary = serve_lines(cfg, script.as_bytes(), &mut out).unwrap();
        let lines = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(String::from)
            .collect();
        (lines, summary)
    }

    fn small_cfg() -> ServiceConfig {
        // threads: 1 -> deterministic matching order over the wire
        ServiceConfig { num_vertices: 16, threads: 1, ..Default::default() }
    }

    #[test]
    fn stdio_session_runs_mixed_epochs_and_stays_maximal() {
        let script = "\
INSERT 0 1 1 2 2 3\n\
EPOCH\n\
DELETE 1 2\n\
EPOCH\n\
INSERT 3 4 0 2\n\
EPOCH\n\
QUERY 0\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[0].contains(r#""op":"queued","count":3"#), "{}", lines[0]);
        assert!(lines[1].contains(r#""op":"epoch""#) && lines[1].contains(r#""new_matches":2"#),
            "{}", lines[1]);
        // with one matcher thread the stream order matches (0,1) and (2,3);
        // deleting (1,2) therefore removes an unmatched edge: no repair
        assert!(lines[3].contains(r#""destroyed_pairs":0"#), "{}", lines[3]);
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(lines.last().unwrap().contains(r#""op":"bye""#));
        assert_eq!(summary.epochs, 3);
        assert!(summary.maximal);
        assert_eq!(summary.total_inserts, 5);
        assert_eq!(summary.total_deletes, 1);
    }

    #[test]
    fn delete_of_matched_edge_reports_repair_over_the_wire() {
        // triangle + pendant: 0-1, 1-2, 2-0, 2-3
        let script = "\
INSERT 0 1 1 2 2 0 2 3\n\
EPOCH\n\
DELETE 0 1\n\
EPOCH\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        // (0,1) matches first in the single-threaded epoch; its deletion
        // must free both endpoints and re-examine their surviving edges
        // (0,2) and (1,2)
        let second_epoch = &lines[3];
        assert!(second_epoch.contains(r#""destroyed_pairs":1"#), "{second_epoch}");
        assert!(second_epoch.contains(r#""freed":2"#), "{second_epoch}");
        assert!(second_epoch.contains(r#""repair_edges":2"#), "{second_epoch}");
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(summary.maximal);
    }

    #[test]
    fn query_reflects_all_prior_updates_without_explicit_epoch() {
        let script = "INSERT 4 5\nQUERY 4\nQUERY 6\nQUIT\n";
        let (lines, _) = drive(&small_cfg(), script);
        let q4 = &lines[1];
        assert!(q4.contains(r#""matched":true"#) && q4.contains(r#""partner":5"#), "{q4}");
        // the second query takes the lock-free fast path (the connection is
        // clean after its barrier) and must still see the applied state
        assert!(lines[2].contains(r#""matched":false"#), "{}", lines[2]);
    }

    #[test]
    fn cheap_stats_skips_the_audit_and_reports_counters() {
        let script = "INSERT 0 1 2 3\nEPOCH\nSTATS\nSTATS full\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        let cheap = &lines[2];
        assert!(cheap.contains(r#""op":"stats""#), "{cheap}");
        assert!(!cheap.contains("maximal"), "cheap STATS must skip the audit: {cheap}");
        assert!(cheap.contains(r#""total_inserts":2"#), "{cheap}");
        assert!(cheap.contains(r#""engine_shards":1"#), "{cheap}");
        let full = &lines[3];
        assert!(full.contains(r#""maximal":true"#), "{full}");
        assert!(summary.maximal);
    }

    #[test]
    fn sharded_engine_serves_epochs_and_stays_maximal() {
        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 2,
            engine_shards: 4,
            ..Default::default()
        };
        let script = "\
INSERT 0 1 1 2 2 3 3 4 10 40 41 11 20 50\n\
EPOCH\n\
DELETE 1 2 10 40\n\
EPOCH\n\
INSERT 5 6 40 42\n\
EPOCH\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&cfg, script);
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(stats.contains(r#""engine_shards":4"#), "{stats}");
        assert!(summary.maximal);
        assert_eq!(summary.epochs, 3);
        assert_eq!(summary.total_inserts, 9);
        assert_eq!(summary.total_deletes, 2);
    }

    #[test]
    fn stats_reports_pool_and_pipeline_modes() {
        // `pooled` reports the live fact: a standing pool exists only for
        // P > 1 under the pool policy — P = 1 always runs inline
        let sharded = ServiceConfig { engine_shards: 4, ..small_cfg() };
        let (lines, _) = drive(&sharded, "STATS\nQUIT\n");
        assert!(lines[0].contains(r#""pooled":true"#), "{}", lines[0]);
        assert!(lines[0].contains(r#""pipelined":true"#), "{}", lines[0]);
        let single = small_cfg(); // engine_shards = 1: inline despite pool=true
        let (lines, _) = drive(&single, "STATS\nQUIT\n");
        assert!(lines[0].contains(r#""pooled":false"#), "{}", lines[0]);
        let off = ServiceConfig {
            engine_shards: 4,
            pool: false,
            pipeline: false,
            ..small_cfg()
        };
        let (lines, _) = drive(&off, "STATS\nQUIT\n");
        assert!(lines[0].contains(r#""pooled":false"#), "{}", lines[0]);
        assert!(lines[0].contains(r#""pipelined":false"#), "{}", lines[0]);
    }

    #[test]
    fn every_mode_combination_serves_the_same_session() {
        // pooled/forked × pipelined/inline over a sharded engine: the wire
        // semantics (epoch boundaries, query answers, counters, audit) must
        // be mode-independent — only the timing fields may differ
        let script = "\
INSERT 0 1 1 2 2 3 3 4\n\
EPOCH\n\
DELETE 1 2 0 1\n\
EPOCH\n\
QUERY 2\n\
STATS full\n\
QUIT\n";
        let mut reference: Option<(String, ServiceSummary)> = None;
        for pool in [true, false] {
            for pipeline in [true, false] {
                let cfg = ServiceConfig {
                    num_vertices: 16,
                    threads: 1,
                    engine_shards: 4,
                    pool,
                    pipeline,
                    ..Default::default()
                };
                let (lines, summary) = drive(&cfg, script);
                let query = lines
                    .iter()
                    .find(|l| l.contains(r#""op":"query""#))
                    .unwrap()
                    .clone();
                let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
                assert!(stats.contains(r#""maximal":true"#), "pool={pool} pipe={pipeline}: {stats}");
                match &reference {
                    None => reference = Some((query, summary)),
                    Some((q0, s0)) => {
                        assert_eq!(&query, q0, "pool={pool} pipe={pipeline}");
                        assert_eq!(summary.epochs, s0.epochs, "pool={pool} pipe={pipeline}");
                        assert_eq!(
                            summary.total_inserts, s0.total_inserts,
                            "pool={pool} pipe={pipeline}"
                        );
                        assert_eq!(
                            summary.total_deletes, s0.total_deletes,
                            "pool={pool} pipe={pipeline}"
                        );
                        assert_eq!(
                            summary.live_edges, s0.live_edges,
                            "pool={pool} pipe={pipeline}"
                        );
                        assert!(summary.maximal, "pool={pool} pipe={pipeline}");
                    }
                }
            }
        }
    }

    #[test]
    fn pipelined_epochs_report_route_timings() {
        // the EPOCH reply must carry the router's route time; overlap may
        // legitimately be zero in a lock-step stdio session, but the field
        // must be present and sane
        let script = "INSERT 0 1 2 3 4 5\nEPOCH\nQUIT\n";
        let (lines, _) = drive(&small_cfg(), script);
        let epoch = lines.iter().find(|l| l.contains(r#""op":"epoch""#)).unwrap();
        assert!(epoch.contains(r#""route_ms":"#), "{epoch}");
        assert!(epoch.contains(r#""route_overlap_ms":"#), "{epoch}");
        assert!(epoch.contains(r#""mutate_run_ms":"#), "{epoch}");
        assert!(epoch.contains(r#""spawn_overhead_ms":"#), "{epoch}");
    }

    #[test]
    fn malformed_and_out_of_range_lines_get_errors_not_death() {
        let script = "FROB\nINSERT 1\nINSERT 0 99\nQUERY 99\nINSERT 0 1\nQUERY 0\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[0].contains(r#""ok":false"#));
        assert!(lines[1].contains("even"));
        assert!(lines[2].contains("out of range"));
        assert!(lines[3].contains("out of range"));
        assert!(lines[4].contains(r#""op":"queued""#));
        assert!(lines[5].contains(r#""matched":true"#), "{}", lines[5]);
        assert!(summary.maximal);
    }

    #[test]
    fn eof_without_quit_flushes_pending_updates() {
        let (_, summary) = drive(&small_cfg(), "INSERT 0 1 2 3\n");
        assert_eq!(summary.total_inserts, 2);
        assert_eq!(summary.matched_vertices, 4);
        assert!(summary.maximal);
        assert!(summary.epochs >= 1);
    }

    #[test]
    fn snapshot_without_data_dir_is_an_error_not_a_crash() {
        let script = "INSERT 0 1\nSNAPSHOT\nQUERY 0\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[1].contains(r#""ok":false"#), "{}", lines[1]);
        assert!(lines[1].contains("--data-dir"), "{}", lines[1]);
        // the SNAPSHOT barrier still flushed the insert (read-your-writes
        // held even through the error reply)
        assert!(lines[2].contains(r#""matched":true"#), "{}", lines[2]);
        assert!(summary.maximal);
        assert_eq!(summary.last_snapshot_epoch, 0);
        assert_eq!(summary.wal_epochs, 0);
    }

    #[test]
    fn crash_without_debug_commands_is_rejected() {
        let script = "CRASH\nCRASH flusher\nINSERT 0 1\nEPOCH\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[0].contains("--debug-commands"), "{}", lines[0]);
        assert!(lines[1].contains("--debug-commands"), "{}", lines[1]);
        assert!(lines[3].contains(r#""op":"epoch""#), "{}", lines[3]);
        assert!(summary.maximal);
    }

    fn fresh_data_dir(tag: &str) -> String {
        use std::sync::atomic::AtomicU64;
        static DIR_ID: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "skipper_serve_{}_{}_{}",
            std::process::id(),
            tag,
            DIR_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn durable_session_logs_snapshots_and_restarts_clean() {
        let data_dir = fresh_data_dir("durable");
        let cfg = ServiceConfig {
            num_vertices: 32,
            threads: 1,
            engine_shards: 2,
            data_dir: Some(data_dir.clone()),
            ..Default::default()
        };
        // session 1: two epochs, an explicit SNAPSHOT, then EOF (graceful)
        let script = "\
INSERT 0 1 1 2 2 3\n\
EPOCH\n\
SNAPSHOT\n\
DELETE 1 2\n\
EPOCH\n\
STATS\n\
QUIT\n";
        let (lines, summary) = drive(&cfg, script);
        let snap = lines.iter().find(|l| l.contains(r#""op":"snapshot""#)).unwrap();
        assert!(snap.contains(r#""epoch":1"#), "{snap}");
        assert!(snap.contains(r#""accepted":true"#), "{snap}");
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""durable":true"#), "{stats}");
        assert!(stats.contains(r#""wal_epochs":2"#), "{stats}");
        assert!(stats.contains(r#""recovery_replayed":0"#), "{stats}");
        assert_eq!(summary.epochs, 2);
        assert_eq!(summary.wal_epochs, 2);
        assert_eq!(summary.last_snapshot_epoch, 2, "final snapshot at shutdown");
        assert_eq!(summary.recovery_replayed, 0);

        // session 2: a clean restart recovers from the final snapshot alone
        // — zero WAL replay — and the state is intact
        let (lines, summary) = drive(&cfg, "STATS full\nQUERY 0\nQUIT\n");
        let stats = &lines[0];
        assert!(stats.contains(r#""epochs":2"#), "epoch timeline resumes: {stats}");
        assert!(stats.contains(r#""live_edges":2"#), "{stats}");
        assert!(stats.contains(r#""recovery_replayed":0"#), "{stats}");
        assert!(stats.contains(r#""last_snapshot_epoch":2"#), "{stats}");
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        // with threads=1 the first epoch matched (0,1) and (2,3); deleting
        // the unmatched (1,2) left the matching intact, and the restore
        // path reproduces it exactly
        assert!(lines[1].contains(r#""partner":1"#), "{}", lines[1]);
        assert_eq!(summary.epochs, 2);
        assert!(summary.maximal);
    }

    #[test]
    fn wal_off_durable_service_still_snapshots_at_shutdown() {
        let data_dir = fresh_data_dir("no_wal");
        let cfg = ServiceConfig {
            num_vertices: 16,
            threads: 1,
            data_dir: Some(data_dir.clone()),
            wal: false,
            ..Default::default()
        };
        let (lines, summary) = drive(&cfg, "INSERT 0 1\nEPOCH\nSTATS\nQUIT\n");
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""durable":true"#), "{stats}");
        assert!(stats.contains(r#""wal_epochs":0"#), "no logging: {stats}");
        assert_eq!(summary.last_snapshot_epoch, 1);
        // restart: the shutdown snapshot alone carries the state
        let (lines, _) = drive(&cfg, "QUERY 0\nQUIT\n");
        assert!(lines[0].contains(r#""matched":true"#), "{}", lines[0]);
    }

    #[test]
    fn tcp_serves_concurrent_clients_and_shuts_down() {
        // sandboxes without loopback can't exercise the TCP front-end; the
        // stdio tests above cover everything but the socket plumbing
        if std::net::TcpListener::bind("127.0.0.1:0").is_err() {
            eprintln!("skipping TCP test: loopback unavailable");
            return;
        }
        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 2,
            engine_shards: 2,
            ..Default::default()
        };
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            serve_tcp(&cfg, "127.0.0.1:0", move |a| addr_tx.send(a).unwrap()).unwrap()
        });
        let addr = addr_rx.recv().unwrap();

        let ask = |script: &str| -> Vec<String> {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(script.as_bytes()).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            buf.lines().map(String::from).collect()
        };

        // two sequential clients mutating the same engine
        let a = ask("INSERT 0 1 2 3\nEPOCH\nQUIT\n");
        assert!(a[1].contains(r#""new_matches":2"#), "{:?}", a);
        let b = ask("DELETE 0 1\nEPOCH\nQUERY 0\nSTATS full\nQUIT\n");
        assert!(b[1].contains(r#""destroyed_pairs":1"#), "{:?}", b);
        assert!(b[2].contains(r#""matched":false"#), "{:?}", b);
        assert!(b[3].contains(r#""maximal":true"#), "{:?}", b);

        // a swarm of parallel clients, then shutdown
        let mut clients = Vec::new();
        for i in 0..4u32 {
            let addr = addr;
            clients.push(std::thread::spawn(move || {
                let base = 8 * (i + 1);
                let mut s = std::net::TcpStream::connect(addr).unwrap();
                let script =
                    format!("INSERT {} {} {} {}\nEPOCH\nQUIT\n", base, base + 1, base + 2, base + 3);
                s.write_all(script.as_bytes()).unwrap();
                s.shutdown(std::net::Shutdown::Write).unwrap();
                let mut buf = String::new();
                s.read_to_string(&mut buf).unwrap();
                assert!(buf.contains(r#""op":"epoch""#), "{buf}");
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let bye = ask("SHUTDOWN\n");
        assert!(bye[0].contains(r#""op":"shutdown""#), "{:?}", bye);
        let summary = server.join().unwrap();
        assert!(summary.maximal);
        assert_eq!(summary.total_inserts, 2 + 16);
        assert_eq!(summary.total_deletes, 1);
    }
}
