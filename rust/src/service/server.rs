//! The long-running match server: connection front-ends, the pooled
//! sharded engine, and the pipelined epoch coordinator.
//!
//! One [`ShardedDynamicMatcher`] is shared by every thread in the process.
//! Client connections (one thread each in TCP mode; the calling thread in
//! stdio mode) parse lines into [`Command`]s and push requests onto the
//! [`ShardedQueue`]. The **router** thread drains all front-end shards
//! round-robin and routes every update straight into a *generation* of the
//! engine's per-shard mailboxes — the mailboxes *are* the coalescing
//! buffer, so concurrent clients share epochs instead of serializing one
//! engine pass per request.
//!
//! At a barrier (an explicit `EPOCH`, a queue-riding `QUERY`/`STATS`, or
//! the coalescing threshold) the routed generation becomes a flush job.
//! With pipelining on (the default), flush jobs cross a small fixed-depth
//! hand-off queue to the **flusher** thread, and the router immediately
//! starts routing the *next* generation into a recycled mailbox set —
//! parse/route work overlaps matching, and the per-epoch overlap is
//! reported in
//! [`EpochReport::route_overlap_s`](crate::dynamic::EpochReport). The
//! flusher drains the hand-off greedily: when several generations have
//! queued behind a slow epoch, their WAL records are appended as **one
//! durable group** (a single `fsync` under `--fsync` — see
//! [`DurableService::log_epochs`]) before the generations are applied in
//! FIFO order. With pipelining off the same jobs execute inline on the
//! router thread, which is exactly the previous serial coordinator. Either
//! way a flush applies one engine epoch: the mutate phase fans out across
//! the engine's persistent shard workers (or forked threads — see
//! [`ShardExec`](crate::dynamic::ShardExec)), and the insert/repair sweeps
//! run against the shared one-byte-per-vertex core. Barrier jobs ride the
//! same FIFO hand-off as the flushes they follow, so `EPOCH`/`STATS`
//! observe everything their client sent earlier and are answered through
//! one-shot [`Promise`]s in order.
//!
//! `QUERY` has a fast path: when the querying connection has no updates
//! queued since its last barrier, the answer comes straight from the owner
//! shard's atomic `partner[]` slot — lock-free, without stalling (or
//! waiting for) any in-flight epoch. A connection with queued updates still
//! rides the queue, preserving the read-your-writes guarantee.
//!
//! Updates are acknowledged at enqueue time (`{"op":"queued"}`); the
//! per-shard bounded queues push back on flooding clients without stalling
//! the others, and the bounded flush hand-off keeps the router at most
//! `FLUSH_QUEUE_DEPTH` generations ahead of the engine.
//!
//! Service observability lives in a per-instance `ServiceMetrics`
//! bundle: lifetime counters and the full-history batch-latency histogram
//! are registry instruments (see [`crate::obs::metrics`]), so `STATS`
//! reads and the `METRICS` Prometheus scrape are two views of the same
//! atomics. `METRICS` and `TRACE` are answered directly on the connection
//! thread — no barrier, no engine round-trip — so scraping never stalls
//! epochs.
//!
//! The wire protocol itself is specified in `docs/PROTOCOL.md`.

use super::protocol::{
    Command, CrashTarget, ReplicaRole, ReplicaStats, Response, StatsSnapshot,
};
use super::{Promise, ShardedQueue};
use crate::dynamic::{EpochReport, ShardExec, ShardMailboxes, ShardedDynamicMatcher, Update};
use crate::obs::{blackbox, metrics, trace};
use crate::par::pump::{BoundedQueue, CloseOnDrop};
use crate::persist::ship::Shipper;
use crate::persist::snapshot::SnapshotData;
use crate::persist::{DurableOptions, DurableService};
use crate::util::json::Json;
use crate::VertexId;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Tunables of one service instance (see `skipper-cli serve --help` for
/// the CLI spellings and defaults).
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Vertex universe `0..num_vertices` (fixed for the server's lifetime).
    pub num_vertices: usize,
    /// Matcher threads inside the engine's parallel sweeps.
    pub threads: usize,
    /// Engine shards (`P`): the vertex partition of the dynamic engine.
    /// Each epoch's mutate phase runs one worker per shard; `1` is the
    /// single-shard engine.
    pub engine_shards: usize,
    /// Use the persistent shard-worker pool for the engine's per-shard
    /// phases (default). `false` forks one scoped thread per shard per
    /// epoch — the measured baseline (`--no-pool`).
    pub pool: bool,
    /// Pipelined coordinator (default): route the next epoch's updates on
    /// the router thread while the flusher thread applies the current one.
    /// `false` runs flushes inline on the router (`--no-pipeline`).
    pub pipeline: bool,
    /// Front-end queue shards (connections hash onto these).
    pub shards: usize,
    /// Per-shard queue capacity (requests) — the back-pressure window.
    pub shard_capacity: usize,
    /// Max requests coalesced per engine drain round.
    pub epoch_max_requests: usize,
    /// Coalescing threshold: pending updates are applied as an epoch once
    /// this many accumulate, even without an explicit `EPOCH` barrier.
    pub epoch_max_updates: usize,
    /// Durability root holding `wal/` and `snapshots/` (`--data-dir`).
    /// `None` = fully volatile service, no recovery at boot.
    pub data_dir: Option<String>,
    /// Append each epoch's update batch to the WAL before applying it
    /// (default with a data dir; `--no-wal` disables logging — recovery
    /// still replays whatever log is on disk).
    pub wal: bool,
    /// `fsync` every WAL append (`--fsync`): durable against power loss,
    /// not just process death, at per-epoch fsync cost.
    pub wal_fsync: bool,
    /// Automatically snapshot every this many applied epochs
    /// (`--snapshot-every`; 0 = only on `SNAPSHOT` commands and at
    /// shutdown).
    pub snapshot_every: u64,
    /// Accept the debug fault-injection command `CRASH`
    /// (`--debug-commands`) — a testing aid, off by default.
    pub debug_commands: bool,
    /// When a coordinator (router/flusher) thread panics, print a
    /// diagnostic and exit the process (code 70) instead of leaving a
    /// half-dead server with hanging clients. On by default; in-process
    /// tests disable it to observe the panic directly.
    pub exit_on_panic: bool,
    /// Worker→core pin policy for the engine's shard pool (`--pin`):
    /// shard workers pin themselves, first-touch their shard's arena and
    /// `partner[]` stripe socket-local, and block slabs are advised onto
    /// huge pages. Placement only — results are identical at any policy.
    pub pin: crate::dynamic::PinPolicy,
    /// Serve live Prometheus scrapes over HTTP at this address
    /// (`--metrics-addr HOST:PORT`): a minimal `GET /metrics` endpoint on
    /// its own listener thread, answering from the same registries as the
    /// `METRICS` command. `None` = no HTTP listener.
    pub metrics_addr: Option<String>,
    /// Ship committed epoch WAL records to followers connecting at this
    /// address (`--replicate-addr HOST:PORT`) — the primary side of
    /// replication (see [`crate::persist::ship`]). `None` = no replication
    /// listener.
    pub replicate_addr: Option<String>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            num_vertices: 1 << 20,
            threads: 4,
            engine_shards: 1,
            pool: true,
            pipeline: true,
            shards: 4,
            shard_capacity: 64,
            epoch_max_requests: 256,
            epoch_max_updates: 8192,
            data_dir: None,
            wal: true,
            wal_fsync: false,
            snapshot_every: 0,
            debug_commands: false,
            exit_on_panic: true,
            pin: crate::dynamic::PinPolicy::None,
            metrics_addr: None,
            replicate_addr: None,
        }
    }
}

impl ServiceConfig {
    /// The engine shard-dispatch policy this config selects.
    pub fn shard_exec(&self) -> ShardExec {
        ShardExec::from_pool_flag(self.pool)
    }
}

/// What the server did over its lifetime — returned to the CLI on exit.
#[derive(Clone, Debug, Default)]
pub struct ServiceSummary {
    /// Engine epochs applied.
    pub epochs: u64,
    /// Insert updates received across all epochs.
    pub total_inserts: u64,
    /// Delete updates received across all epochs.
    pub total_deletes: u64,
    /// Edges re-examined by repair sweeps across all epochs.
    pub total_repair_edges: u64,
    /// Live undirected edges at shutdown.
    pub live_edges: u64,
    /// Matched vertices at shutdown.
    pub matched_vertices: usize,
    /// Final live-set maximality audit.
    pub maximal: bool,
    /// WAL epochs recovery replayed at boot (0 when volatile or clean).
    pub recovery_replayed: u64,
    /// Epoch records appended to the WAL over this run (0 when volatile).
    pub wal_epochs: u64,
    /// Epoch of the newest durably published snapshot at shutdown —
    /// normally the final shutdown snapshot; earlier (or 0) when that
    /// final write failed, and 0 when volatile.
    pub last_snapshot_epoch: u64,
    /// Final Prometheus exposition (process-global registry plus this
    /// service's counters), captured at shutdown — what a last `METRICS`
    /// scrape would have returned. Backs `serve --metrics-file`.
    pub metrics_text: String,
}

enum Request {
    Updates { updates: Vec<Update>, enqueued: Instant },
    Epoch(ReplySlot),
    Query(VertexId, ReplySlot),
    /// `bool`: run the full maximality audit (`STATS full`).
    Stats(bool, ReplySlot),
    /// Barrier + hand the durable state to the background snapshot writer.
    Snapshot(ReplySlot),
    /// Debug fault injection: panic the named coordinator thread.
    Crash(CrashTarget),
    Shutdown,
}

/// Escorts a coordinator thread: if the thread unwinds with a panic while
/// `enabled`, print a diagnostic and exit the whole process — a half-dead
/// server that accepts connections but never answers is strictly worse
/// than a visible crash, and `EngineGuard`'s cleanup cannot reach clients
/// that connect *after* the panic.
struct ExitOnPanic<'a> {
    role: &'static str,
    enabled: bool,
    /// When the service is durable, a panic dumps a blackbox artifact
    /// (metrics exposition + recent trace) into this data dir before the
    /// process exits — a kill-worthy incident leaves post-mortem evidence.
    blackbox: Option<(&'a str, &'a ServiceMetrics)>,
}

/// Exit code used when a coordinator thread dies (EX_SOFTWARE).
pub const PANIC_EXIT_CODE: i32 = 70;

impl Drop for ExitOnPanic<'_> {
    fn drop(&mut self) {
        if self.enabled && std::thread::panicking() {
            if let Some((dir, sm)) = self.blackbox {
                // a failing (or itself-panicking) dump must not turn the
                // orderly exit(70) into an unwind abort
                let role = self.role;
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    blackbox::write_blackbox(Path::new(dir), role, &sm.render_prometheus())
                }));
                match outcome {
                    Ok(Ok(p)) => eprintln!("fatal: blackbox written to {}", p.display()),
                    Ok(Err(e)) => eprintln!("fatal: blackbox dump failed: {e}"),
                    Err(_) => eprintln!("fatal: blackbox dump panicked; continuing exit"),
                }
            }
            eprintln!(
                "fatal: service {} thread panicked; exiting so clients are not left hanging (panic message above)",
                self.role
            );
            std::process::exit(PANIC_EXIT_CODE);
        }
    }
}

/// The engine's end of a [`Promise`]: guarantees the waiting client wakes
/// even when the slot is dropped unfulfilled (engine panic, shutdown
/// unwind, a dropped request buffer) — dropping abandons the promise, which
/// the client's `wait()` observes as `None`. Abandoning after a fulfill is
/// harmless: the fulfilled value still drains to the waiter.
struct ReplySlot(Arc<Promise<Response>>);

impl ReplySlot {
    fn fulfill(&self, r: Response) {
        self.0.fulfill(r);
    }
}

impl Drop for ReplySlot {
    fn drop(&mut self) {
        self.0.abandon();
    }
}

/// Raises the stop flag, closes the queue, and drops (→ abandons) any
/// queued requests when the coordinator thread exits — normally or by panic
/// — so neither clients nor the accept loop ever wait on a dead engine.
struct EngineGuard<'a> {
    queue: &'a ShardedQueue<Request>,
    stop: &'a AtomicBool,
}

impl Drop for EngineGuard<'_> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.close();
        let mut buf = Vec::new();
        while self.queue.drain(&mut buf, 1024) > 0 {
            buf.clear(); // dropping a ReplySlot wakes its waiter
        }
    }
}

/// The service's lifetime instruments, registered in a **per-instance**
/// [`metrics::Registry`] — `STATS` replies and the `METRICS` Prometheus
/// scrape read the same atomics. Per-instance (rather than the process
/// global) because one process can host several services (every in-process
/// test does): totals must not smear across them. The `METRICS` reply
/// concatenates this registry after the process-global one, so a scrape
/// still sees the pool/engine/WAL instruments too.
///
/// The batch-latency histogram replaces the old fixed-size ring of recent
/// samples: its log-scale buckets retain the *full* history, so
/// p50/p99/p999 reflect every batch since boot instead of the last 4096,
/// at the cost of reading each percentile as its bucket's upper bound
/// (≤ 12.5% relative over-report, never under).
struct ServiceMetrics {
    registry: metrics::Registry,
    total_inserts: Arc<metrics::Counter>,
    total_deletes: Arc<metrics::Counter>,
    total_repair_edges: Arc<metrics::Counter>,
    /// Epochs that carried updates (the denominator of the mean repair
    /// fraction).
    update_epochs: Arc<metrics::Counter>,
    repair_frac_last: Arc<metrics::FGauge>,
    repair_frac_sum: Arc<metrics::FGauge>,
    route_seconds: Arc<metrics::FGauge>,
    route_overlap_seconds: Arc<metrics::FGauge>,
    /// Enqueue→applied latency of every update batch, nanoseconds.
    batch_latency: Arc<metrics::Histogram>,
    /// Durable WAL append groups written (one shared `fsync` each).
    wal_groups: Arc<metrics::Counter>,
    /// Epochs logged through those groups; `wal_group_epochs /
    /// wal_groups` is the mean coalescing factor the flusher achieved.
    wal_group_epochs: Arc<metrics::Counter>,
}

impl ServiceMetrics {
    fn new() -> Self {
        let registry = metrics::Registry::new();
        let total_inserts = registry.counter(
            "skipper_service_inserts_total",
            "Insert updates received over the service lifetime",
        );
        let total_deletes = registry.counter(
            "skipper_service_deletes_total",
            "Delete updates received over the service lifetime",
        );
        let total_repair_edges = registry.counter(
            "skipper_service_repair_edges_total",
            "Edges re-examined by repair sweeps over the service lifetime",
        );
        let update_epochs = registry.counter(
            "skipper_service_update_epochs_total",
            "Engine epochs that carried updates",
        );
        let repair_frac_last = registry.fgauge(
            "skipper_service_repair_fraction_last",
            "Repair fraction of the most recent epoch",
        );
        let repair_frac_sum = registry.fgauge(
            "skipper_service_repair_fraction_sum",
            "Sum of per-epoch repair fractions (divide by update epochs for the mean)",
        );
        let route_seconds = registry.fgauge(
            "skipper_service_route_seconds_total",
            "Router wall seconds spent routing updates into shard mailboxes",
        );
        let route_overlap_seconds = registry.fgauge(
            "skipper_service_route_overlap_seconds_total",
            "Portion of route seconds that overlapped a running flush",
        );
        let batch_latency = registry.histogram_secs(
            "skipper_batch_latency_seconds",
            "Update batch latency from enqueue to applied",
        );
        let wal_groups = registry.counter(
            "skipper_wal_groups_total",
            "Durable WAL append groups written (one shared fsync each)",
        );
        let wal_group_epochs = registry.counter(
            "skipper_wal_group_epochs_total",
            "Epochs logged through WAL append groups",
        );
        Self {
            registry,
            total_inserts,
            total_deletes,
            total_repair_edges,
            update_epochs,
            repair_frac_last,
            repair_frac_sum,
            route_seconds,
            route_overlap_seconds,
            batch_latency,
            wal_groups,
            wal_group_epochs,
        }
    }

    /// One batch-latency percentile, in milliseconds (samples are recorded
    /// in nanoseconds).
    fn batch_percentile_ms(&self, p: f64) -> f64 {
        self.batch_latency.percentile(p) as f64 * 1e-6
    }

    /// The full `METRICS` exposition: the process-global registry (pool,
    /// engine shards, WAL, snapshots) followed by this service's
    /// instruments, as one document with a single trailing `# EOF`.
    fn render_prometheus(&self) -> String {
        let mut text = metrics::global().render_prometheus();
        let eof = "# EOF\n";
        debug_assert!(text.ends_with(eof));
        text.truncate(text.len() - eof.len());
        text.push_str(&self.registry.render_prometheus());
        text
    }
}

/// One routed-but-unflushed generation of updates. The engine's per-shard
/// mailboxes double as the coalescing buffer: updates are routed to their
/// owner shard(s) at drain time, so a flush hands each shard worker its
/// work list with no extra pass. In pipelined mode a second generation is
/// being routed while the previous one is applied.
struct PendingGen {
    mailboxes: ShardMailboxes,
    /// Enqueue stamps of the update requests coalesced into this
    /// generation, for the batch-latency percentiles.
    stamps: Vec<Instant>,
    /// The generation's updates in arrival order, kept only when WAL
    /// logging is on — the flusher writes this flat list (mailboxes
    /// double-store cross-shard updates and lose the global order).
    wal_log: Vec<Update>,
    /// Router wall seconds spent routing this generation.
    route_s: f64,
    /// Portion of `route_s` spent while a flush was running — the
    /// pipelining overlap.
    overlap_s: f64,
}

impl PendingGen {
    fn new(mailboxes: ShardMailboxes) -> Self {
        Self {
            mailboxes,
            stamps: Vec::new(),
            wal_log: Vec::new(),
            route_s: 0.0,
            overlap_s: 0.0,
        }
    }
}

/// Work handed from the router to the flush executor. Barrier jobs carry
/// the generation they must flush first, so FIFO handling reproduces the
/// serial coordinator's semantics exactly — a barrier reply always reflects
/// every update its client sent before it.
enum FlushJob {
    /// Coalescing-threshold flush: apply, no reply.
    Apply(PendingGen),
    Epoch(Option<PendingGen>, ReplySlot),
    Query(Option<PendingGen>, VertexId, ReplySlot),
    Stats(Option<PendingGen>, bool, ReplySlot),
    Snapshot(Option<PendingGen>, ReplySlot),
    /// Debug fault injection: panic on the flush executor's thread.
    Crash,
}

/// The flush executor: updates the service instruments, applies
/// generations to the engine, and answers barrier requests. Runs inline on
/// the router thread when pipelining is off, or on the dedicated flusher
/// thread when it is on.
struct FlushExec<'a> {
    cfg: &'a ServiceConfig,
    engine: &'a ShardedDynamicMatcher,
    /// True while `apply_mailboxes` runs — the router reads it to attribute
    /// route time to the pipelining overlap.
    flushing: &'a AtomicBool,
    /// Drained mailbox generations go back here for the router to reuse.
    spares: &'a BoundedQueue<ShardMailboxes>,
    /// Durability bundle (WAL + snapshotter + counters); `None` when the
    /// service runs volatile. Owned here so every append and every state
    /// capture happens at an epoch barrier on the flush thread.
    dur: Option<DurableService>,
    /// The service's lifetime instruments (shared with `STATS`/`METRICS`
    /// readers; this executor is their only writer).
    sm: &'a ServiceMetrics,
    /// Replication shipper (`--replicate-addr`); every committed epoch is
    /// published to it right after the local apply, so followers stream
    /// exactly the epochs this executor ran, in order.
    shipper: Option<&'a Shipper>,
    /// Generations whose WAL records `handle_group` already appended as a
    /// durable group; `flush` skips its per-epoch append for exactly this
    /// many upcoming generations.
    prelogged: u64,
}

impl<'a> FlushExec<'a> {
    fn new(
        cfg: &'a ServiceConfig,
        engine: &'a ShardedDynamicMatcher,
        flushing: &'a AtomicBool,
        spares: &'a BoundedQueue<ShardMailboxes>,
        dur: Option<DurableService>,
        sm: &'a ServiceMetrics,
        shipper: Option<&'a Shipper>,
    ) -> Self {
        Self { cfg, engine, flushing, spares, dur, sm, shipper, prelogged: 0 }
    }

    fn flush(&mut self, gen: PendingGen) -> Option<EpochReport> {
        let PendingGen { mut mailboxes, mut stamps, wal_log, route_s, overlap_s } = gen;
        if mailboxes.is_empty() {
            // unreachable via take_gen (which never yields an empty
            // generation); a future direct caller would silently lose this
            // generation's stamps and route telemetry — catch it in tests
            debug_assert!(false, "flush() called with an empty generation");
            let _ = self.spares.try_push(mailboxes);
            return None;
        }
        // the overlap-attribution window spans the WHOLE flush — WAL
        // append (which can dominate under --fsync), engine apply, and the
        // post-epoch durability work — so the router's concurrent route
        // time lands in route_overlap_s wherever the flusher actually is
        self.flushing.store(true, Ordering::Relaxed);
        // WAL-before-apply: the epoch this flush is about to run gets the
        // number apply_mailboxes will assign (the flusher is the only
        // epoch applier, so the +1 cannot race). A failed append is fatal:
        // applying (and barrier-acknowledging) updates the log refused
        // would hand clients a gapped history after the next crash, so the
        // durability contract wins over availability — the panic-exit
        // guard turns this into a diagnosed process exit.
        if let Some(dur) = self.dur.as_mut() {
            if self.prelogged > 0 {
                // this generation's record went to disk in a group append
                // (handle_group), before any generation of the group was
                // applied — the WAL-before-apply invariant still holds
                self.prelogged -= 1;
            } else {
                if let Err(e) = dur.log_epoch(self.engine.epochs_applied() + 1, &wal_log) {
                    panic!("wal: refusing to apply an unlogged epoch: {e}");
                }
                if dur.log_enabled() && !wal_log.is_empty() {
                    // a lone append is a group of one
                    self.sm.wal_groups.inc();
                    self.sm.wal_group_epochs.inc();
                }
            }
        }
        let mut report = self.engine.apply_mailboxes(&mut mailboxes);
        report.route_wall_s = route_s;
        report.route_overlap_s = overlap_s;
        if let Some(ship) = self.shipper {
            // publish after the local WAL append (above) and apply: the
            // epoch is committed here, and the backlog push is cheap — the
            // socket writes happen on the shipper's sender threads
            ship.publish(report.epoch, &wal_log);
        }
        let now = Instant::now();
        for s in stamps.drain(..) {
            self.sm.batch_latency.record_duration(now.duration_since(s));
        }
        // recycle the drained mailbox set; a full rack just drops it
        let _ = self.spares.try_push(mailboxes);
        self.sm.total_inserts.add(report.inserts as u64);
        self.sm.total_deletes.add(report.deletes as u64);
        self.sm.total_repair_edges.add(report.repair_edges as u64);
        self.sm.repair_frac_last.set(report.repair_fraction());
        self.sm.repair_frac_sum.add(report.repair_fraction());
        self.sm.route_seconds.add(route_s);
        self.sm.route_overlap_seconds.add(overlap_s);
        self.sm.update_epochs.inc();
        if let Some(dur) = self.dur.as_mut() {
            // cadence snapshots + lagged WAL pruning
            dur.after_epoch(self.engine);
        }
        self.flushing.store(false, Ordering::Relaxed);
        Some(report)
    }

    /// Handle a burst of jobs the flusher drained from the hand-off queue
    /// in one go. When the burst carries more than one pending generation,
    /// every generation's WAL record is appended first as **one durable
    /// group** — a single `sync_data` covers the whole burst under
    /// `--fsync` — and only then are the generations applied and the
    /// barriers answered, in FIFO order. WAL-before-apply holds for the
    /// group exactly as it does per epoch: nothing is applied before its
    /// record is on disk.
    fn handle_group(&mut self, group: &mut Vec<FlushJob>) {
        debug_assert_eq!(self.prelogged, 0, "a previous group left unapplied epochs");
        if group.len() > 1 && self.dur.as_ref().is_some_and(|d| d.log_enabled()) {
            // the flusher is the only epoch applier, so numbering the
            // burst's generations base+1, base+2, … cannot race
            let base = self.engine.epochs_applied();
            let mut seq = 0u64;
            let batch: Vec<(u64, &[Update])> = group
                .iter()
                .filter_map(|job| {
                    let gen = match job {
                        FlushJob::Apply(g) => Some(g),
                        FlushJob::Epoch(g, _)
                        | FlushJob::Query(g, _, _)
                        | FlushJob::Stats(g, _, _)
                        | FlushJob::Snapshot(g, _) => g.as_ref(),
                        FlushJob::Crash => None,
                    }?;
                    seq += 1;
                    Some((base + seq, gen.wal_log.as_slice()))
                })
                .collect();
            if batch.len() > 1 {
                let dur = self.dur.as_mut().expect("checked above");
                if let Err(e) = dur.log_epochs(&batch) {
                    panic!("wal: refusing to apply unlogged epochs: {e}");
                }
                self.prelogged = batch.len() as u64;
                self.sm.wal_groups.inc();
                self.sm.wal_group_epochs.add(batch.len() as u64);
            }
        }
        for job in group.drain(..) {
            self.handle(job);
        }
    }

    fn handle(&mut self, job: FlushJob) {
        match job {
            FlushJob::Apply(gen) => {
                self.flush(gen);
            }
            FlushJob::Epoch(gen, p) => {
                let rep = gen.and_then(|g| self.flush(g));
                p.fulfill(match rep {
                    Some(r) => Response::Epoch(r),
                    // flush of nothing: say so instead of fabricating a
                    // zero-count report under the previous epoch number
                    None => Response::EpochIdle {
                        epochs_applied: self.engine.epochs_applied(),
                        live_edges: self.engine.num_live_edges(),
                        matched_vertices: self.engine.matched_vertices(),
                    },
                });
            }
            FlushJob::Query(gen, v, p) => {
                if let Some(g) = gen {
                    self.flush(g);
                }
                p.fulfill(Response::Query { vertex: v, partner: self.engine.partner(v) });
            }
            FlushJob::Stats(gen, full, p) => {
                if let Some(g) = gen {
                    self.flush(g);
                }
                p.fulfill(Response::Stats(snapshot(
                    self.cfg,
                    self.engine,
                    self.sm,
                    full,
                    self.dur.as_ref(),
                    self.shipper,
                )));
            }
            FlushJob::Snapshot(gen, p) => {
                if let Some(g) = gen {
                    self.flush(g);
                }
                p.fulfill(match self.dur.as_mut() {
                    Some(dur) if dur.snapshot_busy() => {
                        // a previous snapshot is still being written: reply
                        // from cheap counters without building the
                        // O(|V|+|E|) barrier copy that would be discarded
                        Response::Snapshot {
                            epoch: self.engine.epochs_applied(),
                            live_edges: self.engine.num_live_edges(),
                            matched_vertices: self.engine.matched_vertices(),
                            accepted: false,
                        }
                    }
                    Some(dur) => {
                        // capture at the barrier; serialization and disk IO
                        // happen on the background writer thread
                        let data = SnapshotData::capture(self.engine);
                        let epoch = data.epoch;
                        let live_edges = data.live_edges.len() as u64;
                        let matched_vertices = 2 * data.matching.len();
                        let accepted = dur.request_snapshot(data);
                        Response::Snapshot { epoch, live_edges, matched_vertices, accepted }
                    }
                    None => Response::Error(
                        "durability is off: restart serve with --data-dir".into(),
                    ),
                });
            }
            FlushJob::Crash => panic!("debug CRASH: deliberate flusher panic"),
        }
    }

    fn summary(mut self) -> ServiceSummary {
        // graceful exit: a final synchronous snapshot makes the next boot a
        // snapshot-only recovery (zero WAL replay)
        let mut recovery_replayed = 0;
        let mut wal_epochs = 0;
        let mut last_snapshot_epoch = 0;
        if let Some(dur) = self.dur.take() {
            recovery_replayed = dur.recovery().replayed_epochs;
            wal_epochs = dur.counters().wal_epochs.load(Ordering::Relaxed);
            last_snapshot_epoch = dur.shutdown(self.engine);
        }
        ServiceSummary {
            epochs: self.engine.epochs_applied(),
            total_inserts: self.sm.total_inserts.get(),
            total_deletes: self.sm.total_deletes.get(),
            total_repair_edges: self.sm.total_repair_edges.get(),
            live_edges: self.engine.num_live_edges(),
            matched_vertices: self.engine.matched_vertices(),
            maximal: self.engine.verify().is_ok(),
            recovery_replayed,
            wal_epochs,
            last_snapshot_epoch,
            metrics_text: self.sm.render_prometheus(),
        }
    }
}

/// Where the router sends flush work: straight into the executor
/// (pipelining off) or across the hand-off queue to the flusher thread.
enum FlushSink<'e, 'q> {
    Inline(FlushExec<'e>),
    Pipe(&'q BoundedQueue<FlushJob>),
}

impl FlushSink<'_, '_> {
    fn send(&mut self, job: FlushJob) {
        match self {
            FlushSink::Inline(ex) => ex.handle(job),
            // a closed hand-off means the flusher died; dropping the job
            // abandons its promises, so waiting clients wake with an error
            // instead of hanging
            FlushSink::Pipe(q) => {
                let _ = q.push(job);
            }
        }
    }
}

/// Depth of the router→flusher hand-off queue. Deeper than one so that
/// when an epoch's flush runs long, the router keeps routing and the
/// generations that pile up behind it are WAL-logged as one durable group
/// (one `fsync` for the burst — see `FlushExec::handle_group`); still
/// small, so the router can never run unboundedly ahead of the engine.
const FLUSH_QUEUE_DEPTH: usize = 4;

/// Spare mailbox generations kept in rotation (one being routed, up to
/// `FLUSH_QUEUE_DEPTH` queued or applying, plus recycling slack).
const MAILBOX_GENERATIONS: usize = FLUSH_QUEUE_DEPTH + 2;

/// The request router: drain → route into the current mailbox generation →
/// hand flush jobs to the sink at barriers, until the queue closes or a
/// `SHUTDOWN` arrives.
#[allow(clippy::too_many_arguments)] // one call site, mirrors engine_loop's locals
fn route_loop(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    queue: &ShardedQueue<Request>,
    stop: &AtomicBool,
    flushing: &AtomicBool,
    spares: &BoundedQueue<ShardMailboxes>,
    sink: &mut FlushSink<'_, '_>,
    keep_wal_log: bool,
) {
    let _guard = EngineGuard { queue, stop };
    let mut buf: Vec<Request> = Vec::new();
    let mut gen = PendingGen::new(engine.mailboxes());

    // Take the current generation for a flush, swapping in a recycled (or
    // fresh) mailbox set so routing can continue immediately.
    let take_gen = |gen: &mut PendingGen| -> Option<PendingGen> {
        if gen.mailboxes.is_empty() {
            return None;
        }
        let fresh = spares.try_pop().unwrap_or_else(|| engine.mailboxes());
        Some(std::mem::replace(gen, PendingGen::new(fresh)))
    };

    // Route one update batch into the current generation, attributing the
    // route time (and, when a flush is running concurrently, the overlap).
    let route = |gen: &mut PendingGen, updates: &[Update], enqueued: Instant| -> bool {
        let t = Instant::now();
        let res = engine.route_into(updates, &mut gen.mailboxes);
        let dt = t.elapsed().as_secs_f64();
        gen.route_s += dt;
        if flushing.load(Ordering::Relaxed) {
            gen.overlap_s += dt;
        }
        match res {
            Ok(()) => {
                gen.stamps.push(enqueued);
                if keep_wal_log {
                    gen.wal_log.extend_from_slice(updates);
                }
                true
            }
            // Connections validate vertex ranges before enqueueing, so the
            // only failure left is a bug — surface it without killing the
            // service (nothing was routed).
            Err(e) => {
                eprintln!("engine: dropped bad batch: {e}");
                false
            }
        }
    };

    // Updates coalesce in the current generation until a barrier request
    // (EPOCH / queue-riding QUERY / STATS) arrives, the coalescing
    // threshold trips, or the queue closes. Deliberately NO flush-on-idle:
    // a client's `INSERT ... / EPOCH` pair must deterministically see its
    // inserts applied *at the barrier*, not racily swept up in between.
    let mut shutdown = false;
    'outer: loop {
        buf.clear();
        queue.drain(&mut buf, cfg.epoch_max_requests);
        if buf.is_empty() {
            if !queue.wait() {
                break;
            }
            continue;
        }
        for req in buf.drain(..) {
            match req {
                Request::Updates { updates, enqueued } => {
                    if route(&mut gen, &updates, enqueued)
                        && gen.mailboxes.num_updates() >= cfg.epoch_max_updates
                    {
                        if let Some(g) = take_gen(&mut gen) {
                            sink.send(FlushJob::Apply(g));
                        }
                    }
                }
                Request::Epoch(p) => sink.send(FlushJob::Epoch(take_gen(&mut gen), p)),
                Request::Query(v, p) => sink.send(FlushJob::Query(take_gen(&mut gen), v, p)),
                Request::Stats(full, p) => {
                    sink.send(FlushJob::Stats(take_gen(&mut gen), full, p))
                }
                Request::Snapshot(p) => {
                    sink.send(FlushJob::Snapshot(take_gen(&mut gen), p))
                }
                Request::Crash(CrashTarget::Router) => {
                    panic!("debug CRASH: deliberate router panic")
                }
                Request::Crash(CrashTarget::Flusher) => sink.send(FlushJob::Crash),
                Request::Shutdown => {
                    // finish answering the rest of this round first — a
                    // mid-buffer break would strand promises un-fulfilled
                    stop.store(true, Ordering::Relaxed);
                    shutdown = true;
                }
            }
        }
        if shutdown {
            break 'outer;
        }
    }

    // Drain stragglers so no client hangs on an unanswered promise, then
    // hand over any last updates.
    queue.close();
    loop {
        buf.clear();
        if queue.drain(&mut buf, usize::MAX) == 0 {
            break;
        }
        for req in buf.drain(..) {
            match req {
                Request::Updates { updates, enqueued } => {
                    route(&mut gen, &updates, enqueued);
                }
                Request::Epoch(p) | Request::Stats(_, p) | Request::Snapshot(p) => {
                    p.fulfill(Response::Error("server shutting down".into()))
                }
                Request::Crash(_) => {}
                Request::Query(v, p) => {
                    // honor the ordering guarantee even during shutdown: the
                    // client's earlier updates (drained just above) must be
                    // visible to its query
                    sink.send(FlushJob::Query(take_gen(&mut gen), v, p))
                }
                Request::Shutdown => {}
            }
        }
    }
    if let Some(g) = take_gen(&mut gen) {
        sink.send(FlushJob::Apply(g));
    }
}

/// The epoch coordinator: run the router, inline or pipelined against a
/// flusher thread, and produce the lifetime summary. The heavy phases of
/// every flush fan out across the engine's shard workers inside
/// [`ShardedDynamicMatcher::apply_mailboxes`].
fn engine_loop(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    queue: &ShardedQueue<Request>,
    stop: &AtomicBool,
    dur: Option<DurableService>,
    sm: &ServiceMetrics,
    shipper: Option<&Shipper>,
) -> ServiceSummary {
    // a router panic must not strand clients on a half-dead server
    let _router_guard = ExitOnPanic {
        role: "router",
        enabled: cfg.exit_on_panic,
        blackbox: cfg.data_dir.as_deref().map(|d| (d, sm)),
    };
    // the flat per-generation update list feeds both the WAL append and
    // the replication backlog — keep it when either consumer exists
    let keep_wal_log =
        dur.as_ref().is_some_and(|d| d.log_enabled()) || shipper.is_some();
    let flushing = AtomicBool::new(false);
    let spares: BoundedQueue<ShardMailboxes> = BoundedQueue::new(MAILBOX_GENERATIONS);
    if !cfg.pipeline {
        let mut sink = FlushSink::Inline(FlushExec::new(
            cfg, engine, &flushing, &spares, dur, sm, shipper,
        ));
        route_loop(cfg, engine, queue, stop, &flushing, &spares, &mut sink, keep_wal_log);
        match sink {
            FlushSink::Inline(ex) => ex.summary(),
            FlushSink::Pipe(_) => unreachable!("inline sink cannot become a pipe"),
        }
    } else {
        // bounded hand-off: a few generations may queue behind the one
        // being applied — the flusher drains them as one WAL group — but
        // the router can never run unboundedly ahead of the engine
        let jobs: BoundedQueue<FlushJob> = BoundedQueue::new(FLUSH_QUEUE_DEPTH);
        std::thread::scope(|s| {
            // if the router panics mid-loop, this unwinds before the scope
            // joins the flusher — closing the hand-off so the flusher can't
            // block forever on an open-but-dead queue (which would deadlock
            // the join and keep the panic-exit diagnostic from running)
            let _close_jobs = CloseOnDrop(&jobs);
            let flusher = {
                let jobs = &jobs;
                let flushing = &flushing;
                let spares = &spares;
                s.spawn(move || {
                    let _flusher_guard = ExitOnPanic {
                        role: "flusher",
                        enabled: cfg.exit_on_panic,
                        blackbox: cfg.data_dir.as_deref().map(|d| (d, sm)),
                    };
                    // closing on exit (including panic) keeps the router from
                    // blocking on a dead flusher; jobs it then fails to send are
                    // dropped, abandoning their promises and waking the waiters
                    let _close = CloseOnDrop(jobs);
                    let mut ex =
                        FlushExec::new(cfg, engine, flushing, spares, dur, sm, shipper);
                    let mut group: Vec<FlushJob> = Vec::with_capacity(FLUSH_QUEUE_DEPTH);
                    while let Some(job) = jobs.pop() {
                        // greedy drain: everything already queued behind
                        // this job is handled as one burst, so a backlog's
                        // WAL records share a single append group
                        group.push(job);
                        while group.len() < FLUSH_QUEUE_DEPTH {
                            match jobs.try_pop() {
                                Some(j) => group.push(j),
                                None => break,
                            }
                        }
                        ex.handle_group(&mut group);
                    }
                    ex.summary()
                })
            };
            {
                let mut sink = FlushSink::Pipe(&jobs);
                route_loop(
                    cfg, engine, queue, stop, &flushing, &spares, &mut sink, keep_wal_log,
                );
            }
            jobs.close();
            flusher.join().expect("flusher thread panicked")
        })
    }
}

fn snapshot(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    sm: &ServiceMetrics,
    audit: bool,
    dur: Option<&DurableService>,
    ship: Option<&Shipper>,
) -> StatsSnapshot {
    let (durable, wal_epochs, wal_bytes, last_snapshot_epoch, recovery_replayed) = match dur {
        Some(d) => {
            let c = d.counters();
            (
                true,
                c.wal_epochs.load(Ordering::Relaxed),
                c.wal_bytes.load(Ordering::Relaxed),
                c.last_snapshot_epoch.load(Ordering::Relaxed),
                c.recovery_replayed.load(Ordering::Relaxed),
            )
        }
        None => (false, 0, 0, 0, 0),
    };
    StatsSnapshot {
        epochs: engine.epochs_applied(),
        live_edges: engine.num_live_edges(),
        matched_vertices: engine.matched_vertices(),
        total_inserts: sm.total_inserts.get(),
        total_deletes: sm.total_deletes.get(),
        total_repair_edges: sm.total_repair_edges.get(),
        repair_frac_last: sm.repair_frac_last.get(),
        repair_frac_mean: {
            let n = sm.update_epochs.get();
            if n > 0 { sm.repair_frac_sum.get() / n as f64 } else { 0.0 }
        },
        p50_batch_ms: sm.batch_percentile_ms(50.0),
        p99_batch_ms: sm.batch_percentile_ms(99.0),
        p999_batch_ms: sm.batch_percentile_ms(99.9),
        // the O(|V|+|E_live|) walk only on `STATS full` — cheap polls must
        // not stall epochs on big graphs
        maximal: audit.then(|| engine.verify().is_ok()),
        adjacency_bytes: engine.adjacency_bytes(),
        engine_shards: engine.num_shards(),
        // the live fact, not the configured policy: P = 1 runs inline, so
        // no pool exists there even under the default ShardExec::Pool
        pooled: engine.pooled(),
        pipelined: cfg.pipeline,
        route_s: sm.route_seconds.get(),
        route_overlap_s: sm.route_overlap_seconds.get(),
        durable,
        wal_epochs,
        wal_bytes,
        last_snapshot_epoch,
        recovery_replayed,
        replica: ship.map(|s| {
            let st = s.stats();
            ReplicaStats {
                role: ReplicaRole::Primary,
                followers: st.followers,
                tip_epoch: st.tip,
                acked_epoch: st.acked,
                lag_epochs: st.lag_epochs,
                lag_bytes: st.lag_bytes,
            }
        }),
    }
}

struct ConnOutcome {
    shutdown: bool,
}

/// Serve one client on `reader`/`writer` through shard `shard`.
fn handle_conn<R: BufRead, W: Write>(
    cfg: &ServiceConfig,
    shard: usize,
    engine: &ShardedDynamicMatcher,
    queue: &ShardedQueue<Request>,
    sm: &ServiceMetrics,
    mut reader: R,
    writer: &mut W,
) -> ConnOutcome {
    let mut outcome = ConnOutcome { shutdown: false };
    let mut reply = |writer: &mut W, resp: &Response| -> bool {
        writeln!(writer, "{}", resp.render()).and_then(|_| writer.flush()).is_ok()
    };
    // Updates this connection queued since its last barrier reply. While
    // clean, a QUERY needs no engine round-trip: read-your-writes is
    // trivially satisfied, so it is answered from the owner shard's atomic
    // partner slot without stalling in-flight epochs.
    let mut dirty = false;
    let mut raw: Vec<u8> = Vec::new();
    loop {
        raw.clear();
        match reader.read_until(b'\n', &mut raw) {
            Ok(0) => break,  // EOF
            Ok(_) => {}
            Err(_) => break, // client went away
        }
        // Byte-tolerant framing: a line that is not valid UTF-8 (a binary
        // client, a truncated multi-byte character) still gets exactly one
        // structured error reply — lossy decoding turns the bad bytes into
        // replacement characters, which no verb matches. The alternative
        // (BufRead::lines erroring out) silently dropped the connection,
        // desyncing the one-reply-per-line framing.
        let line = String::from_utf8_lossy(&raw);
        let cmd = match Command::parse(&line) {
            Ok(None) => continue,
            Ok(Some(c)) => c,
            Err(e) => {
                if !reply(writer, &Response::Error(e)) {
                    break;
                }
                continue;
            }
        };
        match cmd {
            Command::Updates(updates) => {
                let n = cfg.num_vertices;
                if let Some(bad) = updates.iter().find(|u| {
                    let (Update::Insert(a, b) | Update::Delete(a, b)) = **u;
                    a as usize >= n || b as usize >= n
                }) {
                    let err = format!("{bad:?} out of range (|V|={n})");
                    if !reply(writer, &Response::Error(err)) {
                        break;
                    }
                    continue;
                }
                let count = updates.len();
                let req = Request::Updates { updates, enqueued: Instant::now() };
                if queue.push(shard, req).is_err() {
                    let _ = reply(writer, &Response::Error("server shutting down".into()));
                    break;
                }
                dirty = true;
                if !reply(writer, &Response::Queued { count }) {
                    break;
                }
            }
            Command::Metrics => {
                // answered here on the connection thread — a registry
                // render is a lock-free snapshot of the instruments, so
                // scrapes never ride the engine queue or stall an epoch
                if !reply(writer, &Response::Metrics(sm.render_prometheus())) {
                    break;
                }
            }
            Command::Trace(n) => {
                // flight-recorder copy-out; empty (but well-formed) when
                // the server runs without --trace
                let events = trace::last_epochs(trace::collect(), n);
                let mut doc = trace::chrome_trace_json(&events);
                doc.set("ok", Json::from(true))
                    .set("op", Json::from("trace"))
                    .set("events", Json::from(events.len()));
                if !reply(writer, &Response::Trace(doc.render_compact())) {
                    break;
                }
            }
            Command::Query(v) if !dirty => {
                // fast path: nothing of ours is pending, answer lock-free
                // from the atomic partner state
                let resp = if (v as usize) < cfg.num_vertices {
                    Response::Query { vertex: v, partner: engine.partner(v) }
                } else {
                    Response::Error(format!(
                        "vertex {v} out of range (|V|={})",
                        cfg.num_vertices
                    ))
                };
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Epoch | Command::Stats { .. } | Command::Query(_) | Command::Snapshot => {
                let p = Promise::shared();
                let req = match &cmd {
                    Command::Epoch => Request::Epoch(ReplySlot(Arc::clone(&p))),
                    Command::Stats { full } => Request::Stats(*full, ReplySlot(Arc::clone(&p))),
                    Command::Snapshot => Request::Snapshot(ReplySlot(Arc::clone(&p))),
                    Command::Query(v) => {
                        if *v as usize >= cfg.num_vertices {
                            let err = format!("vertex {v} out of range (|V|={})", cfg.num_vertices);
                            if !reply(writer, &Response::Error(err)) {
                                break;
                            }
                            continue;
                        }
                        Request::Query(*v, ReplySlot(Arc::clone(&p)))
                    }
                    _ => unreachable!(),
                };
                if queue.push(shard, req).is_err() {
                    let _ = reply(writer, &Response::Error("server shutting down".into()));
                    break;
                }
                match p.wait() {
                    Some(resp) => {
                        // a successful barrier reply means the coordinator
                        // flushed everything we queued earlier; an Error
                        // (e.g. the shutdown drain answering without a
                        // flush) proves nothing, so the connection must
                        // stay dirty to preserve read-your-writes
                        if !matches!(resp, Response::Error(_)) {
                            dirty = false;
                        }
                        if !reply(writer, &resp) {
                            break;
                        }
                    }
                    None => {
                        let _ = reply(writer, &Response::Error("server shutting down".into()));
                        break;
                    }
                }
            }
            Command::Crash(target) => {
                if !cfg.debug_commands {
                    if !reply(
                        writer,
                        &Response::Error("CRASH requires --debug-commands".into()),
                    ) {
                        break;
                    }
                    continue;
                }
                // no reply on success: the process is about to die by design
                let _ = queue.push(shard, Request::Crash(target));
            }
            Command::Blackbox => {
                // answered on the connection thread, like METRICS: the dump
                // reads lock-free registries and the flight-recorder rings
                let resp = if !cfg.debug_commands {
                    Response::Error("BLACKBOX requires --debug-commands".into())
                } else {
                    match &cfg.data_dir {
                        Some(dir) => {
                            let text = sm.render_prometheus();
                            match blackbox::write_blackbox(Path::new(dir), "command", &text) {
                                Ok(p) => Response::Blackbox { path: p.display().to_string() },
                                Err(e) => Response::Error(e),
                            }
                        }
                        None => Response::Error("BLACKBOX requires --data-dir".into()),
                    }
                };
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Promote => {
                // PROMOTE only means something on a replicating follower
                // (serve --follow); a primary has nothing to be promoted to
                if !reply(
                    writer,
                    &Response::Error(
                        "PROMOTE: this server is not a follower (start one with serve --follow)"
                            .into(),
                    ),
                ) {
                    break;
                }
            }
            Command::Quit => {
                let _ = reply(writer, &Response::Bye);
                break;
            }
            Command::Shutdown => {
                let _ = queue.push(shard, Request::Shutdown);
                let _ = reply(writer, &Response::ShuttingDown);
                outcome.shutdown = true;
                break;
            }
        }
    }
    outcome
}

/// Open the durability bundle when the config names a data dir: recover
/// the engine (snapshot + WAL replay, verified maximal) and report what
/// happened on stderr.
pub(super) fn open_durability(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
) -> Result<Option<DurableService>, String> {
    let Some(dir) = &cfg.data_dir else {
        return Ok(None);
    };
    let opts = DurableOptions {
        data_dir: PathBuf::from(dir),
        wal: cfg.wal,
        fsync: cfg.wal_fsync,
        snapshot_every: cfg.snapshot_every,
    };
    let dur = DurableService::open(&opts, engine)?;
    let r = dur.recovery();
    eprintln!(
        "recovery: snapshot epoch {}, replayed {} wal epochs ({} updates); resuming at epoch {} with {} live edges, {} matched",
        r.snapshot_epoch.map_or("none".to_string(), |e| e.to_string()),
        r.replayed_epochs,
        r.replayed_updates,
        r.resumed_epoch,
        engine.num_live_edges(),
        engine.matched_vertices(),
    );
    Ok(Some(dur))
}

/// Bind the `--replicate-addr` WAL shipping listener when configured.
/// Bound after recovery so the replication horizon is the recovered epoch:
/// followers resuming at or past it stream the delta, anyone older is told
/// to re-seed from a data-dir copy.
fn open_shipper(
    cfg: &ServiceConfig,
    engine: &ShardedDynamicMatcher,
    sm: &ServiceMetrics,
) -> Result<Option<Shipper>, String> {
    let Some(addr) = &cfg.replicate_addr else {
        return Ok(None);
    };
    let ship = Shipper::bind(addr, cfg.num_vertices, engine.epochs_applied(), &sm.registry)?;
    eprintln!(
        "replicate: shipping committed epochs to followers on {} (horizon epoch {})",
        ship.local_addr(),
        engine.epochs_applied()
    );
    Ok(Some(ship))
}

/// Bind the `--metrics-addr` HTTP scrape endpoint (port 0 = ephemeral).
/// Separate from the serve loop so boot fails loudly on a bad address
/// instead of silently dropping scrapes.
fn bind_metrics(cfg: &ServiceConfig) -> Result<Option<TcpListener>, String> {
    let Some(addr) = &cfg.metrics_addr else {
        return Ok(None);
    };
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind metrics {addr}: {e}"))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("metrics nonblocking: {e}"))?;
    if let Ok(local) = listener.local_addr() {
        eprintln!("metrics: scrape http://{local}/metrics");
    }
    Ok(Some(listener))
}

/// Minimal HTTP framing for the scrape endpoint: `GET /metrics` (or `/`)
/// answers 200 with the same exposition the `METRICS` command returns;
/// anything else answers 404. One request per connection
/// (`Connection: close`) — exactly what a Prometheus scraper needs, with
/// none of an HTTP stack's surface.
fn metrics_http_reply(request_line: &str, sm: &ServiceMetrics) -> String {
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    if method == "GET" && (path == "/metrics" || path == "/") {
        let body = sm.render_prometheus();
        format!(
            "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
    } else {
        let body = "scrape endpoint: GET /metrics\n";
        format!(
            "HTTP/1.0 404 Not Found\r\nContent-Type: text/plain; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
    }
}

/// The `--metrics-addr` listener loop: accept, read the request line,
/// answer, close. Scrapes are answered directly from the registries — no
/// barrier, no engine round-trip — so scraping never stalls epochs. Exits
/// when the service raises `stop`.
fn metrics_http_loop(listener: &TcpListener, sm: &ServiceMetrics, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                // a client that connects and stalls must not wedge the loop
                let _ = stream.set_read_timeout(Some(std::time::Duration::from_millis(500)));
                let mut reader = BufReader::new(stream);
                let mut line = String::new();
                let _ = reader.read_line(&mut line);
                let reply = metrics_http_reply(&line, sm);
                let mut stream = reader.into_inner();
                let _ = stream.write_all(reply.as_bytes());
                let _ = stream.flush();
                let _ = stream.shutdown(Shutdown::Both);
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                ) =>
            {
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Err(e) => {
                eprintln!("metrics accept: {e}");
                break;
            }
        }
    }
}

/// Serve a single client over any line stream — `skipper-cli serve` on a
/// stdin pipe, and the CI smoke test. Returns when the stream ends or the
/// client sends `QUIT`/`SHUTDOWN`. Errors only at boot (recovery failure);
/// a durable service writes a final snapshot before returning.
pub fn serve_lines<R: BufRead, W: Write>(
    cfg: &ServiceConfig,
    reader: R,
    writer: &mut W,
) -> Result<ServiceSummary, String> {
    let engine = ShardedDynamicMatcher::with_exec_layout_pin(
        cfg.num_vertices,
        cfg.threads,
        cfg.engine_shards,
        cfg.shard_exec(),
        crate::dynamic::AdjLayout::default(),
        cfg.pin,
    );
    let dur = open_durability(cfg, &engine)?;
    let sm = ServiceMetrics::new();
    let shipper = open_shipper(cfg, &engine, &sm)?;
    let metrics_listener = bind_metrics(cfg)?;
    let queue: ShardedQueue<Request> = ShardedQueue::new(cfg.shards, cfg.shard_capacity);
    let stop = AtomicBool::new(false);
    Ok(std::thread::scope(|s| {
        let engine_ref = &engine;
        let queue_ref = &queue;
        let stop_ref = &stop;
        let sm_ref = &sm;
        let ship_ref = shipper.as_ref();
        let coordinator = s
            .spawn(move || engine_loop(cfg, engine_ref, queue_ref, stop_ref, dur, sm_ref, ship_ref));
        if let Some(listener) = &metrics_listener {
            let sm_ref = &sm;
            let stop_ref = &stop;
            s.spawn(move || metrics_http_loop(listener, sm_ref, stop_ref));
        }
        handle_conn(cfg, 0, &engine, &queue, &sm, reader, writer);
        queue.close();
        // the engine loop's exit guard raises `stop`, which also winds down
        // the metrics listener before the scope joins it
        coordinator.join().expect("engine thread panicked")
    }))
}

/// Serve concurrent clients over TCP. Binds `addr` (use port 0 for an
/// ephemeral port), invokes `on_ready` with the bound address, and runs
/// until a client sends `SHUTDOWN`. Each connection gets its own thread
/// and queue shard.
pub fn serve_tcp(
    cfg: &ServiceConfig,
    addr: &str,
    on_ready: impl FnOnce(SocketAddr),
) -> Result<ServiceSummary, String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("nonblocking: {e}"))?;
    let local = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
    on_ready(local);

    let engine = ShardedDynamicMatcher::with_exec_layout_pin(
        cfg.num_vertices,
        cfg.threads,
        cfg.engine_shards,
        cfg.shard_exec(),
        crate::dynamic::AdjLayout::default(),
        cfg.pin,
    );
    let dur = open_durability(cfg, &engine)?;
    let sm = ServiceMetrics::new();
    let shipper = open_shipper(cfg, &engine, &sm)?;
    let metrics_listener = bind_metrics(cfg)?;
    let queue: ShardedQueue<Request> = ShardedQueue::new(cfg.shards, cfg.shard_capacity);
    let stop = AtomicBool::new(false);
    // every accepted socket, keyed by connection id, so shutdown can
    // unblock handlers parked in a blocking read; each handler removes its
    // own entry on exit — otherwise the dup'd fd would hold the connection
    // established after QUIT (no FIN for the client) and leak one fd per
    // connection
    let open_conns: Mutex<std::collections::HashMap<usize, TcpStream>> =
        Mutex::new(std::collections::HashMap::new());
    let summary = std::thread::scope(|s| {
        let coordinator = {
            let engine_ref = &engine;
            let queue_ref = &queue;
            let stop_ref = &stop;
            let sm_ref = &sm;
            let ship_ref = shipper.as_ref();
            s.spawn(move || {
                engine_loop(cfg, engine_ref, queue_ref, stop_ref, dur, sm_ref, ship_ref)
            })
        };
        if let Some(listener) = &metrics_listener {
            let sm_ref = &sm;
            let stop_ref = &stop;
            s.spawn(move || metrics_http_loop(listener, sm_ref, stop_ref));
        }
        let mut conn_id = 0usize;
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    conn_id += 1;
                    let shard = conn_id;
                    match stream.try_clone() {
                        Ok(clone) => {
                            open_conns.lock().unwrap().insert(shard, clone);
                        }
                        // without a registry dup this handler could never be
                        // woken at shutdown — refuse the connection instead
                        Err(_) => continue,
                    }
                    let engine = &engine;
                    let queue = &queue;
                    let stop = &stop;
                    let sm = &sm;
                    let open_conns = &open_conns;
                    s.spawn(move || {
                        // the listener is nonblocking and some platforms
                        // (BSD/macOS) let accepted sockets inherit that —
                        // reads here must block
                        let _ = stream.set_nonblocking(false);
                        let _ = stream.set_nodelay(true);
                        let reader = match stream.try_clone() {
                            Ok(c) => BufReader::new(c),
                            Err(_) => {
                                open_conns.lock().unwrap().remove(&shard);
                                return;
                            }
                        };
                        let mut writer = stream;
                        let out =
                            handle_conn(cfg, shard, engine, queue, sm, reader, &mut writer);
                        // drop our registry dup so closing `writer` really
                        // closes the connection (FIN reaches the client)
                        open_conns.lock().unwrap().remove(&shard);
                        if out.shutdown {
                            stop.store(true, Ordering::Relaxed);
                        }
                    });
                }
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
                    ) =>
                {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => {
                    eprintln!("accept: {e}");
                    stop.store(true, Ordering::Relaxed);
                }
            }
        }
        // wake handlers blocked mid-read so the scope can actually close
        for (_, c) in open_conns.lock().unwrap().drain() {
            let _ = c.shutdown(Shutdown::Both);
        }
        queue.close();
        coordinator.join().expect("engine thread panicked")
    });
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};

    fn drive(cfg: &ServiceConfig, script: &str) -> (Vec<String>, ServiceSummary) {
        let mut out: Vec<u8> = Vec::new();
        let summary = serve_lines(cfg, script.as_bytes(), &mut out).unwrap();
        let lines = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(String::from)
            .collect();
        (lines, summary)
    }

    fn small_cfg() -> ServiceConfig {
        // threads: 1 -> deterministic matching order over the wire
        ServiceConfig { num_vertices: 16, threads: 1, ..Default::default() }
    }

    #[test]
    fn stdio_session_runs_mixed_epochs_and_stays_maximal() {
        let script = "\
INSERT 0 1 1 2 2 3\n\
EPOCH\n\
DELETE 1 2\n\
EPOCH\n\
INSERT 3 4 0 2\n\
EPOCH\n\
QUERY 0\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[0].contains(r#""op":"queued","count":3"#), "{}", lines[0]);
        assert!(lines[1].contains(r#""op":"epoch""#) && lines[1].contains(r#""new_matches":2"#),
            "{}", lines[1]);
        // with one matcher thread the stream order matches (0,1) and (2,3);
        // deleting (1,2) therefore removes an unmatched edge: no repair
        assert!(lines[3].contains(r#""destroyed_pairs":0"#), "{}", lines[3]);
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(lines.last().unwrap().contains(r#""op":"bye""#));
        assert_eq!(summary.epochs, 3);
        assert!(summary.maximal);
        assert_eq!(summary.total_inserts, 5);
        assert_eq!(summary.total_deletes, 1);
    }

    #[test]
    fn delete_of_matched_edge_reports_repair_over_the_wire() {
        // triangle + pendant: 0-1, 1-2, 2-0, 2-3
        let script = "\
INSERT 0 1 1 2 2 0 2 3\n\
EPOCH\n\
DELETE 0 1\n\
EPOCH\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        // (0,1) matches first in the single-threaded epoch; its deletion
        // must free both endpoints and re-examine their surviving edges
        // (0,2) and (1,2)
        let second_epoch = &lines[3];
        assert!(second_epoch.contains(r#""destroyed_pairs":1"#), "{second_epoch}");
        assert!(second_epoch.contains(r#""freed":2"#), "{second_epoch}");
        assert!(second_epoch.contains(r#""repair_edges":2"#), "{second_epoch}");
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(summary.maximal);
    }

    #[test]
    fn query_reflects_all_prior_updates_without_explicit_epoch() {
        let script = "INSERT 4 5\nQUERY 4\nQUERY 6\nQUIT\n";
        let (lines, _) = drive(&small_cfg(), script);
        let q4 = &lines[1];
        assert!(q4.contains(r#""matched":true"#) && q4.contains(r#""partner":5"#), "{q4}");
        // the second query takes the lock-free fast path (the connection is
        // clean after its barrier) and must still see the applied state
        assert!(lines[2].contains(r#""matched":false"#), "{}", lines[2]);
    }

    #[test]
    fn cheap_stats_skips_the_audit_and_reports_counters() {
        let script = "INSERT 0 1 2 3\nEPOCH\nSTATS\nSTATS full\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        let cheap = &lines[2];
        assert!(cheap.contains(r#""op":"stats""#), "{cheap}");
        assert!(!cheap.contains("maximal"), "cheap STATS must skip the audit: {cheap}");
        assert!(cheap.contains(r#""total_inserts":2"#), "{cheap}");
        assert!(cheap.contains(r#""engine_shards":1"#), "{cheap}");
        let full = &lines[3];
        assert!(full.contains(r#""maximal":true"#), "{full}");
        assert!(summary.maximal);
    }

    #[test]
    fn sharded_engine_serves_epochs_and_stays_maximal() {
        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 2,
            engine_shards: 4,
            ..Default::default()
        };
        let script = "\
INSERT 0 1 1 2 2 3 3 4 10 40 41 11 20 50\n\
EPOCH\n\
DELETE 1 2 10 40\n\
EPOCH\n\
INSERT 5 6 40 42\n\
EPOCH\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&cfg, script);
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(stats.contains(r#""engine_shards":4"#), "{stats}");
        assert!(summary.maximal);
        assert_eq!(summary.epochs, 3);
        assert_eq!(summary.total_inserts, 9);
        assert_eq!(summary.total_deletes, 2);
    }

    #[test]
    fn pinned_service_serves_epochs_and_stays_maximal() {
        // a pinned sharded engine behind the service must behave exactly
        // like an unpinned one (placement changes timings, not results) —
        // including on single-node hosts and hosts that refuse the pin
        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 2,
            engine_shards: 4,
            pin: crate::dynamic::PinPolicy::Compact,
            ..Default::default()
        };
        let script = "\
INSERT 0 1 1 2 2 3 3 4 10 40 41 11 20 50\n\
EPOCH\n\
DELETE 1 2 10 40\n\
EPOCH\n\
STATS full\n\
QUIT\n";
        let (lines, summary) = drive(&cfg, script);
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        assert!(summary.maximal);
        assert_eq!(summary.epochs, 2);
        // the topology gauges are published the moment a pinned pool is built
        assert!(summary.metrics_text.contains("skipper_topology_nodes"), "topology gauges missing");
        assert!(summary.metrics_text.contains("skipper_pinned_workers"), "pin gauge missing");
    }

    #[test]
    fn metrics_http_reply_frames_the_exposition() {
        let sm = ServiceMetrics::new();
        let ok = metrics_http_reply("GET /metrics HTTP/1.1", &sm);
        assert!(ok.starts_with("HTTP/1.0 200 OK\r\n"), "{ok}");
        assert!(ok.contains("Content-Type: text/plain"), "{ok}");
        let body = ok.split("\r\n\r\n").nth(1).unwrap();
        assert!(body.ends_with("# EOF\n"), "body must be a complete exposition");
        assert!(body.contains("skipper_service_inserts_total"), "{body}");
        let len: usize = ok
            .lines()
            .find(|l| l.starts_with("Content-Length:"))
            .and_then(|l| l.split(':').nth(1))
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert_eq!(len, body.len());
        // bare GET / also scrapes; everything else is a 404
        assert!(metrics_http_reply("GET / HTTP/1.0", &sm).starts_with("HTTP/1.0 200"));
        assert!(metrics_http_reply("GET /favicon.ico HTTP/1.1", &sm)
            .starts_with("HTTP/1.0 404"));
        assert!(metrics_http_reply("POST /metrics HTTP/1.1", &sm)
            .starts_with("HTTP/1.0 404"));
        assert!(metrics_http_reply("", &sm).starts_with("HTTP/1.0 404"));
    }

    #[test]
    fn metrics_http_loop_answers_a_live_scrape() {
        let sm = ServiceMetrics::new();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let sm_ref = &sm;
            let stop_ref = &stop;
            let listener_ref = &listener;
            s.spawn(move || metrics_http_loop(listener_ref, sm_ref, stop_ref));
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
            let mut response = String::new();
            conn.read_to_string(&mut response).unwrap();
            assert!(response.starts_with("HTTP/1.0 200 OK"), "{response}");
            assert!(response.contains("skipper_service_update_epochs_total"), "{response}");
            assert!(response.trim_end().ends_with("# EOF"), "{response}");
            stop.store(true, Ordering::Relaxed);
        });
    }

    #[test]
    fn stats_reports_pool_and_pipeline_modes() {
        // `pooled` reports the live fact: a standing pool exists only for
        // P > 1 under the pool policy — P = 1 always runs inline
        let sharded = ServiceConfig { engine_shards: 4, ..small_cfg() };
        let (lines, _) = drive(&sharded, "STATS\nQUIT\n");
        assert!(lines[0].contains(r#""pooled":true"#), "{}", lines[0]);
        assert!(lines[0].contains(r#""pipelined":true"#), "{}", lines[0]);
        let single = small_cfg(); // engine_shards = 1: inline despite pool=true
        let (lines, _) = drive(&single, "STATS\nQUIT\n");
        assert!(lines[0].contains(r#""pooled":false"#), "{}", lines[0]);
        let off = ServiceConfig {
            engine_shards: 4,
            pool: false,
            pipeline: false,
            ..small_cfg()
        };
        let (lines, _) = drive(&off, "STATS\nQUIT\n");
        assert!(lines[0].contains(r#""pooled":false"#), "{}", lines[0]);
        assert!(lines[0].contains(r#""pipelined":false"#), "{}", lines[0]);
    }

    #[test]
    fn every_mode_combination_serves_the_same_session() {
        // pooled/forked × pipelined/inline over a sharded engine: the wire
        // semantics (epoch boundaries, query answers, counters, audit) must
        // be mode-independent — only the timing fields may differ
        let script = "\
INSERT 0 1 1 2 2 3 3 4\n\
EPOCH\n\
DELETE 1 2 0 1\n\
EPOCH\n\
QUERY 2\n\
STATS full\n\
QUIT\n";
        let mut reference: Option<(String, ServiceSummary)> = None;
        for pool in [true, false] {
            for pipeline in [true, false] {
                let cfg = ServiceConfig {
                    num_vertices: 16,
                    threads: 1,
                    engine_shards: 4,
                    pool,
                    pipeline,
                    ..Default::default()
                };
                let (lines, summary) = drive(&cfg, script);
                let query = lines
                    .iter()
                    .find(|l| l.contains(r#""op":"query""#))
                    .unwrap()
                    .clone();
                let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
                assert!(stats.contains(r#""maximal":true"#), "pool={pool} pipe={pipeline}: {stats}");
                match &reference {
                    None => reference = Some((query, summary)),
                    Some((q0, s0)) => {
                        assert_eq!(&query, q0, "pool={pool} pipe={pipeline}");
                        assert_eq!(summary.epochs, s0.epochs, "pool={pool} pipe={pipeline}");
                        assert_eq!(
                            summary.total_inserts, s0.total_inserts,
                            "pool={pool} pipe={pipeline}"
                        );
                        assert_eq!(
                            summary.total_deletes, s0.total_deletes,
                            "pool={pool} pipe={pipeline}"
                        );
                        assert_eq!(
                            summary.live_edges, s0.live_edges,
                            "pool={pool} pipe={pipeline}"
                        );
                        assert!(summary.maximal, "pool={pool} pipe={pipeline}");
                    }
                }
            }
        }
    }

    #[test]
    fn pipelined_epochs_report_route_timings() {
        // the EPOCH reply must carry the router's route time; overlap may
        // legitimately be zero in a lock-step stdio session, but the field
        // must be present and sane
        let script = "INSERT 0 1 2 3 4 5\nEPOCH\nQUIT\n";
        let (lines, _) = drive(&small_cfg(), script);
        let epoch = lines.iter().find(|l| l.contains(r#""op":"epoch""#)).unwrap();
        assert!(epoch.contains(r#""route_ms":"#), "{epoch}");
        assert!(epoch.contains(r#""route_overlap_ms":"#), "{epoch}");
        assert!(epoch.contains(r#""mutate_run_ms":"#), "{epoch}");
        assert!(epoch.contains(r#""spawn_overhead_ms":"#), "{epoch}");
    }

    #[test]
    fn malformed_and_out_of_range_lines_get_errors_not_death() {
        let script = "FROB\nINSERT 1\nINSERT 0 99\nQUERY 99\nINSERT 0 1\nQUERY 0\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[0].contains(r#""ok":false"#));
        assert!(lines[1].contains("even"));
        assert!(lines[2].contains("out of range"));
        assert!(lines[3].contains("out of range"));
        assert!(lines[4].contains(r#""op":"queued""#));
        assert!(lines[5].contains(r#""matched":true"#), "{}", lines[5]);
        assert!(summary.maximal);
    }

    #[test]
    fn eof_without_quit_flushes_pending_updates() {
        let (_, summary) = drive(&small_cfg(), "INSERT 0 1 2 3\n");
        assert_eq!(summary.total_inserts, 2);
        assert_eq!(summary.matched_vertices, 4);
        assert!(summary.maximal);
        assert!(summary.epochs >= 1);
    }

    #[test]
    fn snapshot_without_data_dir_is_an_error_not_a_crash() {
        let script = "INSERT 0 1\nSNAPSHOT\nQUERY 0\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[1].contains(r#""ok":false"#), "{}", lines[1]);
        assert!(lines[1].contains("--data-dir"), "{}", lines[1]);
        // the SNAPSHOT barrier still flushed the insert (read-your-writes
        // held even through the error reply)
        assert!(lines[2].contains(r#""matched":true"#), "{}", lines[2]);
        assert!(summary.maximal);
        assert_eq!(summary.last_snapshot_epoch, 0);
        assert_eq!(summary.wal_epochs, 0);
    }

    #[test]
    fn metrics_scrape_is_valid_prometheus_with_service_counters() {
        let data_dir = fresh_data_dir("metrics");
        let cfg = ServiceConfig {
            num_vertices: 16,
            threads: 1,
            data_dir: Some(data_dir),
            ..Default::default()
        };
        let script = "INSERT 0 1 2 3 4 5\nEPOCH\nDELETE 0 1\nEPOCH\nMETRICS\nQUIT\n";
        let (lines, _) = drive(&cfg, script);
        // the METRICS reply is the one multi-line response: everything from
        // the first exposition line through the `# EOF` framing marker
        let start = lines.iter().position(|l| l.starts_with("# HELP")).unwrap();
        let end = lines.iter().position(|l| l == "# EOF").unwrap();
        assert!(start < end, "exposition before its EOF");
        let text = lines[start..=end].join("\n") + "\n";
        crate::obs::metrics::validate_prometheus(&text).unwrap();
        // service counters come from the same atomics STATS reads
        assert!(lines.contains(&"skipper_service_inserts_total 3".to_string()), "{text}");
        assert!(lines.contains(&"skipper_service_deletes_total 1".to_string()), "{text}");
        // full-history latency histogram: 2 batches → _count 2 plus buckets
        assert!(lines.contains(&"skipper_batch_latency_seconds_count 2".to_string()), "{text}");
        assert!(
            lines.iter().any(|l| l.starts_with("skipper_batch_latency_seconds_bucket{le=\"")),
            "{text}"
        );
        // lock-step barriers flush one generation at a time, so every WAL
        // append is a group of one — two epochs, two singleton groups
        assert!(lines.contains(&"skipper_wal_groups_total 2".to_string()), "{text}");
        assert!(lines.contains(&"skipper_wal_group_epochs_total 2".to_string()), "{text}");
    }

    #[test]
    fn trace_reply_is_one_wellformed_chrome_trace_line() {
        // tracing stays at its default (off) — the reply must still be a
        // complete, loadable trace document, just with no events; flipping
        // the global trace gate here would race the obs unit tests
        let script = "INSERT 0 1\nEPOCH\nTRACE\nTRACE 2\nQUIT\n";
        let (lines, _) = drive(&small_cfg(), script);
        for trace_line in lines.iter().filter(|l| l.contains(r#""op":"trace""#)) {
            assert!(trace_line.contains(r#""ok":true"#), "{trace_line}");
            crate::obs::trace::validate_chrome_trace(trace_line).unwrap();
        }
        assert_eq!(
            lines.iter().filter(|l| l.contains(r#""op":"trace""#)).count(),
            2,
            "{lines:?}"
        );
    }

    #[test]
    fn stats_counters_are_identical_across_pipeline_modes() {
        // the registry-backed STATS must report exactly what the old
        // struct-field telemetry did: lock-step sessions are deterministic,
        // so every counter field must be identical with the flusher thread
        // on and off (only the timing fields may differ)
        let script = "\
INSERT 0 1 1 2 2 3\n\
EPOCH\n\
DELETE 1 2\n\
EPOCH\n\
STATS\n\
QUIT\n";
        for pipeline in [true, false] {
            let cfg = ServiceConfig { pipeline, ..small_cfg() };
            let (lines, summary) = drive(&cfg, script);
            let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
            for field in [
                r#""epochs":2"#,
                r#""total_inserts":3"#,
                r#""total_deletes":1"#,
                r#""total_repair_edges":0"#,
                r#""live_edges":2"#,
            ] {
                assert!(stats.contains(field), "pipeline={pipeline}: missing {field}: {stats}");
            }
            assert_eq!(summary.total_inserts, 3, "pipeline={pipeline}");
            assert_eq!(summary.total_deletes, 1, "pipeline={pipeline}");
            // percentiles come from the full-history histogram now; two
            // batches were recorded, so they are positive and ordered
            let doc = crate::util::json::parse(stats).unwrap();
            let p = |k: &str| doc.get(k).and_then(|v| v.as_f64()).unwrap();
            let (p50, p99, p999) =
                (p("p50_batch_ms"), p("p99_batch_ms"), p("p999_batch_ms"));
            assert!(p50 > 0.0, "pipeline={pipeline}: {stats}");
            assert!(p50 <= p99 && p99 <= p999, "pipeline={pipeline}: {stats}");
        }
    }

    #[test]
    fn flusher_groups_queued_wal_epochs_into_one_append() {
        // drive the flush executor directly: two generations queued behind
        // one another (as when the router outruns a slow epoch) must be
        // WAL-logged as ONE append group covering two epoch records, and
        // both records must replay on the next boot
        let data_dir = fresh_data_dir("wal_group");
        let cfg = ServiceConfig {
            num_vertices: 32,
            threads: 1,
            data_dir: Some(data_dir),
            ..Default::default()
        };
        let engine = ShardedDynamicMatcher::with_exec(
            cfg.num_vertices,
            cfg.threads,
            cfg.engine_shards,
            cfg.shard_exec(),
        );
        let sm = ServiceMetrics::new();
        let flushing = AtomicBool::new(false);
        let spares: BoundedQueue<ShardMailboxes> = BoundedQueue::new(MAILBOX_GENERATIONS);
        let dur = open_durability(&cfg, &engine).unwrap();
        let mut ex = FlushExec::new(&cfg, &engine, &flushing, &spares, dur, &sm, None);
        let make_gen = |updates: &[Update]| -> PendingGen {
            let mut gen = PendingGen::new(engine.mailboxes());
            engine.route_into(updates, &mut gen.mailboxes).unwrap();
            gen.stamps.push(Instant::now());
            gen.wal_log.extend_from_slice(updates);
            gen
        };
        let g1 = make_gen(&[Update::Insert(0, 1), Update::Insert(2, 3)]);
        let g2 = make_gen(&[Update::Insert(4, 5)]);
        let mut group = vec![FlushJob::Apply(g1), FlushJob::Apply(g2)];
        ex.handle_group(&mut group);
        assert_eq!(engine.epochs_applied(), 2);
        assert_eq!(sm.wal_groups.get(), 1, "one durable group for the burst");
        assert_eq!(sm.wal_group_epochs.get(), 2, "covering both epochs");
        assert_eq!(sm.batch_latency.count(), 2, "one stamp per generation");
        // drop without the graceful shutdown snapshot: the next boot can
        // only restore this state by replaying the grouped WAL records
        drop(ex);
        let (lines, summary) = drive(&cfg, "STATS\nQUERY 4\nQUIT\n");
        let stats = &lines[0];
        assert!(stats.contains(r#""recovery_replayed":2"#), "{stats}");
        assert!(lines[1].contains(r#""partner":5"#), "{}", lines[1]);
        assert_eq!(summary.epochs, 2);
        assert!(summary.maximal);
    }

    #[test]
    fn crash_without_debug_commands_is_rejected() {
        let script = "CRASH\nCRASH flusher\nINSERT 0 1\nEPOCH\nQUIT\n";
        let (lines, summary) = drive(&small_cfg(), script);
        assert!(lines[0].contains("--debug-commands"), "{}", lines[0]);
        assert!(lines[1].contains("--debug-commands"), "{}", lines[1]);
        assert!(lines[3].contains(r#""op":"epoch""#), "{}", lines[3]);
        assert!(summary.maximal);
    }

    fn fresh_data_dir(tag: &str) -> String {
        use std::sync::atomic::AtomicU64;
        static DIR_ID: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "skipper_serve_{}_{}_{}",
            std::process::id(),
            tag,
            DIR_ID.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn durable_session_logs_snapshots_and_restarts_clean() {
        let data_dir = fresh_data_dir("durable");
        let cfg = ServiceConfig {
            num_vertices: 32,
            threads: 1,
            engine_shards: 2,
            data_dir: Some(data_dir.clone()),
            ..Default::default()
        };
        // session 1: two epochs, an explicit SNAPSHOT, then EOF (graceful)
        let script = "\
INSERT 0 1 1 2 2 3\n\
EPOCH\n\
SNAPSHOT\n\
DELETE 1 2\n\
EPOCH\n\
STATS\n\
QUIT\n";
        let (lines, summary) = drive(&cfg, script);
        let snap = lines.iter().find(|l| l.contains(r#""op":"snapshot""#)).unwrap();
        assert!(snap.contains(r#""epoch":1"#), "{snap}");
        assert!(snap.contains(r#""accepted":true"#), "{snap}");
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""durable":true"#), "{stats}");
        assert!(stats.contains(r#""wal_epochs":2"#), "{stats}");
        assert!(stats.contains(r#""recovery_replayed":0"#), "{stats}");
        assert_eq!(summary.epochs, 2);
        assert_eq!(summary.wal_epochs, 2);
        assert_eq!(summary.last_snapshot_epoch, 2, "final snapshot at shutdown");
        assert_eq!(summary.recovery_replayed, 0);

        // session 2: a clean restart recovers from the final snapshot alone
        // — zero WAL replay — and the state is intact
        let (lines, summary) = drive(&cfg, "STATS full\nQUERY 0\nQUIT\n");
        let stats = &lines[0];
        assert!(stats.contains(r#""epochs":2"#), "epoch timeline resumes: {stats}");
        assert!(stats.contains(r#""live_edges":2"#), "{stats}");
        assert!(stats.contains(r#""recovery_replayed":0"#), "{stats}");
        assert!(stats.contains(r#""last_snapshot_epoch":2"#), "{stats}");
        assert!(stats.contains(r#""maximal":true"#), "{stats}");
        // with threads=1 the first epoch matched (0,1) and (2,3); deleting
        // the unmatched (1,2) left the matching intact, and the restore
        // path reproduces it exactly
        assert!(lines[1].contains(r#""partner":1"#), "{}", lines[1]);
        assert_eq!(summary.epochs, 2);
        assert!(summary.maximal);
    }

    #[test]
    fn wal_off_durable_service_still_snapshots_at_shutdown() {
        let data_dir = fresh_data_dir("no_wal");
        let cfg = ServiceConfig {
            num_vertices: 16,
            threads: 1,
            data_dir: Some(data_dir.clone()),
            wal: false,
            ..Default::default()
        };
        let (lines, summary) = drive(&cfg, "INSERT 0 1\nEPOCH\nSTATS\nQUIT\n");
        let stats = lines.iter().find(|l| l.contains(r#""op":"stats""#)).unwrap();
        assert!(stats.contains(r#""durable":true"#), "{stats}");
        assert!(stats.contains(r#""wal_epochs":0"#), "no logging: {stats}");
        assert_eq!(summary.last_snapshot_epoch, 1);
        // restart: the shutdown snapshot alone carries the state
        let (lines, _) = drive(&cfg, "QUERY 0\nQUIT\n");
        assert!(lines[0].contains(r#""matched":true"#), "{}", lines[0]);
    }

    #[test]
    fn tcp_serves_concurrent_clients_and_shuts_down() {
        // sandboxes without loopback can't exercise the TCP front-end; the
        // stdio tests above cover everything but the socket plumbing
        if std::net::TcpListener::bind("127.0.0.1:0").is_err() {
            eprintln!("skipping TCP test: loopback unavailable");
            return;
        }
        let cfg = ServiceConfig {
            num_vertices: 64,
            threads: 2,
            engine_shards: 2,
            ..Default::default()
        };
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            serve_tcp(&cfg, "127.0.0.1:0", move |a| addr_tx.send(a).unwrap()).unwrap()
        });
        let addr = addr_rx.recv().unwrap();

        let ask = |script: &str| -> Vec<String> {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(script.as_bytes()).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            buf.lines().map(String::from).collect()
        };

        // two sequential clients mutating the same engine
        let a = ask("INSERT 0 1 2 3\nEPOCH\nQUIT\n");
        assert!(a[1].contains(r#""new_matches":2"#), "{:?}", a);
        let b = ask("DELETE 0 1\nEPOCH\nQUERY 0\nSTATS full\nQUIT\n");
        assert!(b[1].contains(r#""destroyed_pairs":1"#), "{:?}", b);
        assert!(b[2].contains(r#""matched":false"#), "{:?}", b);
        assert!(b[3].contains(r#""maximal":true"#), "{:?}", b);

        // a swarm of parallel clients, then shutdown
        let mut clients = Vec::new();
        for i in 0..4u32 {
            let addr = addr;
            clients.push(std::thread::spawn(move || {
                let base = 8 * (i + 1);
                let mut s = std::net::TcpStream::connect(addr).unwrap();
                let script =
                    format!("INSERT {} {} {} {}\nEPOCH\nQUIT\n", base, base + 1, base + 2, base + 3);
                s.write_all(script.as_bytes()).unwrap();
                s.shutdown(std::net::Shutdown::Write).unwrap();
                let mut buf = String::new();
                s.read_to_string(&mut buf).unwrap();
                assert!(buf.contains(r#""op":"epoch""#), "{buf}");
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let bye = ask("SHUTDOWN\n");
        assert!(bye[0].contains(r#""op":"shutdown""#), "{:?}", bye);
        let summary = server.join().unwrap();
        assert!(summary.maximal);
        assert_eq!(summary.total_inserts, 2 + 16);
        assert_eq!(summary.total_deletes, 1);
    }
}
