//! Warm-standby follower: replay a primary's shipped WAL stream through a
//! real engine, serve reads, survive the primary's death, take over on
//! `PROMOTE`.
//!
//! A follower is a full engine plus (optionally) its own durability bundle
//! — not a passive log sink. Every frame the [`ShipReader`] delivers is
//! handled exactly like the primary's flusher handles a committed epoch:
//! append to the local WAL first (when a `--data-dir` is configured), then
//! apply through [`ShardedDynamicMatcher::apply_epoch`], then ack. The
//! engine is deterministic for a fixed config, so a follower built with the
//! same shard count as its primary converges to bit-identical `partner[]`
//! state — `QUERY` answers on the standby equal the primary's at quiesce.
//!
//! ## Failover invariant
//!
//! Frames carry contiguous epochs and the follower enforces the same
//! epoch-contiguity invariant recovery does (a gap is a loud error, never
//! silently skipped), so "the follower with the longest contiguous log" is
//! simply the one with the highest applied epoch. [`Replica::promote`]
//! flips the standby to a writable primary: the replay loop is aborted,
//! post-promotion epochs append to the follower's own WAL and apply under
//! the same serialization lock the replay path used, resuming the epoch
//! sequence exactly where the stream stopped — zero acked epochs lost.
//!
//! ## Lag accounting
//!
//! Each frame carries the primary's tip epoch at send time;
//! `tip - applied` is the follower's instantaneous lag, exported as the
//! `skipper_replica_lag_epochs` gauge (the primary exports the same gauge
//! from its side: tip minus its slowest live follower's ack).

use super::protocol::{Command, ReplicaRole, ReplicaStats, Response, StatsSnapshot};
use super::server::{open_durability, ServiceConfig};
use crate::dynamic::ShardedDynamicMatcher;
use crate::dynamic::Update;
use crate::obs::{metrics, trace};
use crate::persist::ship::{ShipAbort, ShipReader};
use crate::persist::snapshot::SnapshotData;
use crate::persist::DurableService;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// What a follower front-end reports when it returns.
#[derive(Clone, Debug)]
pub struct ReplicaSummary {
    /// Engine epochs applied (replayed from the stream, plus any written
    /// after promotion).
    pub epochs: u64,
    /// Live undirected edges at exit.
    pub live_edges: u64,
    /// Matched vertices at exit.
    pub matched_vertices: usize,
    /// Final maximality audit verdict over the live set.
    pub maximal: bool,
    /// True when this follower was promoted during the session.
    pub promoted: bool,
    /// Epoch of the final snapshot (0 when volatile).
    pub last_snapshot_epoch: u64,
}

/// Repair-fraction bookkeeping for `STATS` (mirrors the primary's fields;
/// a plain mutex — updated once per applied epoch, read on demand).
#[derive(Default)]
struct RepairFracs {
    last: f64,
    sum: f64,
    epochs: u64,
}

/// A warm standby: an engine fed by a replication stream, promotable to a
/// writable primary. Shareable across threads (`&Replica` is all any front
/// end or the replay loop needs).
pub struct Replica {
    engine: ShardedDynamicMatcher,
    /// The follower's own durability bundle (`--data-dir`): shipped epochs
    /// are WAL-logged before apply, snapshots run on the configured
    /// cadence, and a restart recovers then resumes the stream from its
    /// recovered epoch.
    dur: Mutex<Option<DurableService>>,
    /// The connected stream, consumed by [`replay_loop`](Self::replay_loop).
    reader: Mutex<Option<ShipReader>>,
    /// Closes the stream socket from another thread (promotion/shutdown).
    abort: Mutex<Option<ShipAbort>>,
    /// Serializes epoch applies: stream replay vs post-promotion writes.
    apply_lock: Mutex<()>,
    promoted: AtomicBool,
    /// True from connect until the replay loop exits (EOF, error, abort).
    replaying: AtomicBool,
    /// First replay error (CRC mismatch, gapped history, apply failure).
    replay_error: Mutex<Option<String>>,
    /// The primary's tip epoch from the most recent frame.
    tip_seen: AtomicU64,
    /// The primary's replication horizon from the handshake.
    base_epoch: u64,
    registry: metrics::Registry,
    lag_gauge: std::sync::Arc<metrics::Gauge>,
    applied_counter: std::sync::Arc<metrics::Counter>,
    inserts: std::sync::Arc<metrics::Counter>,
    deletes: std::sync::Arc<metrics::Counter>,
    repair_edges: std::sync::Arc<metrics::Counter>,
    apply_hist: std::sync::Arc<metrics::Histogram>,
    fracs: Mutex<RepairFracs>,
}

impl Replica {
    /// Build the follower engine (recovering from `cfg.data_dir` when set),
    /// connect to the primary's replication listener at `primary`, and
    /// handshake. The stream resumes right after the recovered epoch; a
    /// follower that is behind the primary's replication horizon is
    /// refused at connect (re-seed it from a data-dir copy).
    pub fn new(cfg: &ServiceConfig, primary: &str) -> Result<Replica, String> {
        let engine = ShardedDynamicMatcher::with_exec_layout_pin(
            cfg.num_vertices,
            cfg.threads,
            cfg.engine_shards,
            cfg.shard_exec(),
            crate::dynamic::AdjLayout::default(),
            cfg.pin,
        );
        let dur = open_durability(cfg, &engine)?;
        let reader = ShipReader::connect(primary, engine.epochs_applied())?;
        if reader.num_vertices as usize != cfg.num_vertices {
            return Err(format!(
                "follow {primary}: primary serves |V|={} but this follower was started \
                 with --vertices {} — the universes must match",
                reader.num_vertices, cfg.num_vertices
            ));
        }
        let abort = reader.abort_handle()?;
        let base_epoch = reader.base_epoch;
        eprintln!(
            "follow: replicating from {primary} starting after epoch {} (horizon {})",
            engine.epochs_applied(),
            base_epoch
        );
        let registry = metrics::Registry::new();
        let lag_gauge = registry.gauge(
            "skipper_replica_lag_epochs",
            "Primary tip epochs not yet applied by this follower",
        );
        let applied_counter = registry.counter(
            "skipper_replica_epochs_applied_total",
            "Epochs replayed from the replication stream since connect",
        );
        let inserts = registry.counter(
            "skipper_service_inserts_total",
            "Insert updates received over the service lifetime",
        );
        let deletes = registry.counter(
            "skipper_service_deletes_total",
            "Delete updates received over the service lifetime",
        );
        let repair_edges = registry.counter(
            "skipper_service_repair_edges_total",
            "Edges re-examined by repair sweeps over the service lifetime",
        );
        let apply_hist = registry.histogram_secs(
            "skipper_replica_apply_seconds",
            "Wall time applying one replicated epoch through the engine",
        );
        Ok(Replica {
            engine,
            dur: Mutex::new(dur),
            reader: Mutex::new(Some(reader)),
            abort: Mutex::new(Some(abort)),
            apply_lock: Mutex::new(()),
            promoted: AtomicBool::new(false),
            replaying: AtomicBool::new(true),
            replay_error: Mutex::new(None),
            tip_seen: AtomicU64::new(0),
            base_epoch,
            registry,
            lag_gauge,
            applied_counter,
            inserts,
            deletes,
            repair_edges,
            apply_hist,
            fracs: Mutex::new(RepairFracs::default()),
        })
    }

    /// Consume the replication stream until it ends (primary death or
    /// shutdown: clean, the follower keeps serving), a malformed or gapped
    /// frame arrives (loud error, replay stops), or [`promote`] aborts it.
    /// Run this on its own thread; every other method works concurrently.
    pub fn replay_loop(&self) {
        let mut reader = match self.reader.lock().unwrap().take() {
            Some(r) => r,
            None => {
                self.replaying.store(false, Ordering::Release);
                return;
            }
        };
        loop {
            match reader.next_frame() {
                Ok(Some(frame)) => {
                    self.tip_seen.store(frame.tip, Ordering::Release);
                    let applied = match self.apply_frame(frame.rec.epoch, &frame.rec.updates) {
                        Ok(applied) => applied,
                        Err(e) => {
                            eprintln!("follow: replay stopped: {e}");
                            *self.replay_error.lock().unwrap() = Some(e);
                            break;
                        }
                    };
                    if !applied {
                        break; // promoted under us — stop consuming
                    }
                    // ack failures are non-fatal: a dead primary can no
                    // longer hear us, but the applied state is exactly what
                    // promotion needs
                    let _ = reader.ack(frame.rec.epoch);
                }
                Ok(None) => {
                    eprintln!(
                        "follow: stream ended at epoch {} — standing by for promotion",
                        self.engine.epochs_applied()
                    );
                    break;
                }
                Err(e) => {
                    eprintln!("follow: replay stopped: {e}");
                    *self.replay_error.lock().unwrap() = Some(e);
                    break;
                }
            }
        }
        self.replaying.store(false, Ordering::Release);
    }

    /// WAL-log (when durable) and apply one shipped epoch. Returns
    /// `Ok(false)` when the replica was promoted before the apply could
    /// run — the frame is discarded, replay must stop.
    fn apply_frame(&self, epoch: u64, updates: &[Update]) -> Result<bool, String> {
        let _guard = self.apply_lock.lock().unwrap();
        if self.promoted.load(Ordering::Acquire) {
            return Ok(false);
        }
        let expect = self.engine.epochs_applied() + 1;
        if epoch != expect {
            return Err(format!(
                "replication stream gapped history: got epoch {epoch}, expected {expect}"
            ));
        }
        // WAL before apply — the same invariant the primary honors
        let mut dur = self.dur.lock().unwrap();
        if let Some(d) = dur.as_mut() {
            let _sp = trace::span_epoch("replica_wal", "replica", epoch, updates.len() as u64);
            d.log_epoch(epoch, updates)?;
        }
        let report = {
            let _sp = trace::span_epoch("replica_apply", "replica", epoch, updates.len() as u64);
            let t0 = Instant::now();
            let report = self.engine.apply_epoch(updates)?;
            self.apply_hist.record_duration(t0.elapsed());
            report
        };
        debug_assert_eq!(report.epoch, epoch);
        if let Some(d) = dur.as_mut() {
            d.after_epoch(&self.engine);
        }
        drop(dur);
        self.applied_counter.inc();
        self.inserts.add(report.inserts as u64);
        self.deletes.add(report.deletes as u64);
        self.repair_edges.add(report.repair_edges as u64);
        {
            let mut f = self.fracs.lock().unwrap();
            f.last = report.repair_fraction();
            f.sum += report.repair_fraction();
            f.epochs += 1;
        }
        let tip = self.tip_seen.load(Ordering::Acquire);
        self.lag_gauge.set(tip.saturating_sub(epoch));
        Ok(true)
    }

    /// Highest contiguous epoch applied locally.
    pub fn applied_epoch(&self) -> u64 {
        self.engine.epochs_applied()
    }

    /// True until the replay loop exits (stream EOF, error, or abort).
    pub fn replaying(&self) -> bool {
        self.replaying.load(Ordering::Acquire)
    }

    /// The replay loop's terminal error, if it stopped on one.
    pub fn replay_error(&self) -> Option<String> {
        self.replay_error.lock().unwrap().clone()
    }

    /// Poll until at least `epoch` is applied, or `timeout` elapses.
    /// Returns whether the target was reached — test and quiesce helper.
    pub fn wait_applied(&self, epoch: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            if self.engine.epochs_applied() >= epoch {
                return true;
            }
            if Instant::now() >= deadline || !self.replaying() {
                return self.engine.epochs_applied() >= epoch;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Promote this standby to a writable primary. Taken under the apply
    /// lock, so an epoch mid-apply completes first; the replay loop is then
    /// aborted and discards anything further. Returns the epoch the
    /// promoted node resumes writing from. Idempotent.
    pub fn promote(&self) -> u64 {
        let _sp = trace::span("promote", "replica", self.engine.epochs_applied());
        {
            let _guard = self.apply_lock.lock().unwrap();
            self.promoted.store(true, Ordering::Release);
        }
        self.disconnect();
        // wait (bounded) for the replay loop to drain, so the returned
        // epoch is final — it exits promptly: blocked reads were aborted,
        // and the promoted flag stops any frame already in hand
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.replaying() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let epoch = self.engine.epochs_applied();
        self.lag_gauge.set(0);
        eprintln!("follow: promoted to primary at epoch {epoch}");
        epoch
    }

    /// True once [`promote`](Self::promote) has run.
    pub fn is_promoted(&self) -> bool {
        self.promoted.load(Ordering::Acquire)
    }

    /// Close the replication socket, unblocking the replay loop. Safe to
    /// call repeatedly; used by promotion and front-end wind-down.
    pub fn disconnect(&self) {
        if let Some(a) = self.abort.lock().unwrap().take() {
            a.abort();
        }
    }

    /// Write one epoch on a **promoted** replica: WAL-log locally, apply,
    /// snapshot on cadence — the promoted node is now the system of record.
    pub fn apply_updates(
        &self,
        updates: &[Update],
    ) -> Result<crate::dynamic::EpochReport, String> {
        if !self.is_promoted() {
            return Err("this follower is read-only until PROMOTE".into());
        }
        let _guard = self.apply_lock.lock().unwrap();
        let epoch = self.engine.epochs_applied() + 1;
        let mut dur = self.dur.lock().unwrap();
        if let Some(d) = dur.as_mut() {
            if !updates.is_empty() {
                d.log_epoch(epoch, updates)?;
            }
        }
        let report = self.engine.apply_epoch(updates)?;
        if let Some(d) = dur.as_mut() {
            d.after_epoch(&self.engine);
        }
        drop(dur);
        self.inserts.add(report.inserts as u64);
        self.deletes.add(report.deletes as u64);
        self.repair_edges.add(report.repair_edges as u64);
        {
            let mut f = self.fracs.lock().unwrap();
            f.last = report.repair_fraction();
            f.sum += report.repair_fraction();
            f.epochs += 1;
        }
        Ok(report)
    }

    /// Lock-free partner lookup from the engine's atomic `partner[]`.
    pub fn partner(&self, v: crate::VertexId) -> Option<crate::VertexId> {
        self.engine.partner(v)
    }

    /// Run the full O(|V|+|E_live|) maximality audit.
    pub fn verify(&self) -> Result<(), String> {
        self.engine.verify()
    }

    /// The engine under replication — read-only access for tests and
    /// stats; all mutation goes through the replay loop or
    /// [`apply_updates`](Self::apply_updates).
    pub fn engine(&self) -> &ShardedDynamicMatcher {
        &self.engine
    }

    /// Build the `STATS` snapshot for this replica (role `follower` or
    /// `promoted`). On a follower, `replica_lag_bytes` is reported as 0:
    /// byte-accurate lag needs the primary's backlog sizes, which only the
    /// primary has — its own `STATS` reports both.
    fn stats_snapshot(&self, audit: bool) -> StatsSnapshot {
        let (durable, wal_epochs, wal_bytes, last_snapshot_epoch, recovery_replayed) =
            match self.dur.lock().unwrap().as_ref() {
                Some(d) => {
                    let c = d.counters();
                    (
                        true,
                        c.wal_epochs.load(Ordering::Relaxed),
                        c.wal_bytes.load(Ordering::Relaxed),
                        c.last_snapshot_epoch.load(Ordering::Relaxed),
                        c.recovery_replayed.load(Ordering::Relaxed),
                    )
                }
                None => (false, 0, 0, 0, 0),
            };
        let fracs = {
            let f = self.fracs.lock().unwrap();
            (f.last, if f.epochs > 0 { f.sum / f.epochs as f64 } else { 0.0 })
        };
        let applied = self.engine.epochs_applied();
        let promoted = self.is_promoted();
        let tip = if promoted {
            applied
        } else {
            // before the first frame arrives the tip is unknown; report
            // the applied epoch (lag 0) rather than a bogus negative
            self.tip_seen.load(Ordering::Acquire).max(applied)
        };
        let pct = |p: f64| self.apply_hist.percentile(p) as f64 * 1e-6;
        StatsSnapshot {
            epochs: applied,
            live_edges: self.engine.num_live_edges(),
            matched_vertices: self.engine.matched_vertices(),
            total_inserts: self.inserts.get(),
            total_deletes: self.deletes.get(),
            total_repair_edges: self.repair_edges.get(),
            repair_frac_last: fracs.0,
            repair_frac_mean: fracs.1,
            p50_batch_ms: pct(50.0),
            p99_batch_ms: pct(99.0),
            p999_batch_ms: pct(99.9),
            maximal: audit.then(|| self.engine.verify().is_ok()),
            adjacency_bytes: self.engine.adjacency_bytes(),
            engine_shards: self.engine.num_shards(),
            pooled: self.engine.pooled(),
            pipelined: false,
            route_s: 0.0,
            route_overlap_s: 0.0,
            durable,
            wal_epochs,
            wal_bytes,
            last_snapshot_epoch,
            recovery_replayed,
            replica: Some(ReplicaStats {
                role: if promoted { ReplicaRole::Promoted } else { ReplicaRole::Follower },
                followers: 0,
                tip_epoch: tip,
                acked_epoch: applied,
                lag_epochs: tip.saturating_sub(applied),
                lag_bytes: 0,
            }),
        }
    }

    /// The follower's `METRICS` exposition: the process-global registry
    /// followed by this replica's instruments, one `# EOF`.
    fn render_metrics(&self) -> String {
        let mut text = metrics::global().render_prometheus();
        let eof = "# EOF\n";
        debug_assert!(text.ends_with(eof));
        text.truncate(text.len() - eof.len());
        text.push_str(&self.registry.render_prometheus());
        text
    }

    /// Graceful wind-down: stop replaying, write a final snapshot when
    /// durable, and report the terminal state.
    fn finish(&self) -> ReplicaSummary {
        self.disconnect();
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.replaying() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let last_snapshot_epoch = match self.dur.lock().unwrap().take() {
            Some(d) => d.shutdown(&self.engine),
            None => 0,
        };
        ReplicaSummary {
            epochs: self.engine.epochs_applied(),
            live_edges: self.engine.num_live_edges(),
            matched_vertices: self.engine.matched_vertices(),
            maximal: self.engine.verify().is_ok(),
            promoted: self.is_promoted(),
            last_snapshot_epoch,
        }
    }
}

/// Serve one client over a line stream while the replica replays its
/// primary in the background — `skipper-cli serve --follow` on a stdin
/// pipe, and the CI failover smoke. Returns at stream end or
/// `QUIT`/`SHUTDOWN`; a durable follower writes a final snapshot before
/// returning.
pub fn serve_follower_lines<R: BufRead, W: Write>(
    cfg: &ServiceConfig,
    primary: &str,
    reader: R,
    writer: &mut W,
) -> Result<ReplicaSummary, String> {
    let replica = Replica::new(cfg, primary)?;
    std::thread::scope(|s| {
        s.spawn(|| replica.replay_loop());
        follower_conn(cfg, &replica, reader, writer);
        replica.disconnect();
    });
    Ok(replica.finish())
}

/// Serve concurrent clients over TCP while replaying the primary. Binds
/// `addr` (port 0 = ephemeral), invokes `on_ready` with the bound address,
/// runs until a client sends `SHUTDOWN`.
pub fn serve_follower_tcp(
    cfg: &ServiceConfig,
    primary: &str,
    addr: &str,
    on_ready: impl FnOnce(SocketAddr),
) -> Result<ReplicaSummary, String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("nonblocking: {e}"))?;
    let local = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
    on_ready(local);
    let replica = Replica::new(cfg, primary)?;
    let stop = AtomicBool::new(false);
    // accepted sockets, so SHUTDOWN can unblock handlers parked in a read
    let open_conns: Mutex<std::collections::HashMap<usize, TcpStream>> =
        Mutex::new(std::collections::HashMap::new());
    std::thread::scope(|s| {
        s.spawn(|| replica.replay_loop());
        let mut conn_id = 0usize;
        while !stop.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    conn_id += 1;
                    let id = conn_id;
                    match stream.try_clone() {
                        Ok(clone) => {
                            open_conns.lock().unwrap().insert(id, clone);
                        }
                        Err(_) => continue,
                    }
                    let replica = &replica;
                    let stop = &stop;
                    let open_conns = &open_conns;
                    s.spawn(move || {
                        let _ = stream.set_nonblocking(false);
                        let _ = stream.set_nodelay(true);
                        let reader = match stream.try_clone() {
                            Ok(c) => BufReader::new(c),
                            Err(_) => {
                                open_conns.lock().unwrap().remove(&id);
                                return;
                            }
                        };
                        let mut out = stream;
                        let outcome = follower_conn(cfg, replica, reader, &mut out);
                        if outcome {
                            stop.store(true, Ordering::Release);
                            // wake every parked handler so the scope can join
                            for c in open_conns.lock().unwrap().values() {
                                let _ = c.shutdown(Shutdown::Both);
                            }
                        }
                        open_conns.lock().unwrap().remove(&id);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => {
                    eprintln!("follow accept: {e}");
                    break;
                }
            }
        }
        replica.disconnect();
    });
    Ok(replica.finish())
}

/// Serve one follower connection: reads are answered from the replica's
/// engine, writes are rejected until promotion and buffered per-connection
/// after it (same enqueue-then-`EPOCH` shape as the primary protocol).
/// Returns true when the client asked for `SHUTDOWN`.
fn follower_conn<R: BufRead, W: Write>(
    cfg: &ServiceConfig,
    replica: &Replica,
    mut reader: R,
    writer: &mut W,
) -> bool {
    let mut reply = |writer: &mut W, resp: &Response| -> bool {
        writeln!(writer, "{}", resp.render()).and_then(|_| writer.flush()).is_ok()
    };
    // updates enqueued on this connection since the last EPOCH (only ever
    // non-empty after promotion)
    let mut pending: Vec<Update> = Vec::new();
    let mut shutdown = false;
    let mut raw: Vec<u8> = Vec::new();
    loop {
        raw.clear();
        match reader.read_until(b'\n', &mut raw) {
            Ok(0) => break,
            Ok(_) => {}
            Err(_) => break,
        }
        // same byte-tolerant framing as the primary: invalid UTF-8 yields
        // one structured error, never a dropped connection
        let line = String::from_utf8_lossy(&raw);
        let cmd = match Command::parse(&line) {
            Ok(Some(cmd)) => cmd,
            Ok(None) => continue,
            Err(e) => {
                if !reply(writer, &Response::Error(e)) {
                    break;
                }
                continue;
            }
        };
        match cmd {
            Command::Updates(updates) => {
                if !replica.is_promoted() {
                    let msg = "read-only follower: this standby replays its primary \
                               (PROMOTE to accept writes)";
                    if !reply(writer, &Response::Error(msg.into())) {
                        break;
                    }
                    continue;
                }
                let n = cfg.num_vertices;
                if let Some(bad) = updates.iter().find(|u| {
                    let (Update::Insert(a, b) | Update::Delete(a, b)) = **u;
                    a as usize >= n || b as usize >= n
                }) {
                    let err = format!("{bad:?} out of range (|V|={n})");
                    if !reply(writer, &Response::Error(err)) {
                        break;
                    }
                    continue;
                }
                let count = updates.len();
                pending.extend(updates);
                if !reply(writer, &Response::Queued { count }) {
                    break;
                }
            }
            Command::Epoch => {
                if !replica.is_promoted() {
                    let msg = "read-only follower: this standby replays its primary \
                               (PROMOTE to accept writes)";
                    if !reply(writer, &Response::Error(msg.into())) {
                        break;
                    }
                    continue;
                }
                let resp = if pending.is_empty() {
                    Response::EpochIdle {
                        epochs_applied: replica.applied_epoch(),
                        live_edges: replica.engine().num_live_edges(),
                        matched_vertices: replica.engine().matched_vertices(),
                    }
                } else {
                    let updates = std::mem::take(&mut pending);
                    match replica.apply_updates(&updates) {
                        Ok(report) => Response::Epoch(report),
                        Err(e) => Response::Error(e),
                    }
                };
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Query(v) => {
                let resp = if (v as usize) < cfg.num_vertices {
                    Response::Query { vertex: v, partner: replica.partner(v) }
                } else {
                    Response::Error(format!(
                        "vertex {v} out of range (|V|={})",
                        cfg.num_vertices
                    ))
                };
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Stats { full } => {
                let resp = Response::Stats(replica.stats_snapshot(full));
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Snapshot => {
                let resp = replica.command_snapshot();
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Metrics => {
                if !reply(writer, &Response::Metrics(replica.render_metrics())) {
                    break;
                }
            }
            Command::Trace(n) => {
                let events = trace::last_epochs(trace::collect(), n);
                let mut doc = trace::chrome_trace_json(&events);
                doc.set("ok", Json::from(true))
                    .set("op", Json::from("trace"))
                    .set("events", Json::from(events.len()));
                if !reply(writer, &Response::Trace(doc.render_compact())) {
                    break;
                }
            }
            Command::Promote => {
                let epoch = replica.promote();
                if !reply(writer, &Response::Promoted { epoch }) {
                    break;
                }
            }
            Command::Crash(_) => {
                let msg = "CRASH is not supported on a follower";
                if !reply(writer, &Response::Error(msg.into())) {
                    break;
                }
            }
            Command::Blackbox => {
                let resp = if !cfg.debug_commands {
                    Response::Error("BLACKBOX requires --debug-commands".into())
                } else {
                    match &cfg.data_dir {
                        Some(dir) => {
                            let text = replica.render_metrics();
                            match crate::obs::blackbox::write_blackbox(
                                std::path::Path::new(dir),
                                "command",
                                &text,
                            ) {
                                Ok(p) => Response::Blackbox { path: p.display().to_string() },
                                Err(e) => Response::Error(e),
                            }
                        }
                        None => Response::Error("BLACKBOX requires --data-dir".into()),
                    }
                };
                if !reply(writer, &resp) {
                    break;
                }
            }
            Command::Quit => {
                let _ = reply(writer, &Response::Bye);
                break;
            }
            Command::Shutdown => {
                let _ = reply(writer, &Response::ShuttingDown);
                shutdown = true;
                break;
            }
        }
    }
    shutdown
}

impl Replica {
    /// `SNAPSHOT` entry point: capture under the apply lock (no epoch in
    /// flight) and hand to the background writer.
    fn command_snapshot(&self) -> Response {
        let _guard = self.apply_lock.lock().unwrap();
        let mut dur = self.dur.lock().unwrap();
        match dur.as_mut() {
            Some(d) => {
                if d.snapshot_busy() {
                    return Response::Snapshot {
                        epoch: self.engine.epochs_applied(),
                        live_edges: self.engine.num_live_edges(),
                        matched_vertices: self.engine.matched_vertices(),
                        accepted: false,
                    };
                }
                let data = SnapshotData::capture(&self.engine);
                let (epoch, live_edges, matched) =
                    (data.epoch, self.engine.num_live_edges(), self.engine.matched_vertices());
                let accepted = d.request_snapshot(data);
                Response::Snapshot {
                    epoch,
                    live_edges,
                    matched_vertices: matched,
                    accepted,
                }
            }
            None => Response::Error("SNAPSHOT requires --data-dir".into()),
        }
    }
}
