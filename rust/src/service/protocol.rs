//! Wire protocol of the match service: newline-delimited text commands in,
//! newline-delimited JSON objects out.
//!
//! Commands (case-insensitive verb, whitespace-separated operands):
//!
//! ```text
//! INSERT u v [u v ...]     queue edge insertions
//! DELETE u v [u v ...]     queue edge deletions
//! EPOCH                    flush queued updates as one engine epoch,
//!                          reply with the epoch report
//! QUERY v                  partner of v. When this connection has queued
//!                          updates, the query rides the engine queue so
//!                          the answer reflects everything sent before it;
//!                          otherwise it is answered immediately from the
//!                          owner shard's atomic partner state, without
//!                          stalling any in-flight epoch
//! STATS                    cheap service counters (no graph walk) — safe
//!                          to poll as a metrics scrape
//! STATS full               counters + the live-set maximality audit. The
//!                          audit walks the whole live edge set —
//!                          O(|V|+|E_live|) on the engine thread — so poll
//!                          it like a health check, not a metrics scrape
//! SNAPSHOT                 barrier: flush pending updates, then hand a
//!                          consistent copy of the durable state to the
//!                          background snapshot writer (requires
//!                          --data-dir)
//! METRICS                  every registered instrument in the Prometheus
//!                          text exposition format. Answered immediately
//!                          from the connection thread (no barrier) — safe
//!                          to scrape at any rate
//! TRACE [n]                span events of the last n engine epochs (all
//!                          recorded epochs when n is omitted) as one JSON
//!                          line embedding a Chrome trace-event document.
//!                          Empty unless the server runs with tracing on
//!                          (`serve --trace`)
//! PROMOTE                  failover: turn a follower (`serve --follow`)
//!                          into a writable primary. Errors on a server
//!                          that is not a replicating follower
//! QUIT                     close this connection
//! SHUTDOWN                 stop the whole server: drain, apply remaining
//!                          updates, write a final snapshot when
//!                          durability is on
//! CRASH [router|flusher]   debug fault injection (requires
//!                          --debug-commands): panic the named coordinator
//!                          thread to exercise the panic-exit path
//! BLACKBOX                 dump a post-mortem artifact — the full metrics
//!                          exposition plus the recent Chrome trace — to
//!                          `<data-dir>/blackbox-<ts>.json` (requires
//!                          --debug-commands and --data-dir). The same
//!                          artifact is written automatically when a
//!                          coordinator thread panics
//! ```
//!
//! Every reply is one JSON line with an `"ok"` field, e.g.
//! `{"ok":true,"op":"epoch","epoch":3,"repair_edges":12,...}` or
//! `{"ok":false,"error":"..."}` — parseable by anything, greppable by CI.
//! The single exception is `METRICS`, whose reply is the raw multi-line
//! Prometheus exposition; its final `# EOF` line is the framing marker.
//!
//! The authoritative wire-format specification — every command, every
//! reply schema field by field, backpressure and ordering guarantees, and
//! a worked session transcript — is `docs/PROTOCOL.md` in the repository
//! root. This module is its implementation; when they disagree, fix one of
//! them in the same change.

use crate::dynamic::{EpochReport, Update};
use crate::VertexId;

/// A parsed client command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    /// Mixed updates, in order (INSERT and DELETE lines both map here).
    Updates(Vec<Update>),
    /// Flush queued updates as one engine epoch.
    Epoch,
    /// Partner lookup for one vertex.
    Query(VertexId),
    /// `full` additionally runs the O(|V|+|E_live|) maximality audit.
    Stats {
        /// Run the full audit walk, not just the cheap counters.
        full: bool,
    },
    /// Barrier + hand the durable state to the background snapshot writer.
    Snapshot,
    /// Scrape every registered instrument (Prometheus text exposition).
    Metrics,
    /// Span events of the last `n` engine epochs (`0` = all recorded) as a
    /// Chrome trace-event document.
    Trace(u64),
    /// Failover: promote a replicating follower to a writable primary.
    Promote,
    /// Close this connection.
    Quit,
    /// Stop the whole server (graceful drain; final snapshot when durable).
    Shutdown,
    /// Debug fault injection (gated behind `--debug-commands`): panic the
    /// named coordinator thread.
    Crash(CrashTarget),
    /// Dump a crash-blackbox artifact (metrics exposition + recent trace)
    /// to the data dir (gated behind `--debug-commands`).
    Blackbox,
}

/// Which coordinator thread a debug `CRASH` command panics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashTarget {
    /// The request router thread.
    Router,
    /// The epoch flusher (inline on the router when pipelining is off).
    Flusher,
}

impl Command {
    /// Parse one input line; `Ok(None)` for blank/comment lines.
    pub fn parse(line: &str) -> Result<Option<Command>, String> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        let mut it = line.split_whitespace();
        let verb = it.next().unwrap().to_ascii_uppercase();
        let cmd = match verb.as_str() {
            "INSERT" | "DELETE" => {
                let ids: Vec<VertexId> = it
                    .map(|t| {
                        t.parse::<VertexId>()
                            .map_err(|_| format!("bad vertex id {t:?}"))
                    })
                    .collect::<Result<_, _>>()?;
                if ids.is_empty() || ids.len() % 2 != 0 {
                    return Err(format!(
                        "{verb} expects an even, non-zero number of vertex ids (got {})",
                        ids.len()
                    ));
                }
                let make = |u, v| {
                    if verb == "INSERT" {
                        Update::Insert(u, v)
                    } else {
                        Update::Delete(u, v)
                    }
                };
                Command::Updates(ids.chunks(2).map(|p| make(p[0], p[1])).collect())
            }
            "EPOCH" => no_operands(&mut it, "EPOCH", Command::Epoch)?,
            "QUERY" => {
                let v = it
                    .next()
                    .ok_or("QUERY expects a vertex id")?
                    .parse::<VertexId>()
                    .map_err(|_| "QUERY expects a vertex id".to_string())?;
                no_operands(&mut it, "QUERY", Command::Query(v))?
            }
            "STATS" => match it.next() {
                None => Command::Stats { full: false },
                Some(arg) if arg.eq_ignore_ascii_case("full") => {
                    no_operands(&mut it, "STATS full", Command::Stats { full: true })?
                }
                Some(other) => {
                    return Err(format!("STATS takes no operand or `full` (got {other:?})"))
                }
            },
            "SNAPSHOT" => no_operands(&mut it, "SNAPSHOT", Command::Snapshot)?,
            "METRICS" => no_operands(&mut it, "METRICS", Command::Metrics)?,
            "TRACE" => match it.next() {
                None => Command::Trace(0),
                Some(t) => {
                    let n = t
                        .parse::<u64>()
                        .map_err(|_| format!("TRACE expects an epoch count (got {t:?})"))?;
                    no_operands(&mut it, "TRACE", Command::Trace(n))?
                }
            },
            "PROMOTE" => no_operands(&mut it, "PROMOTE", Command::Promote)?,
            "QUIT" => no_operands(&mut it, "QUIT", Command::Quit)?,
            "SHUTDOWN" => no_operands(&mut it, "SHUTDOWN", Command::Shutdown)?,
            "CRASH" => match it.next() {
                None => Command::Crash(CrashTarget::Router),
                Some(t) if t.eq_ignore_ascii_case("router") => {
                    no_operands(&mut it, "CRASH router", Command::Crash(CrashTarget::Router))?
                }
                Some(t) if t.eq_ignore_ascii_case("flusher") => {
                    no_operands(&mut it, "CRASH flusher", Command::Crash(CrashTarget::Flusher))?
                }
                Some(other) => {
                    return Err(format!("CRASH takes `router` or `flusher` (got {other:?})"))
                }
            },
            "BLACKBOX" => no_operands(&mut it, "BLACKBOX", Command::Blackbox)?,
            other => return Err(format!("unknown command {other:?}")),
        };
        Ok(Some(cmd))
    }
}

fn no_operands<'a>(
    it: &mut impl Iterator<Item = &'a str>,
    verb: &str,
    cmd: Command,
) -> Result<Command, String> {
    match it.next() {
        Some(extra) => Err(format!("{verb} takes no operands (got {extra:?})")),
        None => Ok(cmd),
    }
}

/// Minimal flat-object JSON line builder (serde is unavailable offline).
/// All keys this service emits are plain identifiers and all strings are
/// error messages, so escaping covers quotes, backslashes, and control
/// characters only.
pub struct JsonLine {
    buf: String,
}

impl Default for JsonLine {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonLine {
    /// Start an empty JSON object.
    pub fn new() -> Self {
        Self { buf: String::from("{") }
    }

    fn key(&mut self, k: &str) -> &mut Self {
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        self.buf.push('"');
        self.buf.push_str(k);
        self.buf.push_str("\":");
        self
    }

    /// Append a boolean field.
    pub fn bool(&mut self, k: &str, v: bool) -> &mut Self {
        self.key(k).buf.push_str(if v { "true" } else { "false" });
        self
    }

    /// Append an unsigned integer field.
    pub fn u64(&mut self, k: &str, v: u64) -> &mut Self {
        let s = v.to_string();
        self.key(k).buf.push_str(&s);
        self
    }

    /// Append a float field with 6 decimals (`null` when non-finite).
    pub fn f64(&mut self, k: &str, v: f64) -> &mut Self {
        let s = if v.is_finite() { format!("{v:.6}") } else { "null".into() };
        self.key(k).buf.push_str(&s);
        self
    }

    /// Append a string field, escaping quotes, backslashes, and controls.
    pub fn str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k).buf.push('"');
        for c in v.chars() {
            match c {
                '"' => self.buf.push_str("\\\""),
                '\\' => self.buf.push_str("\\\\"),
                c if (c as u32) < 0x20 => {
                    self.buf.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => self.buf.push(c),
            }
        }
        self.buf.push('"');
        self
    }

    /// Close the object and return the rendered line.
    pub fn finish(&self) -> String {
        let mut s = self.buf.clone();
        s.push('}');
        s
    }
}

/// Service-level roll-up rendered by `STATS`.
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    /// Engine epochs applied so far.
    pub epochs: u64,
    /// Live undirected edges.
    pub live_edges: u64,
    /// Currently matched vertices (2 × matched pairs).
    pub matched_vertices: usize,
    /// Insert updates received over the service lifetime.
    pub total_inserts: u64,
    /// Delete updates received over the service lifetime.
    pub total_deletes: u64,
    /// Edges re-examined by repair sweeps over the service lifetime.
    pub total_repair_edges: u64,
    /// Repair fraction of the most recent epoch.
    pub repair_frac_last: f64,
    /// Mean repair fraction over all update-carrying epochs.
    pub repair_frac_mean: f64,
    /// Batch queue→applied latency percentiles, milliseconds. Computed
    /// from the full-history `skipper_batch_latency_seconds` histogram, so
    /// they reflect every batch since boot (each is the upper bound of the
    /// log-scale bucket holding the nearest-rank sample — never an
    /// under-report, over by at most one bucket's relative width).
    pub p50_batch_ms: f64,
    /// See [`p50_batch_ms`](Self::p50_batch_ms).
    pub p99_batch_ms: f64,
    /// See [`p50_batch_ms`](Self::p50_batch_ms).
    pub p999_batch_ms: f64,
    /// Live-set maximality audit result — `None` when the cheap `STATS`
    /// form skipped the O(|V|+|E_live|) walk (`STATS full` runs it).
    pub maximal: Option<bool>,
    /// Resident bytes of the mutable adjacency sidecar.
    pub adjacency_bytes: usize,
    /// Engine shards (`P`) of the vertex-partitioned engine.
    pub engine_shards: usize,
    /// True when a standing worker pool is actually serving the engine's
    /// shard phases — false for the forked baseline *and* for `P = 1`,
    /// which always runs inline regardless of policy.
    pub pooled: bool,
    /// True when the coordinator routes the next epoch while the previous
    /// one is applied on the flusher thread.
    pub pipelined: bool,
    /// Total router wall seconds spent routing updates into mailboxes.
    pub route_s: f64,
    /// Portion of [`route_s`](Self::route_s) that overlapped a running
    /// flush — the pipelining win.
    pub route_overlap_s: f64,
    /// True when the service runs with a `--data-dir` (WAL + snapshots +
    /// recovery); the durability counters below are 0 otherwise.
    pub durable: bool,
    /// Epoch records appended to the WAL since boot.
    pub wal_epochs: u64,
    /// Bytes appended to the WAL since boot.
    pub wal_bytes: u64,
    /// Epoch of the newest durably published snapshot (0 = none yet).
    pub last_snapshot_epoch: u64,
    /// WAL epochs recovery replayed at boot (0 on a fresh start or a clean
    /// snapshot-only restart).
    pub recovery_replayed: u64,
    /// Replication role and lag telemetry — `None` when the server neither
    /// replicates out (`--replicate-addr`) nor follows (`--follow`), in
    /// which case `STATS` omits the `replica_*` fields entirely.
    pub replica: Option<ReplicaStats>,
}

/// The replication role a serving process is in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaRole {
    /// A writable primary shipping its WAL to followers.
    Primary,
    /// A read-only standby replaying the primary's stream.
    Follower,
    /// A follower promoted to writable primary by `PROMOTE`.
    Promoted,
}

impl ReplicaRole {
    /// The wire spelling rendered into `"replica_role"`.
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaRole::Primary => "primary",
            ReplicaRole::Follower => "follower",
            ReplicaRole::Promoted => "promoted",
        }
    }
}

/// The `REPLICA` section of `STATS`, rendered as flat `replica_*` fields.
/// On a primary, `acked_epoch`/lag describe the slowest live follower; on
/// a follower they describe its own position against the last tip the
/// stream carried.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReplicaStats {
    /// This process's replication role.
    pub role: ReplicaRole,
    /// Live follower connections (always 0 on a follower).
    pub followers: u64,
    /// Newest committed epoch the stream knows about: the primary's own
    /// tip, or the tip carried by the last frame a follower received.
    pub tip_epoch: u64,
    /// Newest fully acknowledged epoch: min over live followers on a
    /// primary, the locally applied epoch on a follower.
    pub acked_epoch: u64,
    /// `tip_epoch - acked_epoch`.
    pub lag_epochs: u64,
    /// Encoded record bytes in flight between tip and ack.
    pub lag_bytes: u64,
}

/// A reply ready to be rendered onto the wire.
#[derive(Clone, Debug)]
pub enum Response {
    /// Updates acknowledged at enqueue time.
    Queued {
        /// Updates accepted from this line.
        count: usize,
    },
    /// The report of the epoch an `EPOCH` barrier flushed.
    Epoch(EpochReport),
    /// `EPOCH` barrier with nothing pending: no engine epoch ran.
    EpochIdle {
        /// Epochs applied before this idle barrier.
        epochs_applied: u64,
        /// Live undirected edges.
        live_edges: u64,
        /// Currently matched vertices.
        matched_vertices: usize,
    },
    /// Partner lookup answer.
    Query {
        /// The queried vertex.
        vertex: VertexId,
        /// Its matched partner, if any.
        partner: Option<VertexId>,
    },
    /// Service counters (and, for `STATS full`, the audit verdict).
    Stats(StatsSnapshot),
    /// Reply to `SNAPSHOT`: the barrier-consistent state handed to the
    /// background writer.
    Snapshot {
        /// Epoch the snapshot captures.
        epoch: u64,
        /// Live undirected edges in the captured state.
        live_edges: u64,
        /// Matched vertices in the captured state.
        matched_vertices: usize,
        /// False when the writer was still busy with a previous snapshot
        /// and this request was skipped.
        accepted: bool,
    },
    /// Reply to `METRICS`: the full Prometheus text exposition. The one
    /// multi-line reply in the protocol — clients read until the `# EOF`
    /// line that always terminates it.
    Metrics(String),
    /// Reply to `TRACE`: one pre-rendered JSON line embedding the Chrome
    /// trace-event document (plus the protocol's `ok`/`op` fields, which
    /// trace viewers ignore).
    Trace(String),
    /// Reply to `PROMOTE` on a follower: the standby is now a writable
    /// primary.
    Promoted {
        /// Highest contiguous epoch the follower had applied at promotion
        /// — the epoch it resumes writing from.
        epoch: u64,
    },
    /// Reply to `BLACKBOX`: where the post-mortem artifact was written.
    Blackbox {
        /// Path of the written `blackbox-<ts>.json` file.
        path: String,
    },
    /// Reply to `QUIT`.
    Bye,
    /// Reply to `SHUTDOWN`.
    ShuttingDown,
    /// Any per-line failure; the connection stays usable.
    Error(String),
}

impl Response {
    /// Render for the wire (no trailing newline). Every variant renders as
    /// one JSON line except [`Metrics`](Self::Metrics), which is the raw
    /// multi-line Prometheus text.
    pub fn render(&self) -> String {
        let mut j = JsonLine::new();
        match self {
            // pre-rendered payloads: the exposition keeps its own framing
            // (# EOF), the trace line is already one JSON object
            Response::Metrics(text) => return text.trim_end_matches('\n').to_string(),
            Response::Trace(line) => return line.clone(),
            Response::Queued { count } => {
                j.bool("ok", true).str("op", "queued").u64("count", *count as u64);
            }
            Response::Epoch(r) => {
                j.bool("ok", true)
                    .str("op", "epoch")
                    .u64("epoch", r.epoch)
                    .u64("inserts", r.inserts as u64)
                    .u64("deletes", r.deletes as u64)
                    .u64("inserted_live", r.inserted_live as u64)
                    .u64("deleted_live", r.deleted_live as u64)
                    .u64("destroyed_pairs", r.destroyed_pairs as u64)
                    .u64("freed", r.freed_vertices as u64)
                    .u64("repair_edges", r.repair_edges as u64)
                    .f64("repair_frac", r.repair_fraction())
                    .u64("new_matches", r.new_matches as u64)
                    .u64("conflicts", r.conflicts)
                    .u64("live_edges", r.live_edges)
                    .u64("matched", r.matched_vertices as u64)
                    .f64("wall_ms", r.wall_s * 1e3)
                    .f64("mutate_ms", r.mutate_wall_s * 1e3)
                    .f64("mutate_run_ms", r.mutate_run_s * 1e3)
                    .f64("spawn_overhead_ms", r.mutate_spawn_overhead_s() * 1e3)
                    .f64("insert_ms", r.insert_wall_s * 1e3)
                    .f64("repair_ms", r.repair_wall_s * 1e3)
                    .f64("route_ms", r.route_wall_s * 1e3)
                    .f64("route_overlap_ms", r.route_overlap_s * 1e3);
            }
            Response::EpochIdle { epochs_applied, live_edges, matched_vertices } => {
                j.bool("ok", true)
                    .str("op", "epoch")
                    .bool("empty", true)
                    .u64("epochs_applied", *epochs_applied)
                    .u64("live_edges", *live_edges)
                    .u64("matched", *matched_vertices as u64);
            }
            Response::Query { vertex, partner } => {
                j.bool("ok", true)
                    .str("op", "query")
                    .u64("vertex", *vertex as u64)
                    .bool("matched", partner.is_some());
                if let Some(p) = partner {
                    j.u64("partner", *p as u64);
                }
            }
            Response::Stats(s) => {
                j.bool("ok", true)
                    .str("op", "stats")
                    .u64("epochs", s.epochs)
                    .u64("live_edges", s.live_edges)
                    .u64("matched", s.matched_vertices as u64)
                    .u64("total_inserts", s.total_inserts)
                    .u64("total_deletes", s.total_deletes)
                    .u64("total_repair_edges", s.total_repair_edges)
                    .f64("repair_frac_last", s.repair_frac_last)
                    .f64("repair_frac_mean", s.repair_frac_mean)
                    .f64("p50_batch_ms", s.p50_batch_ms)
                    .f64("p99_batch_ms", s.p99_batch_ms)
                    .f64("p999_batch_ms", s.p999_batch_ms)
                    .u64("adjacency_bytes", s.adjacency_bytes as u64)
                    .u64("engine_shards", s.engine_shards as u64)
                    .bool("pooled", s.pooled)
                    .bool("pipelined", s.pipelined)
                    .f64("route_s", s.route_s)
                    .f64("route_overlap_s", s.route_overlap_s)
                    .bool("durable", s.durable)
                    .u64("wal_epochs", s.wal_epochs)
                    .u64("wal_bytes", s.wal_bytes)
                    .u64("last_snapshot_epoch", s.last_snapshot_epoch)
                    .u64("recovery_replayed", s.recovery_replayed);
                if let Some(r) = &s.replica {
                    j.str("replica_role", r.role.as_str())
                        .u64("replica_followers", r.followers)
                        .u64("replica_tip_epoch", r.tip_epoch)
                        .u64("replica_acked_epoch", r.acked_epoch)
                        .u64("replica_lag_epochs", r.lag_epochs)
                        .u64("replica_lag_bytes", r.lag_bytes);
                }
                if let Some(maximal) = s.maximal {
                    j.bool("maximal", maximal);
                }
            }
            Response::Snapshot { epoch, live_edges, matched_vertices, accepted } => {
                j.bool("ok", true)
                    .str("op", "snapshot")
                    .u64("epoch", *epoch)
                    .u64("live_edges", *live_edges)
                    .u64("matched", *matched_vertices as u64)
                    .bool("accepted", *accepted);
            }
            Response::Promoted { epoch } => {
                j.bool("ok", true).str("op", "promote").u64("epoch", *epoch);
            }
            Response::Blackbox { path } => {
                j.bool("ok", true).str("op", "blackbox").str("path", path);
            }
            Response::Bye => {
                j.bool("ok", true).str("op", "bye");
            }
            Response::ShuttingDown => {
                j.bool("ok", true).str("op", "shutdown");
            }
            Response::Error(e) => {
                j.bool("ok", false).str("error", e);
            }
        }
        j.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::Update::{Delete, Insert};

    #[test]
    fn parses_update_batches() {
        assert_eq!(
            Command::parse("INSERT 0 1 2 3").unwrap(),
            Some(Command::Updates(vec![Insert(0, 1), Insert(2, 3)]))
        );
        assert_eq!(
            Command::parse("delete 5 6").unwrap(),
            Some(Command::Updates(vec![Delete(5, 6)]))
        );
        assert!(Command::parse("INSERT 0 1 2").unwrap_err().contains("even"));
        assert!(Command::parse("INSERT").unwrap_err().contains("even"));
        assert!(Command::parse("INSERT a b").unwrap_err().contains("bad vertex id"));
    }

    #[test]
    fn parses_control_commands_strictly() {
        assert_eq!(Command::parse("EPOCH").unwrap(), Some(Command::Epoch));
        assert_eq!(Command::parse("QUERY 7").unwrap(), Some(Command::Query(7)));
        assert_eq!(
            Command::parse("stats").unwrap(),
            Some(Command::Stats { full: false })
        );
        assert_eq!(
            Command::parse("STATS full").unwrap(),
            Some(Command::Stats { full: true })
        );
        assert_eq!(
            Command::parse("stats FULL").unwrap(),
            Some(Command::Stats { full: true })
        );
        assert!(Command::parse("STATS quick").is_err());
        assert!(Command::parse("STATS full now").is_err());
        assert_eq!(Command::parse("promote").unwrap(), Some(Command::Promote));
        assert!(Command::parse("PROMOTE now").is_err());
        assert_eq!(Command::parse("QUIT").unwrap(), Some(Command::Quit));
        assert_eq!(Command::parse("SHUTDOWN").unwrap(), Some(Command::Shutdown));
        assert_eq!(Command::parse("SNAPSHOT").unwrap(), Some(Command::Snapshot));
        assert!(Command::parse("SNAPSHOT now").is_err());
        assert_eq!(Command::parse("METRICS").unwrap(), Some(Command::Metrics));
        assert!(Command::parse("METRICS all").is_err());
        assert_eq!(Command::parse("TRACE").unwrap(), Some(Command::Trace(0)));
        assert_eq!(Command::parse("trace 5").unwrap(), Some(Command::Trace(5)));
        assert!(Command::parse("TRACE five").is_err());
        assert!(Command::parse("TRACE 5 6").is_err());
        assert_eq!(
            Command::parse("CRASH").unwrap(),
            Some(Command::Crash(CrashTarget::Router))
        );
        assert_eq!(
            Command::parse("crash flusher").unwrap(),
            Some(Command::Crash(CrashTarget::Flusher))
        );
        assert!(Command::parse("CRASH engine").is_err());
        assert_eq!(Command::parse("blackbox").unwrap(), Some(Command::Blackbox));
        assert!(Command::parse("BLACKBOX now").is_err());
        assert!(Command::parse("EPOCH now").is_err());
        assert!(Command::parse("QUERY").is_err());
        assert!(Command::parse("FROB 1").is_err());
        assert_eq!(Command::parse("  ").unwrap(), None);
        assert_eq!(Command::parse("# comment").unwrap(), None);
    }

    #[test]
    fn responses_render_parseable_json_lines() {
        let q = Response::Queued { count: 4 }.render();
        assert_eq!(q, r#"{"ok":true,"op":"queued","count":4}"#);
        let m = Response::Query { vertex: 3, partner: Some(9) }.render();
        assert!(m.contains(r#""matched":true"#) && m.contains(r#""partner":9"#), "{m}");
        let u = Response::Query { vertex: 3, partner: None }.render();
        assert!(u.contains(r#""matched":false"#) && !u.contains("partner"), "{u}");
        let e = Response::Error("bad \"id\"\n".into()).render();
        assert_eq!(e, "{\"ok\":false,\"error\":\"bad \\\"id\\\"\\u000a\"}");
    }

    #[test]
    fn idle_epoch_is_marked_empty_not_fabricated() {
        let r = Response::EpochIdle { epochs_applied: 3, live_edges: 7, matched_vertices: 4 };
        let line = r.render();
        assert!(line.contains(r#""empty":true"#), "{line}");
        assert!(line.contains(r#""epochs_applied":3"#), "{line}");
        assert!(!line.contains(r#""epoch":"#), "{line}");
    }

    #[test]
    fn epoch_and_stats_surface_repair_telemetry() {
        let mut rep = EpochReport { epoch: 2, repair_edges: 25, live_edges: 1000, ..Default::default() };
        rep.destroyed_pairs = 3;
        rep.mutate_wall_s = 0.004;
        let line = Response::Epoch(rep).render();
        assert!(line.contains(r#""repair_edges":25"#), "{line}");
        assert!(line.contains(r#""repair_frac":0.025"#), "{line}");
        assert!(line.contains(r#""destroyed_pairs":3"#), "{line}");
        assert!(line.contains(r#""mutate_ms":4.000000"#), "{line}");
        let s = Response::Stats(StatsSnapshot {
            maximal: Some(true),
            engine_shards: 4,
            ..Default::default()
        })
        .render();
        assert!(s.contains(r#""maximal":true"#), "{s}");
        assert!(s.contains(r#""engine_shards":4"#), "{s}");
    }

    #[test]
    fn cheap_stats_omits_the_audit_field() {
        let s = Response::Stats(StatsSnapshot { maximal: None, ..Default::default() }).render();
        assert!(!s.contains("maximal"), "{s}");
        assert!(s.contains(r#""epochs":0"#), "{s}");
    }

    #[test]
    fn stats_render_durability_counters() {
        let s = Response::Stats(StatsSnapshot {
            durable: true,
            wal_epochs: 7,
            wal_bytes: 1234,
            last_snapshot_epoch: 5,
            recovery_replayed: 2,
            ..Default::default()
        })
        .render();
        assert!(s.contains(r#""durable":true"#), "{s}");
        assert!(s.contains(r#""wal_epochs":7"#), "{s}");
        assert!(s.contains(r#""wal_bytes":1234"#), "{s}");
        assert!(s.contains(r#""last_snapshot_epoch":5"#), "{s}");
        assert!(s.contains(r#""recovery_replayed":2"#), "{s}");
        // volatile services still render the fields, zeroed, so scrapers
        // need no schema branch
        let off = Response::Stats(StatsSnapshot::default()).render();
        assert!(off.contains(r#""durable":false"#), "{off}");
        assert!(off.contains(r#""wal_epochs":0"#), "{off}");
    }

    #[test]
    fn stats_render_replica_section_only_when_replicating() {
        let s = Response::Stats(StatsSnapshot {
            replica: Some(ReplicaStats {
                role: ReplicaRole::Follower,
                followers: 0,
                tip_epoch: 12,
                acked_epoch: 9,
                lag_epochs: 3,
                lag_bytes: 250,
            }),
            ..Default::default()
        })
        .render();
        assert!(s.contains(r#""replica_role":"follower""#), "{s}");
        assert!(s.contains(r#""replica_followers":0"#), "{s}");
        assert!(s.contains(r#""replica_tip_epoch":12"#), "{s}");
        assert!(s.contains(r#""replica_acked_epoch":9"#), "{s}");
        assert!(s.contains(r#""replica_lag_epochs":3"#), "{s}");
        assert!(s.contains(r#""replica_lag_bytes":250"#), "{s}");
        let p = Response::Stats(StatsSnapshot {
            replica: Some(ReplicaStats {
                role: ReplicaRole::Promoted,
                followers: 0,
                tip_epoch: 12,
                acked_epoch: 12,
                lag_epochs: 0,
                lag_bytes: 0,
            }),
            ..Default::default()
        })
        .render();
        assert!(p.contains(r#""replica_role":"promoted""#), "{p}");
        // non-replicating servers omit the section entirely
        let off = Response::Stats(StatsSnapshot::default()).render();
        assert!(!off.contains("replica_"), "{off}");
    }

    #[test]
    fn stats_render_batch_latency_percentiles() {
        let s = Response::Stats(StatsSnapshot {
            p50_batch_ms: 0.5,
            p99_batch_ms: 2.0,
            p999_batch_ms: 8.0,
            ..Default::default()
        })
        .render();
        assert!(s.contains(r#""p50_batch_ms":0.500000"#), "{s}");
        assert!(s.contains(r#""p99_batch_ms":2.000000"#), "{s}");
        assert!(s.contains(r#""p999_batch_ms":8.000000"#), "{s}");
    }

    #[test]
    fn metrics_reply_is_raw_exposition_and_trace_is_prerendered() {
        let text = "# HELP x y\n# TYPE x counter\nx 1\n# EOF\n";
        let m = Response::Metrics(text.into()).render();
        // writeln! appends the final newline on the wire; render must not
        // double it, and the EOF framing line must survive
        assert_eq!(m, "# HELP x y\n# TYPE x counter\nx 1\n# EOF");
        let t = Response::Trace(r#"{"ok":true,"op":"trace","traceEvents":[]}"#.into()).render();
        assert!(t.contains(r#""traceEvents":[]"#), "{t}");
        assert!(!t.contains('\n'), "one line: {t}");
    }

    #[test]
    fn blackbox_reply_renders() {
        let r = Response::Blackbox { path: "/tmp/d/blackbox-12.json".into() }.render();
        assert_eq!(
            r,
            r#"{"ok":true,"op":"blackbox","path":"/tmp/d/blackbox-12.json"}"#
        );
    }

    #[test]
    fn snapshot_reply_renders() {
        let r = Response::Snapshot {
            epoch: 9,
            live_edges: 42,
            matched_vertices: 10,
            accepted: true,
        }
        .render();
        assert_eq!(
            r,
            r#"{"ok":true,"op":"snapshot","epoch":9,"live_edges":42,"matched":10,"accepted":true}"#
        );
    }
}
