//! Crate-wide observability: a lock-free metrics registry and an epoch
//! span tracer, with Prometheus / Chrome-trace export surfaces.
//!
//! Three layers (see `docs/ARCHITECTURE.md` § Observability):
//!
//! * [`metrics`] — sharded atomic counters, gauges, and fixed-bucket
//!   log-scale histograms behind a process-global registry
//!   ([`metrics::global`]); exported as Prometheus text by the `METRICS`
//!   protocol command and `serve --metrics-file`.
//! * [`trace`] — per-thread flight-recorder rings of begin/end spans
//!   (router, per-shard mutate/repair, WAL append+fsync, snapshot capture,
//!   pool job run/park), disabled by default behind one relaxed atomic
//!   branch; exported as Chrome trace-event JSON by the `TRACE <n>`
//!   protocol command and `churn --trace-out`.
//!
//! Instrumented subsystems register their instruments once at
//! construction and update them lock-free; nothing here appears on the
//! per-edge hot path — the finest-grained sites are per shard-phase,
//! per WAL append, and per pool job.
//!
//! A third surface, [`blackbox`], snapshots both exports into one
//! post-mortem JSON artifact on coordinator-thread panic or the
//! `BLACKBOX` debug command.

pub mod blackbox;
pub mod metrics;
pub mod trace;
