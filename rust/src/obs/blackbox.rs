//! Crash blackbox: a post-mortem artifact combining the full metrics
//! exposition with the recent span trace, written to the service's data
//! dir when a coordinator thread panics (the `ExitOnPanic` exit-70 path)
//! or on the `BLACKBOX` debug command.
//!
//! The artifact is one JSON file, `blackbox-<ts>.json`, whose shape is:
//!
//! ```text
//! {
//!   "schema": "skipper-blackbox-v1",
//!   "written_unix_ms": <u64>,            // wall clock at dump time
//!   "role": "<who dumped: router|flusher|command|...>",
//!   "metrics": "<full Prometheus text exposition, # EOF framed>",
//!   "trace": { Chrome trace-event document of the last N epochs }
//! }
//! ```
//!
//! `trace` embeds the same document `TRACE <n>` serves (empty
//! `traceEvents` when the process runs without `--trace`), so exemplar
//! `span_id` labels inside the `metrics` string resolve against the
//! `trace` object of the same artifact — one self-contained file carries
//! both halves of the link.

use crate::obs::trace;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// How many trailing epochs of span history a blackbox dump retains.
/// The flight-recorder rings are bounded anyway; this keeps the artifact
/// focused on the incident window.
pub const BLACKBOX_TRACE_EPOCHS: u64 = 256;

/// Milliseconds since the Unix epoch, for the artifact filename and the
/// `written_unix_ms` field.
fn unix_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis().min(u128::from(u64::MAX)) as u64)
        .unwrap_or(0)
}

/// Dump a blackbox artifact into `dir`. `role` names the dumper (the
/// panicking thread's role, or `"command"` for `BLACKBOX`); `metrics_text`
/// is the full exposition the caller already knows how to render. The
/// trace document is collected here — the last
/// [`BLACKBOX_TRACE_EPOCHS`] epochs of every ring. Returns the written
/// path. Never panics: this runs on the panic path itself.
pub fn write_blackbox(dir: &Path, role: &str, metrics_text: &str) -> Result<PathBuf, String> {
    let events = trace::last_epochs(trace::collect(), BLACKBOX_TRACE_EPOCHS);
    let trace_doc = trace::chrome_trace_json(&events);
    let ts = unix_ms();
    let mut doc = Json::obj();
    doc.set("schema", Json::from("skipper-blackbox-v1"))
        .set("written_unix_ms", Json::from(ts))
        .set("role", Json::from(role))
        .set("metrics", Json::from(metrics_text))
        .set("trace", trace_doc);
    let mut path = dir.join(format!("blackbox-{ts}.json"));
    // same-millisecond collision (two dumps racing): pick a fresh name
    // rather than clobbering the first incident's evidence
    let mut bump = 0u32;
    while path.exists() {
        bump += 1;
        path = dir.join(format!("blackbox-{ts}-{bump}.json"));
    }
    let text = doc.render_compact();
    std::fs::write(&path, text.as_bytes())
        .map_err(|e| format!("blackbox write {}: {e}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blackbox_artifact_is_parseable_and_self_contained() {
        let dir = std::env::temp_dir().join(format!("skipper-bb-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let metrics = "# HELP x y\n# TYPE x counter\nx 1\n# EOF\n";
        let path = write_blackbox(&dir, "test", metrics).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("skipper-blackbox-v1"));
        assert_eq!(doc.get("role").and_then(Json::as_str), Some("test"));
        assert_eq!(doc.get("metrics").and_then(Json::as_str), Some(metrics));
        let trace = doc.get("trace").expect("trace document embedded");
        assert!(trace.get("traceEvents").and_then(Json::as_arr).is_some());
        assert!(doc.get("written_unix_ms").and_then(Json::as_u64).is_some());
        // a second dump in the same millisecond must not clobber the first
        let path2 = write_blackbox(&dir, "test", metrics).unwrap();
        assert_ne!(path, path2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
