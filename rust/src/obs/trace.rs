//! Epoch span tracing: a per-thread flight recorder exportable as Chrome
//! trace-event JSON.
//!
//! ## Overhead argument
//!
//! Tracing is **disabled by default**. Every instrumentation site calls
//! [`span`], which starts with one relaxed [`AtomicBool`] load and a
//! branch; when disabled it returns `None` immediately — no clock read, no
//! allocation, no lock. That is the entire hot-path cost, so an
//! uninstrumented build and a disabled-tracing build execute the same
//! work per edge (the churn registry gate in CI holds this to numbers).
//!
//! When enabled ([`set_enabled`]), each span reads the monotonic clock
//! twice (construction + drop) and pushes one fixed-size [`SpanEvent`]
//! into its **own thread's** ring under a mutex that only the `TRACE`
//! exporter ever contends on. Rings are bounded ([`RING_CAPACITY`]
//! events); the newest events overwrite the oldest, flight-recorder style,
//! so a long-running server holds a sliding window of recent epochs at a
//! fixed memory cost.
//!
//! ## Export
//!
//! [`chrome_trace_json`] renders the recorded spans as Chrome
//! trace-event JSON (`"ph":"X"` complete events with microsecond
//! timestamps), loadable in `chrome://tracing` or Perfetto. Spans carry
//! the engine epoch where the instrumentation site knows it, which is
//! what lets the `TRACE <n>` protocol command cut the window to the last
//! `n` epochs.

use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity, in spans. At ~6 spans per epoch per thread
/// this holds several hundred epochs of history per thread.
pub const RING_CAPACITY: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span recording on? One relaxed load — this is the branch every
/// disabled-by-default instrumentation site pays.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (`serve --trace`, `churn --trace-out`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The process-wide epoch origin for span timestamps: all `ts` values are
/// microseconds since the first span-related call in the process.
fn origin() -> Instant {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    *ORIGIN.get_or_init(Instant::now)
}

/// One recorded span: a closed `[start, start+dur]` interval on one thread.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Site name (`mutate`, `repair`, `wal_append`, `pool_run`, ...).
    pub name: &'static str,
    /// Category for trace viewers (`engine`, `wal`, `pool`, `service`).
    pub cat: &'static str,
    /// Microseconds since the process trace origin.
    pub ts_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
    /// Recording thread (stable small integer, not the OS tid).
    pub tid: u64,
    /// Engine epoch the span belongs to, 0 when the site has no epoch
    /// context (pool park/wake, snapshot writer).
    pub epoch: u64,
    /// Site-specific argument (shard index, byte count, group size).
    pub arg: u64,
}

struct Ring {
    tid: u64,
    events: Mutex<std::collections::VecDeque<SpanEvent>>,
}

fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static MY_RING: Arc<Ring> = {
        let ring = Arc::new(Ring {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            events: Mutex::new(std::collections::VecDeque::with_capacity(64)),
        });
        rings().lock().unwrap().push(Arc::clone(&ring));
        ring
    };
}

/// An in-flight span; records itself into the thread's ring when dropped.
/// Only ever constructed when tracing is enabled (see [`span`]).
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start: Instant,
    epoch: u64,
    arg: u64,
}

impl SpanGuard {
    /// Attach/replace the site-specific argument after construction.
    pub fn set_arg(&mut self, arg: u64) {
        self.arg = arg;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let dur_us = self.start.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
        let ts_us = self
            .start
            .duration_since(origin())
            .as_micros()
            .min(u128::from(u64::MAX)) as u64;
        let epoch = self.epoch;
        MY_RING.with(|ring| {
            let mut events = ring.events.lock().unwrap();
            if events.len() >= RING_CAPACITY {
                events.pop_front();
            }
            events.push_back(SpanEvent {
                name: self.name,
                cat: self.cat,
                ts_us,
                dur_us,
                tid: ring.tid,
                epoch,
                arg: self.arg,
            });
        });
    }
}

/// Open an epoch-untagged span (sites with no epoch context: pool
/// park/run, snapshot writer). Returns `None` (after one relaxed load)
/// when tracing is disabled.
#[inline]
pub fn span(name: &'static str, cat: &'static str, arg: u64) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    let _ = origin(); // pin the time origin before the first timestamp
    Some(SpanGuard { name, cat, start: Instant::now(), epoch: 0, arg })
}

/// Open a span tagged with an explicit epoch (sites that know it).
#[inline]
pub fn span_epoch(
    name: &'static str,
    cat: &'static str,
    epoch: u64,
    arg: u64,
) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    let _ = origin();
    Some(SpanGuard { name, cat, start: Instant::now(), epoch, arg })
}

/// Copy out every ring's events (the rings keep recording). Sorted by
/// start timestamp.
pub fn collect() -> Vec<SpanEvent> {
    let rings = rings().lock().unwrap();
    let mut out = Vec::new();
    for ring in rings.iter() {
        out.extend(ring.events.lock().unwrap().iter().cloned());
    }
    out.sort_by_key(|e| e.ts_us);
    out
}

/// Clear every ring (used between runs so `--trace-out` captures exactly
/// one workload).
pub fn clear() {
    let rings = rings().lock().unwrap();
    for ring in rings.iter() {
        ring.events.lock().unwrap().clear();
    }
}

/// Restrict `events` to the last `n` engine epochs: spans tagged with an
/// epoch keep the `n` newest distinct epoch numbers; untagged spans
/// (epoch 0 — pool parks, snapshot writer) are kept when they start at or
/// after the window's earliest tagged span. `n = 0` keeps everything.
pub fn last_epochs(mut events: Vec<SpanEvent>, n: u64) -> Vec<SpanEvent> {
    if n == 0 {
        return events;
    }
    let max_epoch = events.iter().map(|e| e.epoch).max().unwrap_or(0);
    if max_epoch == 0 {
        return events; // nothing is epoch-tagged; the window is everything
    }
    let cutoff = max_epoch.saturating_sub(n - 1).max(1);
    let tmin = events
        .iter()
        .filter(|e| e.epoch >= cutoff)
        .map(|e| e.ts_us)
        .min()
        .unwrap_or(0);
    events.retain(|e| e.epoch >= cutoff || (e.epoch == 0 && e.ts_us >= tmin));
    events
}

/// Render spans as a Chrome trace-event JSON object:
/// `{"displayTimeUnit":"ms","traceEvents":[{"ph":"X",...},...]}` —
/// loadable directly in `chrome://tracing` / Perfetto (extra top-level
/// keys, like the protocol's `ok`/`op`, are ignored by the viewers).
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let pid = std::process::id() as u64;
    let arr: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut args = Json::obj();
            args.set("epoch", Json::from(e.epoch)).set("arg", Json::from(e.arg));
            let mut o = Json::obj();
            o.set("name", Json::from(e.name))
                .set("cat", Json::from(e.cat))
                .set("ph", Json::from("X"))
                .set("ts", Json::from(e.ts_us))
                .set("dur", Json::from(e.dur_us))
                .set("pid", Json::from(pid))
                .set("tid", Json::from(e.tid))
                .set("args", args);
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("displayTimeUnit", Json::from("ms"))
        .set("traceEvents", Json::Arr(arr));
    root
}

/// Validate a Chrome trace JSON document: it must parse, expose a
/// `traceEvents` array, and every event needs `name`/`ph`/`ts` fields.
/// Returns the span names found (for `lint --require` checks).
pub fn validate_chrome_trace(text: &str) -> Result<Vec<String>, String> {
    let root = crate::util::json::parse(text)?;
    let events = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("no \"traceEvents\" array")?;
    let mut names = Vec::new();
    for (i, e) in events.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing \"name\""))?;
        if e.get("ph").and_then(Json::as_str).is_none() {
            return Err(format!("event {i}: missing \"ph\""));
        }
        if e.get("ts").and_then(Json::as_f64).is_none() {
            return Err(format!("event {i}: missing \"ts\""));
        }
        if !names.iter().any(|n| n == name) {
            names.push(name.to_string());
        }
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global; tests that flip it serialize here
    /// so cargo's parallel test threads don't interleave recordings.
    fn tracing_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    /// Other tests in the crate run instrumented engines concurrently; any
    /// of their spans recorded while one of these tests has tracing on are
    /// noise. Assertions therefore filter on this test-only category.
    const CAT: &str = "obstest";

    #[test]
    fn disabled_spans_record_nothing() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(false);
        clear();
        assert!(span("obs_noop", CAT, 0).is_none());
        assert!(!collect().iter().any(|e| e.cat == CAT));
    }

    #[test]
    fn spans_record_and_export_chrome_trace() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(true);
        clear();
        {
            let _a = span_epoch("obs_mutate", CAT, 7, 3);
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        {
            let mut b = span("obs_wal", CAT, 0).expect("tracing is on");
            b.set_arg(128);
        }
        set_enabled(false);
        let events: Vec<SpanEvent> =
            collect().into_iter().filter(|e| e.cat == CAT).collect();
        assert_eq!(events.len(), 2);
        let mutate = events.iter().find(|e| e.name == "obs_mutate").unwrap();
        assert_eq!(mutate.epoch, 7);
        assert_eq!(mutate.arg, 3);
        assert!(mutate.dur_us >= 100, "measured {}", mutate.dur_us);
        let wal = events.iter().find(|e| e.name == "obs_wal").unwrap();
        assert_eq!(wal.epoch, 0, "span() leaves the epoch untagged");
        assert_eq!(wal.arg, 128, "set_arg overrides the construction arg");
        let text = chrome_trace_json(&events).render_compact();
        let names = validate_chrome_trace(&text).unwrap();
        assert!(names.contains(&"obs_mutate".to_string()));
        assert!(names.contains(&"obs_wal".to_string()));
        clear();
        assert!(!collect().iter().any(|e| e.cat == CAT));
    }

    #[test]
    fn ring_is_bounded() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(true);
        clear();
        for i in 0..(RING_CAPACITY + 100) as u64 {
            let _s = span_epoch("obs_tick", CAT, 1, i);
        }
        set_enabled(false);
        let events: Vec<SpanEvent> =
            collect().into_iter().filter(|e| e.name == "obs_tick").collect();
        // concurrent tests' spans can displace a few of ours, never add
        assert!(events.len() <= RING_CAPACITY, "ring exceeded capacity");
        assert!(events.len() >= RING_CAPACITY - 100, "ring lost too much");
        assert!(events.iter().all(|e| e.arg >= 100), "the survivors are the newest");
        clear();
    }

    #[test]
    fn last_epochs_windows_tagged_and_untagged_spans() {
        let ev = |name: &'static str, epoch: u64, ts_us: u64| SpanEvent {
            name,
            cat: "test",
            ts_us,
            dur_us: 1,
            tid: 1,
            epoch,
            arg: 0,
        };
        let events = vec![
            ev("mutate", 1, 100),
            ev("park", 0, 150), // before the window's first tagged span
            ev("mutate", 2, 200),
            ev("park", 0, 250),
            ev("mutate", 3, 300),
        ];
        let cut = last_epochs(events.clone(), 2);
        let names: Vec<(u64, u64)> = cut.iter().map(|e| (e.epoch, e.ts_us)).collect();
        assert_eq!(names, vec![(2, 200), (0, 250), (3, 300)]);
        assert_eq!(last_epochs(events.clone(), 0).len(), 5, "n=0 keeps all");
        assert_eq!(last_epochs(events, 10).len(), 5, "window wider than history");
    }

    #[test]
    fn trace_validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{\"a\":1}").is_err(), "no traceEvents");
        assert!(
            validate_chrome_trace("{\"traceEvents\":[{\"ph\":\"X\"}]}").is_err(),
            "event without name"
        );
        let ok = validate_chrome_trace(
            "{\"traceEvents\":[{\"name\":\"m\",\"ph\":\"X\",\"ts\":1,\"dur\":2,\"pid\":1,\"tid\":1}]}",
        )
        .unwrap();
        assert_eq!(ok, vec!["m".to_string()]);
    }
}
