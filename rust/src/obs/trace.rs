//! Epoch span tracing: a per-thread flight recorder exportable as Chrome
//! trace-event JSON.
//!
//! ## Overhead argument
//!
//! Tracing is **disabled by default**. Every instrumentation site calls
//! [`span`], which starts with one relaxed [`AtomicBool`] load and a
//! branch; when disabled it returns `None` immediately — no clock read, no
//! allocation, no lock. That is the entire hot-path cost, so an
//! uninstrumented build and a disabled-tracing build execute the same
//! work per edge (the churn registry gate in CI holds this to numbers).
//!
//! When enabled ([`set_enabled`]), each span reads the monotonic clock
//! twice (construction + drop) and pushes one fixed-size [`SpanEvent`]
//! into its **own thread's** ring under a mutex that only the `TRACE`
//! exporter ever contends on. Rings are bounded ([`RING_CAPACITY`]
//! events); the newest events overwrite the oldest, flight-recorder style,
//! so a long-running server holds a sliding window of recent epochs at a
//! fixed memory cost.
//!
//! ## Export
//!
//! [`chrome_trace_json`] renders the recorded spans as Chrome
//! trace-event JSON (`"ph":"X"` complete events with microsecond
//! timestamps), loadable in `chrome://tracing` or Perfetto. Spans carry
//! the engine epoch where the instrumentation site knows it, which is
//! what lets the `TRACE <n>` protocol command cut the window to the last
//! `n` epochs.
//!
//! ## Span identity and exemplars
//!
//! Every recorded span carries a process-unique `span_id`, and while a
//! [`SpanGuard`] is alive its id/epoch/tid triplet sits in a relaxed
//! per-thread cell readable through [`current_span`]. Histogram
//! recordings that happen inside a span scope (WAL fsync, replica apply)
//! use that cell to attach an OpenMetrics *exemplar* to their bucket —
//! see [`crate::obs::metrics::Histogram`] — so a latency spike in a
//! `METRICS` scrape resolves to the exact span in the `TRACE` output via
//! the `span_id` both sides render ([`format_span_id`]).

use crate::util::json::Json;
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread ring capacity, in spans. At ~6 spans per epoch per thread
/// this holds several hundred epochs of history per thread.
pub const RING_CAPACITY: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span recording on? One relaxed load — this is the branch every
/// disabled-by-default instrumentation site pays.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (`serve --trace`, `churn --trace-out`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// The process-wide epoch origin for span timestamps: all `ts` values are
/// microseconds since the first span-related call in the process.
fn origin() -> Instant {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    *ORIGIN.get_or_init(Instant::now)
}

/// One recorded span: a closed `[start, start+dur]` interval on one thread.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    /// Site name (`mutate`, `repair`, `wal_append`, `pool_run`, ...).
    pub name: &'static str,
    /// Category for trace viewers (`engine`, `wal`, `pool`, `service`).
    pub cat: &'static str,
    /// Microseconds since the process trace origin.
    pub ts_us: u64,
    /// Span duration in microseconds.
    pub dur_us: u64,
    /// Recording thread (stable small integer, not the OS tid).
    pub tid: u64,
    /// Engine epoch the span belongs to, 0 when the site has no epoch
    /// context (pool park/wake, snapshot writer).
    pub epoch: u64,
    /// Site-specific argument (shard index, byte count, group size).
    pub arg: u64,
    /// Process-unique span id — the cross-reference key exemplars carry
    /// (rendered by [`format_span_id`] on both the trace and metrics
    /// sides). 0 only in hand-built test events.
    pub span_id: u64,
}

static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);

/// The span a thread is currently inside: what
/// [`crate::obs::metrics::Histogram::record`] captures as an exemplar.
#[derive(Clone, Copy, Debug)]
pub struct CurrentSpan {
    /// The innermost live span's process-unique id.
    pub span_id: u64,
    /// That span's engine epoch (0 when the site had no epoch context).
    pub epoch: u64,
    /// The recording thread's stable trace tid.
    pub tid: u64,
}

thread_local! {
    /// The innermost live span on this thread, `span_id == 0` when none.
    /// A plain `Cell` (one word set/restore per span) — only this thread
    /// ever touches it, which is the "relaxed per-thread cell" that keeps
    /// exemplar capture off every shared cache line.
    static CURRENT_SPAN: Cell<(u64, u64, u64)> = const { Cell::new((0, 0, 0)) };
}

/// The innermost span currently open on the calling thread, if any.
/// `None` whenever tracing is disabled (guards are only constructed while
/// it is on), so callers pay one thread-local read on the common path.
#[inline]
pub fn current_span() -> Option<CurrentSpan> {
    let (span_id, epoch, tid) = CURRENT_SPAN.with(Cell::get);
    (span_id != 0).then_some(CurrentSpan { span_id, epoch, tid })
}

/// Microseconds since the process trace origin — the clock exemplar
/// timestamps share with span `ts` values.
pub fn now_us() -> u64 {
    origin().elapsed().as_micros().min(u128::from(u64::MAX)) as u64
}

/// Canonical rendering of a span id (16 hex digits), used identically in
/// Chrome-trace `args` and OpenMetrics exemplar labels so `lint` can
/// cross-reference the two by string equality.
pub fn format_span_id(id: u64) -> String {
    format!("{id:016x}")
}

struct Ring {
    tid: u64,
    events: Mutex<std::collections::VecDeque<SpanEvent>>,
}

fn rings() -> &'static Mutex<Vec<Arc<Ring>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static MY_RING: Arc<Ring> = {
        let ring = Arc::new(Ring {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            events: Mutex::new(std::collections::VecDeque::with_capacity(64)),
        });
        rings().lock().unwrap().push(Arc::clone(&ring));
        ring
    };
}

/// An in-flight span; records itself into the thread's ring when dropped.
/// Only ever constructed when tracing is enabled (see [`span`]). While
/// alive it is the thread's [`current_span`]; dropping restores whatever
/// enclosing span (or none) was current before, so nesting behaves like a
/// stack.
pub struct SpanGuard {
    name: &'static str,
    cat: &'static str,
    start: Instant,
    epoch: u64,
    arg: u64,
    span_id: u64,
    /// The cell value this guard displaced, restored on drop.
    prev: (u64, u64, u64),
}

impl SpanGuard {
    fn open(name: &'static str, cat: &'static str, epoch: u64, arg: u64) -> SpanGuard {
        let _ = origin(); // pin the time origin before the first timestamp
        let span_id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
        let tid = MY_RING.with(|ring| ring.tid);
        let prev = CURRENT_SPAN.with(|c| c.replace((span_id, epoch, tid)));
        SpanGuard { name, cat, start: Instant::now(), epoch, arg, span_id, prev }
    }

    /// Attach/replace the site-specific argument after construction.
    pub fn set_arg(&mut self, arg: u64) {
        self.arg = arg;
    }

    /// This span's process-unique id (what exemplars recorded inside the
    /// span's scope will carry).
    pub fn span_id(&self) -> u64 {
        self.span_id
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        CURRENT_SPAN.with(|c| c.set(self.prev));
        let dur_us = self.start.elapsed().as_micros().min(u128::from(u64::MAX)) as u64;
        let ts_us = self
            .start
            .duration_since(origin())
            .as_micros()
            .min(u128::from(u64::MAX)) as u64;
        let epoch = self.epoch;
        MY_RING.with(|ring| {
            let mut events = ring.events.lock().unwrap();
            if events.len() >= RING_CAPACITY {
                events.pop_front();
            }
            events.push_back(SpanEvent {
                name: self.name,
                cat: self.cat,
                ts_us,
                dur_us,
                tid: ring.tid,
                epoch,
                arg: self.arg,
                span_id: self.span_id,
            });
        });
    }
}

/// Open an epoch-untagged span (sites with no epoch context: pool
/// park/run, snapshot writer). Returns `None` (after one relaxed load)
/// when tracing is disabled.
#[inline]
pub fn span(name: &'static str, cat: &'static str, arg: u64) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    Some(SpanGuard::open(name, cat, 0, arg))
}

/// Open a span tagged with an explicit epoch (sites that know it).
#[inline]
pub fn span_epoch(
    name: &'static str,
    cat: &'static str,
    epoch: u64,
    arg: u64,
) -> Option<SpanGuard> {
    if !enabled() {
        return None;
    }
    Some(SpanGuard::open(name, cat, epoch, arg))
}

/// Copy out every ring's events (the rings keep recording). Sorted by
/// start timestamp.
pub fn collect() -> Vec<SpanEvent> {
    let rings = rings().lock().unwrap();
    let mut out = Vec::new();
    for ring in rings.iter() {
        out.extend(ring.events.lock().unwrap().iter().cloned());
    }
    out.sort_by_key(|e| e.ts_us);
    out
}

/// Clear every ring (used between runs so `--trace-out` captures exactly
/// one workload).
pub fn clear() {
    let rings = rings().lock().unwrap();
    for ring in rings.iter() {
        ring.events.lock().unwrap().clear();
    }
}

/// Restrict `events` to the last `n` engine epochs: spans tagged with an
/// epoch keep the `n` newest distinct epoch numbers; untagged spans
/// (epoch 0 — pool parks, snapshot writer) are kept when they start at or
/// after the window's earliest tagged span. `n = 0` keeps everything.
pub fn last_epochs(mut events: Vec<SpanEvent>, n: u64) -> Vec<SpanEvent> {
    if n == 0 {
        return events;
    }
    let max_epoch = events.iter().map(|e| e.epoch).max().unwrap_or(0);
    if max_epoch == 0 {
        return events; // nothing is epoch-tagged; the window is everything
    }
    let cutoff = max_epoch.saturating_sub(n - 1).max(1);
    let tmin = events
        .iter()
        .filter(|e| e.epoch >= cutoff)
        .map(|e| e.ts_us)
        .min()
        .unwrap_or(0);
    events.retain(|e| e.epoch >= cutoff || (e.epoch == 0 && e.ts_us >= tmin));
    events
}

/// Render spans as a Chrome trace-event JSON object:
/// `{"displayTimeUnit":"ms","traceEvents":[{"ph":"X",...},...]}` —
/// loadable directly in `chrome://tracing` / Perfetto (extra top-level
/// keys, like the protocol's `ok`/`op`, are ignored by the viewers).
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let pid = std::process::id() as u64;
    let arr: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut args = Json::obj();
            args.set("epoch", Json::from(e.epoch))
                .set("arg", Json::from(e.arg))
                .set("span_id", Json::from(format_span_id(e.span_id)));
            let mut o = Json::obj();
            o.set("name", Json::from(e.name))
                .set("cat", Json::from(e.cat))
                .set("ph", Json::from("X"))
                .set("ts", Json::from(e.ts_us))
                .set("dur", Json::from(e.dur_us))
                .set("pid", Json::from(pid))
                .set("tid", Json::from(e.tid))
                .set("args", args);
            o
        })
        .collect();
    let mut root = Json::obj();
    root.set("displayTimeUnit", Json::from("ms"))
        .set("traceEvents", Json::Arr(arr));
    root
}

/// Validate a Chrome trace JSON document: it must parse, expose a
/// `traceEvents` array, and every event needs `name`/`ph`/`ts` fields.
/// Returns the span names found (for `lint --require` checks).
pub fn validate_chrome_trace(text: &str) -> Result<Vec<String>, String> {
    let root = crate::util::json::parse(text)?;
    let events = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("no \"traceEvents\" array")?;
    let mut names = Vec::new();
    for (i, e) in events.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing \"name\""))?;
        if e.get("ph").and_then(Json::as_str).is_none() {
            return Err(format!("event {i}: missing \"ph\""));
        }
        if e.get("ts").and_then(Json::as_f64).is_none() {
            return Err(format!("event {i}: missing \"ts\""));
        }
        if !names.iter().any(|n| n == name) {
            names.push(name.to_string());
        }
    }
    Ok(names)
}

/// Collect the distinct `args.span_id` strings of a Chrome trace JSON
/// document — the set `lint --require-exemplars` resolves metric exemplars
/// against. Events without a span id (foreign traces) are skipped.
pub fn chrome_trace_span_ids(text: &str) -> Result<Vec<String>, String> {
    let root = crate::util::json::parse(text)?;
    let events = root
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("no \"traceEvents\" array")?;
    let mut ids = Vec::new();
    for e in events {
        if let Some(id) = e.get("args").and_then(|a| a.get("span_id")).and_then(Json::as_str) {
            if !ids.iter().any(|i| i == id) {
                ids.push(id.to_string());
            }
        }
    }
    Ok(ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tracing state is process-global; tests that flip it serialize here
    /// so cargo's parallel test threads don't interleave recordings.
    fn tracing_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    /// Other tests in the crate run instrumented engines concurrently; any
    /// of their spans recorded while one of these tests has tracing on are
    /// noise. Assertions therefore filter on this test-only category.
    const CAT: &str = "obstest";

    #[test]
    fn disabled_spans_record_nothing() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(false);
        clear();
        assert!(span("obs_noop", CAT, 0).is_none());
        assert!(!collect().iter().any(|e| e.cat == CAT));
    }

    #[test]
    fn spans_record_and_export_chrome_trace() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(true);
        clear();
        {
            let _a = span_epoch("obs_mutate", CAT, 7, 3);
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        {
            let mut b = span("obs_wal", CAT, 0).expect("tracing is on");
            b.set_arg(128);
        }
        set_enabled(false);
        let events: Vec<SpanEvent> =
            collect().into_iter().filter(|e| e.cat == CAT).collect();
        assert_eq!(events.len(), 2);
        let mutate = events.iter().find(|e| e.name == "obs_mutate").unwrap();
        assert_eq!(mutate.epoch, 7);
        assert_eq!(mutate.arg, 3);
        assert!(mutate.dur_us >= 100, "measured {}", mutate.dur_us);
        let wal = events.iter().find(|e| e.name == "obs_wal").unwrap();
        assert_eq!(wal.epoch, 0, "span() leaves the epoch untagged");
        assert_eq!(wal.arg, 128, "set_arg overrides the construction arg");
        let text = chrome_trace_json(&events).render_compact();
        let names = validate_chrome_trace(&text).unwrap();
        assert!(names.contains(&"obs_mutate".to_string()));
        assert!(names.contains(&"obs_wal".to_string()));
        // every recorded span carries a distinct nonzero id, and the
        // exported document exposes them for exemplar cross-referencing
        assert!(events.iter().all(|e| e.span_id != 0));
        assert_ne!(events[0].span_id, events[1].span_id);
        let ids = chrome_trace_span_ids(&text).unwrap();
        assert_eq!(ids.len(), 2);
        assert!(ids.contains(&format_span_id(events[0].span_id)));
        clear();
        assert!(!collect().iter().any(|e| e.cat == CAT));
    }

    #[test]
    fn current_span_cell_tracks_nesting_and_clears() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(true);
        clear();
        assert!(current_span().is_none(), "no span open yet");
        {
            let outer = span_epoch("obs_outer", CAT, 9, 0).unwrap();
            let cur = current_span().expect("outer span is current");
            assert_eq!(cur.span_id, outer.span_id());
            assert_eq!(cur.epoch, 9);
            {
                let inner = span("obs_inner", CAT, 0).unwrap();
                let cur = current_span().expect("inner span is current");
                assert_eq!(cur.span_id, inner.span_id());
                assert_eq!(cur.epoch, 0, "inner span's epoch wins while open");
            }
            let cur = current_span().expect("outer restored after inner drop");
            assert_eq!(cur.span_id, outer.span_id());
            assert_eq!(cur.epoch, 9);
        }
        assert!(current_span().is_none(), "cell cleared after the last drop");
        set_enabled(false);
        assert!(current_span().is_none(), "disabled tracing opens no spans");
        clear();
    }

    #[test]
    fn ring_is_bounded() {
        let _guard = tracing_lock().lock().unwrap();
        set_enabled(true);
        clear();
        for i in 0..(RING_CAPACITY + 100) as u64 {
            let _s = span_epoch("obs_tick", CAT, 1, i);
        }
        set_enabled(false);
        let events: Vec<SpanEvent> =
            collect().into_iter().filter(|e| e.name == "obs_tick").collect();
        // concurrent tests' spans can displace a few of ours, never add
        assert!(events.len() <= RING_CAPACITY, "ring exceeded capacity");
        assert!(events.len() >= RING_CAPACITY - 100, "ring lost too much");
        assert!(events.iter().all(|e| e.arg >= 100), "the survivors are the newest");
        clear();
    }

    #[test]
    fn last_epochs_windows_tagged_and_untagged_spans() {
        let ev = |name: &'static str, epoch: u64, ts_us: u64| SpanEvent {
            name,
            cat: "test",
            ts_us,
            dur_us: 1,
            tid: 1,
            epoch,
            arg: 0,
            span_id: 0,
        };
        let events = vec![
            ev("mutate", 1, 100),
            ev("park", 0, 150), // before the window's first tagged span
            ev("mutate", 2, 200),
            ev("park", 0, 250),
            ev("mutate", 3, 300),
        ];
        let cut = last_epochs(events.clone(), 2);
        let names: Vec<(u64, u64)> = cut.iter().map(|e| (e.epoch, e.ts_us)).collect();
        assert_eq!(names, vec![(2, 200), (0, 250), (3, 300)]);
        assert_eq!(last_epochs(events.clone(), 0).len(), 5, "n=0 keeps all");
        assert_eq!(last_epochs(events, 10).len(), 5, "window wider than history");
    }

    #[test]
    fn trace_validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{\"a\":1}").is_err(), "no traceEvents");
        assert!(
            validate_chrome_trace("{\"traceEvents\":[{\"ph\":\"X\"}]}").is_err(),
            "event without name"
        );
        let ok = validate_chrome_trace(
            "{\"traceEvents\":[{\"name\":\"m\",\"ph\":\"X\",\"ts\":1,\"dur\":2,\"pid\":1,\"tid\":1}]}",
        )
        .unwrap();
        assert_eq!(ok, vec!["m".to_string()]);
    }
}
