//! Lock-free metrics: sharded counters, gauges, and log-scale histograms
//! behind a process-global registry with a Prometheus text exporter.
//!
//! ## Design
//!
//! * **Instruments are registered once, updated lock-free.** Registration
//!   (`counter`/`gauge`/`histogram_*`) takes the registry mutex — a cold
//!   path run at subsystem construction. The returned handles are `Arc`s
//!   whose update methods touch only relaxed atomics, so the hot paths
//!   (per-job, per-epoch, per-append) never contend on a lock.
//! * **Registration is idempotent.** Asking for an instrument whose
//!   `(name, labels)` pair already exists returns the existing handle, so
//!   two engines in one process (common in tests) share instruments
//!   instead of colliding. Monitoring counters are process-wide by design.
//! * **Histograms are fixed log-scale buckets** ([`Histogram`]): every
//!   recorded value lands in a bucket whose relative width is at most
//!   1/8 (12.5%), so percentile estimates computed from the buckets are
//!   within one bucket's relative error of the exact percentile over the
//!   *full* recording history — unlike a bounded latency ring, nothing is
//!   ever evicted.
//! * **Buckets carry exemplars when tracing is on.** A sample recorded
//!   while the thread is inside a [`crate::obs::trace::SpanGuard`] stamps
//!   its bucket with an [`Exemplar`] — the raw value plus the span's
//!   id/epoch/tid and a timestamp on the trace clock. The exporter
//!   renders them in OpenMetrics `# {span_id="..."} value ts` syntax, so
//!   a p999 spike in a scrape resolves to the exact span in the `TRACE`
//!   output. With tracing off (the default) the capture path is one
//!   thread-local read per sample.
//!
//! The [`global`] registry is what the `METRICS` protocol command, the
//! `serve --metrics-file` writer, and the bench record emitters export.

use crate::obs::trace;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// stripes
// ---------------------------------------------------------------------------

/// Stripes per sharded counter — enough that the handful of threads a
/// matching epoch runs (shard workers + router + flusher) rarely collide
/// on a cache line.
const STRIPES: usize = 16;

/// A cache-line-padded atomic, so neighboring stripes never false-share.
#[repr(align(64))]
#[derive(Default)]
struct PadAtomicU64(AtomicU64);

static NEXT_THREAD_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// This thread's stable stripe slot, assigned round-robin on first use.
    static THREAD_SLOT: usize =
        NEXT_THREAD_SLOT.fetch_add(1, Ordering::Relaxed) % STRIPES;
}

#[inline]
fn my_stripe() -> usize {
    THREAD_SLOT.with(|s| *s)
}

// ---------------------------------------------------------------------------
// counter / gauge
// ---------------------------------------------------------------------------

/// Monotonic counter, striped across cache-line-padded atomics so
/// concurrent writers from different threads do not bounce one line.
#[derive(Default)]
pub struct Counter {
    stripes: [PadAtomicU64; STRIPES],
}

impl Counter {
    /// Add `n` (relaxed; this is monitoring, not synchronization).
    #[inline]
    pub fn add(&self, n: u64) {
        self.stripes[my_stripe()].0.fetch_add(n, Ordering::Relaxed);
    }

    /// Increment by one.
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current total (sums the stripes; a racing `add` may or may not be
    /// included — fine for monitoring).
    pub fn get(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Instantaneous integer value (queue depths, live counts). Single atomic:
/// gauges are set/adjusted far less often than counters are bumped.
#[derive(Default)]
pub struct Gauge {
    value: AtomicU64,
}

impl Gauge {
    /// Set the gauge to `v`.
    #[inline]
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    /// Increase by `n`.
    #[inline]
    pub fn inc(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Decrease by `n` (saturating at zero via wrapping guard: callers pair
    /// inc/dec, so underflow indicates a bug — clamp rather than wrap so a
    /// monitoring race never renders as 2^64).
    #[inline]
    pub fn dec(&self, n: u64) {
        let mut cur = self.value.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.value.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Floating-point accumulator (seconds of router time, repair fractions) —
/// an `f64` stored as atomic bits, updated with a CAS loop. Used on
/// per-epoch paths, not per-edge ones, so the loop never spins hot.
#[derive(Default)]
pub struct FGauge {
    bits: AtomicU64,
}

impl FGauge {
    /// Set the value.
    #[inline]
    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Add `v` to the accumulated value.
    pub fn add(&self, v: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

// ---------------------------------------------------------------------------
// histogram
// ---------------------------------------------------------------------------

/// Sub-bucket resolution: 2^3 = 8 sub-buckets per power-of-two octave, so
/// a bucket's width is at most 1/8 of its lower bound.
const SUB_BITS: u32 = 3;
const SUB: u64 = 1 << SUB_BITS;

/// Total fixed buckets covering the full `u64` range at [`SUB`] sub-buckets
/// per octave (values below `2·SUB` get exact single-value buckets). The
/// largest index is `bucket_of(u64::MAX)`: shift 60, so
/// `((60 + 1) << SUB_BITS) + (SUB - 1) = 495`, hence 496 buckets.
pub const NUM_BUCKETS: usize = ((64 - SUB_BITS) as usize + 1) << SUB_BITS;

/// The bucket index of `v` — log-scale with [`SUB`] linear sub-buckets per
/// octave (the HdrHistogram idea at 3 significant bits).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v < SUB * 2 {
        return v as usize; // exact buckets for 0..16
    }
    let msb = 63 - v.leading_zeros();
    let shift = msb - SUB_BITS; // ≥ 1
    let sub = ((v >> shift) - SUB) as usize; // 0..SUB
    ((shift as usize + 1) << SUB_BITS) + sub
}

/// Inclusive `[lo, hi]` value range of bucket `idx` — the exact inverse of
/// [`bucket_of`]: every `v` with `bucket_of(v) == idx` satisfies
/// `lo ≤ v ≤ hi`, and `(hi - lo) ≤ lo / 8` (one bucket's relative error).
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < (SUB * 2) as usize {
        return (idx as u64, idx as u64);
    }
    let shift = (idx >> SUB_BITS) as u32 - 1;
    let sub = (idx & (SUB as usize - 1)) as u64;
    let lo = (SUB + sub) << shift;
    let hi = lo + (1u64 << shift) - 1;
    (lo, hi)
}

/// One bucket's most recent in-span sample: the link from a histogram
/// bucket back to the trace span that produced it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Exemplar {
    /// The raw recorded sample (pre-scale: nanoseconds for `_seconds`
    /// histograms, exported scaled like the bucket bounds).
    pub value: u64,
    /// Capture time in microseconds on the trace clock
    /// ([`trace::now_us`]), the same origin span `ts` values use.
    pub ts_us: u64,
    /// The recording thread's trace tid.
    pub tid: u64,
    /// The enclosing span's engine epoch (0 when it had none).
    pub epoch: u64,
    /// The enclosing span's process-unique id.
    pub span_id: u64,
}

/// Fixed-bucket log-scale histogram over `u64` samples (latencies in
/// nanoseconds, sizes in bytes). Recording is one relaxed `fetch_add`;
/// the full history is retained in bucket form, so percentiles reflect
/// every sample ever recorded, within one bucket's relative error.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    /// Sparse per-bucket exemplar slots, keyed by bucket index. Behind a
    /// mutex taken with `try_lock` on the record path: exemplars are
    /// best-effort monitoring, so a collision skips the update rather
    /// than stall the recording thread. Only populated while tracing is
    /// on (the current-span cell is empty otherwise).
    exemplars: Mutex<Vec<(usize, Exemplar)>>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            exemplars: Mutex::new(Vec::new()),
        }
    }

    /// Record one sample. When the calling thread is inside a live trace
    /// span, the sample also becomes its bucket's exemplar.
    #[inline]
    pub fn record(&self, v: u64) {
        let idx = bucket_of(v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        if let Some(cur) = trace::current_span() {
            self.attach_exemplar(
                idx,
                Exemplar {
                    value: v,
                    ts_us: trace::now_us(),
                    tid: cur.tid,
                    epoch: cur.epoch,
                    span_id: cur.span_id,
                },
            );
        }
    }

    fn attach_exemplar(&self, idx: usize, ex: Exemplar) {
        if let Ok(mut slots) = self.exemplars.try_lock() {
            match slots.iter_mut().find(|(i, _)| *i == idx) {
                Some(slot) => slot.1 = ex,
                None => slots.push((idx, ex)),
            }
        }
    }

    /// The retained exemplars as `(bucket_idx, exemplar)`, ascending by
    /// bucket index.
    pub fn exemplars(&self) -> Vec<(usize, Exemplar)> {
        let mut out = self.exemplars.lock().unwrap().clone();
        out.sort_by_key(|(i, _)| *i);
        out
    }

    /// Record a [`std::time::Duration`] in nanoseconds (saturating).
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u128::from(u64::MAX)) as u64);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// The `p`-th percentile (`0 ≤ p ≤ 100`) by nearest rank, reported as
    /// the **upper bound** of the bucket holding that sample — so the
    /// estimate never under-reports, and over-reports by at most one
    /// bucket's relative width (≤ 12.5%). Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        // nearest-rank: the k-th smallest sample, k in 1..=total
        let rank = ((p / 100.0) * total as f64).ceil().clamp(1.0, total as f64) as u64;
        let mut seen = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return bucket_bounds(idx).1;
            }
        }
        bucket_bounds(NUM_BUCKETS - 1).1
    }

    /// Non-empty buckets as `(upper_bound, cumulative_count)`, ascending —
    /// the Prometheus `_bucket{le=…}` series (the exporter appends `+Inf`).
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        self.bucket_cells().into_iter().map(|(_, hi, cum)| (hi, cum)).collect()
    }

    /// Non-empty buckets as `(bucket_idx, upper_bound, cumulative_count)`,
    /// ascending — the index keys each bucket line to its exemplar slot.
    pub fn bucket_cells(&self) -> Vec<(usize, u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (idx, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c > 0 {
                cum += c;
                out.push((idx, bucket_bounds(idx).1, cum));
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

/// Label set of one instrument: ordered `(key, value)` pairs.
pub type Labels = Vec<(String, String)>;

struct Registered<T> {
    name: String,
    help: String,
    labels: Labels,
    /// Multiplier applied to raw sample values on export (histograms record
    /// integer nanoseconds/bytes; Prometheus wants seconds for latencies).
    scale: f64,
    metric: Arc<T>,
}

#[derive(Default)]
struct Inner {
    counters: Vec<Registered<Counter>>,
    gauges: Vec<Registered<Gauge>>,
    fgauges: Vec<Registered<FGauge>>,
    histograms: Vec<Registered<Histogram>>,
}

fn find<T>(list: &[Registered<T>], name: &str, labels: &Labels) -> Option<Arc<T>> {
    list.iter()
        .find(|r| r.name == name && r.labels == *labels)
        .map(|r| Arc::clone(&r.metric))
}

/// The instrument registry: registration is mutexed (cold), updates via the
/// returned handles are lock-free, and [`render_prometheus`]
/// (Self::render_prometheus) snapshots everything for export.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    /// An empty registry (tests; production code uses [`global`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or register a counter. Same `(name, labels)` → same handle.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, help, Vec::new())
    }

    /// Labelled variant of [`counter`](Self::counter).
    pub fn counter_with(&self, name: &str, help: &str, labels: Labels) -> Arc<Counter> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(m) = find(&inner.counters, name, &labels) {
            return m;
        }
        let metric = Arc::new(Counter::default());
        inner.counters.push(Registered {
            name: name.into(),
            help: help.into(),
            labels,
            scale: 1.0,
            metric: Arc::clone(&metric),
        });
        metric
    }

    /// Get or register an integer gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_with(name, help, Vec::new())
    }

    /// Labelled variant of [`gauge`](Self::gauge).
    pub fn gauge_with(&self, name: &str, help: &str, labels: Labels) -> Arc<Gauge> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(m) = find(&inner.gauges, name, &labels) {
            return m;
        }
        let metric = Arc::new(Gauge::default());
        inner.gauges.push(Registered {
            name: name.into(),
            help: help.into(),
            labels,
            scale: 1.0,
            metric: Arc::clone(&metric),
        });
        metric
    }

    /// Get or register a floating-point gauge.
    pub fn fgauge(&self, name: &str, help: &str) -> Arc<FGauge> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(m) = find(&inner.fgauges, name, &Vec::new()) {
            return m;
        }
        let metric = Arc::new(FGauge::default());
        inner.fgauges.push(Registered {
            name: name.into(),
            help: help.into(),
            labels: Vec::new(),
            scale: 1.0,
            metric: Arc::clone(&metric),
        });
        metric
    }

    /// Get or register a latency histogram: samples are recorded in
    /// **nanoseconds** and exported in seconds.
    pub fn histogram_secs(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.histogram_scaled(name, help, Vec::new(), 1e-9)
    }

    /// Labelled variant of [`histogram_secs`](Self::histogram_secs).
    pub fn histogram_secs_with(&self, name: &str, help: &str, labels: Labels) -> Arc<Histogram> {
        self.histogram_scaled(name, help, labels, 1e-9)
    }

    /// Get or register a raw-unit histogram (bytes, counts): samples are
    /// exported unscaled.
    pub fn histogram_raw(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.histogram_scaled(name, help, Vec::new(), 1.0)
    }

    fn histogram_scaled(
        &self,
        name: &str,
        help: &str,
        labels: Labels,
        scale: f64,
    ) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(m) = find(&inner.histograms, name, &labels) {
            return m;
        }
        let metric = Arc::new(Histogram::new());
        inner.histograms.push(Registered {
            name: name.into(),
            help: help.into(),
            labels,
            scale,
            metric: Arc::clone(&metric),
        });
        metric
    }

    /// Render every registered instrument in the Prometheus text exposition
    /// format, ending with an OpenMetrics-style `# EOF` line (which doubles
    /// as the framing marker the wire protocol's `METRICS` reply needs).
    pub fn render_prometheus(&self) -> String {
        let inner = self.inner.lock().unwrap();
        let mut out = String::new();
        // a labelled family declares HELP/TYPE exactly once
        let mut typed: Vec<String> = Vec::new();
        let mut header = |out: &mut String, name: &str, help: &str, kind: &str| {
            if !typed.iter().any(|t| t == name) {
                out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
                typed.push(name.to_string());
            }
        };
        for r in &inner.counters {
            header(&mut out, &r.name, &r.help, "counter");
            out.push_str(&format!(
                "{}{} {}\n",
                r.name,
                render_labels(&r.labels),
                r.metric.get()
            ));
        }
        for r in &inner.gauges {
            header(&mut out, &r.name, &r.help, "gauge");
            out.push_str(&format!(
                "{}{} {}\n",
                r.name,
                render_labels(&r.labels),
                r.metric.get()
            ));
        }
        for r in &inner.fgauges {
            header(&mut out, &r.name, &r.help, "gauge");
            out.push_str(&format!(
                "{}{} {}\n",
                r.name,
                render_labels(&r.labels),
                render_f64(r.metric.get())
            ));
        }
        for r in &inner.histograms {
            header(&mut out, &r.name, &r.help, "histogram");
            let labels = &r.labels;
            let exemplars = r.metric.exemplars();
            for (idx, hi, cum) in r.metric.bucket_cells() {
                let mut le_labels = labels.clone();
                le_labels.push(("le".into(), render_f64(hi as f64 * r.scale)));
                out.push_str(&format!(
                    "{}_bucket{} {}",
                    r.name,
                    render_labels(&le_labels),
                    cum
                ));
                // OpenMetrics exemplar: `# {span_id="..."} value ts`, on
                // the trace clock so lint can resolve the span by id
                if let Some((_, ex)) = exemplars.iter().find(|(i, _)| *i == idx) {
                    out.push_str(&format!(
                        " # {{span_id=\"{}\"}} {} {}",
                        trace::format_span_id(ex.span_id),
                        render_f64(ex.value as f64 * r.scale),
                        render_f64(ex.ts_us as f64 * 1e-6)
                    ));
                }
                out.push('\n');
            }
            let mut inf_labels = labels.clone();
            inf_labels.push(("le".into(), "+Inf".into()));
            out.push_str(&format!(
                "{}_bucket{} {}\n",
                r.name,
                render_labels(&inf_labels),
                r.metric.count()
            ));
            out.push_str(&format!(
                "{}_sum{} {}\n",
                r.name,
                render_labels(labels),
                render_f64(r.metric.sum() as f64 * r.scale)
            ));
            out.push_str(&format!(
                "{}_count{} {}\n",
                r.name,
                render_labels(labels),
                r.metric.count()
            ));
        }
        out.push_str("# EOF\n");
        out
    }
}

fn render_labels(labels: &Labels) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
        .collect();
    format!("{{{}}}", parts.join(","))
}

fn render_f64(v: f64) -> String {
    if !v.is_finite() {
        return if v > 0.0 { "+Inf".into() } else { "-Inf".into() };
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        // enough digits to round-trip the bucket bounds distinctly
        let s = format!("{v:.9}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

/// The process-global registry every subsystem registers against. Using a
/// global keeps instrument wiring out of constructor signatures: the pool,
/// the WAL, the snapshot writer, and the engine each `get_or_register`
/// their instruments here at construction.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

// ---------------------------------------------------------------------------
// text-format validation
// ---------------------------------------------------------------------------

/// Validate Prometheus text exposition syntax: every line is a comment, a
/// `# HELP`/`# TYPE` declaration, or `name[{labels}] value`; sample names
/// (modulo `_bucket`/`_sum`/`_count` suffixes) have a preceding `# TYPE`;
/// histogram `le` bucket values are non-decreasing per series. Used by the
/// CI smoke (`skipper-cli lint --metrics`) and the obs tests.
pub fn validate_prometheus(text: &str) -> Result<(), String> {
    let name_ok = |s: &str| {
        !s.is_empty()
            && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
            && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    };
    let mut types: Vec<(String, String)> = Vec::new();
    let mut last_bucket: std::collections::BTreeMap<String, u64> = Default::default();
    for (ln, line) in text.lines().enumerate() {
        let ln = ln + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            if rest == "EOF" {
                continue;
            }
            let mut it = rest.splitn(3, ' ');
            let kind = it.next().unwrap_or("");
            let name = it.next().unwrap_or("");
            match kind {
                "HELP" => {
                    if !name_ok(name) {
                        return Err(format!("line {ln}: bad HELP metric name {name:?}"));
                    }
                }
                "TYPE" => {
                    let ty = it.next().unwrap_or("");
                    if !name_ok(name) {
                        return Err(format!("line {ln}: bad TYPE metric name {name:?}"));
                    }
                    if !["counter", "gauge", "histogram", "summary", "untyped"].contains(&ty) {
                        return Err(format!("line {ln}: unknown TYPE {ty:?}"));
                    }
                    types.push((name.to_string(), ty.to_string()));
                }
                _ => return Err(format!("line {ln}: unknown comment directive {kind:?}")),
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(format!("line {ln}: comments must start with '# '"));
        }
        // sample line: name[{labels}] value [# {exemplar-labels} value [ts]]
        let (line, exemplar) = match line.split_once(" # ") {
            Some((main, ex)) => (main, Some(ex)),
            None => (line, None),
        };
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {ln}: no value field"))?;
        let val: f64 = match value {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            "NaN" => f64::NAN,
            v => v
                .parse()
                .map_err(|_| format!("line {ln}: unparsable value {value:?}"))?,
        };
        let (name, labels) = match series.split_once('{') {
            Some((n, rest)) => {
                let body = rest
                    .strip_suffix('}')
                    .ok_or_else(|| format!("line {ln}: unterminated label set"))?;
                (n, Some(body))
            }
            None => (series, None),
        };
        if !name_ok(name) {
            return Err(format!("line {ln}: bad sample name {name:?}"));
        }
        if let Some(body) = labels {
            for pair in split_label_pairs(body) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| format!("line {ln}: label {pair:?} missing '='"))?;
                if !name_ok(k) {
                    return Err(format!("line {ln}: bad label name {k:?}"));
                }
                if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
                    return Err(format!("line {ln}: label value {v:?} not quoted"));
                }
            }
        }
        if let Some(ex) = exemplar {
            if !name.ends_with("_bucket") {
                return Err(format!("line {ln}: exemplar on non-bucket sample {name:?}"));
            }
            validate_exemplar(ex).map_err(|e| format!("line {ln}: {e}"))?;
        }
        // base name: strip histogram sample suffixes for the TYPE check
        let base = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| name.strip_suffix(suf).filter(|b| has_type(&types, b)))
            .unwrap_or(name);
        if !has_type(&types, base) {
            return Err(format!("line {ln}: sample {name:?} has no preceding # TYPE"));
        }
        // per-series histogram bucket monotonicity
        if name.ends_with("_bucket") && val.is_finite() {
            let cum = val as u64;
            let key = series.to_string();
            let prefix = key
                .split_once("le=")
                .map(|(p, _)| p.to_string())
                .unwrap_or_else(|| key.clone());
            if let Some(&prev) = last_bucket.get(&prefix) {
                if cum < prev {
                    return Err(format!("line {ln}: histogram buckets not cumulative"));
                }
            }
            last_bucket.insert(prefix, cum);
        }
    }
    if types.is_empty() {
        return Err("no # TYPE declarations found".into());
    }
    Ok(())
}

fn has_type(types: &[(String, String)], name: &str) -> bool {
    types.iter().any(|(n, _)| n == name)
}

/// Validate one OpenMetrics exemplar suffix (the part after `" # "`):
/// `{label="value",...} value [timestamp]`.
fn validate_exemplar(ex: &str) -> Result<(), String> {
    let body = ex
        .strip_prefix('{')
        .ok_or_else(|| format!("exemplar {ex:?} must start with '{{'"))?;
    let (labels, rest) = body
        .split_once('}')
        .ok_or_else(|| format!("exemplar {ex:?} has an unterminated label set"))?;
    for pair in split_label_pairs(labels) {
        let (k, v) = pair
            .split_once('=')
            .ok_or_else(|| format!("exemplar label {pair:?} missing '='"))?;
        if k.is_empty() || !k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return Err(format!("bad exemplar label name {k:?}"));
        }
        if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
            return Err(format!("exemplar label value {v:?} not quoted"));
        }
    }
    let mut fields = rest.split_whitespace();
    let value = fields.next().ok_or_else(|| format!("exemplar {ex:?} has no value"))?;
    value
        .parse::<f64>()
        .map_err(|_| format!("unparsable exemplar value {value:?}"))?;
    if let Some(ts) = fields.next() {
        ts.parse::<f64>()
            .map_err(|_| format!("unparsable exemplar timestamp {ts:?}"))?;
    }
    if let Some(extra) = fields.next() {
        return Err(format!("trailing exemplar field {extra:?}"));
    }
    Ok(())
}

/// The distinct exemplar span ids attached to `family`'s `_bucket` lines
/// in a rendered exposition — what `lint --require-exemplars` resolves
/// against the trace document's span ids.
pub fn exemplar_span_ids(text: &str, family: &str) -> Vec<String> {
    let prefix = format!("{family}_bucket");
    let mut out = Vec::new();
    for line in text.lines() {
        if !line.starts_with(&prefix) {
            continue;
        }
        let Some((_, ex)) = line.split_once(" # ") else {
            continue;
        };
        let Some(labels) = ex.strip_prefix('{').and_then(|b| b.split_once('}')) else {
            continue;
        };
        for pair in split_label_pairs(labels.0) {
            if let Some((k, v)) = pair.split_once('=') {
                if k == "span_id" {
                    let v = v.trim_matches('"');
                    if !out.iter().any(|s| s == v) {
                        out.push(v.to_string());
                    }
                }
            }
        }
    }
    out
}

/// Split a Prometheus label body on commas that are outside quoted values.
fn split_label_pairs(body: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut escape = false;
    for c in body.chars() {
        if escape {
            cur.push(c);
            escape = false;
            continue;
        }
        match c {
            '\\' if in_quotes => {
                cur.push(c);
                escape = true;
            }
            '"' => {
                cur.push(c);
                in_quotes = !in_quotes;
            }
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_tile_the_u64_range() {
        // bucket_of is monotone, bucket_bounds inverts it, and widths stay
        // within one-eighth of the lower bound
        let mut probes: Vec<u64> = (0..2048).collect();
        for shift in 11..64 {
            probes.push(1u64 << shift);
            probes.push((1u64 << shift) + 1);
            probes.push((1u64 << shift) - 1);
            probes.push((1u64 << shift) | (1 << (shift - 2)));
        }
        probes.push(u64::MAX);
        let mut last_idx = 0usize;
        probes.sort_unstable();
        for &v in &probes {
            let idx = bucket_of(v);
            assert!(idx < NUM_BUCKETS, "v={v} idx={idx}");
            assert!(idx >= last_idx, "bucket_of not monotone at {v}");
            last_idx = idx;
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && v <= hi, "v={v} outside [{lo},{hi}]");
            assert!(hi - lo <= lo.max(8) / 8, "bucket [{lo},{hi}] too wide");
        }
        // adjacent buckets tile without gap or overlap
        for idx in 0..NUM_BUCKETS - 1 {
            let (_, hi) = bucket_bounds(idx);
            let (lo2, _) = bucket_bounds(idx + 1);
            if hi != u64::MAX {
                assert_eq!(lo2, hi + 1, "gap after bucket {idx}");
            }
        }
    }

    #[test]
    fn histogram_percentiles_bracket_exact_values() {
        let h = Histogram::new();
        let vals: Vec<u64> = (1..=1000u64).map(|i| i * i).collect();
        for &v in &vals {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
            let est = h.percentile(p);
            let rank = ((p / 100.0) * 1000.0).ceil().clamp(1.0, 1000.0) as usize;
            let exact = vals[rank - 1];
            let (lo, hi) = bucket_bounds(bucket_of(exact));
            assert!(est >= exact, "p{p}: est {est} < exact {exact}");
            assert_eq!(est, hi, "p{p}: est must be the exact sample's bucket hi");
            assert!(lo <= exact, "p{p}");
        }
        assert_eq!(Histogram::new().percentile(50.0), 0, "empty histogram");
    }

    #[test]
    fn counters_sum_across_threads() {
        let c = Arc::new(Counter::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..10_000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 80_000);
    }

    #[test]
    fn gauge_dec_clamps_at_zero_and_fgauge_accumulates() {
        let g = Gauge::default();
        g.inc(3);
        g.dec(5);
        assert_eq!(g.get(), 0);
        let f = FGauge::default();
        f.add(0.5);
        f.add(0.25);
        assert!((f.get() - 0.75).abs() < 1e-12);
        f.set(2.0);
        assert_eq!(f.get(), 2.0);
    }

    #[test]
    fn registry_dedups_and_renders_valid_prometheus() {
        let reg = Registry::new();
        let c1 = reg.counter("test_ops_total", "ops");
        let c2 = reg.counter("test_ops_total", "ops");
        c1.add(3);
        c2.add(4);
        assert_eq!(c1.get(), 7, "same name must share the instrument");
        reg.gauge("test_depth", "queue depth").set(2);
        reg.fgauge("test_frac", "fraction").set(0.125);
        let h = reg.histogram_secs("test_latency_seconds", "latency");
        h.record(1_000_000); // 1 ms
        h.record(2_000_000);
        let labelled = reg.histogram_secs_with(
            "test_shard_seconds",
            "per-shard",
            vec![("shard".into(), "0".into())],
        );
        labelled.record(500);
        let text = reg.render_prometheus();
        validate_prometheus(&text).unwrap_or_else(|e| panic!("{e}\n---\n{text}"));
        assert!(text.contains("# TYPE test_ops_total counter"));
        assert!(text.contains("test_ops_total 7"));
        assert!(text.contains("# TYPE test_latency_seconds histogram"));
        assert!(text.contains("test_latency_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("test_latency_seconds_count 2"));
        assert!(text.contains("test_shard_seconds_bucket{shard=\"0\",le="));
        assert!(text.ends_with("# EOF\n"));
    }

    #[test]
    fn exemplar_renders_openmetrics_syntax_and_roundtrips() {
        let reg = Registry::new();
        let h = reg.histogram_secs("test_exemplar_seconds", "latency with exemplars");
        h.record(1_000_000); // 1 ms
        h.record(2_000_000_000); // 2 s — a different bucket
        // attach exemplars directly (the span-capture path needs the
        // process-global trace gate; the integration tests cover it)
        let ex = Exemplar { value: 2_000_000_000, ts_us: 1_500_000, tid: 3, epoch: 7, span_id: 0xabcd };
        h.attach_exemplar(bucket_of(ex.value), ex);
        assert_eq!(h.exemplars(), vec![(bucket_of(ex.value), ex)]);
        // a newer sample in the same bucket replaces the slot
        let newer = Exemplar { value: 1_900_000_000, ts_us: 2_000_000, tid: 3, epoch: 8, span_id: 0xabce };
        assert_eq!(bucket_of(newer.value), bucket_of(ex.value), "same bucket");
        h.attach_exemplar(bucket_of(newer.value), newer);
        assert_eq!(h.exemplars(), vec![(bucket_of(ex.value), newer)]);
        let text = reg.render_prometheus();
        validate_prometheus(&text).unwrap_or_else(|e| panic!("{e}\n---\n{text}"));
        let ids = exemplar_span_ids(&text, "test_exemplar_seconds");
        assert_eq!(ids, vec![trace::format_span_id(0xabce)]);
        // the exemplar rides the bucket line, value scaled like the bounds
        let line = text
            .lines()
            .find(|l| l.contains(" # {"))
            .expect("one bucket line carries the exemplar");
        assert!(line.starts_with("test_exemplar_seconds_bucket{le="), "{line}");
        assert!(line.contains("# {span_id=\"000000000000abce\"} 1.9 2"), "{line}");
        // buckets without an exemplar stay bare
        assert!(
            text.lines().any(|l| l.starts_with("test_exemplar_seconds_bucket") && !l.contains('#')),
            "{text}"
        );
    }

    #[test]
    fn validator_rejects_malformed_text() {
        assert!(validate_prometheus("").is_err(), "no TYPE at all");
        assert!(validate_prometheus("#bad comment\n").is_err());
        assert!(
            validate_prometheus("# TYPE m counter\nm not_a_number\n").is_err(),
            "unparsable value"
        );
        assert!(
            validate_prometheus("orphan_sample 1\n").is_err(),
            "sample without TYPE"
        );
        assert!(
            validate_prometheus(
                "# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\n"
            )
            .is_err(),
            "non-cumulative buckets"
        );
        assert!(validate_prometheus("# TYPE m counter\nm{x=unquoted} 1\n").is_err());
    }

    #[test]
    fn validator_checks_exemplar_syntax() {
        let ok = "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # {span_id=\"00ab\"} 0.5 12.25\n";
        validate_prometheus(ok).unwrap();
        let no_ts = "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # {span_id=\"00ab\"} 0.5\n";
        validate_prometheus(no_ts).unwrap();
        for bad in [
            // exemplars only belong on _bucket lines
            "# TYPE m counter\nm 1 # {span_id=\"00ab\"} 0.5\n",
            // missing label braces
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # span_id=\"00ab\" 0.5\n",
            // unquoted label value
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # {span_id=00ab} 0.5\n",
            // missing exemplar value
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # {span_id=\"00ab\"}\n",
            // unparsable exemplar value
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # {span_id=\"00ab\"} x\n",
            // trailing junk after the timestamp
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5 # {span_id=\"00ab\"} 0.5 1 z\n",
        ] {
            assert!(validate_prometheus(bad).is_err(), "accepted: {bad}");
        }
    }
}
