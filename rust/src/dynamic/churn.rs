//! Insert/delete churn driver over the [`ShardedDynamicMatcher`] — the
//! shared workload loop behind `skipper-cli churn`, the `dynamic` and
//! `scale` coordinator experiments, and `benches/dynamic_churn.rs`.
//!
//! The schedule is generator-faithful: the edge *population* comes from one
//! of the synthetic generators, so degree structure (power-law hubs for
//! RMAT/BA, bounded degree for grids) carries into the churn. A warmup
//! phase inserts the population in a few large epochs; each churn epoch
//! then mixes `batch × delete_frac` deletions of uniformly random live
//! edges with insertions drawn from the not-yet-live population (deleted
//! edges are recycled once the population runs dry, so arbitrarily long
//! runs never starve).

use super::adjacency::AdjLayout;
use super::engine::{EpochReport, Update};
use super::partition::{ShardExec, ShardedDynamicMatcher};
use crate::par::topology::PinPolicy;
use crate::graph::gen::{barabasi_albert, erdos_renyi, grid, rmat, GenConfig};
use crate::util::rng::Xoshiro256pp;
use crate::VertexId;

/// Which synthetic generator supplies the churn's edge population.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnGen {
    /// Erdős–Rényi G(n, m).
    Er { n: usize, m: usize },
    /// Barabási–Albert preferential attachment.
    Ba { n: usize, m_per_vertex: usize },
    /// 2-D grid (rows × cols), no torus wrap.
    Grid { rows: usize, cols: usize },
    /// RMAT with Graph500 probabilities.
    Rmat { scale: u32, avg_degree: u32 },
}

impl ChurnGen {
    /// Parse a generator family name with size knobs.
    pub fn parse(name: &str, scale: u32, avg_degree: u32) -> Result<Self, String> {
        let n = 1usize << scale;
        Ok(match name {
            "er" => ChurnGen::Er { n, m: n * avg_degree as usize },
            "ba" => ChurnGen::Ba { n, m_per_vertex: (avg_degree as usize / 2).max(1) },
            "grid" => {
                let side = (n as f64).sqrt().ceil() as usize;
                ChurnGen::Grid { rows: side, cols: side }
            }
            "rmat" => ChurnGen::Rmat { scale, avg_degree },
            other => return Err(format!("unknown generator {other:?} (er|ba|grid|rmat)")),
        })
    }

    /// The family name (`er`/`ba`/`grid`/`rmat`).
    pub fn name(&self) -> &'static str {
        match self {
            ChurnGen::Er { .. } => "er",
            ChurnGen::Ba { .. } => "ba",
            ChurnGen::Grid { .. } => "grid",
            ChurnGen::Rmat { .. } => "rmat",
        }
    }

    /// Vertex-universe size of the generated population.
    pub fn num_vertices(&self) -> usize {
        match *self {
            ChurnGen::Er { n, .. } | ChurnGen::Ba { n, .. } => n,
            ChurnGen::Grid { rows, cols } => rows * cols,
            ChurnGen::Rmat { scale, .. } => 1usize << scale,
        }
    }

    /// Materialize the canonical deduplicated edge population.
    pub fn population(&self, seed: u64) -> Vec<(VertexId, VertexId)> {
        let raw = match *self {
            ChurnGen::Er { n, m } => erdos_renyi::edges(n, m, seed).edges,
            ChurnGen::Ba { n, m_per_vertex } => barabasi_albert::edges(n, m_per_vertex, seed).edges,
            ChurnGen::Grid { rows, cols } => grid::edges(rows, cols, false).edges,
            ChurnGen::Rmat { scale, avg_degree } => {
                rmat::edges_with_probs(
                    &GenConfig { scale, avg_degree, seed },
                    rmat::GRAPH500_PROBS,
                )
                .edges
            }
        };
        let mut canon: Vec<(VertexId, VertexId)> = raw
            .into_iter()
            .filter(|&(u, v)| u != v)
            .map(|(u, v)| (u.min(v), u.max(v)))
            .collect();
        canon.sort_unstable();
        canon.dedup();
        canon
    }
}

/// One steady-state churn batch: `batch/2` random live edges, each deleted
/// and immediately re-inserted — the live count is invariant, so repeated
/// batches measure sustained churn without draining the graph. Shared by
/// the `durability` experiment and `benches/persist.rs` (the
/// warmed-engine logging/recovery workloads), so they provably measure the
/// same schedule shape.
pub fn recycle_batch(
    live: &[(VertexId, VertexId)],
    rng: &mut Xoshiro256pp,
    round: usize,
    batch: usize,
) -> Vec<Update> {
    let mut ups = Vec::with_capacity(batch);
    for i in 0..batch / 2 {
        let (u, v) = live[(rng.next_usize(live.len()) + round + i) % live.len()];
        ups.push(Update::Delete(u, v));
        ups.push(Update::Insert(u, v));
    }
    ups
}

/// Everything one churn run needs: the population generator, the schedule
/// shape, and the engine configuration.
#[derive(Clone, Debug)]
pub struct ChurnConfig {
    /// Edge-population generator family and size.
    pub gen: ChurnGen,
    /// Schedule seed (population shuffle + per-epoch sampling).
    pub seed: u64,
    /// Matcher threads.
    pub threads: usize,
    /// Engine shards (`P`): vertex-partitioned parallel mutate phase.
    /// `1` reproduces the single-shard [`super::DynamicMatcher`] behavior.
    pub engine_shards: usize,
    /// Dispatch shard phases to the persistent worker pool (default);
    /// `false` forks scoped threads per epoch — the measured baseline.
    pub pool: bool,
    /// Adjacency sidecar storage layout (`flat` vs cache-line `blocked`).
    pub layout: AdjLayout,
    /// Worker→core pin policy for the shard pool (`--pin`); placement
    /// only, never decisions — results are identical at any policy.
    pub pin: PinPolicy,
    /// Churn epochs after warmup.
    pub epochs: usize,
    /// Updates per churn epoch.
    pub batch: usize,
    /// Fraction of each batch that deletes live edges (0.5 = the 50/50
    /// schedule of the acceptance run).
    pub delete_frac: f64,
    /// Warmup epochs that insert the initial population.
    pub warmup_epochs: usize,
    /// Verify maximality over the live set after every epoch.
    pub verify: bool,
    /// Write the engine's end-of-run state to this snapshot file
    /// ([`crate::persist::snapshot`] format), so a warmed-up workload can
    /// restart instantly via [`load`](Self::load).
    pub save: Option<String>,
    /// Restore the engine from this snapshot file instead of running the
    /// warmup phase (the snapshot's live edges become the live set; its
    /// universe must match the generator's).
    pub load: Option<String>,
}

impl ChurnConfig {
    /// Defaults matching the acceptance run: 10 epochs of 10k updates at
    /// 50/50 insert/delete, verified, pooled single-shard engine.
    pub fn new(gen: ChurnGen) -> Self {
        Self {
            gen,
            seed: 1,
            threads: 4,
            engine_shards: 1,
            pool: true,
            layout: AdjLayout::default(),
            pin: PinPolicy::None,
            epochs: 10,
            batch: 10_000,
            delete_frac: 0.5,
            warmup_epochs: 8,
            verify: true,
            save: None,
            load: None,
        }
    }

    /// The engine shard-dispatch policy this config selects.
    pub fn shard_exec(&self) -> ShardExec {
        ShardExec::from_pool_flag(self.pool)
    }
}

/// Outcome of one epoch, as handed to the per-epoch observer.
pub struct ChurnEpoch {
    /// The engine's epoch report.
    pub report: EpochReport,
    /// True for population-insertion (warmup) epochs.
    pub warmup: bool,
    /// `None` when verification is off.
    pub verified: Option<Result<(), String>>,
}

/// Run summary across all epochs.
#[derive(Clone, Debug, Default)]
pub struct ChurnSummary {
    /// Churn (post-warmup) epochs run.
    pub epochs: usize,
    /// Warmup epochs run.
    pub warmup_epochs: usize,
    /// Insert updates issued across all epochs.
    pub total_inserts: usize,
    /// Delete updates issued across all epochs.
    pub total_deletes: usize,
    /// Edges re-examined by repair sweeps across all epochs.
    pub total_repair_edges: usize,
    /// Matched pairs destroyed by deletes across all epochs.
    pub destroyed_pairs: usize,
    /// Mean/max repair fraction over the *churn* (post-warmup) epochs.
    pub repair_frac_mean: f64,
    /// See [`repair_frac_mean`](Self::repair_frac_mean).
    pub repair_frac_max: f64,
    /// Per-epoch wall seconds, churn epochs only (for p50/p99 reporting).
    pub epoch_wall_s: Vec<f64>,
    /// Per-epoch mutate-phase wall seconds, churn epochs only — the phase
    /// `engine_shards` parallelizes.
    pub epoch_mutate_s: Vec<f64>,
    /// Per-epoch longest single-shard busy seconds inside the mutate phase
    /// — the "run" half of spawn-vs-run; `epoch_mutate_s[i] -
    /// epoch_mutate_run_s[i]` is that epoch's dispatch overhead.
    pub epoch_mutate_run_s: Vec<f64>,
    /// Per-epoch routing wall seconds (building the per-shard mailboxes).
    pub epoch_route_s: Vec<f64>,
    /// Live undirected edges at the end of the run.
    pub final_live_edges: u64,
    /// Adjacency-sidecar resident bytes at the end of the run — what the
    /// layout sweep compares across flat/blocked storage.
    pub final_adjacency_bytes: usize,
    /// Matched vertices at the end of the run.
    pub final_matched_vertices: usize,
    /// Epochs whose post-epoch verification passed.
    pub verified_epochs: usize,
    /// The end-of-run Prometheus exposition of the process-global metrics
    /// registry — what `churn --metrics-file` writes, byte-identical to a
    /// final `METRICS` scrape of the same instruments (engine, pool, WAL).
    pub metrics_text: String,
}

/// Drive a full warmup + churn schedule, invoking `observe` after every
/// epoch. Fails on the first verification violation.
pub fn run_churn(
    cfg: &ChurnConfig,
    mut observe: impl FnMut(&ChurnEpoch),
) -> Result<ChurnSummary, String> {
    let n = cfg.gen.num_vertices();
    let mut rng = Xoshiro256pp::new(cfg.seed ^ 0x5eed);
    let mut pending = cfg.gen.population(cfg.seed);
    rng.shuffle(&mut pending);
    if pending.is_empty() {
        return Err("generator produced no edges".into());
    }
    let engine = ShardedDynamicMatcher::with_exec_layout_pin(
        n,
        cfg.threads,
        cfg.engine_shards,
        cfg.shard_exec(),
        cfg.layout,
        cfg.pin,
    );
    let mut live: Vec<(VertexId, VertexId)> = Vec::with_capacity(pending.len());
    let mut graveyard: Vec<(VertexId, VertexId)> = Vec::new();
    let mut summary = ChurnSummary::default();

    let mut step = |engine: &ShardedDynamicMatcher,
                    updates: &[Update],
                    warmup: bool,
                    summary: &mut ChurnSummary,
                    observe: &mut dyn FnMut(&ChurnEpoch)|
     -> Result<(), String> {
        let report = engine.apply_epoch(updates)?;
        summary.total_inserts += report.inserts;
        summary.total_deletes += report.deletes;
        summary.total_repair_edges += report.repair_edges;
        summary.destroyed_pairs += report.destroyed_pairs;
        if warmup {
            summary.warmup_epochs += 1;
        } else {
            summary.epochs += 1;
            summary.repair_frac_mean += report.repair_fraction();
            summary.repair_frac_max = summary.repair_frac_max.max(report.repair_fraction());
            summary.epoch_wall_s.push(report.wall_s);
            summary.epoch_mutate_s.push(report.mutate_wall_s);
            summary.epoch_mutate_run_s.push(report.mutate_run_s);
            summary.epoch_route_s.push(report.route_wall_s);
        }
        let verified = cfg.verify.then(|| engine.verify());
        let failure = match &verified {
            Some(Err(e)) => Some(e.clone()),
            _ => None,
        };
        if verified.is_some() && failure.is_none() {
            summary.verified_epochs += 1;
        }
        let epoch = report.epoch;
        // the observer sees the failing epoch too (CLI prints verify=FAIL)
        // before the run aborts
        observe(&ChurnEpoch { report, warmup, verified });
        match failure {
            Some(e) => Err(format!("epoch {epoch}: maximality violated: {e}")),
            None => Ok(()),
        }
    };

    // --- load: restore a saved warm state instead of warming up ----------
    if let Some(path) = &cfg.load {
        let snap = crate::persist::snapshot::read_file(std::path::Path::new(path))?;
        if snap.num_vertices as usize != n {
            return Err(format!(
                "{path}: snapshot universe |V|={} does not match generator |V|={n}",
                snap.num_vertices
            ));
        }
        crate::persist::recovery::restore_into(&engine, &snap)?;
        live = snap.live_edges;
        let live_set: std::collections::HashSet<(VertexId, VertexId)> =
            live.iter().copied().collect();
        pending.retain(|e| !live_set.contains(e));
    }

    // --- warmup: insert the population in a few large epochs (0 = start
    // churning against the empty graph; inserts then come from `pending`) --
    if cfg.load.is_none() && cfg.warmup_epochs > 0 {
        let per_warmup = pending.len().div_ceil(cfg.warmup_epochs);
        for _ in 0..cfg.warmup_epochs {
            if pending.is_empty() {
                break;
            }
            let take = per_warmup.min(pending.len());
            let batch: Vec<Update> = pending
                .drain(pending.len() - take..)
                .map(|(u, v)| Update::Insert(u, v))
                .collect();
            for upd in &batch {
                if let Update::Insert(u, v) = *upd {
                    live.push((u, v));
                }
            }
            step(&engine, &batch, true, &mut summary, &mut observe)?;
        }
    }

    // --- churn: mixed delete/insert epochs --------------------------------
    for _ in 0..cfg.epochs {
        let deletes = ((cfg.batch as f64 * cfg.delete_frac) as usize).min(live.len());
        let inserts = cfg.batch - deletes;
        let mut updates: Vec<Update> = Vec::with_capacity(cfg.batch);
        for _ in 0..deletes {
            let i = rng.next_usize(live.len());
            let (u, v) = live.swap_remove(i);
            graveyard.push((u, v));
            updates.push(Update::Delete(u, v));
        }
        for _ in 0..inserts {
            if pending.is_empty() {
                // recycle deleted edges so long runs never starve — but not
                // ones deleted in THIS epoch (insert-after-delete within an
                // epoch is legal but would skew the schedule's intent)
                let recycle_from = graveyard.len().saturating_sub(deletes);
                if recycle_from == 0 {
                    break;
                }
                pending.extend(graveyard.drain(..recycle_from));
                rng.shuffle(&mut pending);
            }
            match pending.pop() {
                Some((u, v)) => {
                    live.push((u, v));
                    updates.push(Update::Insert(u, v));
                }
                None => break,
            }
        }
        rng.shuffle(&mut updates);
        step(&engine, &updates, false, &mut summary, &mut observe)?;
    }

    if summary.epochs > 0 {
        summary.repair_frac_mean /= summary.epochs as f64;
    }
    summary.final_live_edges = engine.num_live_edges();
    summary.final_adjacency_bytes = engine.adjacency_bytes();
    summary.final_matched_vertices = engine.matched_vertices();
    summary.metrics_text = crate::obs::metrics::global().render_prometheus();

    // --- save: persist the warmed/churned state for instant restarts -----
    if let Some(path) = &cfg.save {
        let data = crate::persist::snapshot::SnapshotData::capture(&engine);
        crate::persist::snapshot::write_file(std::path::Path::new(path), &data)?;
    }
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn churn_runs_verified_on_every_generator_family() {
        for gen in [
            ChurnGen::Er { n: 512, m: 2048 },
            ChurnGen::Ba { n: 512, m_per_vertex: 3 },
            ChurnGen::Grid { rows: 24, cols: 24 },
            ChurnGen::Rmat { scale: 9, avg_degree: 4 },
        ] {
            let cfg = ChurnConfig {
                epochs: 5,
                batch: 200,
                warmup_epochs: 3,
                threads: 2,
                ..ChurnConfig::new(gen)
            };
            let mut seen = 0;
            let summary = run_churn(&cfg, |e| {
                seen += 1;
                assert!(matches!(e.verified, Some(Ok(()))), "{:?}", gen);
            })
            .unwrap_or_else(|e| panic!("{gen:?}: {e}"));
            assert_eq!(summary.epochs, 5, "{gen:?}");
            assert_eq!(seen, summary.epochs + summary.warmup_epochs);
            assert!(summary.final_live_edges > 0);
            assert!(summary.final_matched_vertices > 0);
            assert!(
                summary.metrics_text.ends_with("# EOF\n"),
                "metrics exposition must be EOF-framed"
            );
        }
    }

    #[test]
    fn fifty_fifty_schedule_holds_live_count_steady() {
        let cfg = ChurnConfig {
            epochs: 6,
            batch: 100,
            delete_frac: 0.5,
            warmup_epochs: 2,
            threads: 1,
            ..ChurnConfig::new(ChurnGen::Er { n: 400, m: 1600 })
        };
        let before_after: std::cell::RefCell<Vec<u64>> = Default::default();
        let summary = run_churn(&cfg, |e| {
            if !e.warmup {
                before_after.borrow_mut().push(e.report.live_edges);
            }
        })
        .unwrap();
        let counts = before_after.into_inner();
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(max - min <= 2 * cfg.batch as u64, "live count drifted: {counts:?}");
        assert!(summary.repair_frac_mean > 0.0, "deletes must cause some repair");
        assert!(summary.repair_frac_max <= 1.0);
    }

    #[test]
    fn sharded_churn_stays_verified_and_times_mutate() {
        // the same schedule at P ∈ {1, 4} under both shard-dispatch
        // policies: every epoch verified, and the per-epoch mutate wall,
        // mutate run, and route timings are all recorded
        for shards in [1usize, 4] {
            for pool in [true, false] {
                let cfg = ChurnConfig {
                    epochs: 4,
                    batch: 200,
                    warmup_epochs: 2,
                    threads: 2,
                    engine_shards: shards,
                    pool,
                    ..ChurnConfig::new(ChurnGen::Er { n: 512, m: 2048 })
                };
                let summary = run_churn(&cfg, |e| {
                    assert!(matches!(e.verified, Some(Ok(()))), "P={shards} pool={pool}");
                })
                .unwrap_or_else(|e| panic!("P={shards} pool={pool}: {e}"));
                assert_eq!(summary.epochs, 4, "P={shards} pool={pool}");
                assert_eq!(summary.epoch_mutate_s.len(), summary.epochs);
                assert_eq!(summary.epoch_mutate_run_s.len(), summary.epochs);
                assert_eq!(summary.epoch_route_s.len(), summary.epochs);
                assert!(summary.epoch_mutate_s.iter().all(|&s| s > 0.0));
                assert!(summary.epoch_mutate_run_s.iter().all(|&s| s > 0.0));
                for (wall, run) in summary
                    .epoch_mutate_s
                    .iter()
                    .zip(summary.epoch_mutate_run_s.iter())
                {
                    assert!(run <= &(wall + 1e-9), "run {run} > wall {wall}");
                }
            }
        }
    }

    #[test]
    fn layouts_run_the_same_schedule_to_the_same_state() {
        // flat and blocked storage are alternative layouts of the same
        // abstract list: the whole run — matching decisions included — must
        // be bit-identical across them
        let mut finals = Vec::new();
        for layout in [
            AdjLayout::Flat,
            AdjLayout::Blocked { block_bytes: 64 },
            AdjLayout::Blocked { block_bytes: 256 },
        ] {
            let cfg = ChurnConfig {
                epochs: 4,
                batch: 200,
                warmup_epochs: 2,
                threads: 2,
                engine_shards: 2,
                layout,
                ..ChurnConfig::new(ChurnGen::Rmat { scale: 9, avg_degree: 4 })
            };
            let summary = run_churn(&cfg, |e| {
                assert!(matches!(e.verified, Some(Ok(()))), "{layout:?}");
            })
            .unwrap_or_else(|e| panic!("{layout:?}: {e}"));
            finals.push((summary.final_live_edges, summary.final_matched_vertices));
        }
        assert!(finals.windows(2).all(|w| w[0] == w[1]), "diverged: {finals:?}");
    }

    #[test]
    fn pin_policies_run_the_same_schedule_to_the_same_state() {
        // pinning moves workers and memory, never decisions: the whole run
        // must be bit-identical across pin policies (including on hosts
        // where sched_setaffinity is refused and workers float)
        let mut finals = Vec::new();
        for pin in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Spread] {
            let cfg = ChurnConfig {
                epochs: 4,
                batch: 200,
                warmup_epochs: 2,
                threads: 2,
                engine_shards: 4,
                pin,
                ..ChurnConfig::new(ChurnGen::Rmat { scale: 9, avg_degree: 4 })
            };
            let summary = run_churn(&cfg, |e| {
                assert!(matches!(e.verified, Some(Ok(()))), "{pin:?}");
            })
            .unwrap_or_else(|e| panic!("{pin:?}: {e}"));
            finals.push((summary.final_live_edges, summary.final_matched_vertices));
        }
        assert!(finals.windows(2).all(|w| w[0] == w[1]), "diverged: {finals:?}");
    }

    #[test]
    fn save_then_load_skips_warmup_and_stays_verified() {
        let dir = std::env::temp_dir().join(format!(
            "skipper_churn_saveload_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm.skps").to_string_lossy().into_owned();
        let gen = ChurnGen::Er { n: 512, m: 2048 };
        // run 1: warm up, churn a little, save
        let cfg = ChurnConfig {
            epochs: 2,
            batch: 100,
            warmup_epochs: 2,
            threads: 2,
            save: Some(path.clone()),
            ..ChurnConfig::new(gen)
        };
        let saved = run_churn(&cfg, |_| {}).unwrap();
        assert!(saved.final_live_edges > 0);
        // run 2: load replaces warmup — same live state, every epoch still
        // verified against the model
        let cfg = ChurnConfig {
            epochs: 3,
            batch: 100,
            warmup_epochs: 5, // ignored under load
            threads: 2,
            load: Some(path.clone()),
            ..ChurnConfig::new(gen)
        };
        let mut warmups = 0;
        let loaded = run_churn(&cfg, |e| {
            if e.warmup {
                warmups += 1;
            }
            assert!(matches!(e.verified, Some(Ok(()))));
        })
        .unwrap();
        assert_eq!(warmups, 0, "load must replace the warmup phase");
        assert_eq!(loaded.epochs, 3);
        // 50/50 churn holds the live count near the restored state
        let drift = loaded.final_live_edges.abs_diff(saved.final_live_edges);
        assert!(drift <= 2 * cfg.batch as u64, "drift {drift}");
        // universe mismatch is rejected up front
        let bad = ChurnConfig {
            load: Some(path),
            ..ChurnConfig::new(ChurnGen::Er { n: 256, m: 512 })
        };
        assert!(run_churn(&bad, |_| {}).unwrap_err().contains("universe"));
    }

    #[test]
    fn gen_parse_families() {
        assert_eq!(
            ChurnGen::parse("rmat", 10, 8).unwrap(),
            ChurnGen::Rmat { scale: 10, avg_degree: 8 }
        );
        assert_eq!(
            ChurnGen::parse("er", 8, 4).unwrap(),
            ChurnGen::Er { n: 256, m: 1024 }
        );
        assert!(matches!(ChurnGen::parse("grid", 8, 4).unwrap(), ChurnGen::Grid { .. }));
        assert!(ChurnGen::parse("nope", 8, 4).is_err());
    }
}
