//! Compact mutable adjacency sidecars for the dynamic engine.
//!
//! The Skipper core deliberately keeps *no* topology — one state byte per
//! vertex is the paper's whole memory story. That is exactly why deletions
//! need a sidecar: when a matched edge disappears, the repair sweep must
//! re-run the reservation state machine over the freed endpoints' *surviving*
//! incident edges, and something has to remember what those are.
//!
//! Two layers live here:
//!
//! * [`HalfAdjacency`] — per-vertex edge lists over a contiguous *owned*
//!   vertex range `[start, start+len)`. Each owned vertex stores its full
//!   neighbor list (neighbors may live anywhere in the universe); an
//!   undirected edge is live iff **every owner of an endpoint stores its
//!   half**, which callers maintain by applying each edit on each owned
//!   endpoint. This is the unit the vertex-partitioned
//!   [`super::ShardedDynamicMatcher`] gives every shard.
//! * [`DynamicAdjacency`] — the single-owner (whole-universe) convenience
//!   wrapper: one `HalfAdjacency` covering `0..num_vertices` with symmetric
//!   insert/delete and whole-graph iteration, used by tests and any caller
//!   that wants plain set-semantics edge storage.
//!
//! # Storage layouts
//!
//! The sidecar is the dynamic hot path's memory story, so its physical
//! layout is a policy ([`AdjLayout`]) rather than a fixed choice:
//!
//! * **`flat`** — the historical layout: one independently heap-allocated
//!   `Vec<VertexId>` per vertex. Long lists are contiguous (good for hub
//!   scans), but every touched vertex costs a pointer chase into the
//!   allocator's placement, growth reallocates, and compaction churns the
//!   heap.
//! * **`blocked`** — a shard-local **block arena**: one contiguous slab of
//!   cache-line-aligned edge blocks. Each block holds
//!   `block_bytes/4 - 1` neighbor slots plus a next-block index in its last
//!   word; a per-vertex list is a short chain of blocks threaded through
//!   the arena, with a free list recycling blocks released by compaction.
//!   Every slot not currently holding a neighbor holds
//!   [`INVALID_VERTEX`], so iteration needs no per-slot occupancy metadata,
//!   and the all-ones bit pattern doubles as the nil block link. Sweeps
//!   issue a software prefetch for the next block in the chain while
//!   scanning the current one, and callers can prefetch the next vertex's
//!   metadata and head block ahead of need
//!   ([`HalfAdjacency::prefetch_vertex`] /
//!   [`HalfAdjacency::prefetch_neighbors`]).
//!
//! Both layouts implement identical *semantics* — same slot order, same
//! first-tombstone reuse on insert, same compaction policy — so the engines
//! behave identically under either and the property suite can demand
//! equality, not mere equivalence.
//!
//! Lists grow in amortized-O(1) pushes, delete by **tombstoning** (the slot
//! is overwritten with [`INVALID_VERTEX`] instead of shifting the tail), and
//! reclaim tombstones with **periodic per-vertex compaction** once they
//! outnumber the live entries (block recycling in the arena layout).
//! Inserts reuse the first tombstoned slot before growing, so a vertex under
//! steady insert/delete churn keeps a constant-length list. Deletes cost one
//! scan of the endpoint's list, inserts cost a membership scan at the caller
//! (the structures maintain *set* semantics — the live graph either has an
//! edge or it doesn't, which is what the delete path and the maximality
//! verifier need), and iteration skips tombstones in place. Self-loops are
//! rejected at the [`DynamicAdjacency`] level: the matcher skips them anyway
//! (Algorithm 1 lines 6–7), so they can never affect maximality and keeping
//! them live would only pollute repair sweeps; the sharded engine filters
//! them before its half-edge edits for the same reason.

use crate::instrument::Probe;
use crate::par::topology;
use crate::{VertexId, INVALID_VERTEX};

/// Per-vertex slots start compacting once at least this many tombstones
/// accumulate (and tombstones outnumber live entries) — small lists just
/// tolerate their holes.
const COMPACT_MIN_DEAD: u32 = 8;

/// Nil block index in the arena layout. Shares the all-ones bit pattern
/// with [`INVALID_VERTEX`], so a freshly scrubbed block (every word
/// `INVALID_VERTEX`) has empty slots *and* a nil link in one fill.
const NIL_BLOCK: u32 = u32::MAX;

/// Issue a read prefetch for the cache line at `p` (no-op off x86_64).
#[inline(always)]
pub(crate) fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint; it never faults, even on bad addresses.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// Physical storage policy for [`HalfAdjacency`]: how per-vertex neighbor
/// lists are laid out in memory. Semantics are identical across layouts;
/// only locality, allocation behavior, and prefetchability differ.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdjLayout {
    /// One heap-allocated `Vec<VertexId>` per vertex (the historical
    /// layout): contiguous per-list storage, allocator-placed.
    Flat,
    /// Shard-local block arena: per-vertex chains of cache-line-aligned
    /// blocks carved from one contiguous slab, recycled through a free
    /// list, swept with software prefetch.
    Blocked {
        /// Block size in bytes — a multiple of 64 in `64..=4096`. Each
        /// block stores `block_bytes/4 - 1` neighbor slots plus its link.
        block_bytes: usize,
    },
}

impl Default for AdjLayout {
    /// The arena layout with 64-byte (one cache line) blocks.
    fn default() -> Self {
        AdjLayout::Blocked { block_bytes: 64 }
    }
}

impl AdjLayout {
    /// Parse a layout name: `flat`, `blocked` (64-byte blocks), or
    /// `blocked<N>` with `N` a multiple of 64 in `64..=4096` (e.g.
    /// `blocked128`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "flat" => Ok(AdjLayout::Flat),
            "blocked" => Ok(AdjLayout::Blocked { block_bytes: 64 }),
            _ => {
                let n = s
                    .strip_prefix("blocked")
                    .and_then(|rest| rest.parse::<usize>().ok())
                    .ok_or_else(|| format!("unknown adjacency layout {s:?} (want flat | blocked | blocked<N>)"))?;
                if !(64..=4096).contains(&n) || n % 64 != 0 {
                    return Err(format!(
                        "blocked block size must be a multiple of 64 in 64..=4096, got {n}"
                    ));
                }
                Ok(AdjLayout::Blocked { block_bytes: n })
            }
        }
    }

    /// Canonical name (`flat`, `blocked64`, `blocked128`, ...), accepted
    /// back by [`parse`](Self::parse).
    pub fn name(&self) -> String {
        match self {
            AdjLayout::Flat => "flat".to_string(),
            AdjLayout::Blocked { block_bytes } => format!("blocked{block_bytes}"),
        }
    }
}

// ---------------------------------------------------------------------------
// flat layout
// ---------------------------------------------------------------------------

#[derive(Default)]
struct AdjList {
    /// Neighbor slots; deleted ones hold [`INVALID_VERTEX`].
    slots: Vec<VertexId>,
    /// Tombstone count in `slots`.
    dead: u32,
}

impl AdjList {
    #[inline]
    fn live_len(&self) -> usize {
        self.slots.len() - self.dead as usize
    }

    fn contains(&self, v: VertexId) -> bool {
        self.slots.iter().any(|&s| s == v)
    }

    fn push(&mut self, v: VertexId) {
        // Reuse the first tombstone before growing: under steady
        // delete/insert churn the list length stays constant instead of
        // ratcheting up between compactions.
        if self.dead > 0 {
            if let Some(slot) = self.slots.iter_mut().find(|s| **s == INVALID_VERTEX) {
                *slot = v;
                self.dead -= 1;
                return;
            }
            debug_assert!(false, "dead > 0 with no tombstoned slot");
        }
        self.slots.push(v);
    }

    /// Tombstone the first slot holding `v`; false if absent.
    fn remove(&mut self, v: VertexId) -> bool {
        match self.slots.iter().position(|&s| s == v) {
            Some(i) => {
                self.slots[i] = INVALID_VERTEX;
                self.dead += 1;
                true
            }
            None => false,
        }
    }

    /// Drop tombstones in place when they dominate the list. The capacity
    /// is deliberately kept: under steady churn the list regrows to the
    /// same size, and shrinking here would just thrash the allocator on
    /// every hub compaction.
    fn maybe_compact(&mut self) -> bool {
        if self.dead >= COMPACT_MIN_DEAD && (self.dead as usize) > self.live_len() {
            self.slots.retain(|&s| s != INVALID_VERTEX);
            self.dead = 0;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------------
// blocked layout: the shard-local block arena
// ---------------------------------------------------------------------------

/// One cache line of slot words. Blocks are a whole number of these, so
/// every block starts cache-line-aligned inside the arena slab.
#[repr(C, align(64))]
#[derive(Clone, Copy)]
struct Line([u32; Line::WORDS]);

impl Line {
    const WORDS: usize = 16;
    /// All slots empty, link nil — the scrubbed state.
    const EMPTY: Line = Line([INVALID_VERTEX; Line::WORDS]);
}

/// Per-vertex chain header: 16 bytes, kept in one contiguous array so the
/// sweep over owned vertices streams through it.
#[derive(Clone, Copy)]
struct Meta {
    /// First block of the chain, or [`NIL_BLOCK`].
    head: u32,
    /// Last block of the chain, or [`NIL_BLOCK`].
    tail: u32,
    /// Slot positions in use (live + tombstoned). Appends go at position
    /// `len`; positions beyond it hold [`INVALID_VERTEX`].
    len: u32,
    /// Tombstoned positions below `len`.
    dead: u32,
}

impl Meta {
    const EMPTY: Meta = Meta { head: NIL_BLOCK, tail: NIL_BLOCK, len: 0, dead: 0 };
}

struct BlockStore {
    /// Cache lines per block (`block_bytes / 64`).
    lines_per_block: usize,
    /// Neighbor slots per block (`block_bytes / 4 - 1`; the last word is
    /// the chain link).
    slots_per_block: u32,
    /// The slab: every shard-owned neighbor slot lives here.
    arena: Vec<Line>,
    /// Chain headers, indexed by `v - start`.
    meta: Vec<Meta>,
    /// Head of the recycled-block free list, threaded through link words.
    free_head: u32,
    /// Blocks currently on the free list.
    free_blocks: u64,
    /// Ask the kernel for transparent-huge-page backing on the slab
    /// (`madvise(MADV_HUGEPAGE)`), re-advised whenever growth reallocates
    /// it. Off by default; the NUMA-pinned engine turns it on.
    huge: bool,
    /// Arena capacity (bytes) last advised, so steady-state growth inside
    /// the same allocation does not re-issue the syscall.
    advised_bytes: usize,
}

impl BlockStore {
    fn new(len: usize, block_bytes: usize) -> Self {
        assert!(
            (64..=4096).contains(&block_bytes) && block_bytes % 64 == 0,
            "block_bytes must be a multiple of 64 in 64..=4096, got {block_bytes}"
        );
        Self {
            lines_per_block: block_bytes / 64,
            slots_per_block: (block_bytes / 4 - 1) as u32,
            arena: Vec::new(),
            meta: vec![Meta::EMPTY; len],
            free_head: NIL_BLOCK,
            free_blocks: 0,
            huge: false,
            advised_bytes: 0,
        }
    }

    /// Turn on huge-page advice: the chain headers and the current slab are
    /// advised now, and every future growth that moves the slab re-advises
    /// it. Failures (non-Linux, THP disabled) are silently ignored — the
    /// layout works identically on 4 KiB pages.
    fn advise_hugepages(&mut self) {
        self.huge = true;
        self.advised_bytes = 0;
        let _ = topology::advise_hugepages(
            self.meta.as_ptr() as *const u8,
            self.meta.capacity() * std::mem::size_of::<Meta>(),
        );
        self.readvise();
    }

    /// Re-issue `MADV_HUGEPAGE` if the slab allocation changed size since
    /// the last advice (capacity growth implies a possible move; advice is
    /// per-mapping, so a moved slab starts unadvised).
    fn readvise(&mut self) {
        if !self.huge {
            return;
        }
        let bytes = self.arena.capacity() * std::mem::size_of::<Line>();
        if bytes != self.advised_bytes {
            let _ = topology::advise_hugepages(self.arena.as_ptr() as *const u8, bytes);
            self.advised_bytes = bytes;
        }
    }

    #[inline]
    fn word(&self, b: u32, w: u32) -> u32 {
        let line = b as usize * self.lines_per_block + (w >> 4) as usize;
        self.arena[line].0[(w & 15) as usize]
    }

    #[inline]
    fn set_word(&mut self, b: u32, w: u32, val: u32) {
        let line = b as usize * self.lines_per_block + (w >> 4) as usize;
        self.arena[line].0[(w & 15) as usize] = val;
    }

    #[inline]
    fn link(&self, b: u32) -> u32 {
        self.word(b, self.slots_per_block)
    }

    #[inline]
    fn set_link(&mut self, b: u32, val: u32) {
        self.set_word(b, self.slots_per_block, val);
    }

    /// Address of block `b`'s first line, for prefetch and probes.
    #[inline]
    fn block_ptr(&self, b: u32) -> *const Line {
        &self.arena[b as usize * self.lines_per_block] as *const Line
    }

    /// Pop a scrubbed block off the free list, or grow the slab by one.
    fn alloc_block(&mut self) -> u32 {
        if self.free_head != NIL_BLOCK {
            let b = self.free_head;
            self.free_head = self.link(b);
            self.set_link(b, NIL_BLOCK);
            self.free_blocks -= 1;
            return b;
        }
        let b = (self.arena.len() / self.lines_per_block) as u32;
        debug_assert!(b != NIL_BLOCK, "arena block index space exhausted");
        self.arena.resize(self.arena.len() + self.lines_per_block, Line::EMPTY);
        self.readvise();
        b
    }

    /// Scrub every block of the chain starting at `b` and push it onto the
    /// free list — compaction's "block recycling".
    fn release_chain(&mut self, mut b: u32) {
        while b != NIL_BLOCK {
            let next = self.link(b);
            let at = b as usize * self.lines_per_block;
            for line in &mut self.arena[at..at + self.lines_per_block] {
                *line = Line::EMPTY;
            }
            self.set_link(b, self.free_head);
            self.free_head = b;
            self.free_blocks += 1;
            b = next;
        }
    }

    /// Full-chain membership scan, prefetching each next block while the
    /// current one is scanned.
    fn contains(&self, idx: usize, nb: VertexId) -> bool {
        let mut b = self.meta[idx].head;
        while b != NIL_BLOCK {
            let next = self.link(b);
            if next != NIL_BLOCK {
                prefetch_read(self.block_ptr(next));
            }
            for w in 0..self.slots_per_block {
                if self.word(b, w) == nb {
                    return true;
                }
            }
            b = next;
        }
        false
    }

    /// Append `nb`, reusing the first tombstoned slot before growing the
    /// chain (same slot-order semantics as the flat layout).
    fn push(&mut self, idx: usize, nb: VertexId) {
        debug_assert!(nb != INVALID_VERTEX);
        let spb = self.slots_per_block;
        let m = self.meta[idx];
        if m.dead > 0 {
            let mut b = m.head;
            let mut pos = 0u32;
            while b != NIL_BLOCK && pos < m.len {
                let take = spb.min(m.len - pos);
                for w in 0..take {
                    if self.word(b, w) == INVALID_VERTEX {
                        self.set_word(b, w, nb);
                        self.meta[idx].dead -= 1;
                        return;
                    }
                }
                pos += take;
                b = self.link(b);
            }
            debug_assert!(false, "dead > 0 with no tombstoned slot");
        }
        if m.len % spb == 0 {
            // empty list, or the tail block is exactly full: extend the chain
            let fresh = self.alloc_block();
            if m.head == NIL_BLOCK {
                self.meta[idx].head = fresh;
            } else {
                let tail = self.meta[idx].tail;
                self.set_link(tail, fresh);
            }
            self.meta[idx].tail = fresh;
        }
        let tail = self.meta[idx].tail;
        self.set_word(tail, m.len % spb, nb);
        self.meta[idx].len += 1;
    }

    /// Tombstone the first slot holding `nb`; false if absent.
    fn remove(&mut self, idx: usize, nb: VertexId) -> bool {
        debug_assert!(nb != INVALID_VERTEX);
        let mut b = self.meta[idx].head;
        while b != NIL_BLOCK {
            let next = self.link(b);
            if next != NIL_BLOCK {
                prefetch_read(self.block_ptr(next));
            }
            for w in 0..self.slots_per_block {
                if self.word(b, w) == nb {
                    self.set_word(b, w, INVALID_VERTEX);
                    self.meta[idx].dead += 1;
                    return true;
                }
            }
            b = next;
        }
        false
    }

    /// Same policy as the flat layout; compaction packs the chain in place
    /// and recycles the surplus tail blocks.
    fn maybe_compact(&mut self, idx: usize) -> bool {
        let m = self.meta[idx];
        let live = m.len - m.dead;
        if m.dead < COMPACT_MIN_DEAD || m.dead <= live {
            return false;
        }
        if live == 0 {
            let head = m.head;
            self.meta[idx] = Meta::EMPTY;
            self.release_chain(head);
            return true;
        }
        let spb = self.slots_per_block;
        // two-cursor pack: read walks every used position, write trails it
        // packing live values forward in slot order
        let (mut rb, mut rw) = (m.head, 0u32);
        let (mut wb, mut ww) = (m.head, 0u32);
        let mut pos = 0u32;
        while pos < m.len {
            if rw == spb {
                rb = self.link(rb);
                rw = 0;
                continue;
            }
            let val = self.word(rb, rw);
            rw += 1;
            pos += 1;
            if val != INVALID_VERTEX {
                if ww == spb {
                    wb = self.link(wb);
                    ww = 0;
                }
                self.set_word(wb, ww, val);
                ww += 1;
            }
        }
        for w in ww..spb {
            self.set_word(wb, w, INVALID_VERTEX);
        }
        let surplus = self.link(wb);
        self.set_link(wb, NIL_BLOCK);
        self.release_chain(surplus);
        let meta = &mut self.meta[idx];
        meta.tail = wb;
        meta.len = live;
        meta.dead = 0;
        true
    }

    fn memory_bytes(&self) -> usize {
        self.arena.capacity() * std::mem::size_of::<Line>()
            + self.meta.capacity() * std::mem::size_of::<Meta>()
    }
}

/// Live-neighbor iterator over either layout, in slot order.
enum NeighborIter<'a> {
    Flat(std::slice::Iter<'a, VertexId>),
    Blocked {
        store: &'a BlockStore,
        block: u32,
        next: u32,
        w: u32,
    },
}

impl Iterator for NeighborIter<'_> {
    type Item = VertexId;

    fn next(&mut self) -> Option<VertexId> {
        match self {
            NeighborIter::Flat(it) => it.find(|&&s| s != INVALID_VERTEX).copied(),
            NeighborIter::Blocked { store, block, next, w } => loop {
                if *block == NIL_BLOCK {
                    return None;
                }
                if *w == 0 {
                    // entering a block: learn its successor and prefetch it
                    // so the chain chase overlaps the current block's scan
                    *next = store.link(*block);
                    if *next != NIL_BLOCK {
                        prefetch_read(store.block_ptr(*next));
                    }
                }
                if *w == store.slots_per_block {
                    *block = *next;
                    *w = 0;
                    continue;
                }
                let val = store.word(*block, *w);
                *w += 1;
                if val != INVALID_VERTEX {
                    return Some(val);
                }
            },
        }
    }
}

// ---------------------------------------------------------------------------
// HalfAdjacency: the layout-polymorphic public face
// ---------------------------------------------------------------------------

enum Store {
    Flat(Vec<AdjList>),
    Blocked(BlockStore),
}

/// Half-edge adjacency over the contiguous owned vertex range
/// `[start, start+len)`: tombstoned per-vertex neighbor lists with periodic
/// compaction, edited one endpoint at a time, stored per the configured
/// [`AdjLayout`].
///
/// `HalfAdjacency` does **not** enforce set semantics on its own —
/// [`insert_half`](Self::insert_half) pushes unconditionally so a caller
/// that already ran [`contains_half`](Self::contains_half) (to decide
/// whether the edge is fresh) never pays a second membership scan. Callers
/// keep the two endpoint halves of every undirected edge in agreement by
/// applying each edit on every owned endpoint, in a consistent order per
/// edge.
pub struct HalfAdjacency {
    start: usize,
    len: usize,
    layout: AdjLayout,
    store: Store,
    /// Live directed half-edges stored here (each undirected edge
    /// contributes one per stored endpoint).
    half_edges: u64,
    compactions: u64,
}

impl HalfAdjacency {
    /// Empty lists for the owned range `[start, start+len)` in the default
    /// layout.
    pub fn new(start: VertexId, len: usize) -> Self {
        Self::with_layout(start, len, AdjLayout::default())
    }

    /// Empty lists for the owned range `[start, start+len)` in the given
    /// layout.
    pub fn with_layout(start: VertexId, len: usize, layout: AdjLayout) -> Self {
        let store = match layout {
            AdjLayout::Flat => {
                let mut lists = Vec::new();
                lists.resize_with(len, AdjList::default);
                Store::Flat(lists)
            }
            AdjLayout::Blocked { block_bytes } => Store::Blocked(BlockStore::new(len, block_bytes)),
        };
        Self { start: start as usize, len, layout, store, half_edges: 0, compactions: 0 }
    }

    /// The storage layout this sidecar was built with.
    #[inline]
    pub fn layout(&self) -> AdjLayout {
        self.layout
    }

    /// Ask for transparent-huge-page backing on the block-arena slabs
    /// (`madvise(MADV_HUGEPAGE)`), now and on every future slab growth.
    /// A no-op for the flat layout (per-vertex `Vec`s are too small and
    /// allocator-placed) and on hosts without THP — storage semantics are
    /// identical either way, only TLB pressure changes. Called by the
    /// NUMA-pinned engine from each shard's owner worker, right after the
    /// first-touch construction of this sidecar.
    pub fn advise_hugepages(&mut self) {
        if let Store::Blocked(store) = &mut self.store {
            store.advise_hugepages();
        }
    }

    /// First owned vertex.
    #[inline]
    pub fn start(&self) -> VertexId {
        self.start as VertexId
    }

    /// One past the last owned vertex.
    #[inline]
    pub fn end(&self) -> VertexId {
        (self.start + self.len) as VertexId
    }

    #[inline]
    /// Does this sidecar own vertex `v`’s list?
    pub fn owns(&self, v: VertexId) -> bool {
        let v = v as usize;
        v >= self.start && v < self.start + self.len
    }

    #[inline]
    fn idx(&self, v: VertexId) -> usize {
        v as usize - self.start
    }

    /// Is the half-edge `v → nb` stored? `v` must be owned.
    #[inline]
    pub fn contains_half(&self, v: VertexId, nb: VertexId) -> bool {
        let idx = self.idx(v);
        match &self.store {
            Store::Flat(lists) => lists[idx].contains(nb),
            Store::Blocked(bs) => bs.contains(idx, nb),
        }
    }

    /// Store the half-edge `v → nb` unconditionally (no membership scan —
    /// see the type docs). `v` must be owned.
    #[inline]
    pub fn insert_half(&mut self, v: VertexId, nb: VertexId) {
        let idx = self.idx(v);
        match &mut self.store {
            Store::Flat(lists) => lists[idx].push(nb),
            Store::Blocked(bs) => bs.push(idx, nb),
        }
        self.half_edges += 1;
    }

    /// Tombstone the half-edge `v → nb`; false if it was not stored.
    /// Compacts `v`'s list when its tombstones dominate.
    pub fn remove_half(&mut self, v: VertexId, nb: VertexId) -> bool {
        let idx = self.idx(v);
        let (removed, compacted) = match &mut self.store {
            Store::Flat(lists) => {
                let list = &mut lists[idx];
                if list.remove(nb) {
                    (true, list.maybe_compact())
                } else {
                    (false, false)
                }
            }
            Store::Blocked(bs) => {
                if bs.remove(idx, nb) {
                    (true, bs.maybe_compact(idx))
                } else {
                    (false, false)
                }
            }
        };
        if removed {
            self.half_edges -= 1;
        }
        if compacted {
            self.compactions += 1;
        }
        removed
    }

    /// Live neighbors of owned vertex `v` (tombstones skipped), slot order.
    pub fn neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        let idx = self.idx(v);
        match &self.store {
            Store::Flat(lists) => NeighborIter::Flat(lists[idx].slots.iter()),
            Store::Blocked(bs) => NeighborIter::Blocked {
                store: bs,
                block: bs.meta[idx].head,
                next: NIL_BLOCK,
                w: 0,
            },
        }
    }

    #[inline]
    /// Live (non-tombstoned) neighbor count of owned vertex `v`.
    pub fn live_degree(&self, v: VertexId) -> usize {
        let idx = self.idx(v);
        match &self.store {
            Store::Flat(lists) => lists[idx].live_len(),
            Store::Blocked(bs) => {
                let m = bs.meta[idx];
                (m.len - m.dead) as usize
            }
        }
    }

    /// Raw slot count of `v`'s list, tombstones included — lets callers
    /// pick the sparser endpoint for a membership scan.
    #[inline]
    pub(crate) fn slots_len(&self, v: VertexId) -> usize {
        let idx = self.idx(v);
        match &self.store {
            Store::Flat(lists) => lists[idx].slots.len(),
            Store::Blocked(bs) => bs.meta[idx].len as usize,
        }
    }

    /// Prefetch vertex `v`'s list header (chain meta in the arena layout,
    /// the `Vec` header in the flat one). Call a few iterations ahead of
    /// touching `v` in a sweep; pair with
    /// [`prefetch_neighbors`](Self::prefetch_neighbors) one iteration
    /// ahead.
    #[inline]
    pub fn prefetch_vertex(&self, v: VertexId) {
        if !self.owns(v) {
            return;
        }
        let idx = self.idx(v);
        match &self.store {
            Store::Flat(lists) => prefetch_read(&lists[idx] as *const AdjList),
            Store::Blocked(bs) => prefetch_read(&bs.meta[idx] as *const Meta),
        }
    }

    /// Prefetch the first cache line of vertex `v`'s neighbor slots. Reads
    /// the list header to find them, so it pays off when the header is
    /// already cached (e.g. after a [`prefetch_vertex`](Self::prefetch_vertex)
    /// issued earlier in the sweep).
    #[inline]
    pub fn prefetch_neighbors(&self, v: VertexId) {
        if !self.owns(v) {
            return;
        }
        let idx = self.idx(v);
        match &self.store {
            Store::Flat(lists) => {
                let slots = &lists[idx].slots;
                if !slots.is_empty() {
                    prefetch_read(slots.as_ptr());
                }
            }
            Store::Blocked(bs) => {
                let head = bs.meta[idx].head;
                if head != NIL_BLOCK {
                    prefetch_read(bs.block_ptr(head));
                }
            }
        }
    }

    /// Live directed half-edges stored in this range.
    #[inline]
    pub fn half_edges(&self) -> u64 {
        self.half_edges
    }

    /// Tombstoned slots currently awaiting compaction.
    pub fn tombstones(&self) -> u64 {
        match &self.store {
            Store::Flat(lists) => lists.iter().map(|l| l.dead as u64).sum(),
            Store::Blocked(bs) => bs.meta.iter().map(|m| m.dead as u64).sum(),
        }
    }

    /// Per-vertex compactions performed so far.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Arena blocks currently parked on the recycling free list (0 in the
    /// flat layout).
    pub fn free_blocks(&self) -> u64 {
        match &self.store {
            Store::Flat(_) => 0,
            Store::Blocked(bs) => bs.free_blocks,
        }
    }

    /// Resident bytes (slot storage plus list headers / chain metadata).
    pub fn memory_bytes(&self) -> usize {
        match &self.store {
            Store::Flat(lists) => {
                lists
                    .iter()
                    .map(|l| l.slots.capacity() * std::mem::size_of::<VertexId>())
                    .sum::<usize>()
                    + lists.capacity() * std::mem::size_of::<AdjList>()
            }
            Store::Blocked(bs) => bs.memory_bytes(),
        }
    }

    /// Replay one full iteration sweep (every owned vertex, every slot)
    /// against `probe`, emitting loads at the *actual* resident addresses
    /// of whatever the sweep dereferences — list headers, slot words, and
    /// chain links. Replaying the trace through [`crate::cachesim`] gives
    /// the layout's miss profile the way Fig 8 does for the matchers.
    /// Returns the live half-edges visited (a checksum for `black_box`).
    pub fn probe_sweep(&self, probe: &mut impl Probe) -> u64 {
        let mut live = 0u64;
        match &self.store {
            Store::Flat(lists) => {
                for list in lists {
                    probe.load(list as *const AdjList as u64);
                    for slot in &list.slots {
                        probe.load(slot as *const VertexId as u64);
                        if *slot != INVALID_VERTEX {
                            live += 1;
                        }
                    }
                }
            }
            Store::Blocked(bs) => {
                for m in &bs.meta {
                    probe.load(m as *const Meta as u64);
                    let mut b = m.head;
                    while b != NIL_BLOCK {
                        let base = bs.block_ptr(b) as u64;
                        for w in 0..bs.slots_per_block {
                            probe.load(base + w as u64 * 4);
                            if bs.word(b, w) != INVALID_VERTEX {
                                live += 1;
                            }
                        }
                        // the link word is read to chase the chain
                        probe.load(base + bs.slots_per_block as u64 * 4);
                        b = bs.link(b);
                    }
                }
            }
        }
        live
    }
}

/// Mutable adjacency over a fixed vertex universe `0..num_vertices`, with
/// set semantics on undirected edges (each edge stored in both endpoint
/// lists) and tombstoned deletes — a whole-universe [`HalfAdjacency`] with
/// the symmetry maintained internally.
pub struct DynamicAdjacency {
    half: HalfAdjacency,
}

impl DynamicAdjacency {
    /// Empty adjacency over `0..num_vertices` in the default layout.
    pub fn new(num_vertices: usize) -> Self {
        Self { half: HalfAdjacency::new(0, num_vertices) }
    }

    /// Empty adjacency over `0..num_vertices` in the given layout.
    pub fn with_layout(num_vertices: usize, layout: AdjLayout) -> Self {
        Self { half: HalfAdjacency::with_layout(0, num_vertices, layout) }
    }

    /// The storage layout this sidecar was built with.
    #[inline]
    pub fn layout(&self) -> AdjLayout {
        self.half.layout()
    }

    #[inline]
    /// Size of the vertex universe.
    pub fn num_vertices(&self) -> usize {
        self.half.end() as usize
    }

    /// Live undirected edge count.
    #[inline]
    pub fn num_live_edges(&self) -> u64 {
        self.half.half_edges() / 2
    }

    /// Tombstoned slots currently awaiting compaction (both directions).
    pub fn tombstones(&self) -> u64 {
        self.half.tombstones()
    }

    /// Per-vertex compactions performed so far.
    pub fn compactions(&self) -> u64 {
        self.half.compactions()
    }

    #[inline]
    /// Live neighbor count of `v`.
    pub fn live_degree(&self, v: VertexId) -> usize {
        self.half.live_degree(v)
    }

    /// Is undirected edge `{u,v}` live? (Scans the sparser endpoint.)
    pub fn contains(&self, u: VertexId, v: VertexId) -> bool {
        if !self.half.owns(u) || !self.half.owns(v) {
            return false;
        }
        // scan the sparser endpoint
        if self.half.slots_len(u) <= self.half.slots_len(v) {
            self.half.contains_half(u, v)
        } else {
            self.half.contains_half(v, u)
        }
    }

    /// Insert edge `{u,v}`; false if it is a self-loop, out of range, or
    /// already live.
    pub fn insert(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v || !self.half.owns(u) || !self.half.owns(v) || self.contains(u, v) {
            return false;
        }
        self.half.insert_half(u, v);
        self.half.insert_half(v, u);
        true
    }

    /// Delete edge `{u,v}`; false if it was not live. Compacts either
    /// endpoint's list when its tombstones dominate.
    pub fn delete(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v || !self.half.owns(u) || !self.half.owns(v) {
            return false;
        }
        if !self.half.remove_half(u, v) {
            return false;
        }
        let removed = self.half.remove_half(v, u);
        debug_assert!(removed, "adjacency asymmetry: ({u},{v}) stored one-way");
        true
    }

    /// Live neighbors of `v` (tombstones skipped), in slot order.
    pub fn live_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        self.half.neighbors(v)
    }

    /// All live edges, canonicalized `(min, max)`, each exactly once — the
    /// input [`crate::matching::verify::verify_maximal_dynamic`] wants.
    pub fn live_edge_iter(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices()).flat_map(move |u| {
            let u = u as VertexId;
            self.half.neighbors(u).filter(move |&v| u < v).map(move |v| (u, v))
        })
    }

    /// Resident bytes of the sidecar (slot storage only).
    pub fn memory_bytes(&self) -> usize {
        self.half.memory_bytes()
    }

    /// Replay one full iteration sweep against `probe` at resident
    /// addresses — see [`HalfAdjacency::probe_sweep`].
    pub fn probe_sweep(&self, probe: &mut impl Probe) -> u64 {
        self.half.probe_sweep(probe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every layout the semantics tests must agree across.
    const LAYOUTS: [AdjLayout; 4] = [
        AdjLayout::Flat,
        AdjLayout::Blocked { block_bytes: 64 },
        AdjLayout::Blocked { block_bytes: 128 },
        AdjLayout::Blocked { block_bytes: 256 },
    ];

    #[test]
    fn layout_names_roundtrip() {
        for l in LAYOUTS {
            assert_eq!(AdjLayout::parse(&l.name()).unwrap(), l);
        }
        assert_eq!(AdjLayout::parse("blocked").unwrap(), AdjLayout::Blocked { block_bytes: 64 });
        assert!(AdjLayout::parse("blocked65").is_err());
        assert!(AdjLayout::parse("blocked8192").is_err());
        assert!(AdjLayout::parse("mystery").is_err());
    }

    #[test]
    fn insert_delete_roundtrip_with_set_semantics() {
        for layout in LAYOUTS {
            let mut a = DynamicAdjacency::with_layout(5, layout);
            assert!(a.insert(0, 1));
            assert!(!a.insert(1, 0), "reinsert of the reverse orientation");
            assert!(a.insert(1, 2));
            assert_eq!(a.num_live_edges(), 2);
            assert!(a.contains(0, 1) && a.contains(1, 0));
            assert!(a.delete(1, 0));
            assert!(!a.delete(0, 1), "double delete");
            assert_eq!(a.num_live_edges(), 1);
            assert!(!a.contains(0, 1));
            assert_eq!(a.live_degree(1), 1);
            assert_eq!(a.live_neighbors(1).collect::<Vec<_>>(), vec![2]);
        }
    }

    #[test]
    fn self_loops_and_out_of_range_rejected() {
        let mut a = DynamicAdjacency::new(3);
        assert!(!a.insert(1, 1));
        assert!(!a.insert(0, 7));
        assert!(!a.delete(0, 7));
        assert_eq!(a.num_live_edges(), 0);
    }

    #[test]
    fn tombstones_are_skipped_and_reused() {
        for layout in LAYOUTS {
            let mut a = DynamicAdjacency::with_layout(4, layout);
            a.insert(0, 1);
            a.insert(0, 2);
            a.insert(0, 3);
            a.delete(0, 3); // tail slot becomes a tombstone...
            assert_eq!(a.tombstones(), 2);
            a.insert(0, 3); // ...and is reused by the next push
            assert_eq!(a.live_degree(0), 3);
            a.delete(0, 2);
            assert_eq!(
                a.live_neighbors(0).collect::<Vec<_>>(),
                vec![1, 3],
                "tombstone skipped mid-list ({})",
                layout.name()
            );
        }
    }

    #[test]
    fn first_tombstone_is_reused_before_growth() {
        for layout in LAYOUTS {
            let mut a = DynamicAdjacency::with_layout(8, layout);
            for v in 1..=4 {
                a.insert(0, v);
            }
            a.delete(0, 1); // hole at slot 0
            a.delete(0, 3); // hole at slot 2
            a.insert(0, 5); // must land in the FIRST hole
            assert_eq!(
                a.live_neighbors(0).collect::<Vec<_>>(),
                vec![5, 2, 4],
                "first hole reused ({})",
                layout.name()
            );
        }
    }

    #[test]
    fn sustained_churn_on_one_vertex_keeps_constant_list_length() {
        // the satellite regression: delete+reinsert cycling must not grow
        // the list — every insert lands in the tombstone the delete left
        for layout in LAYOUTS {
            let mut a = DynamicAdjacency::with_layout(64, layout);
            for v in 1..=6 {
                a.insert(0, v);
            }
            let baseline = a.half.slots_len(0);
            for round in 0..1000u32 {
                let v = 1 + (round % 6);
                assert!(a.delete(0, v));
                assert!(a.insert(0, v));
                assert_eq!(
                    a.half.slots_len(0),
                    baseline,
                    "list grew under steady churn ({})",
                    layout.name()
                );
            }
            assert_eq!(a.live_degree(0), 6);
            assert_eq!(a.tombstones(), 0);
        }
    }

    #[test]
    fn compaction_reclaims_dominating_tombstones() {
        for layout in LAYOUTS {
            let n = 64;
            let mut a = DynamicAdjacency::with_layout(n + 1, layout);
            for v in 1..=n {
                a.insert(0, v as VertexId);
            }
            for v in 1..=n - 4 {
                a.delete(0, v as VertexId);
            }
            assert!(a.compactions() > 0, "hub list should have compacted");
            assert_eq!(a.live_degree(0), 4);
            // vertex 0's list really shrank
            assert!(a.half.slots_len(0) <= 8, "slots {}", a.half.slots_len(0));
            assert_eq!(a.num_live_edges(), 4);
        }
    }

    #[test]
    fn blocked_compaction_recycles_blocks() {
        let mut a = DynamicAdjacency::with_layout(256, AdjLayout::Blocked { block_bytes: 64 });
        for v in 1..=128 {
            a.insert(0, v);
        }
        let grown = a.memory_bytes();
        for v in 1..=128 {
            a.delete(0, v);
        }
        assert!(a.compactions() > 0);
        assert!(a.half.free_blocks() > 0, "compaction should recycle chain blocks");
        // the hub re-grows entirely from the free list: the slab must not grow
        for v in 1..=128 {
            a.insert(0, v);
        }
        assert!(
            a.memory_bytes() <= grown,
            "arena grew ({} -> {}) despite a populated free list",
            grown,
            a.memory_bytes()
        );
    }

    #[test]
    fn layouts_agree_exactly_under_random_churn() {
        use crate::util::rng::Xoshiro256pp;
        let n = 80;
        let mut subjects: Vec<DynamicAdjacency> = LAYOUTS
            .iter()
            .map(|&l| DynamicAdjacency::with_layout(n, l))
            .collect();
        let mut rng = Xoshiro256pp::new(42);
        for _ in 0..30_000 {
            let u = rng.next_usize(n) as VertexId;
            let v = rng.next_usize(n) as VertexId;
            let ins = rng.next_usize(3) > 0;
            let results: Vec<bool> = subjects
                .iter_mut()
                .map(|a| if ins { a.insert(u, v) } else { a.delete(u, v) })
                .collect();
            assert!(results.windows(2).all(|w| w[0] == w[1]), "layouts diverged on op");
        }
        let reference: Vec<Vec<VertexId>> = (0..n as VertexId)
            .map(|v| subjects[0].live_neighbors(v).collect())
            .collect();
        for (a, layout) in subjects.iter().zip(LAYOUTS.iter()).skip(1) {
            assert_eq!(a.num_live_edges(), subjects[0].num_live_edges());
            assert_eq!(a.tombstones(), subjects[0].tombstones(), "{}", layout.name());
            assert_eq!(a.compactions(), subjects[0].compactions(), "{}", layout.name());
            for v in 0..n as VertexId {
                assert_eq!(
                    a.live_neighbors(v).collect::<Vec<_>>(),
                    reference[v as usize],
                    "slot order diverged at v={v} ({})",
                    layout.name()
                );
            }
        }
    }

    #[test]
    fn probe_sweep_counts_live_half_edges() {
        use crate::instrument::CountingProbe;
        for layout in LAYOUTS {
            let mut a = DynamicAdjacency::with_layout(16, layout);
            a.insert(0, 1);
            a.insert(2, 3);
            a.insert(0, 3);
            a.delete(2, 3);
            let mut p = CountingProbe::default();
            assert_eq!(a.probe_sweep(&mut p), 4, "{}", layout.name());
            assert!(p.loads > 0);
        }
    }

    #[test]
    fn live_edge_iter_is_canonical_and_complete() {
        let mut a = DynamicAdjacency::new(6);
        for &(u, v) in &[(3u32, 1u32), (1, 2), (4, 5), (2, 3)] {
            a.insert(u, v);
        }
        a.delete(1, 2);
        let mut edges: Vec<_> = a.live_edge_iter().collect();
        edges.sort_unstable();
        assert_eq!(edges, vec![(1, 3), (2, 3), (4, 5)]);
    }

    #[test]
    fn churn_keeps_counts_consistent() {
        use crate::util::rng::Xoshiro256pp;
        for layout in LAYOUTS {
            let n = 50;
            let mut a = DynamicAdjacency::with_layout(n, layout);
            let mut reference: std::collections::HashSet<(VertexId, VertexId)> =
                std::collections::HashSet::new();
            let mut rng = Xoshiro256pp::new(7);
            for _ in 0..20_000 {
                let u = rng.next_usize(n) as VertexId;
                let v = rng.next_usize(n) as VertexId;
                let key = (u.min(v), u.max(v));
                if rng.next_usize(2) == 0 {
                    assert_eq!(a.insert(u, v), u != v && reference.insert(key));
                } else {
                    assert_eq!(a.delete(u, v), reference.remove(&key));
                }
            }
            assert_eq!(a.num_live_edges(), reference.len() as u64);
            let mut live: Vec<_> = a.live_edge_iter().collect();
            live.sort_unstable();
            let mut want: Vec<_> = reference.into_iter().collect();
            want.sort_unstable();
            assert_eq!(live, want);
        }
    }

    #[test]
    fn half_adjacency_owns_only_its_range() {
        for layout in LAYOUTS {
            let mut h = HalfAdjacency::with_layout(8, 4, layout);
            assert_eq!(h.start(), 8);
            assert_eq!(h.end(), 12);
            assert!(h.owns(8) && h.owns(11));
            assert!(!h.owns(7) && !h.owns(12));
            // neighbors may lie outside the owned range
            h.insert_half(9, 1000);
            h.insert_half(9, 3);
            assert_eq!(h.half_edges(), 2);
            assert!(h.contains_half(9, 1000));
            assert!(!h.contains_half(9, 4));
            assert!(h.remove_half(9, 3));
            assert!(!h.remove_half(9, 3), "double remove of a half-edge");
            assert_eq!(h.half_edges(), 1);
            assert_eq!(h.neighbors(9).collect::<Vec<_>>(), vec![1000]);
            assert_eq!(h.live_degree(9), 1);
        }
    }

    #[test]
    fn half_adjacency_compacts_like_the_full_sidecar() {
        for layout in LAYOUTS {
            let mut h = HalfAdjacency::with_layout(0, 1, layout);
            for v in 1..=64u32 {
                h.insert_half(0, v);
            }
            for v in 1..=60u32 {
                assert!(h.remove_half(0, v));
            }
            assert!(h.compactions() > 0);
            assert_eq!(h.live_degree(0), 4);
            assert!(h.slots_len(0) <= 8, "slots {}", h.slots_len(0));
            assert!(h.tombstones() <= 4);
        }
    }

    #[test]
    fn two_halves_compose_into_one_edge_set() {
        // the sharded engine's storage invariant in miniature: shard A owns
        // 0..2, shard B owns 2..4; every edge edit lands on each owner
        let mut a = HalfAdjacency::new(0, 2);
        let mut b = HalfAdjacency::new(2, 2);
        for &(u, v) in &[(0u32, 1u32), (1, 2), (2, 3), (0, 3)] {
            for h in [&mut a, &mut b] {
                if h.owns(u) {
                    h.insert_half(u, v);
                }
                if h.owns(v) {
                    h.insert_half(v, u);
                }
            }
        }
        // (0,1) intra-A: two halves in A; (2,3) intra-B; cross edges split
        assert_eq!(a.half_edges() + b.half_edges(), 8);
        assert_eq!(a.half_edges(), 4); // 0→1, 1→0, 1→2, 0→3
        assert_eq!(b.half_edges(), 4); // 2→1, 2→3, 3→2, 3→0
        // canonical live-edge collection: owner of the min endpoint emits
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for h in [&a, &b] {
            for w in h.start()..h.end() {
                for nb in h.neighbors(w) {
                    if w < nb {
                        edges.push((w, nb));
                    }
                }
            }
        }
        edges.sort_unstable();
        assert_eq!(edges, vec![(0, 1), (0, 3), (1, 2), (2, 3)]);
    }
}
