//! Compact mutable adjacency sidecars for the dynamic engine.
//!
//! The Skipper core deliberately keeps *no* topology — one state byte per
//! vertex is the paper's whole memory story. That is exactly why deletions
//! need a sidecar: when a matched edge disappears, the repair sweep must
//! re-run the reservation state machine over the freed endpoints' *surviving*
//! incident edges, and something has to remember what those are.
//!
//! Two layers live here:
//!
//! * [`HalfAdjacency`] — per-vertex edge lists over a contiguous *owned*
//!   vertex range `[start, start+len)`. Each owned vertex stores its full
//!   neighbor list (neighbors may live anywhere in the universe); an
//!   undirected edge is live iff **every owner of an endpoint stores its
//!   half**, which callers maintain by applying each edit on each owned
//!   endpoint. This is the unit the vertex-partitioned
//!   [`super::ShardedDynamicMatcher`] gives every shard.
//! * [`DynamicAdjacency`] — the single-owner (whole-universe) convenience
//!   wrapper: one `HalfAdjacency` covering `0..num_vertices` with symmetric
//!   insert/delete and whole-graph iteration, used by tests and any caller
//!   that wants plain set-semantics edge storage.
//!
//! Lists grow in amortized-O(1) pushes, delete by **tombstoning** (the slot
//! is overwritten with [`INVALID_VERTEX`] instead of shifting the tail), and
//! reclaim tombstones with **periodic per-vertex compaction** once they
//! outnumber the live entries. Deletes therefore cost one scan of the
//! endpoint's list, inserts cost a membership scan (the structures maintain
//! *set* semantics — the live graph either has an edge or it doesn't, which
//! is what the delete path and the maximality verifier need), and iteration
//! skips tombstones in place. Self-loops are rejected at the
//! [`DynamicAdjacency`] level: the matcher skips them anyway (Algorithm 1
//! lines 6–7), so they can never affect maximality and keeping them live
//! would only pollute repair sweeps; the sharded engine filters them before
//! its half-edge edits for the same reason.

use crate::{VertexId, INVALID_VERTEX};

/// Per-vertex slots start compacting once at least this many tombstones
/// accumulate (and tombstones outnumber live entries) — small lists just
/// tolerate their holes.
const COMPACT_MIN_DEAD: u32 = 8;

#[derive(Default)]
struct AdjList {
    /// Neighbor slots; deleted ones hold [`INVALID_VERTEX`].
    slots: Vec<VertexId>,
    /// Tombstone count in `slots`.
    dead: u32,
}

impl AdjList {
    #[inline]
    fn live_len(&self) -> usize {
        self.slots.len() - self.dead as usize
    }

    fn contains(&self, v: VertexId) -> bool {
        self.slots.iter().any(|&s| s == v)
    }

    fn push(&mut self, v: VertexId) {
        // Reuse a tombstone when one is handy at the tail, else append.
        if self.dead > 0 && self.slots.last() == Some(&INVALID_VERTEX) {
            *self.slots.last_mut().unwrap() = v;
            self.dead -= 1;
        } else {
            self.slots.push(v);
        }
    }

    /// Tombstone the first slot holding `v`; false if absent.
    fn remove(&mut self, v: VertexId) -> bool {
        match self.slots.iter().position(|&s| s == v) {
            Some(i) => {
                self.slots[i] = INVALID_VERTEX;
                self.dead += 1;
                true
            }
            None => false,
        }
    }

    /// Drop tombstones in place when they dominate the list. The capacity
    /// is deliberately kept: under steady churn the list regrows to the
    /// same size, and shrinking here would just thrash the allocator on
    /// every hub compaction.
    fn maybe_compact(&mut self) -> bool {
        if self.dead >= COMPACT_MIN_DEAD && (self.dead as usize) > self.live_len() {
            self.slots.retain(|&s| s != INVALID_VERTEX);
            self.dead = 0;
            true
        } else {
            false
        }
    }
}

/// Half-edge adjacency over the contiguous owned vertex range
/// `[start, start+len)`: tombstoned per-vertex neighbor lists with periodic
/// compaction, edited one endpoint at a time.
///
/// `HalfAdjacency` does **not** enforce set semantics on its own —
/// [`insert_half`](Self::insert_half) pushes unconditionally so a caller
/// that already ran [`contains_half`](Self::contains_half) (to decide
/// whether the edge is fresh) never pays a second membership scan. Callers
/// keep the two endpoint halves of every undirected edge in agreement by
/// applying each edit on every owned endpoint, in a consistent order per
/// edge.
pub struct HalfAdjacency {
    start: usize,
    lists: Vec<AdjList>,
    /// Live directed half-edges stored here (each undirected edge
    /// contributes one per stored endpoint).
    half_edges: u64,
    compactions: u64,
}

impl HalfAdjacency {
    /// Empty lists for the owned range `[start, start+len)`.
    pub fn new(start: VertexId, len: usize) -> Self {
        let mut lists = Vec::new();
        lists.resize_with(len, AdjList::default);
        Self { start: start as usize, lists, half_edges: 0, compactions: 0 }
    }

    /// First owned vertex.
    #[inline]
    pub fn start(&self) -> VertexId {
        self.start as VertexId
    }

    /// One past the last owned vertex.
    #[inline]
    pub fn end(&self) -> VertexId {
        (self.start + self.lists.len()) as VertexId
    }

    #[inline]
    /// Does this sidecar own vertex `v`’s list?
    pub fn owns(&self, v: VertexId) -> bool {
        let v = v as usize;
        v >= self.start && v < self.start + self.lists.len()
    }

    #[inline]
    fn list(&self, v: VertexId) -> &AdjList {
        &self.lists[v as usize - self.start]
    }

    #[inline]
    fn list_mut(&mut self, v: VertexId) -> &mut AdjList {
        &mut self.lists[v as usize - self.start]
    }

    /// Is the half-edge `v → nb` stored? `v` must be owned.
    #[inline]
    pub fn contains_half(&self, v: VertexId, nb: VertexId) -> bool {
        self.list(v).contains(nb)
    }

    /// Store the half-edge `v → nb` unconditionally (no membership scan —
    /// see the type docs). `v` must be owned.
    #[inline]
    pub fn insert_half(&mut self, v: VertexId, nb: VertexId) {
        self.list_mut(v).push(nb);
        self.half_edges += 1;
    }

    /// Tombstone the half-edge `v → nb`; false if it was not stored.
    /// Compacts `v`'s list when its tombstones dominate.
    pub fn remove_half(&mut self, v: VertexId, nb: VertexId) -> bool {
        if !self.list_mut(v).remove(nb) {
            return false;
        }
        self.half_edges -= 1;
        if self.list_mut(v).maybe_compact() {
            self.compactions += 1;
        }
        true
    }

    /// Live neighbors of owned vertex `v` (tombstones skipped), slot order.
    pub fn neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        self.list(v)
            .slots
            .iter()
            .copied()
            .filter(|&s| s != INVALID_VERTEX)
    }

    #[inline]
    /// Live (non-tombstoned) neighbor count of owned vertex `v`.
    pub fn live_degree(&self, v: VertexId) -> usize {
        self.list(v).live_len()
    }

    /// Raw slot count of `v`'s list, tombstones included — lets callers
    /// pick the sparser endpoint for a membership scan.
    #[inline]
    pub(crate) fn slots_len(&self, v: VertexId) -> usize {
        self.list(v).slots.len()
    }

    /// Live directed half-edges stored in this range.
    #[inline]
    pub fn half_edges(&self) -> u64 {
        self.half_edges
    }

    /// Tombstoned slots currently awaiting compaction.
    pub fn tombstones(&self) -> u64 {
        self.lists.iter().map(|l| l.dead as u64).sum()
    }

    /// Per-vertex compactions performed so far.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Resident bytes (slot storage plus list headers).
    pub fn memory_bytes(&self) -> usize {
        self.lists
            .iter()
            .map(|l| l.slots.capacity() * std::mem::size_of::<VertexId>())
            .sum::<usize>()
            + self.lists.capacity() * std::mem::size_of::<AdjList>()
    }
}

/// Mutable adjacency over a fixed vertex universe `0..num_vertices`, with
/// set semantics on undirected edges (each edge stored in both endpoint
/// lists) and tombstoned deletes — a whole-universe [`HalfAdjacency`] with
/// the symmetry maintained internally.
pub struct DynamicAdjacency {
    half: HalfAdjacency,
}

impl DynamicAdjacency {
    /// Empty adjacency over `0..num_vertices`.
    pub fn new(num_vertices: usize) -> Self {
        Self { half: HalfAdjacency::new(0, num_vertices) }
    }

    #[inline]
    /// Size of the vertex universe.
    pub fn num_vertices(&self) -> usize {
        self.half.end() as usize
    }

    /// Live undirected edge count.
    #[inline]
    pub fn num_live_edges(&self) -> u64 {
        self.half.half_edges() / 2
    }

    /// Tombstoned slots currently awaiting compaction (both directions).
    pub fn tombstones(&self) -> u64 {
        self.half.tombstones()
    }

    /// Per-vertex compactions performed so far.
    pub fn compactions(&self) -> u64 {
        self.half.compactions()
    }

    #[inline]
    /// Live neighbor count of `v`.
    pub fn live_degree(&self, v: VertexId) -> usize {
        self.half.live_degree(v)
    }

    /// Is undirected edge `{u,v}` live? (Scans the sparser endpoint.)
    pub fn contains(&self, u: VertexId, v: VertexId) -> bool {
        if !self.half.owns(u) || !self.half.owns(v) {
            return false;
        }
        // scan the sparser endpoint
        if self.half.slots_len(u) <= self.half.slots_len(v) {
            self.half.contains_half(u, v)
        } else {
            self.half.contains_half(v, u)
        }
    }

    /// Insert edge `{u,v}`; false if it is a self-loop, out of range, or
    /// already live.
    pub fn insert(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v || !self.half.owns(u) || !self.half.owns(v) || self.contains(u, v) {
            return false;
        }
        self.half.insert_half(u, v);
        self.half.insert_half(v, u);
        true
    }

    /// Delete edge `{u,v}`; false if it was not live. Compacts either
    /// endpoint's list when its tombstones dominate.
    pub fn delete(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v || !self.half.owns(u) || !self.half.owns(v) {
            return false;
        }
        if !self.half.remove_half(u, v) {
            return false;
        }
        let removed = self.half.remove_half(v, u);
        debug_assert!(removed, "adjacency asymmetry: ({u},{v}) stored one-way");
        true
    }

    /// Live neighbors of `v` (tombstones skipped), in slot order.
    pub fn live_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        self.half.neighbors(v)
    }

    /// All live edges, canonicalized `(min, max)`, each exactly once — the
    /// input [`crate::matching::verify::verify_maximal_dynamic`] wants.
    pub fn live_edge_iter(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        (0..self.num_vertices()).flat_map(move |u| {
            let u = u as VertexId;
            self.half.neighbors(u).filter(move |&v| u < v).map(move |v| (u, v))
        })
    }

    /// Resident bytes of the sidecar (slot storage only).
    pub fn memory_bytes(&self) -> usize {
        self.half.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_delete_roundtrip_with_set_semantics() {
        let mut a = DynamicAdjacency::new(5);
        assert!(a.insert(0, 1));
        assert!(!a.insert(1, 0), "reinsert of the reverse orientation");
        assert!(a.insert(1, 2));
        assert_eq!(a.num_live_edges(), 2);
        assert!(a.contains(0, 1) && a.contains(1, 0));
        assert!(a.delete(1, 0));
        assert!(!a.delete(0, 1), "double delete");
        assert_eq!(a.num_live_edges(), 1);
        assert!(!a.contains(0, 1));
        assert_eq!(a.live_degree(1), 1);
        assert_eq!(a.live_neighbors(1).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn self_loops_and_out_of_range_rejected() {
        let mut a = DynamicAdjacency::new(3);
        assert!(!a.insert(1, 1));
        assert!(!a.insert(0, 7));
        assert!(!a.delete(0, 7));
        assert_eq!(a.num_live_edges(), 0);
    }

    #[test]
    fn tombstones_are_skipped_and_reused() {
        let mut a = DynamicAdjacency::new(4);
        a.insert(0, 1);
        a.insert(0, 2);
        a.insert(0, 3);
        a.delete(0, 3); // tail slot becomes a tombstone...
        assert_eq!(a.tombstones(), 2);
        a.insert(0, 3); // ...and is reused by the next push
        assert_eq!(a.live_degree(0), 3);
        a.delete(0, 2);
        assert_eq!(
            a.live_neighbors(0).collect::<Vec<_>>(),
            vec![1, 3],
            "tombstone skipped mid-list"
        );
    }

    #[test]
    fn compaction_reclaims_dominating_tombstones() {
        let n = 64;
        let mut a = DynamicAdjacency::new(n + 1);
        for v in 1..=n {
            a.insert(0, v as VertexId);
        }
        for v in 1..=n - 4 {
            a.delete(0, v as VertexId);
        }
        assert!(a.compactions() > 0, "hub list should have compacted");
        assert_eq!(a.live_degree(0), 4);
        // vertex 0's list really shrank
        assert!(a.half.slots_len(0) <= 8, "slots {}", a.half.slots_len(0));
        assert_eq!(a.num_live_edges(), 4);
    }

    #[test]
    fn live_edge_iter_is_canonical_and_complete() {
        let mut a = DynamicAdjacency::new(6);
        for &(u, v) in &[(3u32, 1u32), (1, 2), (4, 5), (2, 3)] {
            a.insert(u, v);
        }
        a.delete(1, 2);
        let mut edges: Vec<_> = a.live_edge_iter().collect();
        edges.sort_unstable();
        assert_eq!(edges, vec![(1, 3), (2, 3), (4, 5)]);
    }

    #[test]
    fn churn_keeps_counts_consistent() {
        use crate::util::rng::Xoshiro256pp;
        let n = 50;
        let mut a = DynamicAdjacency::new(n);
        let mut reference: std::collections::HashSet<(VertexId, VertexId)> =
            std::collections::HashSet::new();
        let mut rng = Xoshiro256pp::new(7);
        for _ in 0..20_000 {
            let u = rng.next_usize(n) as VertexId;
            let v = rng.next_usize(n) as VertexId;
            let key = (u.min(v), u.max(v));
            if rng.next_usize(2) == 0 {
                assert_eq!(a.insert(u, v), u != v && reference.insert(key));
            } else {
                assert_eq!(a.delete(u, v), reference.remove(&key));
            }
        }
        assert_eq!(a.num_live_edges(), reference.len() as u64);
        let mut live: Vec<_> = a.live_edge_iter().collect();
        live.sort_unstable();
        let mut want: Vec<_> = reference.into_iter().collect();
        want.sort_unstable();
        assert_eq!(live, want);
    }

    #[test]
    fn half_adjacency_owns_only_its_range() {
        let mut h = HalfAdjacency::new(8, 4);
        assert_eq!(h.start(), 8);
        assert_eq!(h.end(), 12);
        assert!(h.owns(8) && h.owns(11));
        assert!(!h.owns(7) && !h.owns(12));
        // neighbors may lie outside the owned range
        h.insert_half(9, 1000);
        h.insert_half(9, 3);
        assert_eq!(h.half_edges(), 2);
        assert!(h.contains_half(9, 1000));
        assert!(!h.contains_half(9, 4));
        assert!(h.remove_half(9, 3));
        assert!(!h.remove_half(9, 3), "double remove of a half-edge");
        assert_eq!(h.half_edges(), 1);
        assert_eq!(h.neighbors(9).collect::<Vec<_>>(), vec![1000]);
        assert_eq!(h.live_degree(9), 1);
    }

    #[test]
    fn half_adjacency_compacts_like_the_full_sidecar() {
        let mut h = HalfAdjacency::new(0, 1);
        for v in 1..=64u32 {
            h.insert_half(0, v);
        }
        for v in 1..=60u32 {
            assert!(h.remove_half(0, v));
        }
        assert!(h.compactions() > 0);
        assert_eq!(h.live_degree(0), 4);
        assert!(h.slots_len(0) <= 8, "slots {}", h.slots_len(0));
        assert!(h.tombstones() <= 4);
    }

    #[test]
    fn two_halves_compose_into_one_edge_set() {
        // the sharded engine's storage invariant in miniature: shard A owns
        // 0..2, shard B owns 2..4; every edge edit lands on each owner
        let mut a = HalfAdjacency::new(0, 2);
        let mut b = HalfAdjacency::new(2, 2);
        for &(u, v) in &[(0u32, 1u32), (1, 2), (2, 3), (0, 3)] {
            for h in [&mut a, &mut b] {
                if h.owns(u) {
                    h.insert_half(u, v);
                }
                if h.owns(v) {
                    h.insert_half(v, u);
                }
            }
        }
        // (0,1) intra-A: two halves in A; (2,3) intra-B; cross edges split
        assert_eq!(a.half_edges() + b.half_edges(), 8);
        assert_eq!(a.half_edges(), 4); // 0→1, 1→0, 1→2, 0→3
        assert_eq!(b.half_edges(), 4); // 2→1, 2→3, 3→2, 3→0
        // canonical live-edge collection: owner of the min endpoint emits
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for h in [&a, &b] {
            for w in h.start()..h.end() {
                for nb in h.neighbors(w) {
                    if w < nb {
                        edges.push((w, nb));
                    }
                }
            }
        }
        edges.sort_unstable();
        assert_eq!(edges, vec![(0, 1), (0, 3), (1, 2), (2, 3)]);
    }
}
