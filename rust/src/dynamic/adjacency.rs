//! Compact mutable adjacency sidecar for the dynamic engine.
//!
//! The Skipper core deliberately keeps *no* topology — one state byte per
//! vertex is the paper's whole memory story. That is exactly why deletions
//! need a sidecar: when a matched edge disappears, the repair sweep must
//! re-run the reservation state machine over the freed endpoints' *surviving*
//! incident edges, and something has to remember what those are.
//!
//! [`DynamicAdjacency`] is that something: per-vertex edge lists that grow
//! in amortized-O(1) pushes, delete by **tombstoning** (the slot is
//! overwritten with [`INVALID_VERTEX`] instead of shifting the tail), and
//! reclaim tombstones with **periodic per-vertex compaction** once they
//! outnumber the live entries. Deletes therefore cost one scan of the
//! endpoint's list, inserts cost a membership scan (the structure maintains
//! *set* semantics — the live graph either has an edge or it doesn't, which
//! is what the delete path and the maximality verifier need), and iteration
//! skips tombstones in place. Self-loops are rejected at insert: the matcher
//! skips them anyway (Algorithm 1 lines 6–7), so they can never affect
//! maximality and keeping them live would only pollute repair sweeps.

use crate::{VertexId, INVALID_VERTEX};

/// Per-vertex slots start compacting once at least this many tombstones
/// accumulate (and tombstones outnumber live entries) — small lists just
/// tolerate their holes.
const COMPACT_MIN_DEAD: u32 = 8;

#[derive(Default)]
struct AdjList {
    /// Neighbor slots; deleted ones hold [`INVALID_VERTEX`].
    slots: Vec<VertexId>,
    /// Tombstone count in `slots`.
    dead: u32,
}

impl AdjList {
    #[inline]
    fn live_len(&self) -> usize {
        self.slots.len() - self.dead as usize
    }

    fn contains(&self, v: VertexId) -> bool {
        self.slots.iter().any(|&s| s == v)
    }

    fn push(&mut self, v: VertexId) {
        // Reuse a tombstone when one is handy at the tail, else append.
        if self.dead > 0 && self.slots.last() == Some(&INVALID_VERTEX) {
            *self.slots.last_mut().unwrap() = v;
            self.dead -= 1;
        } else {
            self.slots.push(v);
        }
    }

    /// Tombstone the first slot holding `v`; false if absent.
    fn remove(&mut self, v: VertexId) -> bool {
        match self.slots.iter().position(|&s| s == v) {
            Some(i) => {
                self.slots[i] = INVALID_VERTEX;
                self.dead += 1;
                true
            }
            None => false,
        }
    }

    /// Drop tombstones in place when they dominate the list. The capacity
    /// is deliberately kept: under steady churn the list regrows to the
    /// same size, and shrinking here would just thrash the allocator on
    /// every hub compaction.
    fn maybe_compact(&mut self) -> bool {
        if self.dead >= COMPACT_MIN_DEAD && (self.dead as usize) > self.live_len() {
            self.slots.retain(|&s| s != INVALID_VERTEX);
            self.dead = 0;
            true
        } else {
            false
        }
    }
}

/// Mutable adjacency over a fixed vertex universe `0..num_vertices`, with
/// set semantics on undirected edges (each edge stored in both endpoint
/// lists) and tombstoned deletes.
pub struct DynamicAdjacency {
    lists: Vec<AdjList>,
    live_edges: u64,
    compactions: u64,
}

impl DynamicAdjacency {
    pub fn new(num_vertices: usize) -> Self {
        let mut lists = Vec::new();
        lists.resize_with(num_vertices, AdjList::default);
        Self { lists, live_edges: 0, compactions: 0 }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.lists.len()
    }

    /// Live undirected edge count.
    #[inline]
    pub fn num_live_edges(&self) -> u64 {
        self.live_edges
    }

    /// Tombstoned slots currently awaiting compaction (both directions).
    pub fn tombstones(&self) -> u64 {
        self.lists.iter().map(|l| l.dead as u64).sum()
    }

    /// Per-vertex compactions performed so far.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    #[inline]
    pub fn live_degree(&self, v: VertexId) -> usize {
        self.lists[v as usize].live_len()
    }

    pub fn contains(&self, u: VertexId, v: VertexId) -> bool {
        let (u, v) = (u as usize, v as usize);
        if u >= self.lists.len() || v >= self.lists.len() {
            return false;
        }
        // scan the sparser endpoint
        if self.lists[u].slots.len() <= self.lists[v].slots.len() {
            self.lists[u].contains(v as VertexId)
        } else {
            self.lists[v].contains(u as VertexId)
        }
    }

    /// Insert edge `{u,v}`; false if it is a self-loop, out of range, or
    /// already live.
    pub fn insert(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v
            || u as usize >= self.lists.len()
            || v as usize >= self.lists.len()
            || self.contains(u, v)
        {
            return false;
        }
        self.lists[u as usize].push(v);
        self.lists[v as usize].push(u);
        self.live_edges += 1;
        true
    }

    /// Delete edge `{u,v}`; false if it was not live. Compacts either
    /// endpoint's list when its tombstones dominate.
    pub fn delete(&mut self, u: VertexId, v: VertexId) -> bool {
        if u == v || u as usize >= self.lists.len() || v as usize >= self.lists.len() {
            return false;
        }
        if !self.lists[u as usize].remove(v) {
            return false;
        }
        let removed = self.lists[v as usize].remove(u);
        debug_assert!(removed, "adjacency asymmetry: ({u},{v}) stored one-way");
        self.live_edges -= 1;
        for w in [u, v] {
            if self.lists[w as usize].maybe_compact() {
                self.compactions += 1;
            }
        }
        true
    }

    /// Live neighbors of `v` (tombstones skipped), in slot order.
    pub fn live_neighbors(&self, v: VertexId) -> impl Iterator<Item = VertexId> + '_ {
        self.lists[v as usize]
            .slots
            .iter()
            .copied()
            .filter(|&s| s != INVALID_VERTEX)
    }

    /// All live edges, canonicalized `(min, max)`, each exactly once — the
    /// input [`crate::matching::verify::verify_maximal_dynamic`] wants.
    pub fn live_edge_iter(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        self.lists.iter().enumerate().flat_map(|(u, l)| {
            let u = u as VertexId;
            l.slots
                .iter()
                .copied()
                .filter(move |&v| v != INVALID_VERTEX && u < v)
                .map(move |v| (u, v))
        })
    }

    /// Resident bytes of the sidecar (slot storage only).
    pub fn memory_bytes(&self) -> usize {
        self.lists
            .iter()
            .map(|l| l.slots.capacity() * std::mem::size_of::<VertexId>())
            .sum::<usize>()
            + self.lists.capacity() * std::mem::size_of::<AdjList>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_delete_roundtrip_with_set_semantics() {
        let mut a = DynamicAdjacency::new(5);
        assert!(a.insert(0, 1));
        assert!(!a.insert(1, 0), "reinsert of the reverse orientation");
        assert!(a.insert(1, 2));
        assert_eq!(a.num_live_edges(), 2);
        assert!(a.contains(0, 1) && a.contains(1, 0));
        assert!(a.delete(1, 0));
        assert!(!a.delete(0, 1), "double delete");
        assert_eq!(a.num_live_edges(), 1);
        assert!(!a.contains(0, 1));
        assert_eq!(a.live_degree(1), 1);
        assert_eq!(a.live_neighbors(1).collect::<Vec<_>>(), vec![2]);
    }

    #[test]
    fn self_loops_and_out_of_range_rejected() {
        let mut a = DynamicAdjacency::new(3);
        assert!(!a.insert(1, 1));
        assert!(!a.insert(0, 7));
        assert!(!a.delete(0, 7));
        assert_eq!(a.num_live_edges(), 0);
    }

    #[test]
    fn tombstones_are_skipped_and_reused() {
        let mut a = DynamicAdjacency::new(4);
        a.insert(0, 1);
        a.insert(0, 2);
        a.insert(0, 3);
        a.delete(0, 3); // tail slot becomes a tombstone...
        assert_eq!(a.tombstones(), 2);
        a.insert(0, 3); // ...and is reused by the next push
        assert_eq!(a.live_degree(0), 3);
        a.delete(0, 2);
        assert_eq!(
            a.live_neighbors(0).collect::<Vec<_>>(),
            vec![1, 3],
            "tombstone skipped mid-list"
        );
    }

    #[test]
    fn compaction_reclaims_dominating_tombstones() {
        let n = 64;
        let mut a = DynamicAdjacency::new(n + 1);
        for v in 1..=n {
            a.insert(0, v as VertexId);
        }
        for v in 1..=n - 4 {
            a.delete(0, v as VertexId);
        }
        assert!(a.compactions() > 0, "hub list should have compacted");
        assert_eq!(a.live_degree(0), 4);
        // vertex 0's list really shrank
        assert!(a.lists[0].slots.len() <= 8, "slots {}", a.lists[0].slots.len());
        assert_eq!(a.num_live_edges(), 4);
    }

    #[test]
    fn live_edge_iter_is_canonical_and_complete() {
        let mut a = DynamicAdjacency::new(6);
        for &(u, v) in &[(3u32, 1u32), (1, 2), (4, 5), (2, 3)] {
            a.insert(u, v);
        }
        a.delete(1, 2);
        let mut edges: Vec<_> = a.live_edge_iter().collect();
        edges.sort_unstable();
        assert_eq!(edges, vec![(1, 3), (2, 3), (4, 5)]);
    }

    #[test]
    fn churn_keeps_counts_consistent() {
        use crate::util::rng::Xoshiro256pp;
        let n = 50;
        let mut a = DynamicAdjacency::new(n);
        let mut reference: std::collections::HashSet<(VertexId, VertexId)> =
            std::collections::HashSet::new();
        let mut rng = Xoshiro256pp::new(7);
        for _ in 0..20_000 {
            let u = rng.next_usize(n) as VertexId;
            let v = rng.next_usize(n) as VertexId;
            let key = (u.min(v), u.max(v));
            if rng.next_usize(2) == 0 {
                assert_eq!(a.insert(u, v), u != v && reference.insert(key));
            } else {
                assert_eq!(a.delete(u, v), reference.remove(&key));
            }
        }
        assert_eq!(a.num_live_edges(), reference.len() as u64);
        let mut live: Vec<_> = a.live_edge_iter().collect();
        live.sort_unstable();
        let mut want: Vec<_> = reference.into_iter().collect();
        want.sort_unstable();
        assert_eq!(live, want);
    }
}
