//! Fully dynamic maximal matching — inserts **and deletes** (ISSUE 2; the
//! regime of Ghaffari & Trygub's *Parallel Dynamic Maximal Matching*,
//! motivated here by paper §V-C's observation that Skipper is already
//! incremental in expectation), sharded so that epochs are parallel in
//! every phase (ISSUE 3).
//!
//! The paper's single-pass contract ("an edge's fate is decided the moment
//! it is seen, never revisited") makes insertions nearly free — one
//! `process_edge` against the live one-byte-per-vertex state. Deletions are
//! the missing half: removing a matched edge frees two vertices, and
//! maximality over the *live* edge set may break in their neighborhoods.
//! This module restores it without global recomputation:
//!
//! * [`adjacency`] — the compact mutable topology sidecars: [`HalfAdjacency`]
//!   (per-vertex lists over an owned contiguous range, tombstoned deletes,
//!   periodic compaction) and the whole-universe [`DynamicAdjacency`]
//!   wrapper;
//! * [`partition`] — the vertex-partitioned engine:
//!   [`ShardedDynamicMatcher`] splits vertices into `P` contiguous shards
//!   ([`VertexPartition`]), routes each update to its owner shard(s) via
//!   per-shard mailboxes ([`ShardMailboxes`]), runs the mutate phase in
//!   parallel across shards — on a persistent
//!   [`WorkerPool`](crate::par::pool::WorkerPool) by default, see
//!   [`ShardExec`] — and feeds the per-shard insert/repair work
//!   lists into the shared one-byte-per-vertex `SkipperCore` sweeps — the
//!   atomic state array needs no sharding at all;
//! * [`engine`] — the epoch-based update API: [`Update`], [`EpochReport`]
//!   (with per-phase wall times), the repair-sweep invariant proof, and
//!   [`DynamicMatcher`] — the stable `P = 1` specialization existing
//!   callers use;
//! * [`churn`] — the reusable insert/delete workload driver behind
//!   `skipper-cli churn`, the `dynamic`/`scale` coordinator experiments,
//!   and the `dynamic_churn` bench.
//!
//! The long-running service layer in [`crate::service`] owns one
//! [`ShardedDynamicMatcher`] and feeds it coalesced client batches through
//! the same mailbox routing.

pub mod adjacency;
pub mod churn;
pub mod engine;
pub mod partition;

pub use adjacency::{AdjLayout, DynamicAdjacency, HalfAdjacency};
pub use engine::{DynamicMatcher, EpochReport, Update};
pub use partition::{ShardExec, ShardMailboxes, ShardedDynamicMatcher, VertexPartition};
// placement is configured wherever an engine is built, so the policy enum
// rides along with the engine's own types
pub use crate::par::topology::PinPolicy;
