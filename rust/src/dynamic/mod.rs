//! Fully dynamic maximal matching — inserts **and deletes** (ISSUE 2; the
//! regime of Ghaffari & Trygub's *Parallel Dynamic Maximal Matching*,
//! motivated here by paper §V-C's observation that Skipper is already
//! incremental in expectation).
//!
//! The paper's single-pass contract ("an edge's fate is decided the moment
//! it is seen, never revisited") makes insertions nearly free — one
//! `process_edge` against the live one-byte-per-vertex state. Deletions are
//! the missing half: removing a matched edge frees two vertices, and
//! maximality over the *live* edge set may break in their neighborhoods.
//! This module restores it without global recomputation:
//!
//! * [`adjacency`] — the compact mutable topology sidecar (chunked
//!   per-vertex lists, tombstoned deletes, periodic compaction) that
//!   remembers each vertex's surviving incident edges;
//! * [`engine`] — the epoch-based update engine: mixed insert/delete
//!   batches, freed-vertex tracking, and the parallel **repair sweep** that
//!   re-runs the Algorithm-1 reservation state machine over only the
//!   affected neighborhoods (see `engine.rs` for the invariant proof);
//! * [`churn`] — the reusable insert/delete workload driver behind
//!   `skipper-cli churn`, the `dynamic` coordinator experiment, and the
//!   `dynamic_churn` bench.
//!
//! The long-running service layer in [`crate::service`] owns one
//! [`engine::DynamicMatcher`] and feeds it coalesced client batches.

pub mod adjacency;
pub mod churn;
pub mod engine;

pub use adjacency::DynamicAdjacency;
pub use engine::{DynamicMatcher, EpochReport, Update};
