//! Epoch-based fully dynamic maximal-matching engine.
//!
//! ## The repair-sweep invariant
//!
//! Paper §V-C observes that Skipper is *incremental in expectation*: an
//! insertion is one `process_edge` call against the live vertex states.
//! Deletions are the part the single-pass story doesn't cover — removing a
//! matched edge leaves both endpoints free, and any of their surviving
//! neighbors that relied on them for coverage may now violate maximality.
//!
//! The engine's epoch loop restores the invariant with work proportional to
//! the *affected neighborhoods*, never a global recompute:
//!
//! 1. **Mutate** (parallel across shards): apply the epoch's updates to the
//!    adjacency sidecar in arrival order. Each delete that destroys a
//!    matched pair releases both endpoints in the
//!    [`SkipperCore`](crate::matching::core::SkipperCore) (`MCHD → ACC`)
//!    and records them as *freed*.
//! 2. **Insert pass** (parallel): the epoch's surviving new edges go through
//!    the ordinary [`StreamingSkipper`](crate::matching::streaming::StreamingSkipper)
//!    chunk driver — the same `process_chunk` fast path every other driver
//!    uses.
//! 3. **Repair sweep** (parallel): the surviving incident edges of every
//!    still-unmatched freed vertex are re-run through the same Algorithm-1
//!    reservation state machine.
//!
//! Why this suffices: matched vertices only become free in step 1, and only
//! the recorded freed vertices do. Take any live edge `(a,b)` with both
//! endpoints free after the epoch. If it was inserted this epoch, step 2
//! processed it after all frees — `process_edge` leaves an edge unmatched
//! only by observing a matched endpoint, and matched states are stable for
//! the rest of the epoch; contradiction. If it predates the epoch, the
//! pre-epoch matching was maximal, so one endpoint was matched then and must
//! have been freed this epoch — so step 3 re-processed `(a,b)`;
//! contradiction again. Hence the matching is maximal over the live edge
//! set after every epoch, which is exactly what
//! [`crate::matching::verify::verify_maximal_dynamic`] checks and
//! `rust/tests/prop_dynamic.rs` hammers on.
//!
//! The argument never depends on the mutate phase running on one thread —
//! only on every free being recorded and on the sweeps running after the
//! mutate barrier. That is what lets
//! [`ShardedDynamicMatcher`](super::ShardedDynamicMatcher) partition the
//! mutate phase by vertex owner (see `partition.rs` for the cross-shard
//! agreement argument); [`DynamicMatcher`] here is its `P = 1`
//! specialization, kept as the stable single-shard API so existing callers
//! and this proof carry over unchanged.

use super::partition::ShardedDynamicMatcher;
use crate::VertexId;

/// One mutation of the live edge set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Update {
    /// Make edge `{u, v}` live (no-op if it already is).
    Insert(VertexId, VertexId),
    /// Remove edge `{u, v}` from the live set (no-op if it is not live).
    Delete(VertexId, VertexId),
}

/// Telemetry of one applied epoch.
#[derive(Clone, Debug, Default)]
pub struct EpochReport {
    /// 1-based epoch number on this engine.
    pub epoch: u64,
    /// Insert updates received (before dedup against the live set).
    pub inserts: usize,
    /// Delete updates received (before dedup against the live set).
    pub deletes: usize,
    /// Inserts that actually created a live edge and survived to the end of
    /// the mutate phase.
    pub inserted_live: usize,
    /// Deletes that removed a live edge.
    pub deleted_live: usize,
    /// Matched pairs destroyed by deletes.
    pub destroyed_pairs: usize,
    /// Vertices released back to `ACC` (= 2 × destroyed pairs).
    pub freed_vertices: usize,
    /// Surviving incident edges the repair sweep re-processed.
    pub repair_edges: usize,
    /// Matches created this epoch (insert pass + repair sweep).
    pub new_matches: usize,
    /// JIT conflicts across both parallel passes.
    pub conflicts: u64,
    /// Live undirected edges after the epoch.
    pub live_edges: u64,
    /// Matched vertices after the epoch.
    pub matched_vertices: usize,
    /// Wall seconds of the whole epoch (mutate + insert + repair phases).
    pub wall_s: f64,
    /// Wall seconds of the per-shard parallel mutate phase, barrier to
    /// barrier (adjacency edits, partner bookkeeping, freed collection —
    /// including the cost of waking or spawning the shard workers).
    pub mutate_wall_s: f64,
    /// Wall seconds of the insert sweep (phase 2).
    pub insert_wall_s: f64,
    /// Wall seconds of repair collection plus the repair sweep (phase 3).
    pub repair_wall_s: f64,
    /// Longest single-shard busy time *inside* the mutate phase — the
    /// "run" half of spawn-vs-run. The difference to [`mutate_wall_s`]
    /// (see [`mutate_spawn_overhead_s`](Self::mutate_spawn_overhead_s)) is
    /// pure dispatch cost: thread spawn+join for
    /// [`ShardExec::Fork`](super::ShardExec::Fork), run-queue doorbell
    /// wake + countdown for [`ShardExec::Pool`](super::ShardExec::Pool).
    ///
    /// [`mutate_wall_s`]: Self::mutate_wall_s
    pub mutate_run_s: f64,
    /// Wall seconds spent routing this epoch's updates into per-shard
    /// mailboxes. Filled by `apply_epoch` (which routes internally) or by
    /// the service's router for mailbox flushes.
    pub route_wall_s: f64,
    /// Portion of [`route_wall_s`](Self::route_wall_s) that overlapped a
    /// concurrently running engine flush — nonzero only on the service's
    /// pipelined path, where routing epoch `N+1` proceeds while epoch `N`
    /// is being applied.
    pub route_overlap_s: f64,
}

impl EpochReport {
    /// Repair work as a fraction of the live edge set — the headline
    /// "no global recompute" number: for small batches this stays far below
    /// 1 because only affected neighborhoods are touched.
    pub fn repair_fraction(&self) -> f64 {
        self.repair_edges as f64 / (self.live_edges.max(1)) as f64
    }

    /// Mutate-phase share of the epoch wall time — the fraction sharding
    /// parallelizes (the sweeps were already parallel).
    pub fn mutate_fraction(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.mutate_wall_s / self.wall_s
        } else {
            0.0
        }
    }

    /// Dispatch ("spawn") overhead of the mutate phase: barrier-to-barrier
    /// wall time minus the longest per-shard busy time. For very small
    /// epochs under the forked baseline this is the dominant cost — the
    /// persistent worker pool exists to make it disappear, and this number
    /// is how the `scale` experiment and `dynamic_churn` bench show it
    /// doing so.
    pub fn mutate_spawn_overhead_s(&self) -> f64 {
        (self.mutate_wall_s - self.mutate_run_s).max(0.0)
    }
}

/// Fully dynamic maximal matching: a long-lived
/// [`SkipperCore`](crate::matching::core::SkipperCore) plus the adjacency
/// sidecar, mutated in epochs of mixed inserts and deletes.
///
/// This is the single-shard (`P = 1`) specialization of
/// [`ShardedDynamicMatcher`] — one owner for every vertex, so the mutate
/// phase runs inline on the calling thread exactly as the invariant proof
/// above narrates, and all epoch behavior (ordering, netting, counters) is
/// the stable reference the property tests cross-check higher shard counts
/// against.
///
/// # Example
///
/// One matcher thread makes the sweep order deterministic: on the path
/// `0-1-2`, edge `(0,1)` arrives first and matches, and deleting it later
/// frees both endpoints so the repair sweep re-matches `(1,2)`:
///
/// ```
/// use skipper::dynamic::{DynamicMatcher, Update};
///
/// let mut m = DynamicMatcher::new(4, 1);
/// m.apply_epoch(&[Update::Insert(0, 1), Update::Insert(1, 2)]).unwrap();
/// assert_eq!(m.partner(0), Some(1));
///
/// let report = m.apply_epoch(&[Update::Delete(0, 1)]).unwrap();
/// assert_eq!(report.destroyed_pairs, 1);
/// assert_eq!(m.partner(1), Some(2), "repair re-matched the surviving edge");
/// m.verify().unwrap();
/// ```
pub struct DynamicMatcher {
    inner: ShardedDynamicMatcher,
}

impl DynamicMatcher {
    /// Engine over the fixed vertex universe `0..num_vertices` with
    /// `threads` matcher threads inside the insert/repair sweeps.
    pub fn new(num_vertices: usize, threads: usize) -> Self {
        Self { inner: ShardedDynamicMatcher::new(num_vertices, threads, 1) }
    }

    /// Size of the vertex universe.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.inner.num_vertices()
    }

    /// Epochs applied so far.
    #[inline]
    pub fn epochs_applied(&self) -> u64 {
        self.inner.epochs_applied()
    }

    /// Live undirected edge count.
    #[inline]
    pub fn num_live_edges(&self) -> u64 {
        self.inner.num_live_edges()
    }

    /// Currently matched vertices (2 × matched pairs).
    #[inline]
    pub fn matched_vertices(&self) -> usize {
        self.inner.matched_vertices()
    }

    /// Is `v` currently matched?
    #[inline]
    pub fn is_matched(&self, v: VertexId) -> bool {
        self.inner.is_matched(v)
    }

    /// `v`'s current partner, if matched.
    pub fn partner(&self, v: VertexId) -> Option<VertexId> {
        self.inner.partner(v)
    }

    /// Current matching as canonical `(min, max)` pairs.
    pub fn matching_pairs(&self) -> Vec<(VertexId, VertexId)> {
        self.inner.matching_pairs()
    }

    /// The live edge set (canonical, each edge once) — for verification and
    /// the service's audit path.
    pub fn live_edge_iter(&self) -> impl Iterator<Item = (VertexId, VertexId)> + '_ {
        self.inner.live_edges().into_iter()
    }

    /// Adjacency-sidecar health for telemetry.
    pub fn adjacency_bytes(&self) -> usize {
        self.inner.adjacency_bytes()
    }

    /// Tombstoned adjacency slots awaiting compaction.
    pub fn adjacency_tombstones(&self) -> u64 {
        self.inner.adjacency_tombstones()
    }

    /// Full dynamic validity check: matching ⊆ live edges, endpoint-disjoint,
    /// and maximal over the live set.
    pub fn verify(&self) -> Result<(), String> {
        self.inner.verify()
    }

    /// Apply one epoch of mixed updates. Update order within the batch is
    /// respected against the live set (insert-then-delete of the same edge
    /// in one epoch nets out to nothing). Errors on out-of-range vertices,
    /// with no mutation applied.
    pub fn apply_epoch(&mut self, updates: &[Update]) -> Result<EpochReport, String> {
        self.inner.apply_epoch(updates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use Update::{Delete, Insert};

    fn pairs(m: &DynamicMatcher) -> Vec<(VertexId, VertexId)> {
        m.matching_pairs()
    }

    #[test]
    fn delete_of_matched_edge_triggers_repair() {
        // path 0-1-2-3, one matcher thread so the stream order is the
        // match order: skipper matches (0,1) and (2,3).
        let mut m = DynamicMatcher::new(4, 1);
        let r = m
            .apply_epoch(&[Insert(0, 1), Insert(1, 2), Insert(2, 3)])
            .unwrap();
        assert_eq!(r.new_matches, 2);
        assert_eq!(pairs(&m), vec![(0, 1), (2, 3)]);
        m.verify().unwrap();
        // deleting (0,1) frees 0 and 1; the repair sweep re-examines 1's
        // surviving edge (1,2), finds 2 still matched, and correctly leaves
        // 1 free — maximality holds because every live edge of a freed
        // vertex has a matched endpoint.
        let r = m.apply_epoch(&[Delete(0, 1)]).unwrap();
        assert_eq!(r.destroyed_pairs, 1);
        assert_eq!(r.freed_vertices, 2);
        assert_eq!(r.repair_edges, 1, "only (1,2) needs re-examination");
        assert!(!m.is_matched(0) && !m.is_matched(1));
        assert!(m.is_matched(2) && m.is_matched(3));
        m.verify().unwrap();
        // now delete (2,3) too: repair re-runs (1,2) and must re-match it.
        let r = m.apply_epoch(&[Delete(2, 3)]).unwrap();
        assert_eq!(r.destroyed_pairs, 1);
        assert_eq!(r.new_matches, 1, "repair re-matched (1,2)");
        assert!(m.is_matched(1) && m.is_matched(2));
        assert!(!m.is_matched(3));
        m.verify().unwrap();
        assert_eq!(m.partner(1), Some(2));
    }

    #[test]
    fn delete_unmatched_edge_is_free_of_repair() {
        let mut m = DynamicMatcher::new(4, 1);
        m.apply_epoch(&[Insert(0, 1), Insert(0, 2), Insert(0, 3)]).unwrap();
        // star: exactly one matched pair, say (0,x)
        assert_eq!(m.matched_vertices(), 2);
        let unmatched_edge = [(0, 1), (0, 2), (0, 3)]
            .into_iter()
            .find(|&(_, v)| !m.is_matched(v))
            .unwrap();
        let r = m
            .apply_epoch(&[Delete(unmatched_edge.0, unmatched_edge.1)])
            .unwrap();
        assert_eq!(r.destroyed_pairs, 0);
        assert_eq!(r.repair_edges, 0);
        m.verify().unwrap();
    }

    #[test]
    fn insert_then_delete_in_one_epoch_nets_nothing() {
        let mut m = DynamicMatcher::new(4, 2);
        let r = m.apply_epoch(&[Insert(0, 1), Delete(0, 1)]).unwrap();
        assert_eq!(r.inserted_live, 0);
        assert_eq!(r.new_matches, 0);
        assert_eq!(m.num_live_edges(), 0);
        assert_eq!(m.matched_vertices(), 0);
        m.verify().unwrap();
        // and delete-then-reinsert of a matched edge within one epoch
        m.apply_epoch(&[Insert(0, 1)]).unwrap();
        let r = m.apply_epoch(&[Delete(0, 1), Insert(0, 1)]).unwrap();
        assert_eq!(r.destroyed_pairs, 1);
        m.verify().unwrap();
        assert!(m.is_matched(0) && m.is_matched(1), "re-inserted pair re-matches");
    }

    #[test]
    fn duplicate_and_phantom_updates_are_inert() {
        let mut m = DynamicMatcher::new(4, 1);
        let r = m
            .apply_epoch(&[Insert(0, 1), Insert(1, 0), Insert(0, 1), Delete(2, 3)])
            .unwrap();
        assert_eq!(r.inserted_live, 1, "one live edge from three insert updates");
        assert_eq!(r.deleted_live, 0, "phantom delete ignored");
        assert_eq!(m.num_live_edges(), 1);
        m.verify().unwrap();
    }

    #[test]
    fn out_of_range_update_is_rejected_without_mutation() {
        let mut m = DynamicMatcher::new(4, 1);
        m.apply_epoch(&[Insert(0, 1)]).unwrap();
        let err = m.apply_epoch(&[Insert(2, 3), Insert(0, 99)]).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
        assert_eq!(m.num_live_edges(), 1, "failed epoch must not half-apply");
        assert!(!m.inner.contains_edge(2, 3));
    }

    #[test]
    fn cascading_churn_stays_maximal() {
        use crate::util::rng::Xoshiro256pp;
        let n = 300;
        let mut m = DynamicMatcher::new(n, 3);
        let mut rng = Xoshiro256pp::new(11);
        let mut live: Vec<(VertexId, VertexId)> = Vec::new();
        for epoch in 0..30 {
            let mut batch = Vec::new();
            for _ in 0..40 {
                if !live.is_empty() && rng.next_usize(2) == 0 {
                    let i = rng.next_usize(live.len());
                    let (u, v) = live.swap_remove(i);
                    batch.push(Delete(u, v));
                } else {
                    let u = rng.next_usize(n) as VertexId;
                    let v = rng.next_usize(n) as VertexId;
                    batch.push(Insert(u, v));
                    if u != v && !live.contains(&(u.min(v), u.max(v))) {
                        live.push((u.min(v), u.max(v)));
                    }
                }
            }
            let r = m.apply_epoch(&batch).unwrap();
            assert_eq!(m.num_live_edges(), live.len() as u64, "epoch {epoch}");
            m.verify().unwrap_or_else(|e| panic!("epoch {epoch}: {e}"));
            assert_eq!(r.matched_vertices, m.matched_vertices());
        }
    }

    #[test]
    fn repair_fraction_is_sublinear_for_small_batches() {
        use crate::graph::gen::erdos_renyi;
        let n = 4000;
        let el = erdos_renyi::edges(n, 8 * n, 5);
        let mut m = DynamicMatcher::new(n, 2);
        let all: Vec<Update> = el.edges.iter().map(|&(u, v)| Insert(u, v)).collect();
        m.apply_epoch(&all).unwrap();
        m.verify().unwrap();
        // delete 100 random live edges; repair work must touch a small
        // fraction of the ~24k live edges
        let live: Vec<_> = m.live_edge_iter().take(100).collect();
        let dels: Vec<Update> = live.iter().map(|&(u, v)| Delete(u, v)).collect();
        let r = m.apply_epoch(&dels).unwrap();
        m.verify().unwrap();
        assert!(
            r.repair_fraction() < 0.25,
            "repair fraction {} not sublinear (repair {} of {} live)",
            r.repair_fraction(),
            r.repair_edges,
            r.live_edges
        );
    }
}
