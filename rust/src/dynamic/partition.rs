//! Vertex-partitioned sharding for the dynamic engine: parallel epochs in
//! every phase, executed by a **persistent shard-worker pool**.
//!
//! ## Why sharding the *engine* is cheap
//!
//! Skipper's shared algorithm state is one atomic byte per vertex, so the
//! matching sweeps already tolerate any thread interleaving — the
//! [`SkipperCore`] needs no sharding at all, which is the whole trick. What
//! the dynamic engine serialized until now was everything *around* the
//! core: the mutate phase (adjacency edits, `partner[]` bookkeeping,
//! freed-vertex collection) ran on one thread. Ghaffari & Trygub's
//! *Parallel Dynamic Maximal Matching* shows batch updates parallelize with
//! work proportional to affected neighborhoods, and Blelloch et al. justify
//! partition-local greedy processing; this module is that program applied
//! to Skipper's epoch loop.
//!
//! ## Architecture
//!
//! Vertices are split into `P` contiguous shards by a [`VertexPartition`]
//! (the equal-split idea of [`crate::par::scheduler::split_equal_edges`],
//! with [`VertexPartition::from_weights`] available when per-vertex degree
//! hints exist). Each shard exclusively owns
//!
//! * its slice of the adjacency sidecar (a [`HalfAdjacency`] — the shard
//!   stores the half-edges of its owned endpoints),
//! * its owned entries of the global `partner[]` array,
//! * its freed-vertex set for the current epoch.
//!
//! An epoch runs in barriered phases:
//!
//! ```text
//!            route (≤2 shards per edge)
//! updates ──────────────▶ per-shard mailboxes
//!                              │ parallel mutate: half-edge edits,
//!                              │ partner[] clears (owner-written),
//!                              │ core.release of freed endpoints
//!                              ▼  ── barrier ──
//!              fresh-edge work lists (owner of min endpoint)
//!                              │ shared-core insert sweep (StreamingSkipper)
//!                              ▼  ── barrier ──
//!              per-shard repair lists from freed neighborhoods
//!                              │ shared-core repair sweep
//!                              ▼
//!                      epoch report (per-phase wall times)
//! ```
//!
//! ## Persistent shard workers ([`ShardExec`])
//!
//! The parallel phases dispatch one job per shard. Under the default
//! [`ShardExec::Pool`] those jobs run on a standing
//! [`WorkerPool`](crate::par::pool::WorkerPool): worker `i` owns shard `i`
//! for the engine's lifetime, parks between epochs, and is woken by its run
//! queue's doorbell — so a small epoch pays two condvar wakes per shard
//! instead of a thread spawn and join. [`ShardExec::Fork`] keeps the old
//! scoped fork/join (one `std::thread` per shard per epoch) as the measured
//! baseline; the `scale` experiment and `dynamic_churn` bench run both and
//! report the dispatch ("spawn") overhead separately from the per-shard
//! busy ("run") time, via [`EpochReport::mutate_run_s`] and
//! [`EpochReport::mutate_spawn_overhead_s`]. `P = 1` runs inline on the
//! calling thread under either policy.
//!
//! ## Why cross-shard updates need no coordination
//!
//! An edge `{u,v}` touches at most two shards, and the router appends every
//! update to *each* touched mailbox in arrival order, so for any single
//! edge both owners observe the same update subsequence. Liveness is
//! decided from the shard's own half (`contains_half`), and the two halves
//! are edited by exactly the same op sequence — they agree without
//! messages. The matched-pair check on a delete is equally local: the
//! engine's standing invariant `partner[u] == v ⟺ partner[v] == u` lets
//! each owner detect the destroyed pair from its own entry, clear it
//! (owner-written, so the mutate phase never races on `partner[]`), release
//! its own endpoint in the shared core (an atomic store, quiescent w.r.t.
//! `process_edge` between sweeps), and record its own freed vertex. The
//! release hand-shake the design sketch called for degenerates to two
//! independent local decisions — the symmetric invariant *is* the message.
//!
//! The maximality argument is unchanged from [`super::engine`]: mutate
//! only frees recorded vertices, the insert sweep processes every fresh
//! edge after all frees, and the repair sweep re-processes every surviving
//! edge of a still-free freed vertex; the proof in `engine.rs` carries over
//! verbatim with "the mutate loop" replaced by "the per-shard mutate loops,
//! which partition the work by endpoint owner". Which *thread* runs a
//! shard's loop — a freshly forked one or a parked pool worker — never
//! enters the argument; the countdown barrier provides the same
//! happens-before edge the fork/join did.
//!
//! [`super::DynamicMatcher`] is the `P = 1` specialization of
//! [`ShardedDynamicMatcher`] — same code path, one shard, no spawns.
//!
//! The full system walk-through (with this engine in context) lives in
//! `docs/ARCHITECTURE.md`.

use super::adjacency::{AdjLayout, HalfAdjacency};
use super::engine::{EpochReport, Update};
use crate::graph::stream::BatchEdgeSource;
use crate::matching::core::SkipperCore;
use crate::matching::streaming::StreamingSkipper;
use crate::matching::{MatchArena, BUFFER_EDGES};
use crate::obs::{metrics, trace};
use crate::par::pool::{ArriveOnDrop, Countdown, WorkerPool};
use crate::par::run_threads_collect;
use crate::par::topology::PinPolicy;
use crate::{VertexId, INVALID_VERTEX};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Software-prefetch lookahead (in loop iterations) for list *headers*
/// during the mutate/repair sweeps. Headers are prefetched this far ahead
/// so that by the time the one-iteration-ahead slot-line prefetch reads
/// them, they are already resident; the values the sweep needs *now* were
/// requested several iterations ago.
const PF_HEADER: usize = 4;

/// A split of the vertex universe `0..n` into contiguous shard ranges.
#[derive(Clone, Debug)]
pub struct VertexPartition {
    /// `shards + 1` boundaries: shard `i` owns `[starts[i], starts[i+1])`.
    starts: Vec<VertexId>,
}

impl VertexPartition {
    /// Equal-size contiguous split (trailing shards may be empty when
    /// `shards` does not divide `num_vertices`).
    pub fn equal(num_vertices: usize, shards: usize) -> Self {
        let p = shards.max(1);
        let per = num_vertices.div_ceil(p).max(1);
        let starts = (0..=p)
            .map(|i| (i * per).min(num_vertices) as VertexId)
            .collect();
        Self { starts }
    }

    /// Contiguous split with ≈equal total *weight* per shard — the
    /// [`crate::par::scheduler::split_equal_edges`] idea applied to any
    /// per-vertex weight (expected degree, observed degree, ...). Falls
    /// back to trailing empty shards when the weight mass runs out early.
    pub fn from_weights(weights: &[u64], shards: usize) -> Self {
        let n = weights.len();
        let p = shards.max(1);
        let total: u64 = weights.iter().sum();
        let per = (total / p as u64).max(1);
        let mut starts: Vec<VertexId> = vec![0];
        let mut acc = 0u64;
        let mut next_cut = per;
        for (v, &w) in weights.iter().enumerate() {
            acc += w;
            if acc >= next_cut && starts.len() < p && v + 1 > *starts.last().unwrap() as usize {
                starts.push((v + 1) as VertexId);
                next_cut = acc + per;
            }
        }
        while starts.len() <= p {
            starts.push(n as VertexId);
        }
        Self { starts }
    }

    /// Number of shards in the partition.
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.starts.len() - 1
    }

    /// Size of the partitioned vertex universe.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        *self.starts.last().unwrap() as usize
    }

    /// Owned range `[start, end)` of shard `i`.
    #[inline]
    pub fn range(&self, shard: usize) -> (VertexId, VertexId) {
        (self.starts[shard], self.starts[shard + 1])
    }

    /// The shard owning vertex `v` (`v` must be `< num_vertices`).
    #[inline]
    pub fn owner(&self, v: VertexId) -> usize {
        debug_assert!((v as usize) < self.num_vertices());
        self.starts.partition_point(|&s| s <= v) - 1
    }
}

/// How the engine dispatches its per-shard parallel phases.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardExec {
    /// Fork one scoped thread per shard per epoch (the pre-pool baseline;
    /// kept so spawn cost stays measurable).
    Fork,
    /// Submit to a persistent [`WorkerPool`](crate::par::pool::WorkerPool):
    /// worker `i` owns shard `i`, parks between epochs, and is woken by a
    /// run-queue doorbell — no per-epoch thread spawn. The default.
    Pool,
}

impl ShardExec {
    /// The policy a boolean "use the pool" knob (CLI `--no-pool`, config
    /// `pool` fields) selects — the single home of that mapping.
    pub fn from_pool_flag(pool: bool) -> Self {
        if pool {
            ShardExec::Pool
        } else {
            ShardExec::Fork
        }
    }

    /// Short lowercase label for reports and CLI output.
    pub fn name(&self) -> &'static str {
        match self {
            ShardExec::Fork => "fork",
            ShardExec::Pool => "pool",
        }
    }
}

/// Epoch-scoped per-shard update queues, filled by
/// [`ShardedDynamicMatcher::route_into`]. An edge touches at most two
/// shards; the router appends the update to each touched mailbox in
/// arrival order, which is all the cross-shard consistency the mutate
/// phase needs (see the module docs). Reusable across epochs — the service
/// routes straight out of its drain loop and flushes at barriers.
pub struct ShardMailboxes {
    boxes: Vec<Vec<Update>>,
    inserts: usize,
    deletes: usize,
}

impl ShardMailboxes {
    /// Insert updates routed since the last [`clear`](Self::clear).
    #[inline]
    pub fn inserts(&self) -> usize {
        self.inserts
    }

    /// Delete updates routed since the last [`clear`](Self::clear).
    #[inline]
    pub fn deletes(&self) -> usize {
        self.deletes
    }

    /// Updates routed (each counted once, even when mailed to two shards).
    #[inline]
    pub fn num_updates(&self) -> usize {
        self.inserts + self.deletes
    }

    /// True when nothing has been routed since the last clear.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.num_updates() == 0
    }

    /// Empty every mailbox, keeping capacity for the next epoch.
    pub fn clear(&mut self) {
        for b in &mut self.boxes {
            b.clear();
        }
        self.inserts = 0;
        self.deletes = 0;
    }
}

/// State exclusively owned by one shard: its adjacency slice and the freed
/// vertices of the epoch in flight. Behind a `Mutex` only so the engine can
/// hand disjoint shards to worker threads through `&self`; the lock is
/// uncontended by construction (each phase touches each shard from exactly
/// one thread).
struct ShardState {
    adj: HalfAdjacency,
    /// Owned vertices freed by this epoch's deletes; consumed by the
    /// repair-collection phase.
    freed: Vec<VertexId>,
}

/// What one shard's mutate pass reports back to the epoch coordinator.
#[derive(Default)]
struct MutateOut {
    /// Fresh live edges owned by this shard (it owns the min endpoint),
    /// deduped and still live at the end of the phase.
    fresh: Vec<(VertexId, VertexId)>,
    deleted_live: usize,
    destroyed_pairs: usize,
    freed: usize,
}

/// The cross-thread engine state: everything a per-shard job needs. Jobs on
/// the persistent pool are `'static`, so this lives behind an `Arc` that
/// each job clones — the engine facade and the workers share it.
struct EngineShared {
    partition: VertexPartition,
    shards: Vec<Mutex<ShardState>>,
    /// `partner[v]` is `v`'s matched partner, [`INVALID_VERTEX`] when free.
    /// Owner-written during mutate; harvest writes happen between parallel
    /// phases. Atomic so readers never block on an epoch.
    partner: Vec<AtomicU32>,
    core: SkipperCore,
    matched: AtomicUsize,
    /// Per-shard phase-latency histograms (index = shard), registered once
    /// at engine construction against the global metrics registry.
    mutate_hist: Vec<Arc<metrics::Histogram>>,
    repair_hist: Vec<Arc<metrics::Histogram>>,
}

impl EngineShared {
    /// One shard's mutate pass: apply its mailbox in arrival order to the
    /// owned halves, clear owned `partner[]` entries of destroyed pairs,
    /// release the freed endpoints in the shared core, and hand back the
    /// shard's fresh-edge work list. Per-edge counters (`deleted_live`,
    /// `destroyed_pairs`, fresh edges) are reported by the owner of the
    /// *min* endpoint so cross-shard edges are never double-counted.
    fn mutate_shard(&self, i: usize, ops: &[Update], epoch: u64) -> MutateOut {
        let t_obs = Instant::now();
        let _span = trace::span_epoch("mutate", "engine", epoch, i as u64);
        let mut st = self.shards[i].lock().unwrap();
        let st = &mut *st;
        let mut out = MutateOut::default();
        for (k, &op) in ops.iter().enumerate() {
            // Two-distance software prefetch down the op stream: pull the
            // next-but-few op's list header toward the core now, and the
            // *next* op's first slot line once its header (prefetched a few
            // ops ago) is warm — the membership scan below is the phase's
            // dominant memory traffic.
            if let Some(&(Update::Insert(a, b) | Update::Delete(a, b))) = ops.get(k + PF_HEADER) {
                let (u, v) = (a.min(b), a.max(b));
                st.adj.prefetch_vertex(if st.adj.owns(u) { u } else { v });
            }
            if let Some(&(Update::Insert(a, b) | Update::Delete(a, b))) = ops.get(k + 1) {
                let (u, v) = (a.min(b), a.max(b));
                st.adj.prefetch_neighbors(if st.adj.owns(u) { u } else { v });
            }
            match op {
                Update::Insert(a, b) => {
                    if a == b {
                        continue; // self-loops can never affect maximality
                    }
                    let (u, v) = (a.min(b), a.max(b));
                    let is_rep = st.adj.owns(u);
                    // set-semantics check against whichever half we own;
                    // both owners see the same op subsequence for this
                    // edge, so their verdicts agree
                    let (own, nb) = if is_rep { (u, v) } else { (v, u) };
                    if st.adj.contains_half(own, nb) {
                        continue; // already live
                    }
                    if st.adj.owns(u) {
                        st.adj.insert_half(u, v);
                    }
                    if st.adj.owns(v) {
                        st.adj.insert_half(v, u);
                    }
                    if is_rep {
                        out.fresh.push((u, v));
                    }
                }
                Update::Delete(a, b) => {
                    if a == b {
                        continue;
                    }
                    let (u, v) = (a.min(b), a.max(b));
                    let is_rep = st.adj.owns(u);
                    let (own, nb) = if is_rep { (u, v) } else { (v, u) };
                    if !st.adj.contains_half(own, nb) {
                        continue; // not live: phantom delete
                    }
                    if st.adj.owns(u) {
                        let removed = st.adj.remove_half(u, v);
                        debug_assert!(removed, "half ({u},{v}) missing");
                    }
                    if st.adj.owns(v) {
                        let removed = st.adj.remove_half(v, u);
                        debug_assert!(removed, "half ({v},{u}) missing");
                    }
                    if is_rep {
                        out.deleted_live += 1;
                    }
                    // Matched-pair detection from owned partner entries
                    // only: `partner[u] == v ⟺ partner[v] == u`, so both
                    // owners reach the same verdict without a message.
                    for (w, other) in [(u, v), (v, u)] {
                        if st.adj.owns(w)
                            && self.partner[w as usize].load(Ordering::Acquire) == other
                        {
                            self.partner[w as usize].store(INVALID_VERTEX, Ordering::Release);
                            self.core.release(w);
                            st.freed.push(w);
                            out.freed += 1;
                            if w == u {
                                out.destroyed_pairs += 1;
                            }
                        }
                    }
                }
            }
        }
        // An edge inserted then deleted within the epoch is in `fresh` but
        // no longer live — it must not be offered to the matcher. An edge
        // inserted, deleted, and re-inserted is in `fresh` twice — dedup.
        out.fresh.sort_unstable();
        out.fresh.dedup();
        out.fresh.retain(|&(u, v)| {
            let (own, nb) = if st.adj.owns(u) { (u, v) } else { (v, u) };
            st.adj.contains_half(own, nb)
        });
        self.mutate_hist[i].record_duration(t_obs.elapsed());
        out
    }

    /// One shard's repair collection: surviving incident edges of its freed
    /// vertices that the insert pass left unmatched, canonicalized.
    fn collect_repair(&self, i: usize, epoch: u64) -> Vec<(VertexId, VertexId)> {
        let t_obs = Instant::now();
        let _span = trace::span_epoch("repair", "engine", epoch, i as u64);
        let mut st = self.shards[i].lock().unwrap();
        let st = &mut *st;
        let mut repair = Vec::new();
        for (k, &f) in st.freed.iter().enumerate() {
            if let Some(&ahead) = st.freed.get(k + PF_HEADER) {
                st.adj.prefetch_vertex(ahead);
            }
            if let Some(&next) = st.freed.get(k + 1) {
                st.adj.prefetch_neighbors(next);
            }
            // the insert pass may already have re-matched a freed vertex
            if self.partner[f as usize].load(Ordering::Acquire) != INVALID_VERTEX {
                continue;
            }
            for nb in st.adj.neighbors(f) {
                repair.push((f.min(nb), f.max(nb)));
            }
        }
        st.freed.clear();
        self.repair_hist[i].record_duration(t_obs.elapsed());
        repair
    }
}

/// Vertex-partitioned fully dynamic maximal matching: `P` shards each own a
/// slice of the adjacency sidecar and of `partner[]`, epochs run the mutate
/// phase in parallel across shards (on a persistent worker pool by
/// default — see [`ShardExec`]), and the matching sweeps run against the
/// one shared [`SkipperCore`] exactly as in the single-threaded engine.
///
/// All methods take `&self`: shard state sits behind per-shard mutexes and
/// the cross-shard state (`partner[]`, counters, the core's state bytes) is
/// atomic, so a service can answer partner queries from any thread while an
/// epoch is in flight.
pub struct ShardedDynamicMatcher {
    shared: Arc<EngineShared>,
    driver: StreamingSkipper,
    exec: ShardExec,
    /// The standing shard workers (`None` for `P = 1` or [`ShardExec::Fork`]).
    pool: Option<WorkerPool>,
    /// Serializes epoch application: `apply_epoch`/`apply_mailboxes` take
    /// `&self` so readers stay lock-free, but two concurrent epochs would
    /// race mutate against harvest — this gate makes them queue instead.
    epoch_gate: Mutex<()>,
    epoch: AtomicU64,
    /// The adjacency storage layout every shard was built with.
    layout: AdjLayout,
    /// The worker→core pin policy the pool (if any) was built with.
    pin: PinPolicy,
}

/// A raw pointer that crosses into pool jobs for first-touch stripe
/// initialization. Each job writes a disjoint `[start, end)` slice of the
/// `partner[]` allocation and the constructor's countdown barrier sequences
/// every write before the vector's length is set.
#[derive(Clone, Copy)]
struct SendPtr(*mut AtomicU32);
// SAFETY: the pointee is only written through disjoint per-shard ranges
// before the barrier, never read concurrently.
unsafe impl Send for SendPtr {}

impl ShardedDynamicMatcher {
    /// `engine_shards` contiguous equal-size shards over `0..num_vertices`,
    /// `threads` matcher threads inside the shared-core sweeps. Shard
    /// phases run on the persistent pool ([`ShardExec::Pool`]).
    pub fn new(num_vertices: usize, threads: usize, engine_shards: usize) -> Self {
        Self::with_exec(num_vertices, threads, engine_shards, ShardExec::Pool)
    }

    /// Like [`new`](Self::new) with an explicit shard-dispatch policy.
    pub fn with_exec(
        num_vertices: usize,
        threads: usize,
        engine_shards: usize,
        exec: ShardExec,
    ) -> Self {
        Self::with_partition_exec(VertexPartition::equal(num_vertices, engine_shards), threads, exec)
    }

    /// Like [`with_exec`](Self::with_exec) with an explicit adjacency
    /// storage layout — the knob `churn --layout`, the `scale` experiment,
    /// and the layout benches sweep.
    pub fn with_exec_layout(
        num_vertices: usize,
        threads: usize,
        engine_shards: usize,
        exec: ShardExec,
        layout: AdjLayout,
    ) -> Self {
        Self::with_partition_exec_layout(
            VertexPartition::equal(num_vertices, engine_shards),
            threads,
            exec,
            layout,
        )
    }

    /// Like [`with_exec_layout`](Self::with_exec_layout) with an explicit
    /// worker→core pin policy — the knob behind `churn --pin` and
    /// `serve --pin`. Pinning changes *where* shard state lives (which
    /// core each worker runs on, which NUMA node its arena and `partner[]`
    /// stripe land on), never *what* the engine computes: results are
    /// bit-for-bit identical across policies.
    pub fn with_exec_layout_pin(
        num_vertices: usize,
        threads: usize,
        engine_shards: usize,
        exec: ShardExec,
        layout: AdjLayout,
        pin: PinPolicy,
    ) -> Self {
        Self::with_partition_exec_layout_pin(
            VertexPartition::equal(num_vertices, engine_shards),
            threads,
            exec,
            layout,
            pin,
        )
    }

    /// Engine over an explicit partition, pooled shard dispatch.
    pub fn with_partition(partition: VertexPartition, threads: usize) -> Self {
        Self::with_partition_exec(partition, threads, ShardExec::Pool)
    }

    /// Engine over an explicit partition and shard-dispatch policy.
    pub fn with_partition_exec(
        partition: VertexPartition,
        threads: usize,
        exec: ShardExec,
    ) -> Self {
        Self::with_partition_exec_layout(partition, threads, exec, AdjLayout::default())
    }

    /// Engine over an explicit partition, shard-dispatch policy, and
    /// adjacency storage layout. Unpinned ([`PinPolicy::None`]).
    pub fn with_partition_exec_layout(
        partition: VertexPartition,
        threads: usize,
        exec: ShardExec,
        layout: AdjLayout,
    ) -> Self {
        Self::with_partition_exec_layout_pin(partition, threads, exec, layout, PinPolicy::None)
    }

    /// The root constructor: explicit partition, shard-dispatch policy,
    /// adjacency layout, and pin policy.
    ///
    /// Under a pinned pool the pool is built *first* and each shard's state
    /// is constructed by a job on its owner worker — already pinned to its
    /// planned core — so the arena's pages and the shard's `partner[]`
    /// stripe are first-touched on the node the worker will sweep them
    /// from, and the block slabs are advised `MADV_HUGEPAGE`. Unpinned (or
    /// inline/forked) engines construct everything on the calling thread,
    /// exactly as before.
    pub fn with_partition_exec_layout_pin(
        partition: VertexPartition,
        threads: usize,
        exec: ShardExec,
        layout: AdjLayout,
        pin: PinPolicy,
    ) -> Self {
        let n = partition.num_vertices();
        let num_shards = partition.num_shards();
        let pool = (exec == ShardExec::Pool && num_shards > 1)
            .then(|| WorkerPool::with_pin(num_shards, pin));
        let first_touch = pool.is_some() && pin != PinPolicy::None;
        let shards: Vec<Mutex<ShardState>> = if first_touch {
            let pool = pool.as_ref().unwrap();
            let slots: Arc<Vec<Mutex<Option<ShardState>>>> =
                Arc::new((0..num_shards).map(|_| Mutex::new(None)).collect());
            let done = Arc::new(Countdown::new(num_shards));
            for i in 0..num_shards {
                let (s, e) = partition.range(i);
                let slots = Arc::clone(&slots);
                let arrive = ArriveOnDrop(Arc::clone(&done));
                pool.submit(i, move || {
                    let _arrive = arrive;
                    let mut adj = HalfAdjacency::with_layout(s, (e - s) as usize, layout);
                    adj.advise_hugepages();
                    *slots[i].lock().unwrap() =
                        Some(ShardState { adj, freed: Vec::new() });
                });
            }
            done.wait();
            slots
                .iter()
                .map(|slot| {
                    Mutex::new(
                        slot.lock()
                            .unwrap()
                            .take()
                            .expect("shard construction job panicked"),
                    )
                })
                .collect()
        } else {
            (0..num_shards)
                .map(|i| {
                    let (s, e) = partition.range(i);
                    Mutex::new(ShardState {
                        adj: HalfAdjacency::with_layout(s, (e - s) as usize, layout),
                        freed: Vec::new(),
                    })
                })
                .collect()
        };
        let partner: Vec<AtomicU32> = if first_touch && n > 0 {
            let pool = pool.as_ref().unwrap();
            let mut v: Vec<AtomicU32> = Vec::with_capacity(n);
            let ptr = SendPtr(v.as_mut_ptr());
            let done = Arc::new(Countdown::new(num_shards));
            for i in 0..num_shards {
                let (s, e) = partition.range(i);
                let arrive = ArriveOnDrop(Arc::clone(&done));
                pool.submit(i, move || {
                    let _arrive = arrive;
                    // first-touch: shard i's owner worker writes its own
                    // stripe, so those pages land on its node
                    for k in s as usize..e as usize {
                        unsafe { ptr.0.add(k).write(AtomicU32::new(INVALID_VERTEX)) };
                    }
                });
            }
            done.wait();
            // SAFETY: the partition's shard ranges tile `0..n` exactly and
            // the stripe-writing jobs contain no panicking operations, so
            // after the barrier every element is initialized. The countdown
            // (mutex + condvar) sequences the writes before this.
            unsafe { v.set_len(n) };
            v
        } else {
            (0..n).map(|_| AtomicU32::new(INVALID_VERTEX)).collect()
        };
        let reg = metrics::global();
        let shard_hist = |name: &str, help: &str| -> Vec<Arc<metrics::Histogram>> {
            (0..num_shards)
                .map(|i| {
                    reg.histogram_secs_with(name, help, vec![("shard".into(), i.to_string())])
                })
                .collect()
        };
        let mutate_hist = shard_hist(
            "skipper_shard_mutate_seconds",
            "Per-shard mutate-phase busy time per epoch",
        );
        let repair_hist = shard_hist(
            "skipper_shard_repair_seconds",
            "Per-shard repair-collection busy time per epoch",
        );
        Self {
            shared: Arc::new(EngineShared {
                partition,
                shards,
                partner,
                core: SkipperCore::new(n),
                matched: AtomicUsize::new(0),
                mutate_hist,
                repair_hist,
            }),
            driver: StreamingSkipper::new(threads),
            exec,
            pool,
            epoch_gate: Mutex::new(()),
            epoch: AtomicU64::new(0),
            layout,
            pin,
        }
    }

    /// Size of the vertex universe `0..n`.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.shared.partner.len()
    }

    /// Number of vertex shards (`P`).
    #[inline]
    pub fn num_shards(&self) -> usize {
        self.shared.shards.len()
    }

    /// The shard-dispatch policy this engine was built with.
    #[inline]
    pub fn exec(&self) -> ShardExec {
        self.exec
    }

    /// The adjacency storage layout this engine was built with.
    #[inline]
    pub fn layout(&self) -> AdjLayout {
        self.layout
    }

    /// The worker→core pin policy this engine was built with.
    #[inline]
    pub fn pin(&self) -> PinPolicy {
        self.pin
    }

    /// Pool workers whose pin syscall actually succeeded (0 when unpinned,
    /// inline, or forked — and on hosts that refuse `sched_setaffinity`).
    #[inline]
    pub fn pinned_workers(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.pinned_workers())
    }

    /// Is a standing worker pool actually serving the shard phases? False
    /// for [`ShardExec::Fork`] *and* for `P = 1`, which always runs inline
    /// regardless of policy.
    #[inline]
    pub fn pooled(&self) -> bool {
        self.pool.is_some()
    }

    /// The vertex partition backing the shards.
    #[inline]
    pub fn partition(&self) -> &VertexPartition {
        &self.shared.partition
    }

    /// Epochs applied so far.
    #[inline]
    pub fn epochs_applied(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Reset the epoch counter — the recovery hook
    /// ([`crate::persist::recovery`]). Rebuilding a snapshot and replaying
    /// the WAL consume engine epochs of their own; recovery calls this once,
    /// at boot, to resume the *durable* epoch timeline so post-recovery WAL
    /// records keep strictly increasing epoch numbers across crashes. Must
    /// only be called between epochs (nothing in flight).
    pub fn set_epoch_base(&self, epoch: u64) {
        self.epoch.store(epoch, Ordering::Relaxed);
    }

    /// Currently matched vertices (2 × matched pairs).
    #[inline]
    pub fn matched_vertices(&self) -> usize {
        self.shared.matched.load(Ordering::Relaxed)
    }

    /// Is `v` currently matched? Lock-free.
    #[inline]
    pub fn is_matched(&self, v: VertexId) -> bool {
        self.shared.partner[v as usize].load(Ordering::Acquire) != INVALID_VERTEX
    }

    /// `v`'s current partner, if matched. Lock-free: safe to call from any
    /// thread, including while an epoch is mid-flight (the answer is then a
    /// point-in-time read of `v`'s slot).
    pub fn partner(&self, v: VertexId) -> Option<VertexId> {
        if (v as usize) >= self.shared.partner.len() {
            return None;
        }
        let p = self.shared.partner[v as usize].load(Ordering::Acquire);
        (p != INVALID_VERTEX).then_some(p)
    }

    /// Current matching as canonical `(min, max)` pairs.
    pub fn matching_pairs(&self) -> Vec<(VertexId, VertexId)> {
        self.shared
            .partner
            .iter()
            .enumerate()
            .filter_map(|(u, p)| {
                let p = p.load(Ordering::Acquire);
                (p != INVALID_VERTEX && (u as VertexId) < p).then_some((u as VertexId, p))
            })
            .collect()
    }

    /// Live undirected edge count (sums per-shard half-edge counters).
    pub fn num_live_edges(&self) -> u64 {
        let halves: u64 = self
            .shared
            .shards
            .iter()
            .map(|s| s.lock().unwrap().adj.half_edges())
            .sum();
        debug_assert_eq!(halves % 2, 0, "half-edge storage out of sync");
        halves / 2
    }

    /// The live edge set, canonicalized `(min, max)`, each edge exactly
    /// once (the owner of the min endpoint emits it) — for verification and
    /// the service's audit path.
    pub fn live_edges(&self) -> Vec<(VertexId, VertexId)> {
        let mut edges = Vec::new();
        for shard in &self.shared.shards {
            let st = shard.lock().unwrap();
            for w in st.adj.start()..st.adj.end() {
                if w + 1 < st.adj.end() {
                    st.adj.prefetch_neighbors(w + 1);
                }
                for nb in st.adj.neighbors(w) {
                    if w < nb {
                        edges.push((w, nb));
                    }
                }
            }
        }
        edges
    }

    /// Is `{u,v}` live? (Asks the owner of `u` for its half.)
    pub fn contains_edge(&self, u: VertexId, v: VertexId) -> bool {
        if u == v || (u as usize) >= self.num_vertices() || (v as usize) >= self.num_vertices() {
            return false;
        }
        let st = self.shared.shards[self.shared.partition.owner(u)].lock().unwrap();
        st.adj.contains_half(u, v)
    }

    /// Adjacency-sidecar resident bytes, summed over shards.
    pub fn adjacency_bytes(&self) -> usize {
        self.shared
            .shards
            .iter()
            .map(|s| s.lock().unwrap().adj.memory_bytes())
            .sum()
    }

    /// Tombstoned adjacency slots awaiting compaction, summed over shards.
    pub fn adjacency_tombstones(&self) -> u64 {
        self.shared
            .shards
            .iter()
            .map(|s| s.lock().unwrap().adj.tombstones())
            .sum()
    }

    /// Full dynamic validity check: matching ⊆ live edges, endpoint-
    /// disjoint, and maximal over the live set.
    pub fn verify(&self) -> Result<(), String> {
        crate::matching::verify::verify_maximal_dynamic(
            self.num_vertices(),
            self.live_edges().into_iter(),
            &self.matching_pairs(),
        )
    }

    /// Fresh reusable mailboxes matching this engine's shard count.
    pub fn mailboxes(&self) -> ShardMailboxes {
        ShardMailboxes {
            boxes: (0..self.num_shards()).map(|_| Vec::new()).collect(),
            inserts: 0,
            deletes: 0,
        }
    }

    /// Route `updates` into per-shard mailboxes (each update reaches the
    /// owner of each endpoint — at most two shards). Errors on out-of-range
    /// vertices with nothing routed, so a failed call never half-fills the
    /// mailboxes.
    pub fn route_into(
        &self,
        updates: &[Update],
        mailboxes: &mut ShardMailboxes,
    ) -> Result<(), String> {
        let _span = trace::span("route", "engine", updates.len() as u64);
        let n = self.num_vertices();
        if let Some(bad) = updates.iter().find(|u| {
            let (Update::Insert(a, b) | Update::Delete(a, b)) = **u;
            a as usize >= n || b as usize >= n
        }) {
            return Err(format!("update {bad:?} out of range (|V|={n})"));
        }
        for &upd in updates {
            let (Update::Insert(a, b) | Update::Delete(a, b)) = upd;
            match upd {
                Update::Insert(..) => mailboxes.inserts += 1,
                Update::Delete(..) => mailboxes.deletes += 1,
            }
            let sa = self.shared.partition.owner(a);
            mailboxes.boxes[sa].push(upd);
            let sb = self.shared.partition.owner(b);
            if sb != sa {
                mailboxes.boxes[sb].push(upd);
            }
        }
        Ok(())
    }

    /// Apply one epoch of mixed updates. Update order within the batch is
    /// respected against the live set (insert-then-delete of the same edge
    /// in one epoch nets out to nothing). Errors on out-of-range vertices,
    /// with no mutation applied.
    pub fn apply_epoch(&self, updates: &[Update]) -> Result<EpochReport, String> {
        let mut mailboxes = self.mailboxes();
        let t = Instant::now();
        self.route_into(updates, &mut mailboxes)?;
        let route_s = t.elapsed().as_secs_f64();
        let mut rep = self.apply_mailboxes(&mut mailboxes);
        rep.route_wall_s = route_s;
        Ok(rep)
    }

    /// Run one epoch over already-routed mailboxes (they are drained and
    /// left empty for reuse). This is the service's flush path; epoch
    /// numbering, counters, and the report are identical to
    /// [`apply_epoch`](Self::apply_epoch), except that the route timings
    /// belong to the service's router and are filled in by it.
    ///
    /// Concurrent callers serialize on an internal gate (queries stay
    /// lock-free throughout); within one epoch the phases are barriered,
    /// so every reader between epochs observes a quiescent engine.
    pub fn apply_mailboxes(&self, mailboxes: &mut ShardMailboxes) -> EpochReport {
        let _epoch_exclusive = self.epoch_gate.lock().unwrap();
        let t0 = Instant::now();
        let epoch = self.epoch.fetch_add(1, Ordering::Relaxed) + 1;
        let mut rep = EpochReport {
            epoch,
            inserts: mailboxes.inserts(),
            deletes: mailboxes.deletes(),
            ..EpochReport::default()
        };

        // --- phase 1: parallel mutate, one shard worker per shard --------
        // The countdown barrier (pool) or join (fork) is the epoch barrier:
        // every shard's half-edge edits, partner clears, and core releases
        // complete before any matching sweep observes them.
        let tm = Instant::now();
        let outs = self.mutate_all(&mut mailboxes.boxes, epoch);
        rep.mutate_wall_s = tm.elapsed().as_secs_f64();
        let mut fresh: Vec<(VertexId, VertexId)> = Vec::new();
        for (out, busy_s) in outs {
            rep.mutate_run_s = rep.mutate_run_s.max(busy_s);
            rep.deleted_live += out.deleted_live;
            rep.destroyed_pairs += out.destroyed_pairs;
            rep.freed_vertices += out.freed;
            fresh.extend(out.fresh);
        }
        self.shared.matched.fetch_sub(rep.freed_vertices, Ordering::Relaxed);
        rep.inserted_live = fresh.len();

        // --- phase 2: insert pass through the streaming fast path --------
        let ti = Instant::now();
        let (m, c) = self.run_pass(&fresh);
        rep.new_matches += m;
        rep.conflicts += c;
        rep.insert_wall_s = ti.elapsed().as_secs_f64();

        // --- phase 3: repair sweep over affected neighborhoods -----------
        // collection is again parallel per shard; the global sort+dedup
        // removes the duplicates a both-endpoints-freed cross-shard edge
        // produces (each owner emits it once). Insert-only epochs (the
        // steady-state service workload) freed nothing and skip the
        // dispatch entirely.
        let tr = Instant::now();
        let mut repair: Vec<(VertexId, VertexId)> = Vec::new();
        if rep.freed_vertices > 0 {
            for list in self.collect_repair_all(epoch) {
                repair.extend(list);
            }
        }
        repair.sort_unstable();
        repair.dedup();
        rep.repair_edges = repair.len();
        let (m, c) = self.run_pass(&repair);
        rep.new_matches += m;
        rep.conflicts += c;
        rep.repair_wall_s = tr.elapsed().as_secs_f64();

        rep.live_edges = self.num_live_edges();
        rep.matched_vertices = self.shared.matched.load(Ordering::Relaxed);
        rep.wall_s = t0.elapsed().as_secs_f64();
        mailboxes.clear();
        rep
    }

    /// Run one per-shard job on every pool worker and harvest the results
    /// in shard order — the shared scaffolding of every pooled phase:
    /// countdown barrier, result slots, arrive-on-drop panic containment.
    /// `make_job(i)` builds shard `i`'s job, moving in whatever per-shard
    /// data it needs; the job runs against the shared engine state on
    /// worker `i`.
    fn pool_dispatch<T, J>(&self, pool: &WorkerPool, mut make_job: impl FnMut(usize) -> J) -> Vec<T>
    where
        T: Send + 'static,
        J: FnOnce(&EngineShared) -> T + Send + 'static,
    {
        let p = self.num_shards();
        let done = Arc::new(Countdown::new(p));
        let slots: Arc<Vec<Mutex<Option<T>>>> =
            Arc::new((0..p).map(|_| Mutex::new(None)).collect());
        for i in 0..p {
            let job = make_job(i);
            let shared = Arc::clone(&self.shared);
            let slots = Arc::clone(&slots);
            let arrive = ArriveOnDrop(Arc::clone(&done));
            pool.submit(i, move || {
                let _arrive = arrive;
                let out = job(shared.as_ref());
                *slots[i].lock().unwrap() = Some(out);
            });
        }
        done.wait();
        slots
            .iter()
            .enumerate()
            .map(|(i, slot)| {
                slot.lock()
                    .unwrap()
                    .take()
                    .unwrap_or_else(|| panic!("shard worker {i} panicked mid-phase"))
            })
            .collect()
    }

    /// Dispatch the mutate phase: one job per shard, on the persistent
    /// pool, forked scoped threads, or inline for `P = 1`. Returns each
    /// shard's [`MutateOut`] plus its busy seconds (the "run" part of
    /// spawn-vs-run); the mailbox buffers come back with their capacity
    /// intact in every mode.
    fn mutate_all(&self, boxes: &mut [Vec<Update>], epoch: u64) -> Vec<(MutateOut, f64)> {
        let p = self.num_shards();
        if p == 1 {
            let t = Instant::now();
            let out = self.shared.mutate_shard(0, &boxes[0], epoch);
            return vec![(out, t.elapsed().as_secs_f64())];
        }
        match &self.pool {
            Some(pool) => {
                let outs: Vec<(MutateOut, Vec<Update>, f64)> =
                    self.pool_dispatch(pool, |i| {
                        let ops = std::mem::take(&mut boxes[i]);
                        move |shared: &EngineShared| {
                            let t = Instant::now();
                            let out = shared.mutate_shard(i, &ops, epoch);
                            (out, ops, t.elapsed().as_secs_f64())
                        }
                    });
                let mut res = Vec::with_capacity(p);
                for (i, (out, ops, busy_s)) in outs.into_iter().enumerate() {
                    boxes[i] = ops; // hand the buffer back for mailbox reuse
                    res.push((out, busy_s));
                }
                res
            }
            None => {
                let boxes: &[Vec<Update>] = boxes;
                run_threads_collect(p, |i| {
                    let t = Instant::now();
                    let out = self.shared.mutate_shard(i, &boxes[i], epoch);
                    (out, t.elapsed().as_secs_f64())
                })
            }
        }
    }

    /// Dispatch the repair-collection phase across shards (same execution
    /// policy as [`mutate_all`](Self::mutate_all)).
    fn collect_repair_all(&self, epoch: u64) -> Vec<Vec<(VertexId, VertexId)>> {
        let p = self.num_shards();
        if p == 1 {
            return vec![self.shared.collect_repair(0, epoch)];
        }
        match &self.pool {
            Some(pool) => self.pool_dispatch(pool, |i| {
                move |shared: &EngineShared| shared.collect_repair(i, epoch)
            }),
            None => run_threads_collect(p, |i| self.shared.collect_repair(i, epoch)),
        }
    }

    /// Drive `edges` through the Algorithm-1 state machine against the live
    /// core, then harvest the new matches into the partner map. Returns
    /// `(new_matches, jit_conflicts)`. Small batches run inline — spawning
    /// the producer/consumer scope costs more than the matching itself and
    /// would dominate the service's per-epoch latency; large batches go
    /// through the shared [`StreamingSkipper`] chunk driver.
    fn run_pass(&self, edges: &[(VertexId, VertexId)]) -> (usize, u64) {
        const SEQUENTIAL_PASS_MAX: usize = 2048;
        if edges.is_empty() {
            return (0, 0);
        }
        let arena = MatchArena::with_capacity(
            edges.len().min(self.num_vertices()) + (self.driver.threads + 1) * BUFFER_EDGES,
        );
        let conflicts = if edges.len() <= SEQUENTIAL_PASS_MAX || self.driver.threads == 1 {
            let mut writer = arena.writer();
            let mut stats = crate::instrument::conflicts::ConflictStats::default();
            self.shared.core.process_chunk(
                edges,
                &mut writer,
                &mut stats,
                &mut crate::instrument::NoProbe,
            );
            stats
        } else {
            let driver = StreamingSkipper {
                chunk_edges: edges
                    .len()
                    .div_ceil(self.driver.threads)
                    .clamp(1, self.driver.chunk_edges),
                ..self.driver
            };
            driver
                .run_with_core(
                    &self.shared.core,
                    &arena,
                    BatchEdgeSource::new(self.num_vertices(), edges),
                )
                .expect("dynamic pass failed")
                .conflicts
        };
        let new = arena.into_matching();
        for (u, v) in new.iter() {
            debug_assert_eq!(
                self.shared.partner[u as usize].load(Ordering::Acquire),
                INVALID_VERTEX
            );
            debug_assert_eq!(
                self.shared.partner[v as usize].load(Ordering::Acquire),
                INVALID_VERTEX
            );
            self.shared.partner[u as usize].store(v, Ordering::Release);
            self.shared.partner[v as usize].store(u, Ordering::Release);
        }
        self.shared.matched.fetch_add(2 * new.len(), Ordering::Relaxed);
        (new.len(), conflicts.total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use Update::{Delete, Insert};

    #[test]
    fn equal_partition_covers_contiguously() {
        let p = VertexPartition::equal(10, 4);
        assert_eq!(p.num_shards(), 4);
        assert_eq!(p.num_vertices(), 10);
        let mut covered = 0usize;
        for i in 0..p.num_shards() {
            let (s, e) = p.range(i);
            assert!(s <= e);
            covered += (e - s) as usize;
            for v in s..e {
                assert_eq!(p.owner(v), i, "vertex {v}");
            }
        }
        assert_eq!(covered, 10);
        // more shards than vertices: trailing shards are empty, every
        // vertex still has exactly one owner
        let p = VertexPartition::equal(2, 4);
        assert_eq!(p.num_shards(), 4);
        assert_eq!(p.owner(0), 0);
        assert_eq!(p.owner(1), 1);
    }

    #[test]
    fn weighted_partition_balances_mass() {
        // one hub holding half the mass: it must end a shard on its own
        let mut w = vec![1u64; 64];
        w[0] = 64;
        let p = VertexPartition::from_weights(&w, 4);
        assert_eq!(p.num_shards(), 4);
        assert_eq!(p.num_vertices(), 64);
        let (s, e) = p.range(0);
        assert_eq!((s, e), (0, 1), "hub shard is just the hub");
        // every shard's weight is within one vertex of the target
        let total: u64 = w.iter().sum();
        let per = total / 4;
        for i in 0..4 {
            let (s, e) = p.range(i);
            let mass: u64 = (s..e).map(|v| w[v as usize]).sum();
            assert!(mass <= per + 64, "shard {i} mass {mass}");
        }
    }

    #[test]
    fn routing_reaches_each_owner_once() {
        let m = ShardedDynamicMatcher::new(8, 1, 2); // shards: 0..4, 4..8
        let mut mb = m.mailboxes();
        m.route_into(
            &[Insert(0, 1), Insert(1, 5), Delete(6, 7), Insert(5, 2)],
            &mut mb,
        )
        .unwrap();
        assert_eq!(mb.inserts(), 3);
        assert_eq!(mb.deletes(), 1);
        assert_eq!(mb.boxes[0], vec![Insert(0, 1), Insert(1, 5), Insert(5, 2)]);
        assert_eq!(mb.boxes[1], vec![Insert(1, 5), Delete(6, 7), Insert(5, 2)]);
        // out-of-range routes nothing
        let mut mb2 = m.mailboxes();
        assert!(m.route_into(&[Insert(0, 99)], &mut mb2).is_err());
        assert!(mb2.is_empty() && mb2.boxes.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn cross_shard_matched_delete_frees_both_owners() {
        // shards 0..2 and 2..4; edge (1,2) crosses them
        let m = ShardedDynamicMatcher::new(4, 1, 2);
        let r = m.apply_epoch(&[Insert(1, 2)]).unwrap();
        assert_eq!(r.new_matches, 1);
        assert_eq!(m.partner(1), Some(2));
        assert_eq!(m.partner(2), Some(1));
        let r = m.apply_epoch(&[Delete(1, 2)]).unwrap();
        assert_eq!(r.destroyed_pairs, 1, "counted once, not once per owner");
        assert_eq!(r.freed_vertices, 2);
        assert_eq!(r.deleted_live, 1);
        assert!(!m.is_matched(1) && !m.is_matched(2));
        assert_eq!(m.num_live_edges(), 0);
        m.verify().unwrap();
    }

    #[test]
    fn cross_shard_repair_reexamines_surviving_edges() {
        // path 0-1-2-3 over two shards {0,1} and {2,3}: matching is
        // (0,1),(2,3); deleting both matched edges forces the repair sweep
        // to re-match the cross-shard middle edge (1,2).
        let m = ShardedDynamicMatcher::new(4, 1, 2);
        m.apply_epoch(&[Insert(0, 1), Insert(1, 2), Insert(2, 3)]).unwrap();
        assert_eq!(m.matching_pairs(), vec![(0, 1), (2, 3)]);
        let r = m.apply_epoch(&[Delete(0, 1), Delete(2, 3)]).unwrap();
        assert_eq!(r.destroyed_pairs, 2);
        assert_eq!(r.freed_vertices, 4);
        // (1,2) survives, both endpoints freed in different shards — the
        // global dedup collapses the two owners' emissions to one edge
        assert_eq!(r.repair_edges, 1);
        assert_eq!(r.new_matches, 1, "repair re-matched (1,2)");
        assert_eq!(m.partner(1), Some(2));
        m.verify().unwrap();
    }

    #[test]
    fn insert_delete_netting_holds_across_shards() {
        let m = ShardedDynamicMatcher::new(4, 2, 2);
        let r = m.apply_epoch(&[Insert(1, 2), Delete(1, 2)]).unwrap();
        assert_eq!(r.inserted_live, 0);
        assert_eq!(r.new_matches, 0);
        assert_eq!(m.num_live_edges(), 0);
        // delete-then-reinsert of a matched cross-shard edge in one epoch
        m.apply_epoch(&[Insert(1, 2)]).unwrap();
        let r = m.apply_epoch(&[Delete(1, 2), Insert(1, 2)]).unwrap();
        assert_eq!(r.destroyed_pairs, 1);
        assert!(m.is_matched(1) && m.is_matched(2), "re-inserted pair re-matches");
        m.verify().unwrap();
    }

    #[test]
    fn shard_counts_agree_on_random_churn() {
        use crate::util::rng::Xoshiro256pp;
        let n = 200;
        let engines: Vec<ShardedDynamicMatcher> = [1usize, 2, 4]
            .iter()
            .map(|&p| ShardedDynamicMatcher::new(n, 2, p))
            .collect();
        let mut rng = Xoshiro256pp::new(42);
        let mut live: Vec<(VertexId, VertexId)> = Vec::new();
        for epoch in 0..15 {
            let mut batch = Vec::new();
            for _ in 0..30 {
                if !live.is_empty() && rng.next_usize(2) == 0 {
                    let i = rng.next_usize(live.len());
                    let (u, v) = live.swap_remove(i);
                    batch.push(Delete(u, v));
                } else {
                    let u = rng.next_usize(n) as VertexId;
                    let v = rng.next_usize(n) as VertexId;
                    batch.push(Insert(u, v));
                    if u != v && !live.contains(&(u.min(v), u.max(v))) {
                        live.push((u.min(v), u.max(v)));
                    }
                }
            }
            for (pi, m) in engines.iter().enumerate() {
                let r = m.apply_epoch(&batch).unwrap();
                assert_eq!(
                    m.num_live_edges(),
                    live.len() as u64,
                    "epoch {epoch} shards {pi}"
                );
                let mut got = m.live_edges();
                got.sort_unstable();
                let mut want = live.clone();
                want.sort_unstable();
                assert_eq!(got, want, "epoch {epoch} shards {pi}");
                m.verify()
                    .unwrap_or_else(|e| panic!("epoch {epoch} shards {pi}: {e}"));
                assert_eq!(r.matched_vertices, m.matched_vertices());
                assert_eq!(r.matched_vertices, 2 * m.matching_pairs().len());
            }
            // all shard counts see the same live set; matchings may differ
            // (different fresh-edge orders) but all must be maximal
            let e0 = engines[0].num_live_edges();
            assert!(engines.iter().all(|m| m.num_live_edges() == e0));
        }
    }

    #[test]
    fn forked_and_pooled_engines_take_identical_decisions() {
        // Same schedule, threads=1 (deterministic sweep order), P=4: the
        // pooled engine must reproduce the forked engine's matching and
        // counters exactly — per-shard processing order and fresh-edge
        // collection order are identical by construction; only the thread
        // that runs each shard differs.
        use crate::util::rng::Xoshiro256pp;
        let n = 120;
        let fork = ShardedDynamicMatcher::with_exec(n, 1, 4, ShardExec::Fork);
        let pool = ShardedDynamicMatcher::with_exec(n, 1, 4, ShardExec::Pool);
        assert_eq!(fork.exec(), ShardExec::Fork);
        assert_eq!(pool.exec(), ShardExec::Pool);
        let mut rng = Xoshiro256pp::new(77);
        let mut live: Vec<(VertexId, VertexId)> = Vec::new();
        for epoch in 0..12 {
            let mut batch = Vec::new();
            for _ in 0..25 {
                if !live.is_empty() && rng.next_usize(3) == 0 {
                    let i = rng.next_usize(live.len());
                    let (u, v) = live.swap_remove(i);
                    batch.push(Delete(u, v));
                } else {
                    let u = rng.next_usize(n) as VertexId;
                    let v = rng.next_usize(n) as VertexId;
                    batch.push(Insert(u, v));
                    if u != v && !live.contains(&(u.min(v), u.max(v))) {
                        live.push((u.min(v), u.max(v)));
                    }
                }
            }
            let rf = fork.apply_epoch(&batch).unwrap();
            let rp = pool.apply_epoch(&batch).unwrap();
            assert_eq!(rf.new_matches, rp.new_matches, "epoch {epoch}");
            assert_eq!(rf.destroyed_pairs, rp.destroyed_pairs, "epoch {epoch}");
            assert_eq!(rf.repair_edges, rp.repair_edges, "epoch {epoch}");
            assert_eq!(fork.matching_pairs(), pool.matching_pairs(), "epoch {epoch}");
            assert_eq!(fork.num_live_edges(), pool.num_live_edges(), "epoch {epoch}");
            fork.verify().unwrap();
            pool.verify().unwrap();
        }
    }

    #[test]
    fn pinned_engine_matches_unpinned_bit_for_bit() {
        // Placement moves memory and threads around, never decisions: at
        // every pin policy the engine must reproduce the unpinned engine's
        // matching, counters, and live set exactly — including on hosts
        // where the pin syscall is refused and workers float.
        use crate::util::rng::Xoshiro256pp;
        let n = 120;
        let base = ShardedDynamicMatcher::with_exec(n, 1, 4, ShardExec::Pool);
        let engines: Vec<ShardedDynamicMatcher> = [PinPolicy::Compact, PinPolicy::Spread]
            .iter()
            .map(|&pin| {
                let e = ShardedDynamicMatcher::with_exec_layout_pin(
                    n,
                    1,
                    4,
                    ShardExec::Pool,
                    AdjLayout::default(),
                    pin,
                );
                assert_eq!(e.pin(), pin);
                assert!(e.pooled());
                e
            })
            .collect();
        assert_eq!(base.pin(), PinPolicy::None);
        let mut rng = Xoshiro256pp::new(99);
        let mut live: Vec<(VertexId, VertexId)> = Vec::new();
        for epoch in 0..10 {
            let mut batch = Vec::new();
            for _ in 0..25 {
                if !live.is_empty() && rng.next_usize(3) == 0 {
                    let i = rng.next_usize(live.len());
                    let (u, v) = live.swap_remove(i);
                    batch.push(Delete(u, v));
                } else {
                    let u = rng.next_usize(n) as VertexId;
                    let v = rng.next_usize(n) as VertexId;
                    batch.push(Insert(u, v));
                    if u != v && !live.contains(&(u.min(v), u.max(v))) {
                        live.push((u.min(v), u.max(v)));
                    }
                }
            }
            let rb = base.apply_epoch(&batch).unwrap();
            for e in &engines {
                let re = e.apply_epoch(&batch).unwrap();
                assert_eq!(rb.new_matches, re.new_matches, "epoch {epoch}");
                assert_eq!(rb.destroyed_pairs, re.destroyed_pairs, "epoch {epoch}");
                assert_eq!(rb.repair_edges, re.repair_edges, "epoch {epoch}");
                assert_eq!(base.matching_pairs(), e.matching_pairs(), "epoch {epoch}");
                assert_eq!(base.num_live_edges(), e.num_live_edges(), "epoch {epoch}");
                e.verify().unwrap();
            }
        }
    }

    #[test]
    fn pool_workers_persist_across_many_small_epochs() {
        // hundreds of tiny epochs through one pooled engine: the standing
        // workers must serve all of them (a fork-per-epoch bug or a dead
        // worker would hang or panic here)
        let m = ShardedDynamicMatcher::new(64, 1, 4);
        for e in 0..200u32 {
            let u = (e * 7) % 64;
            let v = (e * 7 + 1) % 64; // consecutive mod 64: never equal to u
            m.apply_epoch(&[Insert(u, v)]).unwrap();
            m.apply_epoch(&[Delete(u, v)]).unwrap();
        }
        assert_eq!(m.num_live_edges(), 0);
        assert_eq!(m.matched_vertices(), 0);
        assert_eq!(m.epochs_applied(), 400);
    }

    #[test]
    fn single_shard_is_the_sequential_engine() {
        // P=1 must reproduce the exact deterministic behavior the
        // DynamicMatcher unit tests pin down (threads=1, path graph)
        let m = ShardedDynamicMatcher::new(4, 1, 1);
        let r = m
            .apply_epoch(&[Insert(0, 1), Insert(1, 2), Insert(2, 3)])
            .unwrap();
        assert_eq!(r.new_matches, 2);
        assert_eq!(m.matching_pairs(), vec![(0, 1), (2, 3)]);
        let r = m.apply_epoch(&[Delete(0, 1)]).unwrap();
        assert_eq!(r.repair_edges, 1, "only (1,2) needs re-examination");
        assert!(!m.is_matched(0) && !m.is_matched(1));
        m.verify().unwrap();
    }

    #[test]
    fn phase_timings_are_populated() {
        let m = ShardedDynamicMatcher::new(64, 2, 4);
        let ups: Vec<Update> = (0..32).map(|i| Insert(i, i + 32)).collect();
        let r = m.apply_epoch(&ups).unwrap();
        assert!(r.mutate_wall_s > 0.0);
        assert!(r.insert_wall_s > 0.0);
        assert!(r.wall_s >= r.mutate_wall_s);
        // spawn-vs-run decomposition: the run part is positive, never
        // exceeds the barrier-to-barrier wall, and the derived overhead is
        // non-negative
        assert!(r.mutate_run_s > 0.0);
        assert!(r.mutate_run_s <= r.mutate_wall_s + 1e-9);
        assert!(r.mutate_spawn_overhead_s() >= 0.0);
        // apply_epoch routed the updates itself, so route time is recorded
        assert!(r.route_wall_s > 0.0);
        assert_eq!(r.route_overlap_s, 0.0, "no pipelining on the direct path");
    }
}
