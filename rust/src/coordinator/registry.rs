//! Perf-trajectory registry: committed `BENCH_*.json` files that make every
//! performance claim in this repo provable (and every regression visible).
//!
//! The pattern follows the ASM-registry idiom (SNIPPETS.md §1): each bench
//! has one registry file `BENCH/BENCH_<bench>.json` holding an append-only
//! list of runs. Every run carries
//!
//! * a **machine manifest** — OS, arch, CPU count, CPU model — because perf
//!   numbers are only comparable on comparable hardware;
//! * the full **config** (generator, layout, threads, shards, batch, …) and
//!   its CRC-32 **config hash**, so runs of different configs never get
//!   compared by accident;
//! * the **metrics**, named by convention (see [`MetricKind`]).
//!
//! Workflow: a bench/experiment/CLI run writes a single-record *candidate*
//! file (`churn --record out.json`), `skipper-cli report --publish` appends
//! it to the registry, `report` renders the trajectory as markdown, and
//! `report --gate` compares a candidate against the last committed run of
//! the *same config* and fails on regression beyond a threshold. Gate rules
//! tolerate machine variance explicitly:
//!
//! * no baseline with this config hash → **seeding** (pass) — a fresh
//!   config bootstraps its own trajectory;
//! * `exact_*` metrics are schedule-deterministic (e.g. the final live-edge
//!   count is the set-semantics of the update stream, independent of
//!   threads and timing) → compared **exactly**, even across hosts;
//! * wall-clock metrics (`*_s`, `*_per_s`) are **strict only between runs
//!   whose host fingerprints match**; across different machines they only
//!   warn — a laptop is not a CI runner.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::dynamic::churn::{ChurnConfig, ChurnSummary};
use crate::persist::crc32;
use crate::util::json::{self, Json};
use crate::util::stats;

/// Registry schema identifier (bump on breaking file-shape changes).
pub const SCHEMA: &str = "skipper-bench/v1";

/// Default gate threshold: relative regression tolerated on wall-clock
/// metrics before the gate fails (15% absorbs CI-runner noise).
pub const DEFAULT_THRESHOLD: f64 = 0.15;

// ---------------------------------------------------------------------------
// machine manifest
// ---------------------------------------------------------------------------

/// The hardware/OS identity a run was measured on.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MachineManifest {
    /// Operating system (`std::env::consts::OS`).
    pub os: String,
    /// CPU architecture (`std::env::consts::ARCH`).
    pub arch: String,
    /// Logical CPUs available to the process.
    pub ncpus: usize,
    /// CPU model string from `/proc/cpuinfo` (or `"unknown"`).
    pub cpu_model: String,
}

impl MachineManifest {
    /// Detect the current machine.
    pub fn detect() -> Self {
        let cpu_model = std::fs::read_to_string("/proc/cpuinfo")
            .ok()
            .and_then(|text| {
                text.lines()
                    .find(|l| l.starts_with("model name"))
                    .and_then(|l| l.split(':').nth(1))
                    .map(|m| m.trim().to_string())
            })
            .unwrap_or_else(|| "unknown".to_string());
        MachineManifest {
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            ncpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            cpu_model,
        }
    }

    /// Host identity string — two runs are wall-clock-comparable iff their
    /// fingerprints are equal.
    pub fn fingerprint(&self) -> String {
        format!("{}/{}/{}cpu/{}", self.os, self.arch, self.ncpus, self.cpu_model)
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("os", Json::from(self.os.as_str()))
            .set("arch", Json::from(self.arch.as_str()))
            .set("ncpus", Json::from(self.ncpus))
            .set("cpu_model", Json::from(self.cpu_model.as_str()));
        o
    }

    fn from_json(v: &Json) -> Result<Self, String> {
        let field = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("manifest missing {k:?}"))
        };
        Ok(MachineManifest {
            os: field("os")?,
            arch: field("arch")?,
            ncpus: v
                .get("ncpus")
                .and_then(Json::as_u64)
                .ok_or("manifest missing \"ncpus\"")? as usize,
            cpu_model: field("cpu_model")?,
        })
    }
}

// ---------------------------------------------------------------------------
// bench records
// ---------------------------------------------------------------------------

/// One measured run of one bench config on one machine.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    /// Bench identity — names the registry file (e.g. `churn_rmat13_t8_p8`).
    pub bench: String,
    /// Unix seconds when the run was recorded.
    pub recorded_unix_s: u64,
    /// Where it ran.
    pub manifest: MachineManifest,
    /// Full run configuration, stringly-typed and order-canonical.
    pub config: BTreeMap<String, String>,
    /// Measured metrics, named per [`MetricKind`] conventions.
    pub metrics: BTreeMap<String, f64>,
}

impl BenchRecord {
    /// A record for the current machine, stamped now.
    pub fn new(
        bench: impl Into<String>,
        config: BTreeMap<String, String>,
        metrics: BTreeMap<String, f64>,
    ) -> Self {
        let recorded_unix_s = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        BenchRecord {
            bench: bench.into(),
            recorded_unix_s,
            manifest: MachineManifest::detect(),
            config,
            metrics,
        }
    }

    /// CRC-32 of the canonical config rendering, as 8 hex digits. Two runs
    /// gate against each other only when these match.
    pub fn config_hash(&self) -> String {
        let mut o = Json::obj();
        for (k, v) in &self.config {
            o.set(k, Json::from(v.as_str()));
        }
        format!("{:08x}", crc32(o.render_compact().as_bytes()))
    }

    /// Render as the canonical JSON object stored in registries and
    /// candidate files.
    pub fn to_json(&self) -> Json {
        let mut cfg = Json::obj();
        for (k, v) in &self.config {
            cfg.set(k, Json::from(v.as_str()));
        }
        let mut met = Json::obj();
        for (k, v) in &self.metrics {
            met.set(k, Json::from(*v));
        }
        let mut o = Json::obj();
        o.set("bench", Json::from(self.bench.as_str()))
            .set("recorded_unix_s", Json::from(self.recorded_unix_s))
            .set("manifest", self.manifest.to_json())
            .set("config", cfg)
            .set("config_hash", Json::from(self.config_hash()))
            .set("metrics", met);
        o
    }

    /// Parse a record object (the stored `config_hash` is recomputed, not
    /// trusted).
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let bench = v
            .get("bench")
            .and_then(Json::as_str)
            .ok_or("record missing \"bench\"")?
            .to_string();
        let recorded_unix_s =
            v.get("recorded_unix_s").and_then(Json::as_u64).unwrap_or(0);
        let manifest =
            MachineManifest::from_json(v.get("manifest").ok_or("record missing \"manifest\"")?)?;
        let mut config = BTreeMap::new();
        for (k, val) in v
            .get("config")
            .and_then(Json::as_obj)
            .ok_or("record missing \"config\"")?
        {
            config.insert(
                k.clone(),
                val.as_str().map(str::to_string).unwrap_or_else(|| val.render_compact()),
            );
        }
        let mut metrics = BTreeMap::new();
        for (k, val) in v
            .get("metrics")
            .and_then(Json::as_obj)
            .ok_or("record missing \"metrics\"")?
        {
            metrics.insert(
                k.clone(),
                val.as_f64().ok_or_else(|| format!("metric {k:?} is not a number"))?,
            );
        }
        Ok(BenchRecord { bench, recorded_unix_s, manifest, config, metrics })
    }

    /// Write a single-record candidate file.
    pub fn write_file(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().render_pretty())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Read a single-record candidate file.
    pub fn read_file(path: &Path) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::from_json(&json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?)
    }
}

// ---------------------------------------------------------------------------
// registry files
// ---------------------------------------------------------------------------

/// The append-only trajectory of one bench: all committed runs, oldest
/// first.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    /// The bench this registry tracks.
    pub bench: String,
    /// Committed runs, oldest first.
    pub runs: Vec<BenchRecord>,
}

impl Registry {
    /// An empty trajectory for `bench`.
    pub fn new(bench: impl Into<String>) -> Self {
        Registry { bench: bench.into(), runs: Vec::new() }
    }

    /// The conventional file name, `BENCH_<bench>.json`.
    pub fn file_name(bench: &str) -> String {
        format!("BENCH_{bench}.json")
    }

    /// The conventional path under the registry directory.
    pub fn path_for(dir: &Path, bench: &str) -> PathBuf {
        dir.join(Self::file_name(bench))
    }

    /// Load a registry file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let v = json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let schema = v.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != SCHEMA {
            return Err(format!(
                "{}: schema {schema:?}, this binary speaks {SCHEMA:?}",
                path.display()
            ));
        }
        let bench = v
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{}: missing \"bench\"", path.display()))?
            .to_string();
        let mut runs = Vec::new();
        for r in v.get("runs").and_then(Json::as_arr).unwrap_or(&[]) {
            runs.push(BenchRecord::from_json(r).map_err(|e| format!("{}: {e}", path.display()))?);
        }
        Ok(Registry { bench, runs })
    }

    /// Load `dir/BENCH_<bench>.json`, or start an empty trajectory if the
    /// file does not exist yet.
    pub fn load_or_new(dir: &Path, bench: &str) -> Result<Self, String> {
        let path = Self::path_for(dir, bench);
        if path.exists() {
            Self::load(&path)
        } else {
            Ok(Self::new(bench))
        }
    }

    /// Canonical-render into `dir/BENCH_<bench>.json` (creates `dir`).
    pub fn save(&self, dir: &Path) -> Result<PathBuf, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = Self::path_for(dir, &self.bench);
        let mut o = Json::obj();
        o.set("schema", Json::from(SCHEMA))
            .set("bench", Json::from(self.bench.as_str()))
            .set("runs", Json::Arr(self.runs.iter().map(BenchRecord::to_json).collect()));
        std::fs::write(&path, o.render_pretty()).map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(path)
    }

    /// Append a run (the record's bench must match).
    pub fn publish(&mut self, rec: BenchRecord) -> Result<(), String> {
        if rec.bench != self.bench {
            return Err(format!(
                "candidate is for bench {:?}, registry tracks {:?}",
                rec.bench, self.bench
            ));
        }
        self.runs.push(rec);
        Ok(())
    }

    /// The most recent committed run with the candidate's config hash — the
    /// gate baseline.
    pub fn baseline_for(&self, candidate: &BenchRecord) -> Option<&BenchRecord> {
        let hash = candidate.config_hash();
        self.runs.iter().rev().find(|r| r.config_hash() == hash)
    }

    /// All `BENCH_*.json` registries under `dir`, sorted by bench name.
    pub fn load_dir(dir: &Path) -> Result<Vec<Registry>, String> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(dir) {
            Ok(e) => e,
            Err(_) => return Ok(out), // no registry dir yet: empty trajectory
        };
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().into_owned();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                out.push(Self::load(&entry.path())?);
            }
        }
        out.sort_by(|a, b| a.bench.cmp(&b.bench));
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// metric naming conventions
// ---------------------------------------------------------------------------

/// How a metric is compared by the gate, derived from its name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    /// `exact_*` — schedule-deterministic; must match bit-for-bit even
    /// across hosts (a mismatch means the *code changed behavior*, not that
    /// the machine was slow).
    Exact,
    /// `*_per_s` — throughput; regression = candidate below baseline by
    /// more than the threshold.
    HigherIsBetter,
    /// `*_s` — wall time; regression = candidate above baseline by more
    /// than the threshold.
    LowerIsBetter,
    /// Anything else — reported, never gated.
    Advisory,
}

impl MetricKind {
    /// Classify a metric name.
    pub fn of(name: &str) -> MetricKind {
        if name.starts_with("exact_") {
            MetricKind::Exact
        } else if name.ends_with("_per_s") {
            MetricKind::HigherIsBetter
        } else if name.ends_with("_s") {
            MetricKind::LowerIsBetter
        } else {
            MetricKind::Advisory
        }
    }
}

// ---------------------------------------------------------------------------
// gate
// ---------------------------------------------------------------------------

/// Result of gating a candidate against a registry.
#[derive(Clone, Debug)]
pub struct GateOutcome {
    /// Did the candidate pass?
    pub pass: bool,
    /// True when there was no baseline for this config (first run seeds the
    /// trajectory).
    pub seeded: bool,
    /// Human-readable per-metric verdicts.
    pub lines: Vec<String>,
}

/// Compare `candidate` against the last committed run of the same config.
///
/// `threshold` is the tolerated relative regression on wall-clock metrics
/// (see [`DEFAULT_THRESHOLD`]). Cross-host wall-clock differences only
/// warn; `exact_*` mismatches always fail; a missing baseline seeds.
pub fn gate(registry: &Registry, candidate: &BenchRecord, threshold: f64) -> GateOutcome {
    let mut out = GateOutcome { pass: true, seeded: false, lines: Vec::new() };
    let hash = candidate.config_hash();
    let Some(base) = registry.baseline_for(candidate) else {
        out.seeded = true;
        out.lines.push(format!(
            "no committed baseline for config {hash}: seeding the trajectory (gate passes)"
        ));
        return out;
    };
    let same_host = base.manifest.fingerprint() == candidate.manifest.fingerprint();
    out.lines.push(format!(
        "baseline: recorded_unix_s={} host={}{}",
        base.recorded_unix_s,
        base.manifest.fingerprint(),
        if same_host { " (same host: strict)" } else { " (different host: advisory)" }
    ));
    for (name, &base_v) in &base.metrics {
        let Some(&cand_v) = candidate.metrics.get(name) else {
            match MetricKind::of(name) {
                MetricKind::Exact => {
                    out.pass = false;
                    out.lines.push(format!("FAIL {name}: present in baseline, missing in candidate"));
                }
                _ => out.lines.push(format!("warn {name}: missing in candidate")),
            }
            continue;
        };
        match MetricKind::of(name) {
            MetricKind::Exact => {
                if cand_v == base_v {
                    out.lines.push(format!("ok   {name}: {cand_v} (exact)"));
                } else {
                    out.pass = false;
                    out.lines.push(format!(
                        "FAIL {name}: {cand_v} != baseline {base_v} (deterministic metric — \
                         behavior changed)"
                    ));
                }
            }
            MetricKind::HigherIsBetter | MetricKind::LowerIsBetter => {
                let regressed = if MetricKind::of(name) == MetricKind::HigherIsBetter {
                    base_v > 0.0 && cand_v < base_v * (1.0 - threshold)
                } else {
                    base_v > 0.0 && cand_v > base_v * (1.0 + threshold)
                };
                let rel = if base_v != 0.0 { (cand_v - base_v) / base_v * 100.0 } else { 0.0 };
                if !regressed {
                    out.lines.push(format!("ok   {name}: {cand_v:.6} ({rel:+.1}% vs baseline)"));
                } else if same_host {
                    out.pass = false;
                    out.lines.push(format!(
                        "FAIL {name}: {cand_v:.6} vs baseline {base_v:.6} ({rel:+.1}%, threshold \
                         ±{:.0}%)",
                        threshold * 100.0
                    ));
                } else {
                    out.lines.push(format!(
                        "warn {name}: {cand_v:.6} vs baseline {base_v:.6} ({rel:+.1}%) — \
                         different host, not gated"
                    ));
                }
            }
            MetricKind::Advisory => {
                out.lines.push(format!("info {name}: {cand_v:.6} (baseline {base_v:.6})"));
            }
        }
    }
    for name in candidate.metrics.keys() {
        if !base.metrics.contains_key(name) {
            out.lines.push(format!("note {name}: new metric (no baseline)"));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// markdown report
// ---------------------------------------------------------------------------

/// Render the trajectories of `registries` as a markdown report.
pub fn report_markdown(registries: &[Registry]) -> String {
    let mut out = String::from("# Perf trajectory\n");
    if registries.is_empty() {
        out.push_str("\n_No BENCH_*.json registries found._\n");
        return out;
    }
    for reg in registries {
        out.push_str(&format!("\n## {}\n\n", reg.bench));
        if reg.runs.is_empty() {
            out.push_str("_No committed runs yet (registry awaiting its first publish)._\n");
            continue;
        }
        let mut metric_names: Vec<&str> = Vec::new();
        for run in &reg.runs {
            for name in run.metrics.keys() {
                if !metric_names.contains(&name.as_str()) {
                    metric_names.push(name);
                }
            }
        }
        metric_names.sort_unstable();
        // config keys whose values differ across the committed runs — they
        // are what tells rows apart (e.g. `layout=flat` vs
        // `layout=blocked64`), so they join the hash in the config cell
        let mut varying: Vec<&str> = Vec::new();
        if let Some(first) = reg.runs.first() {
            for run in &reg.runs {
                for (k, v) in &run.config {
                    if first.config.get(k) != Some(v) && !varying.contains(&k.as_str()) {
                        varying.push(k);
                    }
                }
                for k in first.config.keys() {
                    if !run.config.contains_key(k) && !varying.contains(&k.as_str()) {
                        varying.push(k);
                    }
                }
            }
        }
        varying.sort_unstable();
        out.push_str("| date | host | config | ");
        out.push_str(&metric_names.join(" | "));
        out.push_str(" |\n|---|---|---|");
        out.push_str(&"---|".repeat(metric_names.len()));
        out.push('\n');
        for run in &reg.runs {
            let cells: Vec<String> = metric_names
                .iter()
                .map(|m| {
                    run.metrics
                        .get(*m)
                        .map(|v| format_metric(*v))
                        .unwrap_or_else(|| "—".to_string())
                })
                .collect();
            let mut config_cell = String::new();
            for k in &varying {
                if let Some(v) = run.config.get(*k) {
                    config_cell.push_str(&format!("{k}={v} "));
                }
            }
            config_cell.push_str(&format!("`{}`", run.config_hash()));
            out.push_str(&format!(
                "| {} | {} | {} | {} |\n",
                format_date(run.recorded_unix_s),
                run.manifest.fingerprint(),
                config_cell,
                cells.join(" | ")
            ));
        }
    }
    out
}

fn format_metric(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else {
        format!("{v:.4}")
    }
}

/// `YYYY-MM-DD` from unix seconds (civil-from-days, proleptic Gregorian).
fn format_date(unix_s: u64) -> String {
    let days = (unix_s / 86_400) as i64;
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if m <= 2 { y + 1 } else { y };
    format!("{y:04}-{m:02}-{d:02}")
}

// ---------------------------------------------------------------------------
// churn adapter
// ---------------------------------------------------------------------------

/// The conventional bench name for a churn config:
/// `churn_<gen><log2 n>_t<threads>_p<shards>`. The adjacency layout lives in
/// the config (hence the config hash), not the name — flat and blocked runs
/// of the same shape share one trajectory file, so the report shows them
/// side by side.
pub fn churn_bench_name(cfg: &ChurnConfig) -> String {
    let n = cfg.gen.num_vertices();
    let log2n = (usize::BITS - 1).saturating_sub(n.leading_zeros());
    format!("churn_{}{}_t{}_p{}", cfg.gen.name(), log2n, cfg.threads, cfg.engine_shards)
}

/// Build the candidate record for a finished churn run.
pub fn churn_record(cfg: &ChurnConfig, summary: &ChurnSummary) -> BenchRecord {
    let mut config = BTreeMap::new();
    config.insert("workload".to_string(), "churn".to_string());
    config.insert("gen".to_string(), cfg.gen.name().to_string());
    config.insert("n".to_string(), cfg.gen.num_vertices().to_string());
    config.insert("seed".to_string(), cfg.seed.to_string());
    config.insert("threads".to_string(), cfg.threads.to_string());
    config.insert("shards".to_string(), cfg.engine_shards.to_string());
    config.insert("pool".to_string(), cfg.pool.to_string());
    config.insert("layout".to_string(), cfg.layout.name());
    config.insert("pin".to_string(), cfg.pin.name().to_string());
    config.insert("epochs".to_string(), cfg.epochs.to_string());
    config.insert("batch".to_string(), cfg.batch.to_string());
    config.insert("delete_frac".to_string(), cfg.delete_frac.to_string());
    config.insert("warmup_epochs".to_string(), cfg.warmup_epochs.to_string());

    let wall_total: f64 = summary.epoch_wall_s.iter().sum();
    let mut metrics = BTreeMap::new();
    metrics.insert("exact_epochs".to_string(), summary.epochs as f64);
    metrics.insert("exact_final_live_edges".to_string(), summary.final_live_edges as f64);
    if wall_total > 0.0 && summary.epochs > 0 {
        metrics.insert("epochs_per_s".to_string(), summary.epochs as f64 / wall_total);
        metrics.insert(
            "updates_per_s".to_string(),
            (summary.epochs * cfg.batch) as f64 / wall_total,
        );
        metrics
            .insert("epoch_wall_p50_s".to_string(), stats::median(&summary.epoch_wall_s));
        metrics.insert(
            "mutate_wall_mean_s".to_string(),
            stats::mean(&summary.epoch_mutate_s),
        );
        metrics
            .insert("route_wall_mean_s".to_string(), stats::mean(&summary.epoch_route_s));
    }
    metrics.insert("repair_frac_mean".to_string(), summary.repair_frac_mean);
    BenchRecord::new(churn_bench_name(cfg), config, metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dynamic::churn::{run_churn, ChurnGen};

    fn sample_record(bench: &str, layout: &str, wall: f64) -> BenchRecord {
        let mut config = BTreeMap::new();
        config.insert("layout".to_string(), layout.to_string());
        config.insert("threads".to_string(), "4".to_string());
        let mut metrics = BTreeMap::new();
        metrics.insert("epoch_wall_p50_s".to_string(), wall);
        metrics.insert("updates_per_s".to_string(), 1000.0 / wall);
        metrics.insert("exact_final_live_edges".to_string(), 2048.0);
        BenchRecord::new(bench, config, metrics)
    }

    #[test]
    fn records_roundtrip_through_canonical_json() {
        let rec = sample_record("churn_rmat9_t4_p2", "blocked64", 0.125);
        let parsed = BenchRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(parsed, rec);
        assert_eq!(parsed.config_hash(), rec.config_hash());
        // canonical: render → parse → render is a fixed point
        let text = rec.to_json().render_pretty();
        assert_eq!(
            crate::util::json::parse(&text).unwrap().render_pretty(),
            text
        );
    }

    #[test]
    fn config_hash_separates_layouts() {
        let flat = sample_record("b", "flat", 0.1);
        let blocked = sample_record("b", "blocked64", 0.1);
        assert_ne!(flat.config_hash(), blocked.config_hash());
    }

    #[test]
    fn registry_files_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join(format!("skipper_registry_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut reg = Registry::new("churn_rmat9_t4_p2");
        reg.publish(sample_record("churn_rmat9_t4_p2", "flat", 0.2)).unwrap();
        reg.publish(sample_record("churn_rmat9_t4_p2", "blocked64", 0.1)).unwrap();
        let path = reg.save(&dir).unwrap();
        assert!(path.ends_with("BENCH_churn_rmat9_t4_p2.json"));
        let loaded = Registry::load(&path).unwrap();
        assert_eq!(loaded.bench, reg.bench);
        assert_eq!(loaded.runs, reg.runs);
        // bench mismatch is rejected
        assert!(loaded.clone().publish(sample_record("other", "flat", 0.1)).is_err());
        // directory scan finds it
        let all = Registry::load_dir(&dir).unwrap();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].runs.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gate_seeds_when_no_baseline_matches() {
        let reg = Registry::new("b");
        let out = gate(&reg, &sample_record("b", "flat", 0.1), DEFAULT_THRESHOLD);
        assert!(out.pass && out.seeded);
        // a committed run of a DIFFERENT config also seeds
        let mut reg = Registry::new("b");
        reg.publish(sample_record("b", "blocked64", 0.1)).unwrap();
        let out = gate(&reg, &sample_record("b", "flat", 0.1), DEFAULT_THRESHOLD);
        assert!(out.pass && out.seeded);
    }

    #[test]
    fn gate_fails_same_host_regressions_and_exact_mismatches() {
        let mut reg = Registry::new("b");
        reg.publish(sample_record("b", "flat", 0.1)).unwrap();
        // within threshold: pass
        let out = gate(&reg, &sample_record("b", "flat", 0.11), 0.15);
        assert!(out.pass && !out.seeded, "{:?}", out.lines);
        // wall time blows the threshold on the same host: fail
        let out = gate(&reg, &sample_record("b", "flat", 0.2), 0.15);
        assert!(!out.pass, "{:?}", out.lines);
        // exact_* mismatch: fail even when wall time is fine
        let mut cand = sample_record("b", "flat", 0.1);
        cand.metrics.insert("exact_final_live_edges".to_string(), 2047.0);
        let out = gate(&reg, &cand, 0.15);
        assert!(!out.pass, "{:?}", out.lines);
        assert!(out.lines.iter().any(|l| l.contains("behavior changed")));
    }

    #[test]
    fn gate_downgrades_wall_clock_to_advisory_across_hosts() {
        let mut base = sample_record("b", "flat", 0.1);
        base.manifest.cpu_model = "SomeOtherCpu 9000".to_string();
        let mut reg = Registry::new("b");
        reg.publish(base).unwrap();
        // 10× slower but on different hardware: warn, don't fail
        let out = gate(&reg, &sample_record("b", "flat", 1.0), 0.15);
        assert!(out.pass, "{:?}", out.lines);
        assert!(out.lines.iter().any(|l| l.contains("different host")));
        // exact metrics still gate across hosts
        let mut cand = sample_record("b", "flat", 1.0);
        cand.metrics.insert("exact_final_live_edges".to_string(), 1.0);
        assert!(!gate(&reg, &cand, 0.15).pass);
    }

    #[test]
    fn metric_kinds_follow_naming() {
        assert_eq!(MetricKind::of("exact_final_live_edges"), MetricKind::Exact);
        assert_eq!(MetricKind::of("updates_per_s"), MetricKind::HigherIsBetter);
        assert_eq!(MetricKind::of("epoch_wall_p50_s"), MetricKind::LowerIsBetter);
        assert_eq!(MetricKind::of("repair_frac_mean"), MetricKind::Advisory);
    }

    #[test]
    fn churn_runs_produce_publishable_records() {
        let cfg = crate::dynamic::churn::ChurnConfig {
            epochs: 3,
            batch: 100,
            warmup_epochs: 2,
            threads: 2,
            ..crate::dynamic::churn::ChurnConfig::new(ChurnGen::Er { n: 256, m: 1024 })
        };
        let summary = run_churn(&cfg, |_| {}).unwrap();
        let rec = churn_record(&cfg, &summary);
        assert_eq!(rec.bench, "churn_er8_t2_p1");
        assert_eq!(rec.config["layout"], "blocked64");
        assert_eq!(rec.config["pin"], "none");
        // a pinned run of the same shape gets its own config hash
        let pinned = crate::dynamic::churn::ChurnConfig {
            pin: crate::dynamic::PinPolicy::Compact,
            ..cfg.clone()
        };
        let rec_pinned = churn_record(&pinned, &summary);
        assert_eq!(rec_pinned.config["pin"], "compact");
        assert_ne!(rec_pinned.config_hash(), rec.config_hash());
        assert!(rec.metrics["updates_per_s"] > 0.0);
        assert_eq!(rec.metrics["exact_epochs"], 3.0);
        assert!(rec.metrics["exact_final_live_edges"] > 0.0);
        // deterministic replay ⇒ the exact metric really is exact
        let rec2 = churn_record(&cfg, &run_churn(&cfg, |_| {}).unwrap());
        assert_eq!(
            rec.metrics["exact_final_live_edges"],
            rec2.metrics["exact_final_live_edges"]
        );
        // the trajectory report renders it
        let mut reg = Registry::new(rec.bench.clone());
        reg.publish(rec).unwrap();
        let md = report_markdown(&[reg]);
        assert!(md.contains("churn_er8_t2_p1"));
        assert!(md.contains("updates_per_s"));
    }

    #[test]
    fn report_shows_varying_config_keys_beside_the_hash() {
        let mut reg = Registry::new("b");
        reg.publish(sample_record("b", "flat", 0.1)).unwrap();
        reg.publish(sample_record("b", "blocked64", 0.2)).unwrap();
        let md = report_markdown(&[reg]);
        assert!(md.contains("## b"), "{md}");
        assert!(md.contains("layout=flat"), "{md}");
        assert!(md.contains("layout=blocked64"), "{md}");
        // shared keys stay out of the config cell — only the differing ones
        // (plus the hash) distinguish rows
        let row = md.lines().find(|l| l.contains("layout=flat")).unwrap();
        assert!(!row.contains("threads="), "{row}");

        let empty = Registry::new("quiet");
        let md = report_markdown(&[empty]);
        assert!(md.contains("awaiting its first publish"), "{md}");
    }

    #[test]
    fn dates_render_from_unix_seconds() {
        assert_eq!(format_date(0), "1970-01-01");
        assert_eq!(format_date(1_754_000_000), "2025-07-31");
    }
}
