//! Experiment coordinator: dataset registry (the scaled analogue suite),
//! cost-model calibration against real host measurements, the experiment
//! registry (one entry per paper table/figure — DESIGN.md §5), report
//! writers, and the committed perf-trajectory registry ([`registry`],
//! `BENCH_*.json`).

pub mod calibrate;
pub mod config;
pub mod datasets;
pub mod experiments;
pub mod registry;
pub mod report;
