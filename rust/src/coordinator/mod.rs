//! Experiment coordinator: dataset registry (the scaled analogue suite),
//! cost-model calibration against real host measurements, the experiment
//! registry (one entry per paper table/figure — DESIGN.md §5), report
//! writers, the committed perf-trajectory registry ([`registry`],
//! `BENCH_*.json`), and the static HTML dashboard renderer ([`dash`]).

pub mod calibrate;
pub mod config;
pub mod dash;
pub mod datasets;
pub mod experiments;
pub mod registry;
pub mod report;
