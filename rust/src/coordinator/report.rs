//! Report writer: collects experiment outputs and writes them as plain text
//! + a combined markdown summary under the configured report directory.

use std::io::Write;

#[derive(Default)]
/// Ordered collection of experiment outputs, written as text + markdown.
pub struct Report {
    sections: Vec<(String, String)>,
}

impl Report {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one experiment’s rendered output under `id`.
    pub fn add(&mut self, id: &str, content: String) {
        self.sections.push((id.to_string(), content));
    }

    /// The collected `(id, content)` sections, in insertion order.
    pub fn sections(&self) -> &[(String, String)] {
        &self.sections
    }

    /// Write one `<id>.txt` per section plus `summary.md`.
    pub fn write_dir(&self, dir: &str) -> Result<Vec<String>, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {dir}: {e}"))?;
        let mut written = Vec::new();
        for (id, content) in &self.sections {
            let path = format!("{dir}/{id}.txt");
            std::fs::write(&path, content).map_err(|e| format!("write {path}: {e}"))?;
            written.push(path);
        }
        let summary = format!("{dir}/summary.md");
        let mut f =
            std::fs::File::create(&summary).map_err(|e| format!("create {summary}: {e}"))?;
        writeln!(f, "# Skipper reproduction — experiment summary\n").map_err(|e| e.to_string())?;
        for (id, content) in &self.sections {
            writeln!(f, "## {id}\n\n```\n{content}\n```\n").map_err(|e| e.to_string())?;
        }
        written.push(summary);
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_sections_and_summary() {
        let dir = std::env::temp_dir().join("skipper_report_test");
        let dir = dir.to_str().unwrap();
        let _ = std::fs::remove_dir_all(dir);
        let mut r = Report::new();
        r.add("table1", "row row\n".into());
        r.add("fig7", "data\n".into());
        let files = r.write_dir(dir).unwrap();
        assert_eq!(files.len(), 3);
        let summary = std::fs::read_to_string(format!("{dir}/summary.md")).unwrap();
        assert!(summary.contains("## table1"));
        assert!(std::fs::read_to_string(format!("{dir}/fig7.txt")).unwrap().contains("data"));
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn empty_report_still_writes_summary() {
        let dir = std::env::temp_dir().join("skipper_report_empty");
        let dir = dir.to_str().unwrap();
        let _ = std::fs::remove_dir_all(dir);
        let files = Report::new().write_dir(dir).unwrap();
        assert_eq!(files.len(), 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}
