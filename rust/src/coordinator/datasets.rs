//! The scaled analogue suite standing in for the paper's Table I datasets
//! (DESIGN.md §3). Every generator is seeded; a given (name, scale) pair is
//! bit-reproducible. Generated graphs can be cached to disk (`data/*.skg`).

use crate::graph::gen::{
    barabasi_albert, hostweb::HostWebConfig, hostweb, knn_overlap::KnnConfig, knn_overlap, rmat,
    GenConfig,
};
use crate::graph::{io::binary, CsrGraph};

/// Suite scale: `Tiny` is used for trace-based cache simulation, `Small`
/// for tests, `Medium` for the shipped experiment runs, `Large` when more
/// runtime budget is available.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// 1/16 of medium — trace/cachesim scale.
    Tiny,
    /// 1/4 of medium — the default.
    Small,
    /// The reference scale.
    Medium,
    /// 4× medium.
    Large,
}

impl Scale {
    /// Parse a scale name (`tiny|small|medium|large`).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "tiny" => Ok(Scale::Tiny),
            "small" => Ok(Scale::Small),
            "medium" => Ok(Scale::Medium),
            "large" => Ok(Scale::Large),
            _ => Err(format!("unknown scale {s:?} (tiny|small|medium|large)")),
        }
    }

    /// log2 shrink relative to Medium.
    fn shift(&self) -> i32 {
        match self {
            Scale::Tiny => 4,
            Scale::Small => 2,
            Scale::Medium => 0,
            Scale::Large => -2,
        }
    }

    /// The lowercase scale name.
    pub fn name(&self) -> &'static str {
        match self {
            Scale::Tiny => "tiny",
            Scale::Small => "small",
            Scale::Medium => "medium",
            Scale::Large => "large",
        }
    }
}

fn scaled_min(base: usize, scale: Scale, min: usize) -> usize {
    let s = scale.shift();
    if s >= 0 {
        (base >> s).max(min)
    } else {
        base << (-s)
    }
}

/// Vertex-count scaling (floor 1024 so tiny graphs stay meaningful).
fn scaled(base: usize, scale: Scale) -> usize {
    scaled_min(base, scale, 1024)
}

/// Host-count scaling for the web generators (floor 32).
fn scaled_hosts(base: usize, scale: Scale) -> usize {
    scaled_min(base, scale, 32)
}

/// One suite entry: our analogue of a paper dataset.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    /// Paper's dataset name this analogue stands in for.
    pub paper_name: &'static str,
    /// Our analogue's name.
    pub name: &'static str,
    /// Category label from the paper’s Table I (Social/Synth/Bio/Web).
    pub kind: &'static str,
    /// Generator seed — datasets are bit-reproducible.
    pub seed: u64,
}

/// The seven scaled analogues of the paper’s Table I suite.
pub const SUITE: [DatasetSpec; 7] = [
    DatasetSpec { paper_name: "twitter10", name: "twitter10s", kind: "Social", seed: 101 },
    DatasetSpec { paper_name: "g500", name: "g500s", kind: "Synth", seed: 102 },
    DatasetSpec { paper_name: "msa10", name: "msa10s", kind: "Bio", seed: 103 },
    DatasetSpec { paper_name: "clueweb12", name: "clueweb12s", kind: "Web", seed: 104 },
    DatasetSpec { paper_name: "wdc14", name: "wdc14s", kind: "Web", seed: 105 },
    DatasetSpec { paper_name: "eu15", name: "eu15s", kind: "Web", seed: 106 },
    DatasetSpec { paper_name: "wdc12", name: "wdc12s", kind: "Web", seed: 107 },
];

/// Generate one dataset at the given scale.
pub fn generate(spec: &DatasetSpec, scale: Scale) -> CsrGraph {
    match spec.name {
        // Social: preferential attachment (hubs, heavy tail)
        "twitter10s" => barabasi_albert::generate(scaled(1 << 17, scale), 8, spec.seed),
        // Synthetic: Graph500 RMAT
        "g500s" => {
            let base_scale = 17i32 - scale.shift();
            rmat::generate(&GenConfig {
                scale: base_scale.max(10) as u32,
                avg_degree: 16,
                seed: spec.seed,
            })
        }
        // Bio: banded sequence-similarity
        "msa10s" => knn_overlap::generate(&KnnConfig {
            n: scaled(1 << 17, scale),
            k: 12,
            window: 32,
            long_range_p: 0.05,
            seed: spec.seed,
        }),
        // Web graphs: host-block locality + power-law cross links, with
        // |V| and density increasing across the four entries like the
        // paper's clueweb12 < wdc14 < eu15 < wdc12 progression.
        "clueweb12s" => hostweb::generate(&HostWebConfig {
            num_hosts: scaled_hosts(512, scale),
            vertices_per_host: 256,
            intra_degree: 10,
            inter_degree: 2,
            seed: spec.seed,
        }),
        "wdc14s" => hostweb::generate(&HostWebConfig {
            num_hosts: scaled_hosts(1024, scale),
            vertices_per_host: 256,
            intra_degree: 10,
            inter_degree: 2,
            seed: spec.seed,
        }),
        "eu15s" => hostweb::generate(&HostWebConfig {
            num_hosts: scaled_hosts(512, scale),
            vertices_per_host: 512,
            intra_degree: 14,
            inter_degree: 2,
            seed: spec.seed,
        }),
        "wdc12s" => hostweb::generate(&HostWebConfig {
            num_hosts: scaled_hosts(2048, scale),
            vertices_per_host: 256,
            intra_degree: 10,
            inter_degree: 2,
            seed: spec.seed,
        }),
        other => panic!("unknown dataset {other}"),
    }
}

/// Find a suite entry by our name or the paper’s dataset name.
pub fn spec_by_name(name: &str) -> Option<&'static DatasetSpec> {
    SUITE.iter().find(|s| s.name == name || s.paper_name == name)
}

/// Canonical on-disk cache location of a (dataset, scale) pair — the one
/// place the `.skg` naming convention lives (streaming runs open this path
/// directly).
pub fn cache_path(spec: &DatasetSpec, scale: Scale, cache_dir: &str) -> String {
    format!("{cache_dir}/{}_{}.skg", spec.name, scale.name())
}

/// Generate with an on-disk cache under `cache_dir`.
pub fn generate_cached(spec: &DatasetSpec, scale: Scale, cache_dir: &str) -> CsrGraph {
    let path = cache_path(spec, scale, cache_dir);
    if let Ok(g) = binary::read_file(&path) {
        return g;
    }
    let g = generate(spec, scale);
    let _ = std::fs::create_dir_all(cache_dir);
    let _ = binary::write_file(&path, &g);
    g
}

/// Like [`generate_cached`], but also guarantees the `.skg` cache file
/// exists on disk afterwards (streaming consumers read it back), returning
/// its path alongside the graph.
pub fn generate_cached_path(
    spec: &DatasetSpec,
    scale: Scale,
    cache_dir: &str,
) -> Result<(CsrGraph, String), String> {
    let g = generate_cached(spec, scale, cache_dir);
    let path = cache_path(spec, scale, cache_dir);
    if !std::path::Path::new(&path).exists() {
        let _ = std::fs::create_dir_all(cache_dir);
        binary::write_file(&path, &g)?;
    }
    Ok((g, path))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_generates_at_tiny_scale() {
        for spec in &SUITE {
            let g = generate(spec, Scale::Tiny);
            assert!(g.num_vertices() > 0, "{}", spec.name);
            assert!(g.num_edge_slots() > 0, "{}", spec.name);
            assert!(g.is_symmetric(), "{}", spec.name);
        }
    }

    #[test]
    fn scales_are_ordered() {
        let spec = spec_by_name("g500s").unwrap();
        let tiny = generate(spec, Scale::Tiny);
        let small = generate(spec, Scale::Small);
        assert!(small.num_edge_slots() > tiny.num_edge_slots());
    }

    #[test]
    fn lookup_by_paper_name() {
        assert_eq!(spec_by_name("twitter10").unwrap().name, "twitter10s");
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn deterministic_generation() {
        let spec = spec_by_name("msa10s").unwrap();
        assert_eq!(generate(spec, Scale::Tiny), generate(spec, Scale::Tiny));
    }

    #[test]
    fn cache_roundtrip() {
        let dir = std::env::temp_dir().join("skipper_ds_cache_test");
        let dir = dir.to_str().unwrap();
        let _ = std::fs::remove_dir_all(dir);
        let spec = spec_by_name("twitter10s").unwrap();
        let a = generate_cached(spec, Scale::Tiny, dir);
        let b = generate_cached(spec, Scale::Tiny, dir); // from cache
        assert_eq!(a, b);
        let _ = std::fs::remove_dir_all(dir);
    }
}
