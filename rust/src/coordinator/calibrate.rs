//! Cost-model calibration: anchor the APRAM cost model's `ns_per_access`
//! to a *measured* single-thread SGMM run on this host, so simulated times
//! are host-consistent and ratios are driven purely by measured work.

use crate::apram::cost::{CostModel, WorkProfile};
use crate::cachesim::Hierarchy;
use crate::coordinator::datasets::{generate, spec_by_name, Scale};
use crate::instrument::{CountingProbe, TracingProbe};
use crate::matching::sgmm::Sgmm;
use crate::matching::MaximalMatcher;
use std::time::Instant;

/// Calibrate against SGMM on the g500 analogue (RMAT — the least
/// locality-friendly dataset, giving a conservative per-access cost).
pub fn calibrate() -> CostModel {
    let spec = spec_by_name("g500s").expect("suite contains g500s");
    let g = generate(spec, Scale::Small);
    // measured wall time (median of 3)
    let mut times = Vec::new();
    for _ in 0..3 {
        let t0 = Instant::now();
        std::hint::black_box(Sgmm.run(&g));
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let wall = times[1];
    // measured work
    let mut cp = CountingProbe::default();
    let _ = Sgmm.run_probed(&g, &mut cp);
    // simulated misses on a tiny twin → miss rate → misses estimate
    // (same scaled geometry the experiments use, so rates are consistent)
    let tiny = generate(spec, Scale::Tiny);
    let geo = crate::cachesim::Geometry::for_working_set(
        tiny.memory_bytes() + tiny.num_vertices(),
    );
    let mut tp = TracingProbe::default();
    let _ = Sgmm.run_probed(&tiny, &mut tp);
    let stats = Hierarchy::replay_with(&tp, geo);
    let l3_misses = (stats.l3_miss_rate() * cp.total() as f64) as u64;
    CostModel::calibrated(
        wall,
        &WorkProfile {
            accesses: cp.total(),
            l3_misses,
            iterations: 0,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_yields_positive_costs() {
        let m = calibrate();
        assert!(m.ns_per_access > 0.0 && m.ns_per_access.is_finite());
        // sanity: a memory access on any real machine is 0.05–1000 ns
        assert!(m.ns_per_access < 1000.0, "ns_per_access {}", m.ns_per_access);
    }
}
