//! Experiment registry — one entry per paper table/figure (DESIGN.md §5).
//!
//! All experiments consume [`DatasetMetrics`], a per-dataset bundle of
//! *measured* quantities (wall-clock on this host, counted memory accesses,
//! cache-simulated miss rates, APRAM-simulated 64-thread behaviour). Each
//! `fig*`/`table*` function renders the same rows/series the paper reports,
//! so `skipper-cli experiment <id>` regenerates the artifact directly.

use crate::apram::cost::{CostModel, WorkProfile};
use crate::apram::{simulate_skipper, SimConfig};
use crate::cachesim::Hierarchy;
use crate::coordinator::datasets::{generate_cached, DatasetSpec, Scale, SUITE};
use crate::graph::CsrGraph;
use crate::instrument::conflicts::{ConflictStats, BUCKET_LABELS};
use crate::instrument::{CountingProbe, TracingProbe};
use crate::matching::ems::sidmm::Sidmm;
use crate::matching::sgmm::Sgmm;
use crate::matching::skipper::Skipper;
use crate::matching::{verify, MaximalMatcher};
use crate::util::benchlib::Table;
use crate::util::stats::geomean;
use std::time::Instant;

/// Threads the paper's parallel runs use.
pub const PAPER_THREADS: usize = 64;

/// Everything the figures/tables need, measured once per dataset.
#[derive(Clone, Debug)]
pub struct DatasetMetrics {
    /// The dataset these metrics describe.
    pub spec: &'static DatasetSpec,
    /// Vertices.
    pub v: usize,
    /// Stored CSR edge slots (2× undirected edges).
    pub e_slots: usize,
    // --- real measured wall-clock, single thread ---
    /// Measured single-thread SGMM wall seconds.
    pub sgmm_wall_s: f64,
    /// Measured single-thread SIDMM wall seconds.
    pub sidmm_wall_s: f64,
    /// Measured single-thread Skipper wall seconds.
    pub skipper_wall_1t_s: f64,
    // --- counted memory accesses ---
    /// Counted SGMM memory accesses.
    pub sgmm_accesses: u64,
    /// Counted SIDMM memory accesses.
    pub sidmm_accesses: u64,
    /// SIDMM sampling iterations (synchronized rounds).
    pub sidmm_iterations: u64,
    /// Counted single-thread Skipper accesses.
    pub skipper_accesses_1t: u64,
    // --- cache-simulated L3 miss rates (tiny-twin traces) ---
    /// Cache-simulated SGMM L3 miss rate.
    pub sgmm_miss_rate: f64,
    /// Cache-simulated SIDMM L3 miss rate.
    pub sidmm_miss_rate: f64,
    /// Cache-simulated Skipper L3 miss rate.
    pub skipper_miss_rate: f64,
    // --- APRAM simulation at PAPER_THREADS ---
    /// APRAM-simulated makespan at 64 virtual threads.
    pub skipper_sim64_makespan: u64,
    /// APRAM-simulated total ops at 64 virtual threads.
    pub skipper_sim64_total: u64,
    /// JIT conflicts at 64 simulated threads (Table II).
    pub conflicts64: ConflictStats,
    /// JIT conflicts at 16 simulated threads.
    pub conflicts16: ConflictStats,
    // --- matching sizes (for validation reporting) ---
    /// |M| of the validated Skipper run.
    pub matching_size: usize,
}

impl DatasetMetrics {
    /// Modeled SGMM L3 misses (rate × accesses).
    pub fn sgmm_l3_misses(&self) -> u64 {
        (self.sgmm_miss_rate * self.sgmm_accesses as f64) as u64
    }
    /// Modeled SIDMM L3 misses (rate × accesses).
    pub fn sidmm_l3_misses(&self) -> u64 {
        (self.sidmm_miss_rate * self.sidmm_accesses as f64) as u64
    }
    /// Modeled Skipper L3 misses for the simulated 64-thread run.
    pub fn skipper_l3_misses_sim64(&self) -> u64 {
        (self.skipper_miss_rate * self.skipper_sim64_total as f64) as u64
    }

    /// SIDMM work profile for the cost model.
    pub fn sidmm_profile(&self) -> WorkProfile {
        WorkProfile {
            accesses: self.sidmm_accesses,
            l3_misses: self.sidmm_l3_misses(),
            iterations: self.sidmm_iterations,
        }
    }

    /// SGMM work profile for the cost model.
    pub fn sgmm_profile(&self) -> WorkProfile {
        WorkProfile {
            accesses: self.sgmm_accesses,
            l3_misses: self.sgmm_l3_misses(),
            iterations: 0,
        }
    }

    /// Modeled sequential SGMM time — the consistent reference for the
    /// simulated parallel times in Figs 3/9/10 (the measured wall-clock is
    /// used in Fig 11, where everything is measured on the same host).
    pub fn sgmm_model_seconds(&self, cost: &CostModel) -> f64 {
        cost.seq_seconds(&self.sgmm_profile())
    }

    /// Simulated parallel times at `t` threads.
    pub fn sidmm_par_seconds(&self, cost: &CostModel, t: usize) -> f64 {
        cost.par_seconds(&self.sidmm_profile(), t)
    }
    /// Simulated Skipper parallel time at `t` threads.
    pub fn skipper_par_seconds(&self, cost: &CostModel, t: usize) -> f64 {
        cost.skipper_seconds(self.skipper_sim64_makespan, self.skipper_l3_misses_sim64(), t)
    }
}

fn wall<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Collect metrics for one dataset. `table2_runs` controls the number of
/// APRAM simulations per thread count (the paper uses 5 and reports the
/// run with the most conflicting edges).
pub fn collect_dataset(
    spec: &'static DatasetSpec,
    scale: Scale,
    cache_dir: &str,
    table2_runs: usize,
) -> DatasetMetrics {
    let g = generate_cached(spec, scale, cache_dir);
    let tiny = generate_cached(spec, Scale::Tiny, cache_dir);

    // --- real single-thread wall times (uninstrumented) ---
    let (m_sgmm, sgmm_wall_s) = wall(|| Sgmm.run(&g));
    let (m_sidmm, sidmm_wall_s) = wall(|| Sidmm::default().run(&g));
    let (m_skip, skipper_wall_1t_s) = wall(|| Skipper::new(1).run(&g));
    verify::check(&g, &m_sgmm).expect("SGMM invalid");
    verify::check(&g, &m_sidmm).expect("SIDMM invalid");
    verify::check(&g, &m_skip).expect("Skipper invalid");

    // --- counted accesses ---
    let mut p_sgmm = CountingProbe::default();
    let _ = Sgmm.run_probed(&g, &mut p_sgmm);
    let mut p_sidmm = CountingProbe::default();
    let (_, sidmm_tel) = Sidmm::default().run_probed(&g, &mut p_sidmm);
    let (_, _, skipper_probes) = Skipper::new(1).run_instrumented::<CountingProbe>(&g);
    let skipper_accesses_1t = CountingProbe::merge(&skipper_probes).total();

    // --- miss rates from tiny-twin traces, replayed against a cache
    //     geometry scaled to the twin's working set (the paper's graphs
    //     are 300-15000x the testbed L3; see Geometry::for_working_set) ---
    let geo = crate::cachesim::Geometry::for_working_set(
        tiny.memory_bytes() + tiny.num_vertices(),
    );
    let mut t_sgmm = TracingProbe::default();
    let _ = Sgmm.run_probed(&tiny, &mut t_sgmm);
    let sgmm_miss_rate = Hierarchy::replay_with(&t_sgmm, geo).l3_miss_rate();
    let mut t_sidmm = TracingProbe::default();
    let _ = Sidmm::default().run_probed(&tiny, &mut t_sidmm);
    let sidmm_miss_rate = Hierarchy::replay_with(&t_sidmm, geo).l3_miss_rate();
    let (_, _, skipper_traces) =
        Skipper::new(PAPER_THREADS).run_instrumented::<TracingProbe>(&tiny);
    let sk_stats = Hierarchy::replay_sharded_with(&skipper_traces, geo);
    let skipper_miss_rate = sk_stats.l3_miss_rate();

    // --- APRAM simulation: Table II (5 runs, max-conflict run) + timing ---
    let pick_max = |threads: usize| -> ConflictStats {
        (0..table2_runs.max(1))
            .map(|r| {
                simulate_skipper(
                    &g,
                    &SimConfig {
                        threads,
                        blocks_per_thread: 16,
                        seed: 0xA11CE + r as u64,
                    },
                )
                .conflicts
            })
            .max_by_key(|c| c.edges_with_conflicts)
            .unwrap()
    };
    let sim64 = simulate_skipper(&g, &SimConfig::new(PAPER_THREADS));
    verify::check(&g, &sim64.matching).expect("sim matching invalid");
    let conflicts64 = pick_max(PAPER_THREADS);
    let conflicts16 = pick_max(16);

    DatasetMetrics {
        spec,
        v: g.num_vertices(),
        e_slots: g.num_edge_slots(),
        sgmm_wall_s,
        sidmm_wall_s,
        skipper_wall_1t_s,
        sgmm_accesses: p_sgmm.total(),
        sidmm_accesses: p_sidmm.total(),
        sidmm_iterations: sidmm_tel.iterations as u64,
        skipper_accesses_1t,
        sgmm_miss_rate,
        sidmm_miss_rate,
        skipper_miss_rate,
        skipper_sim64_makespan: sim64.makespan_ops(),
        skipper_sim64_total: sim64.total_ops(),
        conflicts64,
        conflicts16,
        matching_size: sim64.matching.len(),
    }
}

/// Collect the whole suite.
pub fn collect_suite(scale: Scale, cache_dir: &str, table2_runs: usize) -> Vec<DatasetMetrics> {
    SUITE
        .iter()
        .map(|spec| collect_dataset(spec, scale, cache_dir, table2_runs))
        .collect()
}

// ---------------------------------------------------------------------------
// Experiment renderers
// ---------------------------------------------------------------------------

/// Table I: SIDMM vs Skipper execution time (simulated 64-thread) + speedup.
pub fn table1(metrics: &[DatasetMetrics], cost: &CostModel) -> String {
    let mut t = Table::new(&["Name", "Type", "|V|", "|E|", "SIDMM(s)", "Skipper(s)", "Speedup"]);
    let mut speedups = Vec::new();
    for m in metrics {
        let sidmm = m.sidmm_par_seconds(cost, PAPER_THREADS);
        let skipper = m.skipper_par_seconds(cost, PAPER_THREADS);
        let sp = sidmm / skipper;
        speedups.push(sp);
        t.row(&[
            m.spec.paper_name.into(),
            m.spec.kind.into(),
            m.v.to_string(),
            (m.e_slots / 2).to_string(),
            format!("{sidmm:.4}"),
            format!("{skipper:.4}"),
            format!("{sp:.1}"),
        ]);
    }
    format!(
        "Table I — Skipper vs SIDMM, simulated t={PAPER_THREADS} (paper: 4.9-15.6x, geomean 8.0x)\n{}\ngeomean speedup: {:.1}x\n",
        t.render(),
        geomean(&speedups).unwrap_or(f64::NAN)
    )
}

/// Table II: JIT conflict statistics at t=64 and t=16.
pub fn table2(metrics: &[DatasetMetrics]) -> String {
    let mut header = vec!["Dataset", "t", "Max", "Total", "#Edges", "Avg"];
    header.extend(BUCKET_LABELS);
    let mut t = Table::new(&header);
    for m in metrics {
        for (threads, c) in [(64usize, &m.conflicts64), (16, &m.conflicts16)] {
            let mut row = vec![
                m.spec.paper_name.to_string(),
                threads.to_string(),
                c.max_per_edge.to_string(),
                c.total.to_string(),
                c.edges_with_conflicts.to_string(),
                format!("{:.1}", c.avg_per_conflicting_edge()),
            ];
            row.extend(c.buckets.iter().map(|b| {
                if *b == 0 {
                    String::new()
                } else {
                    b.to_string()
                }
            }));
            t.row(&row);
        }
    }
    format!(
        "Table II — JIT conflicts (APRAM sim, max of 5 runs; paper: conflicting edges / |E| < 0.1%)\n{}",
        t.render()
    )
}

/// Fig 3: SIDMM parallelization gain vs normalized memory accesses.
pub fn fig3(metrics: &[DatasetMetrics], cost: &CostModel) -> String {
    let mut t = Table::new(&["Dataset", "SIDMM accesses / SGMM", "SIDMM gain vs SGMM"]);
    let (mut ratios, mut gains) = (Vec::new(), Vec::new());
    for m in metrics {
        let ratio = m.sidmm_accesses as f64 / m.sgmm_accesses as f64;
        let gain = m.sgmm_model_seconds(cost) / m.sidmm_par_seconds(cost, PAPER_THREADS);
        ratios.push(ratio);
        gains.push(gain);
        t.row(&[
            m.spec.paper_name.into(),
            format!("{ratio:.1}"),
            format!("{gain:.1}"),
        ]);
    }
    format!(
        "Fig 3 — SIDMM work overhead vs gain (paper: 33-58x accesses, 1.7-4.5x gain)\n{}\ngeomean accesses ratio: {:.1}  geomean gain: {:.1}\n",
        t.render(),
        geomean(&ratios).unwrap_or(f64::NAN),
        geomean(&gains).unwrap_or(f64::NAN)
    )
}

/// Fig 7: memory accesses normalized to |E| (edge slots).
pub fn fig7(metrics: &[DatasetMetrics]) -> String {
    let mut t = Table::new(&["Dataset", "SGMM/|E|", "SIDMM/|E|", "Skipper/|E|"]);
    let (mut a, mut b, mut c) = (Vec::new(), Vec::new(), Vec::new());
    for m in metrics {
        let e = m.e_slots as f64;
        let (x, y, z) = (
            m.sgmm_accesses as f64 / e,
            m.sidmm_accesses as f64 / e,
            m.skipper_accesses_1t as f64 / e,
        );
        a.push(x);
        b.push(y);
        c.push(z);
        t.row(&[
            m.spec.paper_name.into(),
            format!("{x:.2}"),
            format!("{y:.1}"),
            format!("{z:.2}"),
        ]);
    }
    format!(
        "Fig 7 — accesses per edge (paper: SGMM 0.3-0.8, SIDMM 16.7-26.9 gm 21.0, Skipper 1.2-3.4 gm 2.1)\n{}\ngeomeans: SGMM {:.2}  SIDMM {:.1}  Skipper {:.2}\n",
        t.render(),
        geomean(&a).unwrap_or(f64::NAN),
        geomean(&b).unwrap_or(f64::NAN),
        geomean(&c).unwrap_or(f64::NAN)
    )
}

/// Fig 8: L3 misses relative to SGMM.
pub fn fig8(metrics: &[DatasetMetrics]) -> String {
    let mut t = Table::new(&["Dataset", "SIDMM L3 / SGMM", "Skipper L3 / SGMM"]);
    let (mut rs, mut rk) = (Vec::new(), Vec::new());
    for m in metrics {
        let base = m.sgmm_l3_misses().max(1) as f64;
        let s = m.sidmm_l3_misses() as f64 / base;
        let k = m.skipper_l3_misses_sim64() as f64 / base;
        rs.push(s);
        rk.push(k);
        t.row(&[
            m.spec.paper_name.into(),
            format!("{s:.1}"),
            format!("{k:.2}"),
        ]);
    }
    format!(
        "Fig 8 — L3 misses vs SGMM (paper: SIDMM 14.2-16.5x gm 15.4, Skipper 0.7-1.4x gm 1.0)\n{}\ngeomeans: SIDMM {:.1}  Skipper {:.2}\n",
        t.render(),
        geomean(&rs).unwrap_or(f64::NAN),
        geomean(&rk).unwrap_or(f64::NAN)
    )
}

/// Fig 9: execution times (SGMM measured; SIDMM/Skipper simulated t=64).
pub fn fig9(metrics: &[DatasetMetrics], cost: &CostModel) -> String {
    let mut t = Table::new(&["Dataset", "SGMM(s)", "SIDMM(s)", "Skipper(s)"]);
    for m in metrics {
        t.row(&[
            m.spec.paper_name.into(),
            format!("{:.4}", m.sgmm_model_seconds(cost)),
            format!("{:.4}", m.sidmm_par_seconds(cost, PAPER_THREADS)),
            format!("{:.4}", m.skipper_par_seconds(cost, PAPER_THREADS)),
        ]);
    }
    format!(
        "Fig 9 — execution time, SGMM 1t (modeled) vs SIDMM/Skipper t=64 (simulated)\n{}",
        t.render()
    )
}

/// Fig 10: parallelization gain relative to SGMM.
pub fn fig10(metrics: &[DatasetMetrics], cost: &CostModel) -> String {
    let mut t = Table::new(&["Dataset", "SIDMM gain", "Skipper gain"]);
    let (mut gs, mut gk) = (Vec::new(), Vec::new());
    for m in metrics {
        let s = m.sgmm_model_seconds(cost) / m.sidmm_par_seconds(cost, PAPER_THREADS);
        let k = m.sgmm_model_seconds(cost) / m.skipper_par_seconds(cost, PAPER_THREADS);
        gs.push(s);
        gk.push(k);
        t.row(&[
            m.spec.paper_name.into(),
            format!("{s:.1}"),
            format!("{k:.1}"),
        ]);
    }
    format!(
        "Fig 10 — parallelization gain (paper: SIDMM 1.7-4.5 gm 3.0, Skipper 14.0-35.2 gm 20.0)\n{}\ngeomeans: SIDMM {:.1}  Skipper {:.1}\n",
        t.render(),
        geomean(&gs).unwrap_or(f64::NAN),
        geomean(&gk).unwrap_or(f64::NAN)
    )
}

/// Fig 11: serial slowdown — all REAL measured single-thread wall times.
pub fn fig11(metrics: &[DatasetMetrics]) -> String {
    let mut t = Table::new(&["Dataset", "SIDMM 1t / SGMM", "Skipper 1t / SGMM"]);
    let (mut ss, mut sk) = (Vec::new(), Vec::new());
    for m in metrics {
        let s = m.sidmm_wall_s / m.sgmm_wall_s;
        let k = m.skipper_wall_1t_s / m.sgmm_wall_s;
        ss.push(s);
        sk.push(k);
        t.row(&[
            m.spec.paper_name.into(),
            format!("{s:.1}"),
            format!("{k:.2}"),
        ]);
    }
    format!(
        "Fig 11 — serial slowdown, measured (paper: SIDMM 7.3-16.8 gm 10.7, Skipper 1.1-2.2 gm 1.4)\n{}\ngeomeans: SIDMM {:.1}  Skipper {:.2}\n",
        t.render(),
        geomean(&ss).unwrap_or(f64::NAN),
        geomean(&sk).unwrap_or(f64::NAN)
    )
}

/// Streaming ingest→match vs materialized CSR (beyond the paper: the
/// semi-external regime). For every suite dataset: match once from the
/// in-memory CSR through the block-scheduler driver, and once streamed
/// chunk-by-chunk from the on-disk `.skg` cache through the
/// [`crate::matching::streaming::StreamingSkipper`] pipeline — comparing
/// wall time and peak topology-resident bytes, and verifying the streamed
/// matching against the materialized graph.
pub fn stream_vs_csr(scale: Scale, cache_dir: &str, threads: usize) -> Result<String, String> {
    use crate::graph::stream::SkgEdgeSource;
    use crate::matching::streaming::StreamingSkipper;
    let mut t = Table::new(&[
        "Dataset", "|V|", "slots", "CSR(s)", "Stream(s)", "CSR bytes", "Stream peak", "mem ratio",
        "|M| csr/stream",
    ]);
    let mut ratios = Vec::new();
    for spec in &SUITE {
        let (g, path) =
            crate::coordinator::datasets::generate_cached_path(spec, scale, cache_dir)?;
        let (m_csr, csr_s) = wall(|| Skipper::new(threads).run(&g));
        let sk = StreamingSkipper::new(threads);
        let (rep, stream_s) = {
            let source = SkgEdgeSource::open(&path)?;
            let t0 = Instant::now();
            let rep = sk.run(source)?;
            (rep, t0.elapsed().as_secs_f64())
        };
        verify::check(&g, &rep.matching).map_err(|e| format!("{}: streamed matching: {e}", spec.name))?;
        let csr_b = g.memory_bytes();
        let st_b = rep.peak_topology_bytes();
        let ratio = csr_b as f64 / st_b.max(1) as f64;
        ratios.push(ratio);
        t.row(&[
            spec.paper_name.into(),
            g.num_vertices().to_string(),
            g.num_edge_slots().to_string(),
            format!("{csr_s:.4}"),
            format!("{stream_s:.4}"),
            csr_b.to_string(),
            st_b.to_string(),
            format!("{ratio:.1}x"),
            format!("{}/{}", m_csr.len(), rep.matching.len()),
        ]);
    }
    Ok(format!(
        "Streaming ingest→match vs materialized CSR (real t={threads}; streamed matchings verified maximal)\n{}\ngeomean topology-memory reduction: {:.1}x\n",
        t.render(),
        geomean(&ratios).unwrap_or(f64::NAN)
    ))
}

/// Fully dynamic churn experiment (`experiment dynamic`): for each synthetic
/// generator family, run a warmup + 50/50 insert/delete churn schedule
/// through the [`crate::dynamic::DynamicMatcher`], verifying maximality over
/// the live edge set after **every** epoch, and report how much repair work
/// deletions caused as a fraction of the live graph — the "no global
/// recompute" claim, measured.
pub fn dynamic_churn(scale: Scale, threads: usize) -> Result<String, String> {
    use crate::dynamic::churn::{run_churn, ChurnConfig, ChurnGen};
    // log2 of the per-family vertex count at each suite scale
    let exp: u32 = match scale {
        Scale::Tiny => 10,
        Scale::Small => 13,
        Scale::Medium => 16,
        Scale::Large => 19,
    };
    let n = 1usize << exp;
    let fams = [
        ChurnGen::Er { n, m: 8 * n },
        ChurnGen::Ba { n, m_per_vertex: 4 },
        ChurnGen::Grid {
            rows: 1 << exp.div_ceil(2),
            cols: 1 << (exp / 2),
        },
        ChurnGen::Rmat { scale: exp, avg_degree: 8 },
    ];
    let mut t = Table::new(&[
        "Generator", "|V|", "live |E|", "epochs", "batch", "destroyed", "repair frac (mean)",
        "repair frac (max)", "|M|", "verified",
    ]);
    for gen in fams {
        let cfg = ChurnConfig {
            epochs: 8,
            batch: (n / 8).max(64),
            delete_frac: 0.5,
            warmup_epochs: 4,
            threads,
            verify: true,
            ..ChurnConfig::new(gen)
        };
        let summary = run_churn(&cfg, |_| {})
            .map_err(|e| format!("{} churn failed: {e}", gen.name()))?;
        t.row(&[
            gen.name().into(),
            gen.num_vertices().to_string(),
            summary.final_live_edges.to_string(),
            format!("{}+{}", summary.warmup_epochs, summary.epochs),
            cfg.batch.to_string(),
            summary.destroyed_pairs.to_string(),
            format!("{:.4}", summary.repair_frac_mean),
            format!("{:.4}", summary.repair_frac_max),
            (summary.final_matched_vertices / 2).to_string(),
            format!("{}/{} epochs", summary.verified_epochs,
                summary.warmup_epochs + summary.epochs),
        ]);
    }
    Ok(format!(
        "Fully dynamic churn — 50/50 insert/delete epochs, maximality verified over the LIVE edge set after every epoch (t={threads})\n{}\nrepair fraction = repaired edges / live edges per epoch; ≪ 1 means deletions cost only their neighborhoods, never a recompute\n",
        t.render()
    ))
}

/// Shard-count scaling experiment (`experiment scale`): the same RMAT churn
/// schedule driven through the vertex-partitioned engine at
/// `engine_shards ∈ {1, 2, 4, 8}`, with maximality verified over the live
/// set after every epoch. Reports epoch throughput and — the point of the
/// sharding refactor — the mutate-phase wall time, which was the engine's
/// only serial phase before vertex partitioning.
pub fn shard_scale(scale: Scale, threads: usize) -> Result<String, String> {
    use crate::dynamic::churn::{run_churn, ChurnConfig, ChurnGen};
    use crate::dynamic::AdjLayout;
    use crate::util::stats::percentile;
    let exp: u32 = match scale {
        Scale::Tiny => 10,
        Scale::Small => 13,
        Scale::Medium => 16,
        Scale::Large => 19,
    };
    let n = 1usize << exp;
    let gen = ChurnGen::Rmat { scale: exp, avg_degree: 8 };
    // Two batch regimes: the large batch shows throughput scaling with P,
    // the small batch is where per-epoch dispatch cost dominates — exactly
    // the regime the persistent pool exists for, so spawn-vs-run is
    // reported for both dispatch policies side by side.
    let mut t = Table::new(&[
        "shards", "workers", "batch", "epochs", "updates/s", "epoch p50 ms",
        "mutate p50 ms", "run p50 ms", "spawn ovh p50 ms", "mutate share",
        "repair frac (mean)", "|M|", "verified",
    ]);
    // `large` ≥ 512 keeps the two regimes ordered even at Scale::Tiny
    // (n=1024, where n/8 would undercut the small batch).
    for &batch in &[(n / 8).max(512), 128] {
        for shards in [1usize, 2, 4, 8] {
            for pool in [false, true] {
                let cfg = ChurnConfig {
                    epochs: 6,
                    batch,
                    delete_frac: 0.5,
                    warmup_epochs: 3,
                    threads,
                    engine_shards: shards,
                    pool,
                    verify: true,
                    ..ChurnConfig::new(gen)
                };
                let summary = run_churn(&cfg, |_| {}).map_err(|e| {
                    format!("scale P={shards} {} churn failed: {e}", cfg.shard_exec().name())
                })?;
                let wall: f64 = summary.epoch_wall_s.iter().sum();
                let mutate: f64 = summary.epoch_mutate_s.iter().sum();
                let spawn_overhead: Vec<f64> = summary
                    .epoch_mutate_s
                    .iter()
                    .zip(summary.epoch_mutate_run_s.iter())
                    .map(|(w, r)| (w - r).max(0.0))
                    .collect();
                let updates = (summary.epochs * cfg.batch) as f64;
                t.row(&[
                    shards.to_string(),
                    cfg.shard_exec().name().to_string(),
                    cfg.batch.to_string(),
                    format!("{}+{}", summary.warmup_epochs, summary.epochs),
                    format!("{:.0}", updates / wall.max(1e-9)),
                    format!("{:.2}", percentile(&summary.epoch_wall_s, 50.0) * 1e3),
                    format!("{:.2}", percentile(&summary.epoch_mutate_s, 50.0) * 1e3),
                    format!("{:.2}", percentile(&summary.epoch_mutate_run_s, 50.0) * 1e3),
                    format!("{:.3}", percentile(&spawn_overhead, 50.0) * 1e3),
                    format!("{:.1}%", 100.0 * mutate / wall.max(1e-9)),
                    format!("{:.4}", summary.repair_frac_mean),
                    (summary.final_matched_vertices / 2).to_string(),
                    format!(
                        "{}/{} epochs",
                        summary.verified_epochs,
                        summary.warmup_epochs + summary.epochs
                    ),
                ]);
            }
        }
    }
    // Adjacency layout sweep at the acceptance point of the blocked-arena
    // work: P=8, persistent pool, large batch. Same schedule for every
    // layout (identical seed + config apart from storage), so throughput
    // deltas are attributable to cache behaviour alone.
    let mut lt = Table::new(&[
        "layout", "batch", "updates/s", "epoch p50 ms", "mutate p50 ms",
        "adj MB", "verified",
    ]);
    for layout in [
        AdjLayout::Flat,
        AdjLayout::Blocked { block_bytes: 64 },
        AdjLayout::Blocked { block_bytes: 128 },
        AdjLayout::Blocked { block_bytes: 256 },
    ] {
        let cfg = ChurnConfig {
            epochs: 6,
            batch: (n / 8).max(512),
            delete_frac: 0.5,
            warmup_epochs: 3,
            threads,
            engine_shards: 8,
            pool: true,
            layout,
            verify: true,
            ..ChurnConfig::new(gen)
        };
        let summary = run_churn(&cfg, |_| {})
            .map_err(|e| format!("scale layout={} churn failed: {e}", layout.name()))?;
        let wall: f64 = summary.epoch_wall_s.iter().sum();
        let updates = (summary.epochs * cfg.batch) as f64;
        lt.row(&[
            layout.name(),
            cfg.batch.to_string(),
            format!("{:.0}", updates / wall.max(1e-9)),
            format!("{:.2}", percentile(&summary.epoch_wall_s, 50.0) * 1e3),
            format!("{:.2}", percentile(&summary.epoch_mutate_s, 50.0) * 1e3),
            format!("{:.1}", summary.final_adjacency_bytes as f64 / 1e6),
            format!(
                "{}/{} epochs",
                summary.verified_epochs,
                summary.warmup_epochs + summary.epochs
            ),
        ]);
    }
    // Topology-pinning sweep at the same acceptance point: P=8 pool
    // workers, large batch, one row per pin policy. Same schedule per row,
    // so |M| must be identical — placement moves memory, never decisions.
    // On the single-node CI host the rows differ only in pinned-worker
    // count; on a multi-socket box the compact/spread deltas are the
    // experiment.
    let topo = crate::par::topology::Topology::discover();
    let mut pt = Table::new(&[
        "pin", "batch", "updates/s", "epoch p50 ms", "mutate p50 ms",
        "|M|", "verified",
    ]);
    use crate::dynamic::PinPolicy;
    let mut pin_matchings = Vec::new();
    for pin in [PinPolicy::None, PinPolicy::Compact, PinPolicy::Spread] {
        let cfg = ChurnConfig {
            epochs: 6,
            batch: (n / 8).max(512),
            delete_frac: 0.5,
            warmup_epochs: 3,
            threads,
            engine_shards: 8,
            pool: true,
            pin,
            verify: true,
            ..ChurnConfig::new(gen)
        };
        let summary = run_churn(&cfg, |_| {})
            .map_err(|e| format!("scale pin={} churn failed: {e}", pin.name()))?;
        let wall: f64 = summary.epoch_wall_s.iter().sum();
        let updates = (summary.epochs * cfg.batch) as f64;
        pin_matchings.push(summary.final_matched_vertices);
        pt.row(&[
            pin.name().to_string(),
            cfg.batch.to_string(),
            format!("{:.0}", updates / wall.max(1e-9)),
            format!("{:.2}", percentile(&summary.epoch_wall_s, 50.0) * 1e3),
            format!("{:.2}", percentile(&summary.epoch_mutate_s, 50.0) * 1e3),
            (summary.final_matched_vertices / 2).to_string(),
            format!(
                "{}/{} epochs",
                summary.verified_epochs,
                summary.warmup_epochs + summary.epochs
            ),
        ]);
    }
    if pin_matchings.windows(2).any(|w| w[0] != w[1]) {
        return Err(format!(
            "pin policies diverged on the same schedule: {pin_matchings:?}"
        ));
    }
    Ok(format!(
        "Engine-shard scaling — identical rmat 50/50 churn at engine_shards ∈ {{1,2,4,8}} × workers ∈ {{fork,pool}}, |V|={n} (t={threads}; maximality verified after every epoch)\n{}\nmutate share = parallel per-shard mutate phase / epoch wall; before sharding this phase was single-threaded.\nspawn ovh = mutate wall − longest per-shard run: per-epoch thread spawn+join cost for forked workers, doorbell wake + countdown for the persistent pool — the small-batch rows are where the pool earns its keep\n\nAdjacency layout sweep at P=8 pool workers, same rmat schedule per row — flat per-vertex Vecs vs the cache-line block arena at three block sizes:\n{}\nadj MB = resident adjacency bytes after the final epoch (blocked rows include recycled free-list blocks; flat is live Vec capacity)\n\nTopology-pinning sweep at P=8 pool workers on {} NUMA node(s) / {} CPU(s), same rmat schedule per row — shard workers pinned per policy, arenas and partner[] stripes first-touched socket-local, block slabs advised MADV_HUGEPAGE:\n{}\nidentical |M| across rows is asserted: placement changes timings only, never matching decisions\n",
        t.render(),
        lt.render(),
        topo.num_nodes(),
        topo.num_cpus(),
        pt.render()
    ))
}

/// Durability experiment (`experiment durability`), two questions:
///
/// 1. **Logging overhead** — identical rmat 50/50 churn epochs through the
///    engine with the WAL off / buffered / fsync'd per record, reporting
///    update throughput, epoch p50, logged bytes, and the slowdown vs the
///    volatile baseline.
/// 2. **Recovery time vs WAL length** — snapshot a warmed engine once, log
///    `K` further churn epochs, "crash", and time a cold
///    [`crate::persist::recovery::recover`] (snapshot restore + WAL replay +
///    maximality audit) into a fresh engine.
pub fn durability(scale: Scale, threads: usize) -> Result<String, String> {
    use crate::dynamic::churn::{recycle_batch, ChurnGen};
    use crate::dynamic::{ShardedDynamicMatcher, Update};
    use crate::persist::recovery;
    use crate::persist::snapshot::{self, SnapshotData};
    use crate::persist::wal::{Wal, WalOptions};
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats::percentile;

    let exp: u32 = match scale {
        Scale::Tiny => 10,
        Scale::Small => 13,
        Scale::Medium => 16,
        Scale::Large => 19,
    };
    let n = 1usize << exp;
    let gen = ChurnGen::Rmat { scale: exp, avg_degree: 8 };
    let population = gen.population(17);
    let batch = (n / 8).max(256);
    let epochs = 8usize;
    let base =
        std::env::temp_dir().join(format!("skipper_durability_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).map_err(|e| format!("mkdir {}: {e}", base.display()))?;

    let warm_engine = || -> Result<ShardedDynamicMatcher, String> {
        let engine = ShardedDynamicMatcher::new(n, threads, 1);
        let ups: Vec<Update> =
            population.iter().map(|&(u, v)| Update::Insert(u, v)).collect();
        engine.apply_epoch(&ups)?;
        Ok(engine)
    };

    // --- (1) logging overhead: off vs buffered vs fsync vs group fsync ---
    // fsync-group4 models a flusher that drains 4 coalesced epochs per
    // durable group: 4 records, one `sync_data` (Wal::append_epochs) — the
    // WAL-before-apply invariant holds for the whole group.
    let mut t = Table::new(&[
        "wal", "epochs", "batch", "updates/s", "epoch p50 ms", "wal MB", "slowdown vs off",
    ]);
    let mut off_updates_s = 0.0f64;
    let mut fsync_updates_s = 0.0f64;
    let mut group_updates_s = 0.0f64;
    for mode in ["off", "buffered", "fsync", "fsync-group4"] {
        let engine = warm_engine()?;
        let live: Vec<(u32, u32)> = engine.live_edges();
        let mut rng = Xoshiro256pp::new(23);
        let mut wal = match mode {
            "off" => None,
            _ => {
                let opts =
                    WalOptions { fsync: mode.starts_with("fsync"), ..WalOptions::default() };
                Some(Wal::open(&base.join(format!("wal_{mode}")), opts)?.0)
            }
        };
        let group = if mode == "fsync-group4" { 4usize } else { 1 };
        let mut epoch_s = Vec::new();
        for g in 0..epochs / group {
            let batches: Vec<Vec<Update>> = (0..group)
                .map(|j| recycle_batch(&live, &mut rng, g * group + j, batch))
                .collect();
            let t0 = Instant::now();
            if let Some(w) = wal.as_mut() {
                let next = engine.epochs_applied() + 1;
                if group == 1 {
                    w.append_epoch(next, &batches[0])?;
                } else {
                    let recs: Vec<(u64, &[Update])> = batches
                        .iter()
                        .enumerate()
                        .map(|(j, b)| (next + j as u64, b.as_slice()))
                        .collect();
                    w.append_epochs(&recs)?;
                }
            }
            for b in &batches {
                engine.apply_epoch(b)?;
            }
            // per-epoch figure either way, so rows stay comparable
            epoch_s.push(t0.elapsed().as_secs_f64() / group as f64);
        }
        engine.verify()?;
        let wall: f64 = epoch_s.iter().sum::<f64>() * group as f64;
        let updates_s = (epochs * batch) as f64 / wall.max(1e-9);
        match mode {
            "off" => off_updates_s = updates_s,
            "fsync" => fsync_updates_s = updates_s,
            "fsync-group4" => group_updates_s = updates_s,
            _ => {}
        }
        let wal_mb =
            wal.as_ref().map_or(0.0, |w| w.bytes_appended() as f64 / 1e6);
        t.row(&[
            mode.into(),
            epochs.to_string(),
            batch.to_string(),
            format!("{updates_s:.0}"),
            format!("{:.2}", percentile(&epoch_s, 50.0) * 1e3),
            format!("{wal_mb:.2}"),
            if mode == "off" {
                "1.00x".into()
            } else {
                format!("{:.2}x", off_updates_s / updates_s.max(1e-9))
            },
        ]);
    }
    let group_delta = group_updates_s / fsync_updates_s.max(1e-9);

    // --- (2) recovery time vs WAL length ---------------------------------
    let mut r = Table::new(&[
        "wal epochs", "updates replayed", "snapshot MB", "recover ms", "recovered",
    ]);
    for k in [2usize, 8, 32] {
        let dir = base.join(format!("recover_{k}"));
        let snap_dir = recovery::snapshot_dir(&dir);
        std::fs::create_dir_all(&snap_dir)
            .map_err(|e| format!("mkdir {}: {e}", snap_dir.display()))?;
        let engine = warm_engine()?;
        let snap = SnapshotData::capture(&engine);
        let snap_bytes = snapshot::write_file(
            &snap_dir.join(snapshot::file_name(snap.epoch)),
            &snap,
        )?;
        let live: Vec<(u32, u32)> = engine.live_edges();
        let mut rng = Xoshiro256pp::new(29);
        let (mut wal, _) =
            Wal::open(&recovery::wal_dir(&dir), WalOptions::default())?;
        let mut replayed_updates = 0usize;
        for e in 0..k {
            let ups = recycle_batch(&live, &mut rng, e, batch);
            replayed_updates += ups.len();
            wal.append_epoch(engine.epochs_applied() + 1, &ups)?;
            engine.apply_epoch(&ups)?;
        }
        drop(wal);
        drop(engine); // the crash: no final snapshot, WAL left as-is
        let fresh = ShardedDynamicMatcher::new(n, threads, 1);
        let t0 = Instant::now();
        let (_, report) = recovery::recover(&fresh, &dir, WalOptions::default())?;
        let recover_s = t0.elapsed().as_secs_f64();
        r.row(&[
            k.to_string(),
            replayed_updates.to_string(),
            format!("{:.2}", snap_bytes as f64 / 1e6),
            format!("{:.2}", recover_s * 1e3),
            format!(
                "snap@{} + {} epochs, maximal",
                report.snapshot_epoch.unwrap_or(0),
                report.replayed_epochs
            ),
        ]);
    }
    let _ = std::fs::remove_dir_all(&base);
    Ok(format!(
        "Durability — WAL logging overhead and crash-recovery cost (rmat |V|={n}, t={threads})\n{}\nrecovery = newest valid snapshot restore + WAL replay through real engine epochs + maximality audit\n{}\nbuffered = flushed to the OS per epoch; fsync = forced to media per epoch (the power-loss-safe mode)\nfsync-group4 = 4 coalesced epochs per sync_data (Wal::append_epochs): {group_delta:.2}x the per-epoch fsync write throughput\n",
        t.render(),
        r.render()
    ))
}

/// Cross-layer experiment: the XLA-backed (L1 Pallas + L2 JAX) EMS matcher
/// vs Skipper and SGMM on padded small graphs. Requires `make artifacts`.
pub fn xla_ems(cache_dir: &str) -> Result<String, String> {
    use crate::graph::gen::{erdos_renyi, rmat, GenConfig};
    let matcher = crate::runtime::XlaEmsMatcher::from_default_artifacts()
        .map_err(|e| format!("{e:#}"))?;
    let cases: Vec<(&str, CsrGraph)> = vec![
        ("rmat-v256", rmat::generate(&GenConfig { scale: 8, avg_degree: 3, seed: 21 })),
        ("er-v1024", erdos_renyi::generate(1024, 1800, 22)),
        ("rmat-v4096", rmat::generate(&GenConfig { scale: 12, avg_degree: 3, seed: 23 })),
    ];
    let _ = cache_dir;
    let mut t = Table::new(&["Graph", "|V|", "|E|", "XLA-EMS(s)", "rounds", "Skipper(s)", "SGMM(s)", "|M| xla/skip"]);
    for (name, g) in &cases {
        let (xm, xla_s) = wall(|| matcher.match_graph(g).expect("xla run"));
        let (sk, sk_s) = wall(|| Skipper::new(2).run(g));
        let (sg, sg_s) = wall(|| Sgmm.run(g));
        verify::check(g, &xm.0).expect("xla matching invalid");
        verify::check(g, &sk).expect("skipper matching invalid");
        let _ = sg;
        t.row(&[
            name.to_string(),
            g.num_vertices().to_string(),
            (g.num_edge_slots() / 2).to_string(),
            format!("{xla_s:.4}"),
            xm.1.to_string(),
            format!("{sk_s:.4}"),
            format!("{sg_s:.4}"),
            format!("{}/{}", xm.0.len(), sk.len()),
        ]);
    }
    Ok(format!(
        "Cross-layer — AOT XLA (L1 Pallas + L2 JAX EMS) vs L3 Skipper (all layers compose)\n{}",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::datasets::spec_by_name;

    fn tiny_metrics() -> DatasetMetrics {
        let dir = std::env::temp_dir().join("skipper_exp_test");
        collect_dataset(
            spec_by_name("twitter10s").unwrap(),
            Scale::Tiny,
            dir.to_str().unwrap(),
            2,
        )
    }

    #[test]
    fn collect_and_render_all() {
        let m = vec![tiny_metrics()];
        let cost = CostModel::default();
        for s in [
            table1(&m, &cost),
            table2(&m),
            fig3(&m, &cost),
            fig7(&m),
            fig8(&m),
            fig9(&m, &cost),
            fig10(&m, &cost),
            fig11(&m),
        ] {
            assert!(s.contains("twitter10"), "missing dataset row in: {s}");
        }
    }

    #[test]
    fn dynamic_churn_renders_all_families_verified() {
        let s = dynamic_churn(Scale::Tiny, 2).unwrap();
        for fam in ["er", "ba", "grid", "rmat"] {
            assert!(s.contains(fam), "missing {fam} row in: {s}");
        }
        assert!(s.contains("12/12 epochs"), "unverified epochs in: {s}");
        assert!(s.contains("repair fraction"), "{s}");
    }

    #[test]
    fn shard_scale_renders_all_shard_counts_verified() {
        let s = shard_scale(Scale::Tiny, 2).unwrap();
        // one fully verified row per (batch, shard count, worker mode),
        // plus the four adjacency-layout sweep rows and the three
        // pin-policy sweep rows at P=8
        assert_eq!(
            s.matches("9/9 epochs").count(),
            23,
            "expected 2 batches × 4 shard counts × 2 worker modes + 4 layout rows + 3 pin rows in: {s}"
        );
        assert!(s.contains("engine_shards"), "{s}");
        assert!(s.contains("mutate share"), "{s}");
        assert!(s.contains("spawn ovh"), "{s}");
        assert!(s.contains("fork"), "{s}");
        assert!(s.contains("pool"), "{s}");
        // layout sweep rows: flat baseline plus blocked at three block sizes
        assert!(s.contains("flat"), "{s}");
        assert!(s.contains("blocked64"), "{s}");
        assert!(s.contains("blocked256"), "{s}");
        // pin sweep rows: one per policy, identical |M| asserted inside
        assert!(s.contains("Topology-pinning sweep"), "{s}");
        assert!(s.contains("compact"), "{s}");
        assert!(s.contains("spread"), "{s}");
    }

    #[test]
    fn durability_renders_modes_and_recovery_rows() {
        let s = durability(Scale::Tiny, 2).unwrap();
        for mode in ["off", "buffered", "fsync", "fsync-group4"] {
            assert!(s.contains(mode), "missing {mode} row in: {s}");
        }
        assert!(s.contains("slowdown vs off"), "{s}");
        assert!(s.contains("coalesced epochs per sync_data"), "{s}");
        assert!(s.contains("recover ms"), "{s}");
        assert_eq!(
            s.matches("maximal").count(),
            4,
            "3 recovery rows verified + legend in: {s}"
        );
    }

    #[test]
    fn stream_vs_csr_renders_all_datasets() {
        let dir = std::env::temp_dir().join("skipper_stream_exp_test");
        let s = stream_vs_csr(Scale::Tiny, dir.to_str().unwrap(), 2).unwrap();
        for spec in &SUITE {
            assert!(s.contains(spec.paper_name), "missing {}", spec.paper_name);
        }
        assert!(s.contains("memory reduction"), "{s}");
    }

    #[test]
    fn shape_claims_hold_on_tiny() {
        let m = tiny_metrics();
        // SIDMM does much more work than SGMM; Skipper stays near SGMM.
        assert!(m.sidmm_accesses > 5 * m.sgmm_accesses);
        assert!(m.skipper_accesses_1t < 3 * m.sgmm_accesses.max(1) * 10);
        // Skipper's simulated 64t time beats SIDMM's.
        let cost = CostModel::default();
        assert!(m.skipper_par_seconds(&cost, 64) < m.sidmm_par_seconds(&cost, 64));
    }
}
