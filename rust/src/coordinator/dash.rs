//! Static HTML perf dashboard: render every committed `BENCH_*.json`
//! trajectory as inline-SVG sparkline panels, with an optional live
//! snapshot section from a Prometheus exposition.
//!
//! Dependency-free by construction (no JS frameworks, no external assets,
//! no script tags at all): the output of [`render_dash`] is one
//! self-contained HTML file whose only moving parts are `<svg>` elements —
//! it renders identically from `file://`, a CI artifact store, or a
//! git-hosted preview.
//!
//! **Determinism invariant**: the rendered bytes are a pure function of the
//! input registries and live text. Metric names and config hashes iterate
//! in sorted order, colors come from a fixed palette assigned by sorted
//! hash position, and no wall-clock value is read at render time — the same
//! inputs always produce byte-identical HTML (asserted by
//! `tests/prop_obs.rs`), so CI can diff dashboards like any other artifact.
//!
//! Panel anatomy, per bench × metric:
//!
//! * one polyline per config hash (runs of different configs are never
//!   visually merged, mirroring the gate's comparison rule);
//! * for gated metric kinds (`*_per_s`, `*_s`), a shaded horizontal band at
//!   the newest committed value ± the gate threshold — a run drifting out
//!   of the band is what `report --gate` would fail;
//! * a `data-bench` attribute for CI smoke greps (`grep 'data-bench="..."'`
//!   proves every committed trajectory made it into the artifact).
//!
//! The live section parses the exposition text shallowly: scalar samples
//! become a table, histogram `_bucket` lines are summarized, and exemplars
//! (`# {span_id="..."} v ts`, see [`crate::obs::metrics`]) on latency
//! families are listed as annotations linking buckets to trace spans.

use super::registry::{MetricKind, Registry, DEFAULT_THRESHOLD};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A live metrics exposition to append under the trajectories.
#[derive(Clone, Debug)]
pub struct LiveSource {
    /// Where the exposition came from (a file path or `host:port`) —
    /// rendered in the section heading.
    pub origin: String,
    /// The raw Prometheus text exposition.
    pub text: String,
}

/// Fixed series palette; config hashes map onto it by sorted position.
const PALETTE: [&str; 8] = [
    "#2563eb", "#dc2626", "#16a34a", "#9333ea", "#ea580c", "#0891b2", "#4d7c0f", "#be185d",
];

/// Sparkline geometry (viewBox units).
const SVG_W: f64 = 560.0;
/// Sparkline height (viewBox units).
const SVG_H: f64 = 96.0;
/// Inner padding keeping strokes off the frame.
const PAD: f64 = 10.0;

/// Escape a string for HTML text/attribute positions.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' => out.push_str("&amp;"),
            '<' => out.push_str("&lt;"),
            '>' => out.push_str("&gt;"),
            '"' => out.push_str("&quot;"),
            c => out.push(c),
        }
    }
    out
}

/// Shortest stable rendering of a metric value for labels.
fn fmt_val(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else if v.abs() >= 1000.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.6}")
    }
}

/// Render the full dashboard document.
pub fn render_dash(registries: &[Registry], live: Option<&LiveSource>) -> String {
    let mut out = String::new();
    out.push_str(
        "<!DOCTYPE html>\n<html lang=\"en\">\n<head>\n<meta charset=\"utf-8\">\n\
         <title>skipper perf dashboard</title>\n<style>\n\
         body{font:14px/1.5 system-ui,sans-serif;margin:2rem auto;max-width:64rem;\
         padding:0 1rem;color:#111}\n\
         h1{font-size:1.4rem} h2{font-size:1.15rem;margin-top:2rem;\
         border-bottom:1px solid #ddd;padding-bottom:.25rem}\n\
         h3{font-size:.95rem;margin:.9rem 0 .25rem}\n\
         .kind{font-weight:normal;color:#666;font-size:.8rem;margin-left:.5rem}\n\
         .legend{font-size:.8rem;color:#444;margin:.25rem 0}\n\
         .legend b{font-family:monospace;font-weight:normal}\n\
         svg.sparkline{display:block;background:#fafafa;border:1px solid #e5e5e5;\
         border-radius:4px}\n\
         table{border-collapse:collapse;font-size:.85rem}\n\
         td,th{border:1px solid #ddd;padding:.15rem .5rem;text-align:left}\n\
         td.num{text-align:right;font-variant-numeric:tabular-nums}\n\
         ul.exemplars{font-size:.85rem} ul.exemplars code{background:#f3f3f3;\
         padding:0 .25rem;border-radius:3px}\n\
         .empty{color:#666;font-style:italic}\n\
         .origin{font-weight:normal;color:#666;font-size:.8rem;margin-left:.5rem}\n\
         </style>\n</head>\n<body>\n<h1>skipper perf dashboard</h1>\n",
    );
    if registries.is_empty() {
        out.push_str("<p class=\"empty\">No BENCH_*.json registries found.</p>\n");
    }
    for reg in registries {
        render_bench(&mut out, reg);
    }
    if let Some(live) = live {
        render_live(&mut out, live);
    }
    out.push_str("</body>\n</html>\n");
    out
}

/// One bench section: legend of config hashes plus a sparkline panel per
/// metric the trajectory has ever recorded.
fn render_bench(out: &mut String, reg: &Registry) {
    let _ = writeln!(out, "<h2 id=\"bench-{0}\">{0}</h2>", esc(&reg.bench));
    if reg.runs.is_empty() {
        // keep a greppable (empty) sparkline so CI sees the trajectory
        let _ = writeln!(
            out,
            "<p class=\"empty\">No committed runs yet.</p>\n\
             <svg class=\"sparkline\" data-bench=\"{}\" width=\"{SVG_W}\" height=\"{SVG_H}\" \
             viewBox=\"0 0 {SVG_W} {SVG_H}\"></svg>",
            esc(&reg.bench)
        );
        return;
    }
    // sorted config hashes -> palette slots; sorted order keeps the color
    // assignment independent of run order
    let mut hashes: Vec<String> = reg.runs.iter().map(|r| r.config_hash()).collect();
    hashes.sort_unstable();
    hashes.dedup();
    let color_of = |hash: &str| -> &'static str {
        let idx = hashes.iter().position(|h| h == hash).unwrap_or(0);
        PALETTE[idx % PALETTE.len()]
    };
    out.push_str("<p class=\"legend\">");
    for (i, h) in hashes.iter().enumerate() {
        let runs = reg.runs.iter().filter(|r| &r.config_hash() == h).count();
        if i > 0 {
            out.push_str(" &middot; ");
        }
        let _ = write!(
            out,
            "<span style=\"color:{}\">&#9632;</span> config <b>{}</b> ({} run{})",
            color_of(h),
            esc(h),
            runs,
            if runs == 1 { "" } else { "s" }
        );
    }
    out.push_str("</p>\n");
    // every metric this trajectory has ever recorded, sorted
    let mut metric_names: Vec<&str> = Vec::new();
    for run in &reg.runs {
        for name in run.metrics.keys() {
            if !metric_names.contains(&name.as_str()) {
                metric_names.push(name);
            }
        }
    }
    metric_names.sort_unstable();
    for metric in metric_names {
        render_metric_panel(out, reg, metric, &color_of);
    }
}

/// The sparkline panel of one metric over one trajectory.
fn render_metric_panel(
    out: &mut String,
    reg: &Registry,
    metric: &str,
    color_of: &dyn Fn(&str) -> &'static str,
) {
    let kind = MetricKind::of(metric);
    let kind_label = match kind {
        MetricKind::Exact => "exact (bit-for-bit gated)",
        MetricKind::HigherIsBetter => "throughput (higher is better)",
        MetricKind::LowerIsBetter => "wall time (lower is better)",
        MetricKind::Advisory => "advisory (not gated)",
    };
    // (run index, config hash, value) for every run carrying this metric
    let points: Vec<(usize, String, f64)> = reg
        .runs
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.metrics.get(metric).map(|v| (i, r.config_hash(), *v)))
        .collect();
    if points.is_empty() {
        return;
    }
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (_, _, v) in &points {
        lo = lo.min(*v);
        hi = hi.max(*v);
    }
    // pad a flat series so the single line doesn't sit on the frame
    if hi - lo < f64::EPSILON * hi.abs().max(1.0) {
        let pad = hi.abs().max(1.0) * 0.1;
        lo -= pad;
        hi += pad;
    }
    let span = hi - lo;
    let n_runs = reg.runs.len();
    let x_of = |i: usize| -> f64 {
        if n_runs <= 1 {
            SVG_W / 2.0
        } else {
            PAD + (SVG_W - 2.0 * PAD) * i as f64 / (n_runs - 1) as f64
        }
    };
    let y_of = |v: f64| -> f64 { PAD + (SVG_H - 2.0 * PAD) * (1.0 - (v - lo) / span) };
    let _ = writeln!(
        out,
        "<h3>{} <span class=\"kind\">{}</span></h3>\n\
         <svg class=\"sparkline\" data-bench=\"{}\" data-metric=\"{}\" width=\"{SVG_W}\" \
         height=\"{SVG_H}\" viewBox=\"0 0 {SVG_W} {SVG_H}\" role=\"img\" \
         aria-label=\"{} trajectory\">",
        esc(metric),
        kind_label,
        esc(&reg.bench),
        esc(metric),
        esc(metric),
    );
    // gate band: what report --gate would tolerate around the newest
    // committed value (drawn first, under the series)
    if matches!(kind, MetricKind::HigherIsBetter | MetricKind::LowerIsBetter) {
        let newest = points.last().map(|(_, _, v)| *v).unwrap_or(0.0);
        let band_lo = (newest * (1.0 - DEFAULT_THRESHOLD)).max(lo);
        let band_hi = (newest * (1.0 + DEFAULT_THRESHOLD)).min(hi);
        if band_hi > band_lo {
            let y_top = y_of(band_hi);
            let h = y_of(band_lo) - y_top;
            let _ = writeln!(
                out,
                "<rect class=\"gate-band\" x=\"{PAD:.1}\" y=\"{y_top:.1}\" \
                 width=\"{:.1}\" height=\"{h:.1}\" fill=\"#d1fae5\" opacity=\"0.7\">\
                 <title>gate band: newest &plusmn;{:.0}%</title></rect>",
                SVG_W - 2.0 * PAD,
                DEFAULT_THRESHOLD * 100.0
            );
        }
    }
    // one series per config hash, in sorted-hash order (stable bytes)
    let mut by_hash: BTreeMap<&str, Vec<(usize, f64)>> = BTreeMap::new();
    for (i, h, v) in &points {
        by_hash.entry(h.as_str()).or_default().push((*i, *v));
    }
    for (hash, series) in &by_hash {
        let color = color_of(hash);
        if series.len() > 1 {
            let coords: Vec<String> = series
                .iter()
                .map(|(i, v)| format!("{:.1},{:.1}", x_of(*i), y_of(*v)))
                .collect();
            let _ = writeln!(
                out,
                "<polyline fill=\"none\" stroke=\"{color}\" stroke-width=\"1.5\" \
                 points=\"{}\"/>",
                coords.join(" ")
            );
        }
        for (i, v) in series {
            let _ = writeln!(
                out,
                "<circle cx=\"{:.1}\" cy=\"{:.1}\" r=\"2.5\" fill=\"{color}\">\
                 <title>run {}: {}</title></circle>",
                x_of(*i),
                y_of(*v),
                i + 1,
                fmt_val(*v)
            );
        }
    }
    // newest value, printed at the right edge
    if let Some((_, _, v)) = points.last() {
        let _ = writeln!(
            out,
            "<text x=\"{:.1}\" y=\"{:.1}\" font-size=\"10\" fill=\"#333\" \
             text-anchor=\"end\">{}</text>",
            SVG_W - 2.0,
            12.0,
            fmt_val(*v)
        );
    }
    out.push_str("</svg>\n");
}

/// One exemplar pulled off a histogram bucket line.
struct BucketExemplar {
    family: String,
    le: String,
    span_id: String,
    value: String,
}

/// Shallow exposition scan: scalar samples (name+labels → value), bucket
/// counts per family, and bucket exemplars.
struct LiveParse {
    scalars: Vec<(String, String)>,
    bucket_families: BTreeMap<String, u64>,
    exemplars: Vec<BucketExemplar>,
}

fn parse_live(text: &str) -> LiveParse {
    let mut out = LiveParse {
        scalars: Vec::new(),
        bucket_families: BTreeMap::new(),
        exemplars: Vec::new(),
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (sample, exemplar) = match line.split_once(" # ") {
            Some((s, e)) => (s.trim_end(), Some(e)),
            None => (line, None),
        };
        let name_end = sample.find(['{', ' ']).unwrap_or(sample.len());
        let name = &sample[..name_end];
        if let Some(family) = name.strip_suffix("_bucket") {
            *out.bucket_families.entry(family.to_string()).or_insert(0) += 1;
            if let Some(ex) = exemplar {
                let le = label_value(sample, "le").unwrap_or_default();
                let span_id = label_value(ex, "span_id").unwrap_or_default();
                // exemplar value: first token after the closing brace
                let value = ex
                    .split_once('}')
                    .map(|(_, rest)| rest.trim())
                    .and_then(|rest| rest.split_whitespace().next())
                    .unwrap_or("")
                    .to_string();
                out.exemplars.push(BucketExemplar {
                    family: family.to_string(),
                    le,
                    span_id,
                    value,
                });
            }
            continue;
        }
        // scalar sample: series id (name + labels) and the value token
        let series_end = match sample.find('{') {
            Some(b) => sample[b..].find('}').map(|e| b + e + 1).unwrap_or(sample.len()),
            None => name_end,
        };
        let series = &sample[..series_end];
        let value = sample[series_end..].split_whitespace().next().unwrap_or("");
        out.scalars.push((series.to_string(), value.to_string()));
    }
    out
}

/// First `key="…"` label value inside the braces of `s`.
fn label_value(s: &str, key: &str) -> Option<String> {
    let open = s.find('{')?;
    let close = s[open..].find('}')? + open;
    let body = &s[open + 1..close];
    for pair in body.split(',') {
        let (k, v) = pair.split_once('=')?;
        if k.trim() == key {
            return Some(v.trim().trim_matches('"').to_string());
        }
    }
    None
}

/// The live snapshot section: scalar table, bucket summary, exemplar
/// annotations on latency families.
fn render_live(out: &mut String, live: &LiveSource) {
    let parsed = parse_live(&live.text);
    let _ = writeln!(
        out,
        "<h2 id=\"live\">Live snapshot <span class=\"origin\">{}</span></h2>",
        esc(&live.origin)
    );
    if parsed.scalars.is_empty() && parsed.bucket_families.is_empty() {
        out.push_str("<p class=\"empty\">The exposition carried no samples.</p>\n");
        return;
    }
    if !parsed.scalars.is_empty() {
        out.push_str("<table>\n<tr><th>series</th><th>value</th></tr>\n");
        for (series, value) in &parsed.scalars {
            let _ = writeln!(
                out,
                "<tr><td><code>{}</code></td><td class=\"num\">{}</td></tr>",
                esc(series),
                esc(value)
            );
        }
        out.push_str("</table>\n");
    }
    if !parsed.bucket_families.is_empty() {
        out.push_str("<h3>Histograms</h3>\n<table>\n<tr><th>family</th><th>buckets</th></tr>\n");
        for (family, buckets) in &parsed.bucket_families {
            let _ = writeln!(
                out,
                "<tr><td><code>{}</code></td><td class=\"num\">{}</td></tr>",
                esc(family),
                buckets
            );
        }
        out.push_str("</table>\n");
    }
    if !parsed.exemplars.is_empty() {
        out.push_str(
            "<h3>Latency exemplars</h3>\n<p class=\"legend\">Each links a histogram bucket to \
             the span that produced its most recent sample (resolve the span id against a \
             TRACE dump or blackbox artifact).</p>\n<ul class=\"exemplars\">\n",
        );
        for ex in &parsed.exemplars {
            let _ = writeln!(
                out,
                "<li><code>{}</code> le={} value={} span_id=<code>{}</code></li>",
                esc(&ex.family),
                esc(&ex.le),
                esc(&ex.value),
                esc(&ex.span_id)
            );
        }
        out.push_str("</ul>\n");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::registry::BenchRecord;
    use std::collections::BTreeMap;

    fn rec(bench: &str, layout: &str, wall: f64, when: u64) -> BenchRecord {
        let mut config = BTreeMap::new();
        config.insert("layout".to_string(), layout.to_string());
        let mut metrics = BTreeMap::new();
        metrics.insert("epoch_wall_p50_s".to_string(), wall);
        metrics.insert("updates_per_s".to_string(), 1000.0 / wall);
        metrics.insert("exact_final_live_edges".to_string(), 2048.0);
        let mut r = BenchRecord::new(bench, config, metrics);
        r.recorded_unix_s = when; // pin: rendered HTML must not depend on now
        r
    }

    fn sample_registry() -> Registry {
        let mut reg = Registry::new("churn_test");
        reg.publish(rec("churn_test", "flat", 0.2, 100)).unwrap();
        reg.publish(rec("churn_test", "blocked64", 0.1, 200)).unwrap();
        reg.publish(rec("churn_test", "blocked64", 0.11, 300)).unwrap();
        reg
    }

    #[test]
    fn dash_renders_sparklines_per_bench_with_gate_bands() {
        let html = render_dash(&[sample_registry()], None);
        assert!(html.contains("<!DOCTYPE html>"), "self-contained document");
        assert!(html.contains("data-bench=\"churn_test\""), "{html}");
        assert!(html.contains("data-metric=\"updates_per_s\""), "{html}");
        assert!(html.contains("gate-band"), "gated metrics draw a band: {html}");
        assert!(html.contains("<polyline"), "multi-run config draws a line");
        // two config hashes -> two legend entries
        assert_eq!(html.matches("config <b>").count(), 2, "{html}");
        assert!(!html.contains("<script"), "no JS anywhere");
    }

    #[test]
    fn dash_is_deterministic_byte_for_byte() {
        let a = render_dash(&[sample_registry()], None);
        let b = render_dash(&[sample_registry()], None);
        assert_eq!(a, b);
    }

    #[test]
    fn dash_renders_empty_registry_with_greppable_sparkline() {
        let html = render_dash(&[Registry::new("quiet")], None);
        assert!(html.contains("data-bench=\"quiet\""), "{html}");
        assert!(html.contains("No committed runs yet"), "{html}");
        let none = render_dash(&[], None);
        assert!(none.contains("No BENCH_*.json registries found"), "{none}");
    }

    #[test]
    fn live_section_tables_scalars_and_annotates_exemplars() {
        let text = "# HELP skipper_wal_fsync_seconds t\n\
                    # TYPE skipper_wal_fsync_seconds histogram\n\
                    skipper_wal_fsync_seconds_bucket{le=\"0.001\"} 3 # {span_id=\"00000000000000ab\"} 0.0009 1.5\n\
                    skipper_wal_fsync_seconds_bucket{le=\"+Inf\"} 3\n\
                    skipper_wal_fsync_seconds_sum 0.002\n\
                    skipper_wal_fsync_seconds_count 3\n\
                    skipper_epochs_total 41\n\
                    # EOF\n";
        let live = LiveSource { origin: "/tmp/m.prom".to_string(), text: text.to_string() };
        let html = render_dash(&[], Some(&live));
        assert!(html.contains("Live snapshot"), "{html}");
        assert!(html.contains("/tmp/m.prom"), "{html}");
        assert!(html.contains("<code>skipper_epochs_total</code>"), "{html}");
        assert!(html.contains("Latency exemplars"), "{html}");
        assert!(html.contains("00000000000000ab"), "{html}");
        assert!(html.contains("le=0.001"), "{html}");
        assert!(html.contains("value=0.0009"), "{html}");
        // the histogram family shows up summarized, not as raw bucket rows
        assert!(html.contains("<code>skipper_wal_fsync_seconds</code>"), "{html}");
        assert!(!html.contains("_bucket{"), "{html}");
    }

    #[test]
    fn html_escaping_covers_text_and_attributes() {
        assert_eq!(esc("a<b>&\"c\""), "a&lt;b&gt;&amp;&quot;c&quot;");
    }
}
