//! Experiment-run configuration, parsed from a TOML-subset file
//! (`configs/*.toml`). Every field has a sensible default so the CLI works
//! with no config at all.

use crate::coordinator::datasets::Scale;
use crate::util::tomlite::Document;

#[derive(Clone, Debug)]
/// Coordinator run configuration (scale, threads, directories, dataset
/// filter) with defaults that work without any config file.
pub struct RunConfig {
    /// Suite scale (tiny|small|medium|large).
    pub scale: Scale,
    /// APRAM-simulated thread count for the "paper" runs.
    pub threads: usize,
    /// Runs per Table II cell (paper: 5).
    pub table2_runs: usize,
    /// Output directory for reports.
    pub report_dir: String,
    /// Graph cache directory.
    pub cache_dir: String,
    /// Restrict to these dataset names (empty = full suite).
    pub datasets: Vec<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            scale: Scale::Small,
            threads: 64,
            table2_runs: 5,
            report_dir: "reports".into(),
            cache_dir: "data".into(),
            datasets: Vec::new(),
        }
    }
}

impl RunConfig {
    /// Parse from TOML-subset text; unknown keys are ignored.
    pub fn parse(text: &str) -> Result<Self, String> {
        let doc = Document::parse(text)?;
        let mut cfg = RunConfig::default();
        if let Some(v) = doc.root.get("scale").and_then(|v| v.as_str()) {
            cfg.scale = Scale::parse(v)?;
        }
        if let Some(v) = doc.root.get("threads").and_then(|v| v.as_int()) {
            if v < 1 {
                return Err("threads must be >= 1".into());
            }
            cfg.threads = v as usize;
        }
        if let Some(v) = doc.root.get("table2_runs").and_then(|v| v.as_int()) {
            cfg.table2_runs = (v as usize).max(1);
        }
        if let Some(out) = doc.sections.get("output") {
            if let Some(v) = out.get("report_dir").and_then(|v| v.as_str()) {
                cfg.report_dir = v.to_string();
            }
            if let Some(v) = out.get("cache_dir").and_then(|v| v.as_str()) {
                cfg.cache_dir = v.to_string();
            }
        }
        if let Some(arr) = doc.root.get("datasets").and_then(|v| v.as_array()) {
            cfg.datasets = arr
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect();
        }
        Ok(cfg)
    }

    /// Load and parse a config file.
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::parse(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_apply() {
        let cfg = RunConfig::parse("").unwrap();
        assert_eq!(cfg.threads, 64);
        assert_eq!(cfg.scale, Scale::Small);
        assert_eq!(cfg.table2_runs, 5);
    }

    #[test]
    fn full_config_parses() {
        let cfg = RunConfig::parse(
            r#"
            scale = "medium"
            threads = 16
            table2_runs = 3
            datasets = ["g500s", "twitter10s"]

            [output]
            report_dir = "out/reports"
            cache_dir = "out/data"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.scale, Scale::Medium);
        assert_eq!(cfg.threads, 16);
        assert_eq!(cfg.table2_runs, 3);
        assert_eq!(cfg.report_dir, "out/reports");
        assert_eq!(cfg.datasets, vec!["g500s", "twitter10s"]);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::parse("scale = \"huge\"").is_err());
        assert!(RunConfig::parse("threads = 0").is_err());
    }
}
