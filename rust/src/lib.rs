//! # Skipper — Asynchronous Maximal Matching with a Single Pass over Edges
//!
//! A production-grade reproduction of the CS.DC 2025 paper by Mohsen Koohi
//! Esfahani. The crate contains:
//!
//! * [`matching::core`] — `SkipperCore`, the paper's per-edge state machine
//!   (Algorithm 1), shared by every driver below.
//! * [`matching::skipper`] — the paper's configuration: a CAS-based,
//!   single-pass, asynchronous maximal matching over a materialized CSR.
//! * [`matching::streaming`] — the streaming ingest→match pipeline: edges
//!   pulled chunk-by-chunk from any [`graph::stream::EdgeSource`] (disk,
//!   generator, batch) through a bounded queue; no CSR is ever built.
//! * [`dynamic`] — the fully dynamic engine: a mutable adjacency sidecar
//!   plus an epoch-based insert/delete update engine whose repair sweep
//!   re-runs the reservation state machine over only the neighborhoods a
//!   deletion disturbed.
//! * [`service`] — the long-running match server: a line-delimited
//!   `INSERT`/`DELETE`/`QUERY`/`STATS`/`EPOCH` protocol over stdin or TCP,
//!   with a sharded front-end queue coalescing client batches into engine
//!   epochs.
//! * [`persist`] — durability for the service: a CRC-checked epoch
//!   write-ahead log with segment rotation, atomic binary snapshots written
//!   by a background thread, and the crash-recovery boot path (newest valid
//!   snapshot + WAL replay through the real engine epochs).
//! * [`matching`] — every baseline the paper discusses: sequential greedy
//!   (SGMM), IDMM, SIDMM (the GBBS comparator), PBMM, Israeli–Itai, Birn
//!   et al., and Auer–Bisseling.
//! * [`graph`] — the CSR/COO graph substrate, loaders, streaming edge
//!   sources, and the scaled synthetic analogues of the paper's dataset
//!   suite.
//! * [`par`] — the thread-dispersed locality-preserving block scheduler
//!   with work stealing (paper §IV-C) on top of a scoped thread pool.
//! * [`instrument`] — software memory-access counters and JIT-conflict
//!   telemetry (paper Table II, Figs 3/7).
//! * [`cachesim`] — a set-associative multi-level cache simulator used to
//!   reproduce the L3-miss comparison (Fig 8) without PAPI.
//! * [`apram`] — an APRAM virtual-thread interleaving simulator that runs
//!   the algorithms' shared-memory state machines under t simulated threads
//!   (the sandbox has a single physical core; see DESIGN.md §3).
//! * [`runtime`] — the PJRT runtime that loads the AOT-compiled JAX/Pallas
//!   EMS matcher (`artifacts/*.hlo.txt`) and exposes it as a baseline.
//! * [`obs`] — crate-wide observability: a lock-free metrics registry
//!   (counters, gauges, log-scale histograms) exported as Prometheus text,
//!   and a per-thread span tracer exported as Chrome trace-event JSON.
//! * [`coordinator`] — config system, dataset registry, experiment registry
//!   (one entry per paper table/figure), and report writers.
//! * [`util`] — RNG, bitset, stats, CLI parsing, a mini property-testing
//!   framework and a bench harness (criterion is unavailable offline).
//!
//! ## Quickstart
//!
//! ```no_run
//! use skipper::graph::gen::{rmat, GenConfig};
//! use skipper::matching::{skipper::Skipper, MaximalMatcher, verify};
//!
//! let g = rmat::generate(&GenConfig { scale: 10, avg_degree: 8, seed: 42 });
//! let m = Skipper::new(4).run(&g);
//! verify::check(&g, &m).expect("valid maximal matching");
//! ```
//!
//! A top-to-bottom architecture tour — every layer from [`graph::stream`]'s
//! `EdgeSource` to the [`service`] wire protocol, with the per-layer
//! invariants collected in one place — lives in `docs/ARCHITECTURE.md`;
//! the service wire format is specified in `docs/PROTOCOL.md`.

#![warn(missing_docs)]

pub mod apram;
pub mod cachesim;
pub mod coordinator;
pub mod dynamic;
pub mod graph;
pub mod instrument;
pub mod matching;
pub mod obs;
pub mod par;
pub mod persist;
pub mod runtime;
pub mod service;
pub mod util;

/// Vertex identifier. The paper's suite reaches 3.6G vertices; our scaled
/// analogues stay well under `u32::MAX`.
pub type VertexId = u32;

/// Index into the CSR `neighbors` array (edge slot). 64-bit: |E| exceeds
/// `u32::MAX` for the larger generated graphs.
pub type EdgeIdx = u64;

/// Sentinel written into unfilled tail slots of per-thread match buffers
/// (paper §IV-C: "filled with -1 to indicate invalid values").
pub const INVALID_VERTEX: VertexId = VertexId::MAX;
