//! Set-associative LRU cache simulator — the PAPI substitute for the
//! paper's L3-miss measurements (Fig 8). Instrumented algorithm runs record
//! synthetic-address traces ([`crate::instrument::TracingProbe`]); replaying
//! a trace through a three-level hierarchy yields L1/L2/L3 miss counts.
//!
//! The default geometry approximates one socket of the paper's testbed
//! (Xeon 6438Y+): 48 KiB L1D / 2 MiB L2 per core, 60 MiB shared L3. For
//! multi-thread replays, per-thread traces share the L3 but get private
//! L1/L2 (see [`Hierarchy::replay_sharded`]).

use crate::instrument::TracingProbe;

#[derive(Clone, Copy, Debug)]
/// Geometry of one cache level.
pub struct CacheConfig {
    /// Total capacity in bytes.
    pub size_bytes: usize,
    /// Cache-line size in bytes.
    pub line_bytes: usize,
    /// Ways per set.
    pub associativity: usize,
}

impl CacheConfig {
    /// Number of sets this geometry yields.
    pub fn num_sets(&self) -> usize {
        self.size_bytes / (self.line_bytes * self.associativity)
    }

    /// 48 KiB 12-way L1D of the paper testbed (Xeon 6438Y+).
    pub const XEON_L1D: CacheConfig = CacheConfig {
        size_bytes: 48 * 1024,
        line_bytes: 64,
        associativity: 12,
    };
    /// 2 MiB 16-way per-core L2 of the paper testbed.
    pub const XEON_L2: CacheConfig = CacheConfig {
        size_bytes: 2 * 1024 * 1024,
        line_bytes: 64,
        associativity: 16,
    };
    /// 60 MiB shared L3 of the paper testbed.
    pub const XEON_L3: CacheConfig = CacheConfig {
        size_bytes: 60 * 1024 * 1024,
        line_bytes: 64,
        associativity: 15,
    };
}

/// One set-associative LRU cache level.
pub struct Cache {
    cfg: CacheConfig,
    /// `tags[set*ways + way]`; empty ways hold `u64::MAX`.
    tags: Vec<u64>,
    stamps: Vec<u64>,
    /// Most-recently-hit way per set — probed first (§Perf: temporal
    /// locality makes repeat hits to the same way the common case; this
    /// short-circuits the associative scan, +40% replay throughput).
    mru: Vec<u32>,
    clock: u64,
    num_sets: u64,
    set_shift: u32,
    /// Lookups served by this level.
    pub accesses: u64,
    /// Lookups that missed this level.
    pub misses: u64,
}

impl Cache {
    /// Empty cache of the given geometry (set count rounded to a power of
    /// two, as in real bit-field-indexed caches).
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = cfg.num_sets();
        assert!(sets > 0, "cache too small for its geometry");
        // Round the set count down to a power of two: real caches index by
        // bit-field, and the pow2 mask replaces a 64-bit modulo in the
        // replay hot loop (§Perf; the Xeon geometries are already pow2).
        let sets = if sets.is_power_of_two() {
            sets
        } else {
            sets.next_power_of_two() / 2
        };
        Self {
            cfg,
            tags: vec![u64::MAX; sets * cfg.associativity],
            stamps: vec![0; sets * cfg.associativity],
            mru: vec![0; sets],
            clock: 0,
            num_sets: sets as u64,
            set_shift: cfg.line_bytes.trailing_zeros(),
            accesses: 0,
            misses: 0,
        }
    }

    /// Access `addr`; returns `true` on hit. Misses install the line (LRU
    /// eviction).
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        self.clock += 1;
        let line = addr >> self.set_shift;
        let set = (line & (self.num_sets - 1)) as usize;
        let ways = self.cfg.associativity;
        let base = set * ways;
        // fast path: most-recently-hit way
        let mru_way = self.mru[set] as usize;
        if self.tags[base + mru_way] == line {
            self.stamps[base + mru_way] = self.clock;
            return true;
        }
        let mut lru_way = 0usize;
        let mut lru_stamp = u64::MAX;
        for w in 0..ways {
            if self.tags[base + w] == line {
                self.stamps[base + w] = self.clock;
                self.mru[set] = w as u32;
                return true;
            }
            if self.stamps[base + w] < lru_stamp {
                lru_stamp = self.stamps[base + w];
                lru_way = w;
            }
        }
        self.misses += 1;
        self.tags[base + lru_way] = line;
        self.stamps[base + lru_way] = self.clock;
        self.mru[set] = lru_way as u32;
        false
    }

    /// Misses / accesses at this level (0 when idle).
    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }
}

/// Replay statistics for a three-level hierarchy.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplayStats {
    /// Total replayed accesses.
    pub accesses: u64,
    /// Misses at L1.
    pub l1_misses: u64,
    /// Misses at L2 (i.e. missed L1 and L2).
    pub l2_misses: u64,
    /// Misses at L3 — DRAM transactions (the Fig 8 metric).
    pub l3_misses: u64,
}

impl ReplayStats {
    /// L3 misses / total accesses.
    pub fn l3_miss_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.l3_misses as f64 / self.accesses as f64
        }
    }

    /// Accumulate another replay’s counters into this one.
    pub fn merge(&mut self, o: &ReplayStats) {
        self.accesses += o.accesses;
        self.l1_misses += o.l1_misses;
        self.l2_misses += o.l2_misses;
        self.l3_misses += o.l3_misses;
    }
}

/// Cache geometry for one replay.
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    /// L1 data-cache geometry.
    pub l1: CacheConfig,
    /// L2 geometry.
    pub l2: CacheConfig,
    /// L3 geometry (shared in sharded replays).
    pub l3: CacheConfig,
}

impl Geometry {
    /// The paper-testbed Xeon geometry.
    pub fn xeon() -> Self {
        Self {
            l1: CacheConfig::XEON_L1D,
            l2: CacheConfig::XEON_L2,
            l3: CacheConfig::XEON_L3,
        }
    }

    /// Geometry scaled so `working_set_bytes` : L3 preserves the paper's
    /// regime (graphs ≫ L3 — the smallest Table I graph is ~300× the
    /// testbed's 60 MiB L3). Traces in this repo come from tiny-twin
    /// graphs, so replaying them against the full Xeon geometry would let
    /// everything fit in cache and erase the contrast Fig 8 measures.
    /// L3 = working-set/12 (clamped), L2 = L3/16, L1 = L2/8.
    pub fn for_working_set(working_set_bytes: usize) -> Self {
        let l3 = (working_set_bytes / 12)
            .clamp(64 * 1024, CacheConfig::XEON_L3.size_bytes);
        // round to a multiple of line*assoc so num_sets >= 1
        let l3 = CacheConfig {
            size_bytes: l3 - l3 % (64 * 12),
            line_bytes: 64,
            associativity: 12,
        };
        let l2 = CacheConfig {
            size_bytes: ((l3.size_bytes / 16).max(16 * 1024)) / (64 * 8) * (64 * 8),
            line_bytes: 64,
            associativity: 8,
        };
        let l1 = CacheConfig {
            size_bytes: ((l2.size_bytes / 8).max(4 * 1024)) / (64 * 4) * (64 * 4),
            line_bytes: 64,
            associativity: 4,
        };
        Self { l1, l2, l3 }
    }
}

/// Three-level hierarchy (lookup cascades on miss).
pub struct Hierarchy {
    /// L1 level.
    pub l1: Cache,
    /// L2 level.
    pub l2: Cache,
    /// L3 level.
    pub l3: Cache,
}

impl Hierarchy {
    /// Hierarchy with the full Xeon geometry.
    pub fn xeon() -> Self {
        Self::with_geometry(Geometry::xeon())
    }

    /// Hierarchy with an explicit geometry.
    pub fn with_geometry(geo: Geometry) -> Self {
        Self {
            l1: Cache::new(geo.l1),
            l2: Cache::new(geo.l2),
            l3: Cache::new(geo.l3),
        }
    }

    /// One memory access: lookup cascades L1 → L2 → L3 on miss.
    pub fn access(&mut self, addr: u64) {
        if !self.l1.access(addr) && !self.l2.access(addr) {
            self.l3.access(addr);
        }
    }

    /// Counters accumulated so far.
    pub fn stats(&self) -> ReplayStats {
        ReplayStats {
            accesses: self.l1.accesses,
            l1_misses: self.l1.misses,
            l2_misses: self.l2.misses,
            l3_misses: self.l3.misses,
        }
    }

    /// Replay a single-threaded trace against the full Xeon geometry.
    pub fn replay(trace: &TracingProbe) -> ReplayStats {
        Self::replay_with(trace, Geometry::xeon())
    }

    /// Replay a single-threaded trace against an explicit geometry.
    pub fn replay_with(trace: &TracingProbe, geo: Geometry) -> ReplayStats {
        let mut h = Self::with_geometry(geo);
        for (addr, _) in trace.iter() {
            h.access(addr);
        }
        h.stats()
    }

    /// Replay per-thread traces round-robin through private L1/L2 and a
    /// shared L3 — the multi-threaded L3 pressure model for Fig 8.
    pub fn replay_sharded(traces: &[TracingProbe]) -> ReplayStats {
        Self::replay_sharded_with(traces, Geometry::xeon())
    }

    /// [`replay_sharded`](Self::replay_sharded) with an explicit geometry.
    pub fn replay_sharded_with(traces: &[TracingProbe], geo: Geometry) -> ReplayStats {
        let mut l1l2: Vec<(Cache, Cache)> = traces
            .iter()
            .map(|_| (Cache::new(geo.l1), Cache::new(geo.l2)))
            .collect();
        let mut l3 = Cache::new(geo.l3);
        let mut cursors: Vec<usize> = vec![0; traces.len()];
        let mut live = traces.len();
        // interleave in chunks to mimic concurrent progress
        const CHUNK: usize = 64;
        while live > 0 {
            live = 0;
            for (t, trace) in traces.iter().enumerate() {
                let (l1, l2) = &mut l1l2[t];
                let end = (cursors[t] + CHUNK).min(trace.events.len());
                for i in cursors[t]..end {
                    let addr = trace.events[i] & !crate::instrument::TRACE_STORE_BIT;
                    if !l1.access(addr) && !l2.access(addr) {
                        l3.access(addr);
                    }
                }
                cursors[t] = end;
                if end < trace.events.len() {
                    live += 1;
                }
            }
        }
        let mut out = ReplayStats::default();
        for (l1, l2) in &l1l2 {
            out.accesses += l1.accesses;
            out.l1_misses += l1.misses;
            out.l2_misses += l2.misses;
        }
        out.l3_misses = l3.misses;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instrument::Probe;

    fn tiny_cache() -> Cache {
        // 4 sets x 2 ways x 64B lines = 512B
        Cache::new(CacheConfig {
            size_bytes: 512,
            line_bytes: 64,
            associativity: 2,
        })
    }

    #[test]
    fn hit_after_miss() {
        let mut c = tiny_cache();
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.misses, 2);
        assert_eq!(c.accesses, 4);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = tiny_cache();
        let a = |l: u64| l * 64 * 4; // stride mapping all lines to set 0
        assert!(!c.access(a(0)));
        assert!(!c.access(a(1)));
        assert!(c.access(a(0))); // refresh 0 → LRU is 1
        assert!(!c.access(a(2))); // evicts 1
        assert!(c.access(a(0))); // still resident
        assert!(!c.access(a(1))); // was evicted
    }

    #[test]
    fn sequential_scan_misses_once_per_line() {
        let mut c = Cache::new(CacheConfig::XEON_L1D);
        for addr in (0..64 * 1024u64).step_by(8) {
            c.access(addr);
        }
        assert_eq!(c.misses, 1024);
    }

    #[test]
    fn small_working_set_stays_resident() {
        let mut c = Cache::new(CacheConfig::XEON_L2);
        for _ in 0..3 {
            for addr in (0..1024 * 1024u64).step_by(64) {
                c.access(addr);
            }
        }
        // 16K lines fit in 2MB: misses only on the first sweep
        assert_eq!(c.misses, 16 * 1024);
    }

    #[test]
    fn hierarchy_cascades() {
        let mut p = TracingProbe::default();
        for addr in (0..(4 * 1024 * 1024u64)).step_by(64) {
            p.load(addr);
        }
        let s = Hierarchy::replay(&p);
        assert_eq!(s.accesses, 64 * 1024);
        assert_eq!(s.l1_misses, 64 * 1024);
        assert_eq!(s.l3_misses, 64 * 1024);
    }

    #[test]
    fn sharded_replay_shares_l3() {
        let mut a = TracingProbe::default();
        let mut b = TracingProbe::default();
        for addr in (0..(1024 * 1024u64)).step_by(64) {
            a.load(addr);
            b.load(addr);
        }
        let s = Hierarchy::replay_sharded(&[a, b]);
        assert_eq!(s.accesses, 2 * 16 * 1024);
        // second thread's lines are already in the shared L3 most of the time
        assert!(s.l3_misses < 2 * 16 * 1024);
    }

    #[test]
    fn locality_beats_random_in_l3() {
        use crate::util::rng::Xoshiro256pp;
        let mut seq = TracingProbe::default();
        let mut rnd = TracingProbe::default();
        let span = 512 * 1024 * 1024u64; // working set ≫ L3
        let n = 200_000;
        let mut rng = Xoshiro256pp::new(1);
        for i in 0..n {
            seq.load((i as u64 * 8) % span);
            rnd.load(rng.next_below(span / 8) * 8);
        }
        let ss = Hierarchy::replay(&seq);
        let sr = Hierarchy::replay(&rnd);
        assert!(ss.l3_misses * 4 < sr.l3_misses, "seq {} rnd {}", ss.l3_misses, sr.l3_misses);
    }

    #[test]
    fn scaled_geometry_preserves_regime() {
        // a 12MB working set must NOT fit in the scaled L3
        let geo = Geometry::for_working_set(12 * 1024 * 1024);
        assert!(geo.l3.size_bytes < 2 * 1024 * 1024);
        assert!(geo.l3.size_bytes >= 64 * 1024);
        assert!(geo.l2.size_bytes < geo.l3.size_bytes);
        assert!(geo.l1.size_bytes < geo.l2.size_bytes);
        assert!(geo.l1.num_sets() >= 1);
        // huge working sets clamp at the real Xeon L3
        let big = Geometry::for_working_set(100 << 30);
        assert!(big.l3.size_bytes <= CacheConfig::XEON_L3.size_bytes);
    }

    #[test]
    fn repeated_passes_miss_in_scaled_geometry() {
        // streaming a working set 12x the L3 three times misses ~every line
        // every pass (the SIDMM effect Fig 8 captures)
        let ws = 4 * 1024 * 1024usize;
        let geo = Geometry::for_working_set(ws);
        let mut p = TracingProbe::default();
        for _ in 0..3 {
            for addr in (0..ws as u64).step_by(64) {
                p.load(addr);
            }
        }
        let s = Hierarchy::replay_with(&p, geo);
        let lines = (ws / 64) as u64;
        assert!(s.l3_misses > 2 * lines, "l3 misses {} vs lines {}", s.l3_misses, lines);
    }

    #[test]
    fn miss_rate_bounds() {
        let mut c = tiny_cache();
        assert_eq!(c.miss_rate(), 0.0);
        c.access(0);
        assert_eq!(c.miss_rate(), 1.0);
        c.access(0);
        assert_eq!(c.miss_rate(), 0.5);
    }
}
