//! Bounded chunk hand-off between an ingest producer and matcher consumers.
//!
//! [`BoundedQueue`] is a small Mutex+Condvar MPMC queue with close
//! semantics: `push` blocks while the queue is at capacity (back-pressure
//! on the reader so ingest can never race ahead of matching by more than
//! `capacity` chunks of memory), `pop` blocks while it is empty, and
//! `close` wakes everyone — pending `pop`s drain the remaining items and
//! then observe end-of-stream. The capacity bound is what makes streaming
//! memory O(chunk · capacity) instead of O(|E|).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer/multi-consumer queue with close semantics.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// `capacity` is clamped to at least 1.
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// The fixed capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Blocking push; returns the item back if the queue was closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        while inner.items.len() >= self.capacity && !inner.closed {
            inner = self.not_full.wait(inner).unwrap();
        }
        if inner.closed {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Non-blocking push: `Err` back immediately when the queue is full or
    /// closed. The service layer's doorbell rides on this — ringing an
    /// already-rung doorbell must not block the ringer.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.items.len() >= self.capacity {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop: `None` when currently empty (closed or not).
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        let item = inner.items.pop_front();
        if item.is_some() {
            drop(inner);
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: pending and future `push`es fail, `pop`s drain the
    /// backlog then return `None`. Idempotent.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Closes the queue when dropped — attached to every consumer so a panicking
/// consumer unblocks the producer instead of deadlocking the pipeline.
pub struct CloseOnDrop<'a, T>(pub &'a BoundedQueue<T>);

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::run_threads;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn fifo_single_thread() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.try_pop(), None);
        q.close();
        assert_eq!(q.pop(), None);
        assert!(q.push(3).is_err());
    }

    #[test]
    fn producer_consumer_transfers_everything() {
        let q: BoundedQueue<usize> = BoundedQueue::new(3);
        let sum = AtomicUsize::new(0);
        let count = AtomicUsize::new(0);
        let total = 10_000usize;
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    while let Some(x) = q.pop() {
                        sum.fetch_add(x, Ordering::Relaxed);
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
            for i in 0..total {
                q.push(i).unwrap();
            }
            q.close();
        });
        assert_eq!(count.load(Ordering::Relaxed), total);
        assert_eq!(sum.load(Ordering::Relaxed), total * (total - 1) / 2);
    }

    #[test]
    fn push_blocks_at_capacity_until_pop() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        let pushed = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                q.push(3).unwrap(); // must block until the pop below
                pushed.store(1, Ordering::SeqCst);
            });
            std::thread::sleep(std::time::Duration::from_millis(30));
            assert_eq!(pushed.load(Ordering::SeqCst), 0, "push went through while full");
            assert_eq!(q.pop(), Some(1));
        });
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn try_push_rejects_full_and_closed_without_blocking() {
        let q = BoundedQueue::new(1);
        assert!(q.try_push(1).is_ok());
        assert_eq!(q.try_push(2), Err(2), "full queue must bounce, not block");
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        q.close();
        assert_eq!(q.try_push(4), Err(4));
        // backlog still drains after close
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_drains_backlog_before_none() {
        let q = BoundedQueue::new(8);
        q.push("a").unwrap();
        q.push("b").unwrap();
        q.close();
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_poppers() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        run_threads(4, |tid| {
            if tid == 0 {
                std::thread::sleep(std::time::Duration::from_millis(20));
                q.close();
            } else {
                assert_eq!(q.pop(), None);
            }
        });
    }

    #[test]
    fn close_on_drop_guard_closes() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        {
            let _guard = CloseOnDrop(&q);
        }
        assert!(q.push(1).is_err());
    }
}
